//! Serializable dataset graphs.
//!
//! A pipeline is a linear chain of [`Node`]s rooted at a source — the same
//! shape tf.data graphs take after functionalization. Clients serialize a
//! [`GraphDef`] and register it with the dispatcher; the dispatcher ships
//! it to every worker (§3.1). UDFs are referenced *by name* and resolved
//! against the worker's [`super::udf::UdfRegistry`].

use crate::storage::dataset::DatasetSpec;
use crate::wire::{Decode, Encode, Reader, WireError, WireResult, Writer};

/// One pipeline stage.
#[derive(Debug, Clone, PartialEq)]
pub enum Node {
    /// Sharded vision dataset source (yields `(pixels u8[H,W,C], label u32)`).
    SourceVision { spec: DatasetSpec },
    /// Sharded text dataset source (yields `(tokens u32[len], label u32)`).
    SourceText { spec: DatasetSpec },
    /// Synthetic integer range source for tests (yields `(i64 scalar,)`).
    SourceRange { n: u64 },
    /// Apply a named UDF to each element. `parallelism` 0 means AUTOTUNE.
    Map { udf: String, parallelism: u32 },
    /// Keep elements for which the named predicate UDF returns nonzero.
    Filter { udf: String },
    /// Uniform shuffle over a sliding buffer.
    Shuffle { buffer: u32, seed: u64 },
    /// Fixed-size batch by stacking same-shaped tensors.
    Batch { size: u32, drop_remainder: bool },
    /// Batch of variable-length rank-1 tensors, padded to the longest
    /// sample in the batch (the paper's NLP batching mode).
    PaddedBatch { size: u32, drop_remainder: bool },
    /// Background prefetch buffer.
    Prefetch { n: u32 },
    /// Repeat the upstream `n` times; 0 = indefinitely.
    Repeat { n: u32 },
    /// At most `n` elements.
    Take { n: u64 },
    /// Drop the first `n` elements.
    Skip { n: u64 },
    /// Materialize upstream on first pass, replay thereafter.
    Cache,
    /// Read `cycle` source shards round-robin (file-level interleave).
    Interleave { cycle: u32 },
    /// Group samples into per-length-bucket batches (Fig. 7 line 1).
    /// Bucket `i` holds lengths in `(boundaries[i-1], boundaries[i]]`;
    /// a final bucket catches everything above the last boundary.
    BucketBySequenceLength { boundaries: Vec<u32>, batch_size: u32 },
    /// Emit `window_size` consecutive elements sharing a bucket key
    /// (Fig. 7 line 2; the subsequent `flat_map` is folded in).
    GroupByWindow { window_size: u32 },
    /// Identity marker kept for API fidelity with Fig. 7 line 3.
    FlatMap,
}

impl Node {
    pub fn is_source(&self) -> bool {
        matches!(
            self,
            Node::SourceVision { .. } | Node::SourceText { .. } | Node::SourceRange { .. }
        )
    }

    /// Short operator name for logs and metrics.
    pub fn op_name(&self) -> &'static str {
        match self {
            Node::SourceVision { .. } => "source_vision",
            Node::SourceText { .. } => "source_text",
            Node::SourceRange { .. } => "source_range",
            Node::Map { .. } => "map",
            Node::Filter { .. } => "filter",
            Node::Shuffle { .. } => "shuffle",
            Node::Batch { .. } => "batch",
            Node::PaddedBatch { .. } => "padded_batch",
            Node::Prefetch { .. } => "prefetch",
            Node::Repeat { .. } => "repeat",
            Node::Take { .. } => "take",
            Node::Skip { .. } => "skip",
            Node::Cache => "cache",
            Node::Interleave { .. } => "interleave",
            Node::BucketBySequenceLength { .. } => "bucket_by_sequence_length",
            Node::GroupByWindow { .. } => "group_by_window",
            Node::FlatMap => "flat_map",
        }
    }
}

impl Encode for Node {
    fn encode(&self, w: &mut Writer) {
        match self {
            Node::SourceVision { spec } => {
                w.put_u8(0);
                spec.encode(w);
            }
            Node::SourceText { spec } => {
                w.put_u8(1);
                spec.encode(w);
            }
            Node::SourceRange { n } => {
                w.put_u8(2);
                w.put_u64(*n);
            }
            Node::Map { udf, parallelism } => {
                w.put_u8(3);
                udf.encode(w);
                w.put_u32(*parallelism);
            }
            Node::Filter { udf } => {
                w.put_u8(4);
                udf.encode(w);
            }
            Node::Shuffle { buffer, seed } => {
                w.put_u8(5);
                w.put_u32(*buffer);
                w.put_u64(*seed);
            }
            Node::Batch { size, drop_remainder } => {
                w.put_u8(6);
                w.put_u32(*size);
                drop_remainder.encode(w);
            }
            Node::PaddedBatch { size, drop_remainder } => {
                w.put_u8(7);
                w.put_u32(*size);
                drop_remainder.encode(w);
            }
            Node::Prefetch { n } => {
                w.put_u8(8);
                w.put_u32(*n);
            }
            Node::Repeat { n } => {
                w.put_u8(9);
                w.put_u32(*n);
            }
            Node::Take { n } => {
                w.put_u8(10);
                w.put_u64(*n);
            }
            Node::Skip { n } => {
                w.put_u8(11);
                w.put_u64(*n);
            }
            Node::Cache => w.put_u8(12),
            Node::Interleave { cycle } => {
                w.put_u8(13);
                w.put_u32(*cycle);
            }
            Node::BucketBySequenceLength { boundaries, batch_size } => {
                w.put_u8(14);
                boundaries.encode(w);
                w.put_u32(*batch_size);
            }
            Node::GroupByWindow { window_size } => {
                w.put_u8(15);
                w.put_u32(*window_size);
            }
            Node::FlatMap => w.put_u8(16),
        }
    }
}

impl Decode for Node {
    fn decode(r: &mut Reader) -> WireResult<Self> {
        Ok(match r.get_u8()? {
            0 => Node::SourceVision { spec: DatasetSpec::decode(r)? },
            1 => Node::SourceText { spec: DatasetSpec::decode(r)? },
            2 => Node::SourceRange { n: r.get_u64()? },
            3 => Node::Map { udf: String::decode(r)?, parallelism: r.get_u32()? },
            4 => Node::Filter { udf: String::decode(r)? },
            5 => Node::Shuffle { buffer: r.get_u32()?, seed: r.get_u64()? },
            6 => Node::Batch { size: r.get_u32()?, drop_remainder: bool::decode(r)? },
            7 => Node::PaddedBatch { size: r.get_u32()?, drop_remainder: bool::decode(r)? },
            8 => Node::Prefetch { n: r.get_u32()? },
            9 => Node::Repeat { n: r.get_u32()? },
            10 => Node::Take { n: r.get_u64()? },
            11 => Node::Skip { n: r.get_u64()? },
            12 => Node::Cache,
            13 => Node::Interleave { cycle: r.get_u32()? },
            14 => Node::BucketBySequenceLength {
                boundaries: Vec::<u32>::decode(r)?,
                batch_size: r.get_u32()?,
            },
            15 => Node::GroupByWindow { window_size: r.get_u32()? },
            16 => Node::FlatMap,
            tag => return Err(WireError::BadTag { tag, ty: "Node" }),
        })
    }
}

/// A complete pipeline definition: a source followed by transformations.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct GraphDef {
    pub nodes: Vec<Node>,
}

impl Encode for GraphDef {
    fn encode(&self, w: &mut Writer) {
        crate::wire::encode_vec(&self.nodes, w);
    }
}

impl Decode for GraphDef {
    fn decode(r: &mut Reader) -> WireResult<Self> {
        Ok(GraphDef { nodes: crate::wire::decode_vec(r)? })
    }
}

impl GraphDef {
    /// Validate structural invariants: exactly one source, at the front.
    pub fn validate(&self) -> Result<(), String> {
        match self.nodes.first() {
            Some(n) if n.is_source() => {}
            Some(n) => return Err(format!("first node must be a source, got {}", n.op_name())),
            None => return Err("empty graph".into()),
        }
        if self.nodes.iter().skip(1).any(|n| n.is_source()) {
            return Err("multiple sources".into());
        }
        for n in &self.nodes {
            match n {
                Node::Batch { size, .. } | Node::PaddedBatch { size, .. } if *size == 0 => {
                    return Err("batch size 0".into())
                }
                Node::BucketBySequenceLength { boundaries, batch_size } => {
                    if *batch_size == 0 {
                        return Err("bucket batch size 0".into());
                    }
                    if boundaries.windows(2).any(|w| w[0] >= w[1]) {
                        return Err("bucket boundaries must be strictly increasing".into());
                    }
                }
                Node::GroupByWindow { window_size } if *window_size == 0 => {
                    return Err("window size 0".into())
                }
                _ => {}
            }
        }
        Ok(())
    }

    /// Content fingerprint: jobs sharing a fingerprint can share ephemeral
    /// data (§3.5 requires "identical input pipelines").
    pub fn fingerprint(&self) -> u64 {
        let bytes = self.to_bytes();
        let digest = crate::util::sha256::sha256(&bytes);
        u64::from_le_bytes(digest[..8].try_into().unwrap())
    }
}

/// Fluent builder mirroring the Python tf.data API (Fig. 4 / Fig. 7).
#[derive(Debug, Clone, Default)]
pub struct PipelineBuilder {
    nodes: Vec<Node>,
}

impl PipelineBuilder {
    pub fn source_vision(spec: DatasetSpec) -> Self {
        PipelineBuilder { nodes: vec![Node::SourceVision { spec }] }
    }

    pub fn source_text(spec: DatasetSpec) -> Self {
        PipelineBuilder { nodes: vec![Node::SourceText { spec }] }
    }

    pub fn source_range(n: u64) -> Self {
        PipelineBuilder { nodes: vec![Node::SourceRange { n }] }
    }

    pub fn map(mut self, udf: &str) -> Self {
        self.nodes.push(Node::Map { udf: udf.into(), parallelism: 1 });
        self
    }

    pub fn map_parallel(mut self, udf: &str, parallelism: u32) -> Self {
        self.nodes.push(Node::Map { udf: udf.into(), parallelism });
        self
    }

    /// AUTOTUNE parallelism.
    pub fn map_autotune(mut self, udf: &str) -> Self {
        self.nodes.push(Node::Map { udf: udf.into(), parallelism: 0 });
        self
    }

    pub fn filter(mut self, udf: &str) -> Self {
        self.nodes.push(Node::Filter { udf: udf.into() });
        self
    }

    pub fn shuffle(mut self, buffer: u32, seed: u64) -> Self {
        self.nodes.push(Node::Shuffle { buffer, seed });
        self
    }

    pub fn batch(mut self, size: u32) -> Self {
        self.nodes.push(Node::Batch { size, drop_remainder: true });
        self
    }

    pub fn batch_partial(mut self, size: u32) -> Self {
        self.nodes.push(Node::Batch { size, drop_remainder: false });
        self
    }

    pub fn padded_batch(mut self, size: u32) -> Self {
        self.nodes.push(Node::PaddedBatch { size, drop_remainder: true });
        self
    }

    pub fn prefetch(mut self, n: u32) -> Self {
        self.nodes.push(Node::Prefetch { n });
        self
    }

    pub fn repeat(mut self, n: u32) -> Self {
        self.nodes.push(Node::Repeat { n });
        self
    }

    pub fn take(mut self, n: u64) -> Self {
        self.nodes.push(Node::Take { n });
        self
    }

    pub fn skip(mut self, n: u64) -> Self {
        self.nodes.push(Node::Skip { n });
        self
    }

    pub fn cache(mut self) -> Self {
        self.nodes.push(Node::Cache);
        self
    }

    pub fn interleave(mut self, cycle: u32) -> Self {
        self.nodes.push(Node::Interleave { cycle });
        self
    }

    pub fn bucket_by_sequence_length(mut self, boundaries: Vec<u32>, batch_size: u32) -> Self {
        self.nodes.push(Node::BucketBySequenceLength { boundaries, batch_size });
        self
    }

    pub fn group_by_window(mut self, window_size: u32) -> Self {
        self.nodes.push(Node::GroupByWindow { window_size });
        self
    }

    pub fn flat_map(mut self) -> Self {
        self.nodes.push(Node::FlatMap);
        self
    }

    pub fn build(self) -> GraphDef {
        GraphDef { nodes: self.nodes }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_spec() -> DatasetSpec {
        DatasetSpec {
            prefix: "d".into(),
            shards: vec!["d/shard-00000".into()],
            samples_per_shard: 4,
            total_samples: 4,
        }
    }

    #[test]
    fn builder_produces_valid_graph() {
        let g = PipelineBuilder::source_vision(demo_spec())
            .map_parallel("vision.normalize", 4)
            .shuffle(128, 7)
            .batch(32)
            .prefetch(2)
            .build();
        g.validate().unwrap();
        assert_eq!(g.nodes.len(), 5);
    }

    #[test]
    fn graph_wire_roundtrip_all_nodes() {
        let g = GraphDef {
            nodes: vec![
                Node::SourceText { spec: demo_spec() },
                Node::Map { udf: "a".into(), parallelism: 0 },
                Node::Filter { udf: "p".into() },
                Node::Shuffle { buffer: 16, seed: 3 },
                Node::Batch { size: 4, drop_remainder: true },
                Node::PaddedBatch { size: 8, drop_remainder: false },
                Node::Prefetch { n: 2 },
                Node::Repeat { n: 0 },
                Node::Take { n: 100 },
                Node::Skip { n: 5 },
                Node::Cache,
                Node::Interleave { cycle: 4 },
                Node::BucketBySequenceLength { boundaries: vec![64, 128], batch_size: 16 },
                Node::GroupByWindow { window_size: 2 },
                Node::FlatMap,
            ],
        };
        let back = GraphDef::from_bytes(&g.to_bytes()).unwrap();
        assert_eq!(g, back);
    }

    #[test]
    fn validate_rejects_bad_graphs() {
        assert!(GraphDef::default().validate().is_err());
        let no_source = GraphDef { nodes: vec![Node::Cache] };
        assert!(no_source.validate().is_err());
        let two_sources = GraphDef {
            nodes: vec![Node::SourceRange { n: 1 }, Node::SourceRange { n: 2 }],
        };
        assert!(two_sources.validate().is_err());
        let zero_batch = GraphDef {
            nodes: vec![Node::SourceRange { n: 1 }, Node::Batch { size: 0, drop_remainder: true }],
        };
        assert!(zero_batch.validate().is_err());
        let bad_bounds = GraphDef {
            nodes: vec![
                Node::SourceRange { n: 1 },
                Node::BucketBySequenceLength { boundaries: vec![128, 64], batch_size: 4 },
            ],
        };
        assert!(bad_bounds.validate().is_err());
    }

    #[test]
    fn fingerprint_distinguishes_pipelines() {
        let a = PipelineBuilder::source_range(10).batch(2).build();
        let b = PipelineBuilder::source_range(10).batch(4).build();
        let a2 = PipelineBuilder::source_range(10).batch(2).build();
        assert_eq!(a.fingerprint(), a2.fingerprint());
        assert_ne!(a.fingerprint(), b.fingerprint());
    }
}
