//! CRC-framed record files (the TFRecord stand-in).
//!
//! Layout per record:
//!
//! ```text
//! +----------+---------------+---------------+
//! | len: u32 | crc32(data)   | data: len B   |
//! +----------+---------------+---------------+
//! ```
//!
//! A dataset is a set of such files, one per source shard. CRCs catch
//! corruption at read time; a corrupt record surfaces as
//! [`StorageError::Corrupt`](super::StorageError::Corrupt) rather than
//! silently feeding garbage into training.

use super::{StorageError, StorageResult};
use crate::util::crc32::Hasher;

/// Serializes records into an in-memory file body.
#[derive(Debug, Default)]
pub struct RecordWriter {
    buf: Vec<u8>,
    count: usize,
}

impl RecordWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, record: &[u8]) {
        let mut h = Hasher::new();
        h.update(record);
        let crc = h.finalize();
        self.buf.extend_from_slice(&(record.len() as u32).to_le_bytes());
        self.buf.extend_from_slice(&crc.to_le_bytes());
        self.buf.extend_from_slice(record);
        self.count += 1;
    }

    pub fn count(&self) -> usize {
        self.count
    }

    pub fn byte_len(&self) -> usize {
        self.buf.len()
    }

    pub fn finish(self) -> Vec<u8> {
        self.buf
    }
}

/// Iterates records out of a file body, verifying CRCs.
pub struct RecordReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> RecordReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        RecordReader { buf, pos: 0 }
    }

    /// Next record, `Ok(None)` at clean EOF, `Err` on corruption.
    pub fn next_record(&mut self) -> StorageResult<Option<&'a [u8]>> {
        if self.pos == self.buf.len() {
            return Ok(None);
        }
        if self.buf.len() - self.pos < 8 {
            return Err(StorageError::Corrupt("truncated record header".into()));
        }
        let len = u32::from_le_bytes(self.buf[self.pos..self.pos + 4].try_into().unwrap()) as usize;
        let crc = u32::from_le_bytes(self.buf[self.pos + 4..self.pos + 8].try_into().unwrap());
        let start = self.pos + 8;
        if self.buf.len() - start < len {
            return Err(StorageError::Corrupt(format!(
                "truncated record body: want {len}, have {}",
                self.buf.len() - start
            )));
        }
        let data = &self.buf[start..start + len];
        let mut h = Hasher::new();
        h.update(data);
        if h.finalize() != crc {
            return Err(StorageError::Corrupt("crc mismatch".into()));
        }
        self.pos = start + len;
        Ok(Some(data))
    }

    /// Eagerly read all records.
    pub fn read_all(mut self) -> StorageResult<Vec<Vec<u8>>> {
        let mut out = Vec::new();
        while let Some(r) = self.next_record()? {
            out.push(r.to_vec());
        }
        Ok(out)
    }

    /// Count records without copying.
    pub fn count(mut self) -> StorageResult<usize> {
        let mut n = 0;
        while self.next_record()?.is_some() {
            n += 1;
        }
        Ok(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_records() {
        let mut w = RecordWriter::new();
        w.push(b"alpha");
        w.push(b"");
        w.push(&[0u8; 1024]);
        assert_eq!(w.count(), 3);
        let body = w.finish();
        let records = RecordReader::new(&body).read_all().unwrap();
        assert_eq!(records.len(), 3);
        assert_eq!(records[0], b"alpha");
        assert_eq!(records[1], b"");
        assert_eq!(records[2], vec![0u8; 1024]);
    }

    #[test]
    fn empty_file_is_empty() {
        assert_eq!(RecordReader::new(&[]).read_all().unwrap().len(), 0);
    }

    #[test]
    fn crc_corruption_detected() {
        let mut w = RecordWriter::new();
        w.push(b"payload");
        let mut body = w.finish();
        let last = body.len() - 1;
        body[last] ^= 0xff;
        let mut r = RecordReader::new(&body);
        assert!(matches!(r.next_record(), Err(StorageError::Corrupt(_))));
    }

    #[test]
    fn truncated_header_detected() {
        let mut w = RecordWriter::new();
        w.push(b"payload");
        let body = w.finish();
        let mut r = RecordReader::new(&body[..4]);
        assert!(matches!(r.next_record(), Err(StorageError::Corrupt(_))));
    }

    #[test]
    fn truncated_body_detected() {
        let mut w = RecordWriter::new();
        w.push(b"payload");
        let body = w.finish();
        let mut r = RecordReader::new(&body[..body.len() - 2]);
        assert!(matches!(r.next_record(), Err(StorageError::Corrupt(_))));
    }

    #[test]
    fn count_matches() {
        let mut w = RecordWriter::new();
        for i in 0..57u32 {
            w.push(&i.to_le_bytes());
        }
        let body = w.finish();
        assert_eq!(RecordReader::new(&body).count().unwrap(), 57);
    }
}
