//! Deterministic, dependency-free random number generation.
//!
//! The offline crate set has no `rand`, so we ship a small PCG-XSH-RR-64/32
//! generator seeded through SplitMix64, plus the distributions the
//! simulator and workload generators need (uniform, normal, lognormal,
//! exponential, zipf) and Fisher-Yates shuffling. Everything is seeded and
//! reproducible: every experiment records its seed.

/// SplitMix64: used to expand a user seed into PCG state/stream.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// PCG-XSH-RR 64/32 with a SplitMix64-derived stream. Deterministic,
/// fast, and statistically solid for simulation purposes.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    inc: u64,
}

const PCG_MUL: u64 = 6_364_136_223_846_793_005;

impl Rng {
    /// Create a generator from a seed. Two generators with different seeds
    /// produce independent-looking streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let init_state = splitmix64(&mut sm);
        let init_inc = splitmix64(&mut sm) | 1;
        let mut rng = Rng { state: 0, inc: init_inc };
        rng.state = init_state.wrapping_add(rng.inc);
        rng.next_u32();
        rng
    }

    /// Derive a child generator (stable under reordering of other draws).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let mut sm = self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let s = splitmix64(&mut sm);
        Rng::new(s)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MUL).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). Unbiased via rejection.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let zone = u64::MAX - u64::MAX % n;
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform usize in [0, n).
    pub fn below_usize(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Bernoulli with probability p.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.f64()).max(f64::MIN_POSITIVE); // avoid ln(0)
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Lognormal with the given parameters of the underlying normal.
    /// Heavy-tailed — used to model the fleet resource-usage CDFs (Fig 1).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal_ms(mu, sigma).exp()
    }

    /// Exponential with rate lambda (mean 1/lambda).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        let u = (1.0 - self.f64()).max(f64::MIN_POSITIVE);
        -u.ln() / lambda
    }

    /// Zipf-distributed rank in [1, n] with exponent s (approximate inverse
    /// CDF sampling; exact enough for workload skew modeling).
    pub fn zipf(&mut self, n: u64, s: f64) -> u64 {
        debug_assert!(n >= 1);
        // Inverse-transform on the generalized harmonic CDF via the
        // integral approximation: H(k) ≈ (k^(1-s) - 1)/(1-s) for s != 1.
        if (s - 1.0).abs() < 1e-9 {
            let hn = (n as f64).ln() + 0.5772156649;
            let target = self.f64() * hn;
            let k = target.exp() as u64;
            return k.clamp(1, n);
        }
        let one_minus = 1.0 - s;
        let hn = ((n as f64).powf(one_minus) - 1.0) / one_minus;
        let target = self.f64() * hn;
        let k = (target * one_minus + 1.0).powf(1.0 / one_minus);
        (k as u64).clamp(1, n)
    }

    /// In-place Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniform element.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below_usize(xs.len())]
    }

    /// Random alphanumeric string (ids, tokens).
    pub fn ident(&mut self, len: usize) -> String {
        const A: &[u8] = b"abcdefghijklmnopqrstuvwxyz0123456789";
        (0..len).map(|_| A[self.below_usize(A.len())] as char).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(4);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(6);
        let n = 50_000;
        let mean = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn lognormal_is_heavy_tailed() {
        let mut r = Rng::new(7);
        let xs: Vec<f64> = (0..20_000).map(|_| r.lognormal(0.0, 1.5)).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let mut sorted = xs.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = sorted[xs.len() / 2];
        assert!(mean > 1.8 * median, "mean={mean} median={median}");
    }

    #[test]
    fn zipf_rank1_most_popular() {
        let mut r = Rng::new(8);
        let mut counts = [0u32; 11];
        for _ in 0..20_000 {
            counts[r.zipf(10, 1.2) as usize] += 1;
        }
        assert!(counts[1] > counts[2], "{counts:?}");
        assert!(counts[2] > counts[5], "{counts:?}");
        assert_eq!(counts[0], 0);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // overwhelmingly likely
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(10);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn range_inclusive_bounds() {
        let mut r = Rng::new(11);
        let mut saw_lo = false;
        let mut saw_hi = false;
        for _ in 0..2_000 {
            let v = r.range_u64(3, 6);
            assert!((3..=6).contains(&v));
            saw_lo |= v == 3;
            saw_hi |= v == 6;
        }
        assert!(saw_lo && saw_hi);
    }
}
