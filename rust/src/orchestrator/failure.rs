//! Failure injection: preemptions on multi-tenant machines.
//!
//! The paper's workers "run on multi-tenant machines with fungible
//! resources" — preemption is routine, which is why the relaxed-visitation
//! fault-tolerance design matters. The injector kills a random worker at a
//! configurable rate and (optionally) restarts a replacement after a
//! delay, exercising the §3.4 recovery paths end to end.

use super::Cell;
use crate::util::rng::Rng;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Injection policy.
#[derive(Debug, Clone)]
pub struct FailureConfig {
    /// Probability a kill fires at each tick.
    pub kill_probability: f64,
    pub tick: Duration,
    /// Restart a replacement this long after each kill (None = never).
    pub restart_after: Option<Duration>,
    /// Advance preemption notice (spot/maintenance `DrainNotice`): with
    /// `Some(notice)`, each kill is preceded by a graceful drain begin
    /// and deferred by `notice` — the kill then fires *regardless* of
    /// whether the drain completed (real preemption does not wait), but
    /// a worker whose drain finished in time was already reaped with
    /// nothing left on it. `None` = plain kill, no warning.
    pub drain_notice: Option<Duration>,
    pub seed: u64,
}

impl Default for FailureConfig {
    fn default() -> Self {
        FailureConfig {
            kill_probability: 0.5,
            tick: Duration::from_millis(100),
            restart_after: Some(Duration::from_millis(200)),
            drain_notice: None,
            seed: 0xdead_beef,
        }
    }
}

/// Handle to a running injector; dropping stops it.
pub struct FailureInjector {
    stop: Arc<AtomicBool>,
    pub kills: Arc<AtomicU64>,
    pub restarts: Arc<AtomicU64>,
    /// Drain-notice (`DrainNotice`) events delivered before kills.
    pub drains: Arc<AtomicU64>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl FailureInjector {
    /// Start injecting failures into `cell`.
    pub fn start(cell: Arc<Cell>, cfg: FailureConfig) -> FailureInjector {
        let stop = Arc::new(AtomicBool::new(false));
        let kills = Arc::new(AtomicU64::new(0));
        let restarts = Arc::new(AtomicU64::new(0));
        let drains = Arc::new(AtomicU64::new(0));
        let (s2, k2, r2, d2) = (stop.clone(), kills.clone(), restarts.clone(), drains.clone());
        let thread = std::thread::Builder::new()
            .name("failure-injector".into())
            .spawn(move || {
                let mut rng = Rng::new(cfg.seed);
                let mut pending_restarts: Vec<std::time::Instant> = Vec::new();
                // Kills deferred by an advance drain notice: (handle, due).
                let mut pending_kills: Vec<(u64, std::time::Instant)> = Vec::new();
                while !s2.load(Ordering::SeqCst) {
                    std::thread::sleep(cfg.tick);
                    // Due restarts.
                    let now = std::time::Instant::now();
                    pending_restarts.retain(|t| {
                        if *t <= now {
                            if cell.add_worker().is_ok() {
                                r2.fetch_add(1, Ordering::SeqCst);
                            }
                            false
                        } else {
                            true
                        }
                    });
                    // A drain that finished inside the notice window is
                    // reaped cleanly; the deferred kill below then finds
                    // the handle gone and is a no-op (the preemption hit
                    // an already-empty container).
                    cell.reap_drained();
                    // Due deferred kills: the preemption fires whether or
                    // not the drain completed (a cleanly-reaped handle
                    // makes it a no-op), and the replacement is scheduled
                    // either way — the machine was preempted regardless.
                    pending_kills.retain(|&(handle, due)| {
                        if due <= now {
                            let _ = cell.kill_worker(handle);
                            k2.fetch_add(1, Ordering::SeqCst);
                            if let Some(d) = cfg.restart_after {
                                pending_restarts.push(now + d);
                            }
                            false
                        } else {
                            true
                        }
                    });
                    // Maybe kill (with advance notice when configured).
                    if rng.chance(cfg.kill_probability) {
                        let handles = cell.worker_handles();
                        if handles.len() > 1 {
                            let victim = *rng.choice(&handles);
                            match cfg.drain_notice {
                                Some(notice) => {
                                    // DrainNotice event: begin the graceful
                                    // drain now, kill after the notice.
                                    if !pending_kills.iter().any(|&(h, _)| h == victim)
                                        && cell.drain_worker(victim)
                                    {
                                        d2.fetch_add(1, Ordering::SeqCst);
                                        pending_kills.push((victim, now + notice));
                                    }
                                }
                                None => {
                                    if cell.kill_worker(victim) {
                                        k2.fetch_add(1, Ordering::SeqCst);
                                        if let Some(d) = cfg.restart_after {
                                            pending_restarts.push(now + d);
                                        }
                                    }
                                }
                            }
                        }
                    }
                    cell.tick();
                }
            })
            .ok();
        FailureInjector { stop, kills, restarts, drains, thread }
    }

    pub fn stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
    }
}

impl Drop for FailureInjector {
    fn drop(&mut self) {
        self.stop();
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::udf::UdfRegistry;
    use crate::service::dispatcher::DispatcherConfig;
    use crate::storage::ObjectStore;

    #[test]
    fn injector_kills_and_restarts() {
        let store = ObjectStore::in_memory();
        let cell = Arc::new(
            Cell::new(store, UdfRegistry::with_builtins(), DispatcherConfig::default()).unwrap(),
        );
        cell.scale_to(4).unwrap();
        let inj = FailureInjector::start(
            cell.clone(),
            FailureConfig {
                kill_probability: 1.0,
                tick: Duration::from_millis(20),
                restart_after: Some(Duration::from_millis(40)),
                drain_notice: None,
                seed: 7,
            },
        );
        std::thread::sleep(Duration::from_millis(400));
        inj.stop();
        assert!(inj.kills.load(Ordering::SeqCst) >= 2, "kills happened");
        assert!(inj.restarts.load(Ordering::SeqCst) >= 1, "restarts happened");
        // Never drops to zero workers (injector keeps >= 1).
        assert!(cell.worker_count() >= 1);
    }
}
