"""AOT bridge: lower every L2 entry point to HLO *text* + a manifest.

Run once at build time (`make artifacts`); Rust loads the artifacts via
`HloModuleProto::from_text_file` and never touches Python again.

Why HLO text and not `lowered.compile().serialize()` / serialized protos:
jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the
`xla` crate's bundled xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`).
The HLO text parser reassigns ids, so text round-trips cleanly (see
/opt/xla-example/README.md).

Usage: cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    Rust side always unwraps one tuple, regardless of output arity)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dtype_name(dt) -> str:
    return {
        "uint8": "u8",
        "uint32": "u32",
        "int32": "i32",
        "int64": "i64",
        "float32": "f32",
        "float64": "f64",
    }[str(dt)]


def emit(out_dir: str, cfg: model.ModelConfig = model.DEFAULT_CONFIG) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "format": "hlo-text/1",
        "model": {
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "d_ff": cfg.d_ff,
            "seq_len": cfg.seq_len,
            "batch": cfg.batch,
            "param_count": int(model.param_count(cfg)),
            "param_shapes": [
                {"name": n, "shape": list(s)} for n, s in model.param_shapes(cfg)
            ],
        },
        "vision": {
            "batch": model.VISION_BATCH,
            "height": model.VISION_HW,
            "width": model.VISION_HW,
            "channels": model.VISION_C,
        },
        "nlp": {"batch": model.NLP_BATCH, "seq": model.NLP_SEQ},
        "artifacts": {},
    }
    for name, (fn, args) in model.aot_entries(cfg).items():
        lowered = fn.lower(*args)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        n_out = len(lowered.out_info) if hasattr(lowered, "out_info") else None
        inputs = [
            {"dtype": _dtype_name(a.dtype), "shape": list(a.shape)} for a in args
        ]
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": inputs,
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
            "bytes": len(text),
        }
        print(f"  {name}: {len(text)} chars, {len(inputs)} inputs -> {path}")
    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"  manifest -> {mpath}")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="compat: ignored single-file path")
    args = ap.parse_args()
    out_dir = args.out_dir
    if args.out:  # legacy Makefile target passed a single file path
        out_dir = os.path.dirname(args.out) or "."
    jax.config.update("jax_platforms", "cpu")
    emit(out_dir)


if __name__ == "__main__":
    main()
