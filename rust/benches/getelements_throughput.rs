//! Batched streaming data plane (`GetElements`) vs the single-element
//! `GetElement` RPC, on the two shapes that bracket the design space:
//!
//! * small elements (~100 B on the wire): per-RPC overhead dominates,
//!   which is exactly what batching amortizes;
//! * large elements (~196 KiB): byte throughput dominates, batching
//!   should at least not hurt.
//!
//! Prints elements/s, RPCs issued, and RPCs-per-element for both paths,
//! plus the speedup and RPC-amplification drop. Acceptance targets:
//! >= 2x element throughput and >= 8x fewer RPCs per element on the
//! small shape at default settings.

use std::sync::Arc;
use std::time::Instant;
use tfdatasvc::data::exec::ElemIter;
use tfdatasvc::data::graph::{GraphDef, PipelineBuilder};
use tfdatasvc::data::udf::UdfRegistry;
use tfdatasvc::orchestrator::Cell;
use tfdatasvc::service::dispatcher::DispatcherConfig;
use tfdatasvc::service::proto::ShardingPolicy;
use tfdatasvc::service::{ServiceClient, ServiceClientConfig};
use tfdatasvc::storage::dataset::{generate_vision, VisionGenConfig};
use tfdatasvc::storage::ObjectStore;

struct RunStats {
    elements: u64,
    secs: f64,
    rpcs: u64,
    bytes: u64,
}

fn run(cell: &Cell, graph: &GraphDef, batching: bool) -> RunStats {
    let client = ServiceClient::new(&cell.dispatcher_addr());
    let mut it = client
        .distribute(
            graph,
            ServiceClientConfig {
                sharding: ShardingPolicy::Off,
                batching,
                ..Default::default()
            },
        )
        .unwrap();
    let t0 = Instant::now();
    let mut elements = 0u64;
    while let Ok(Some(_)) = it.next() {
        elements += 1;
    }
    let secs = t0.elapsed().as_secs_f64();
    it.release();
    RunStats {
        elements,
        secs,
        rpcs: client.metrics().counter("client/rpcs").get(),
        bytes: client.metrics().counter("client/bytes_fetched").get(),
    }
}

fn main() {
    let store = ObjectStore::in_memory();
    let cell = Arc::new(
        Cell::new(store.clone(), UdfRegistry::with_builtins(), DispatcherConfig::default())
            .unwrap(),
    );
    // Deep worker buffers so the data plane, not production, is measured.
    cell.set_worker_config_mutator(|c| {
        c.buffer_size = 256;
        c.cache_window = 1024;
    });
    cell.scale_to(1).unwrap();

    // Small shape: 8 range rows per element, ~100 B on the wire.
    let small = PipelineBuilder::source_range(4096).batch(8).build();
    // Large shape: 16-image vision batches, ~196 KiB on the wire.
    let spec = generate_vision(
        &store,
        "bench",
        &VisionGenConfig { num_shards: 2, samples_per_shard: 256, ..Default::default() },
    );
    let large = PipelineBuilder::source_vision(spec).batch(16).build();

    println!("=== getelements_throughput (1 worker, loopback) ===");
    println!(
        "{:<18} {:>10} {:>12} {:>8} {:>12}",
        "shape/path", "elements", "elements/s", "rpcs", "rpcs/element"
    );
    for (name, graph) in [("small", &small), ("large", &large)] {
        let single = run(&cell, graph, false);
        let batched = run(&cell, graph, true);
        assert_eq!(
            single.elements, batched.elements,
            "both paths must deliver the same stream"
        );
        for (path, s) in [("single", &single), ("batched", &batched)] {
            println!(
                "{:<18} {:>10} {:>12.0} {:>8} {:>12.3}",
                format!("{name}/{path}"),
                s.elements,
                s.elements as f64 / s.secs,
                s.rpcs,
                s.rpcs as f64 / s.elements as f64
            );
        }
        let speedup = single.secs / batched.secs;
        let rpc_drop = (single.rpcs as f64 / single.elements as f64)
            / (batched.rpcs as f64 / batched.elements as f64);
        println!(
            "{name}: batched speedup {speedup:.2}x, rpc amplification drop {rpc_drop:.1}x, \
             bytes fetched {} -> {}",
            single.bytes, batched.bytes
        );
        if name == "small" {
            assert!(
                speedup >= 2.0,
                "acceptance: batched must sustain >= 2x element throughput on small \
                 elements (got {speedup:.2}x)"
            );
            assert!(
                rpc_drop >= 8.0,
                "acceptance: client/rpcs per element must drop >= 8x (got {rpc_drop:.1}x)"
            );
        }
    }
    println!("getelements_throughput OK");
}
