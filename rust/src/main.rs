//! tfdatasvc CLI: launch service components as real processes.
//!
//! ```text
//! tfdatasvc dispatcher --addr 127.0.0.1:7700 [--journal PATH]
//! tfdatasvc worker     --addr 127.0.0.1:0 --dispatcher 127.0.0.1:7700 [--cache-window N]
//! tfdatasvc demo       [--workers N]      # in-process quickstart
//! ```
//!
//! The dispatcher and worker subcommands run until killed, letting you
//! assemble a multi-process deployment by hand; `demo` runs the
//! single-process flow the examples use.

use std::sync::Arc;
use tfdatasvc::data::exec::ElemIter;
use tfdatasvc::data::graph::PipelineBuilder;
use tfdatasvc::data::udf::UdfRegistry;
use tfdatasvc::orchestrator::Cell;
use tfdatasvc::service::dispatcher::{Dispatcher, DispatcherConfig};
use tfdatasvc::service::proto::ShardingPolicy;
use tfdatasvc::service::worker::{Worker, WorkerConfig};
use tfdatasvc::service::{ServiceClient, ServiceClientConfig};
use tfdatasvc::storage::dataset::{generate_vision, VisionGenConfig};
use tfdatasvc::storage::ObjectStore;
use tfdatasvc::util::cli::Args;

fn main() {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = if argv.is_empty() { "help".to_string() } else { argv.remove(0) };
    let args = Args::parse(argv);
    match cmd.as_str() {
        "dispatcher" => run_dispatcher(&args),
        "worker" => run_worker(&args),
        "demo" => run_demo(&args),
        _ => {
            eprintln!(
                "usage: tfdatasvc <dispatcher|worker|demo> [--addr A] [--dispatcher A] \
                 [--journal PATH] [--cache-window N] [--workers N]"
            );
            std::process::exit(if cmd == "help" { 0 } else { 2 });
        }
    }
}

fn run_dispatcher(args: &Args) {
    let addr = args.str_or("addr", "127.0.0.1:7700");
    let cfg = DispatcherConfig {
        journal_path: args.get("journal").map(std::path::PathBuf::from),
        ..Default::default()
    };
    let d = Dispatcher::start(&addr, cfg).expect("start dispatcher");
    println!("dispatcher listening on {}", d.addr());
    loop {
        std::thread::sleep(std::time::Duration::from_secs(1));
        d.tick();
    }
}

fn run_worker(args: &Args) {
    let addr = args.str_or("addr", "127.0.0.1:0");
    let dispatcher = args.str_or("dispatcher", "127.0.0.1:7700");
    let store = ObjectStore::in_memory();
    let udfs = UdfRegistry::with_builtins();
    // Register the XLA preprocessing UDFs when artifacts are available.
    if let Ok(engine) = tfdatasvc::runtime::Engine::load(tfdatasvc::runtime::default_artifacts_dir()) {
        tfdatasvc::runtime::udfs::register_xla_udfs(&udfs, &engine);
        println!("XLA preprocessing UDFs registered");
    }
    let mut cfg = WorkerConfig::new(store, udfs);
    cfg.cache_window = args.usize_or("cache-window", 16);
    let w = Worker::start(&addr, &dispatcher, cfg).expect("start worker");
    println!("worker {} serving on {} (dispatcher {dispatcher})", w.worker_id(), w.addr());
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn run_demo(args: &Args) {
    let n_workers = args.usize_or("workers", 2);
    let store = ObjectStore::in_memory();
    let spec = generate_vision(
        &store,
        "datasets/demo",
        &VisionGenConfig { num_shards: 8, samples_per_shard: 32, ..Default::default() },
    );
    let cell =
        Arc::new(Cell::new(store, UdfRegistry::with_builtins(), DispatcherConfig::default()).unwrap());
    cell.scale_to(n_workers).unwrap();
    println!("demo cell: dispatcher {} + {n_workers} workers", cell.dispatcher_addr());
    let graph = PipelineBuilder::source_vision(spec)
        .map_parallel("vision.normalize+vision.augment", 4)
        .batch(16)
        .build();
    let client = ServiceClient::new(&cell.dispatcher_addr());
    let mut it = client
        .distribute(
            &graph,
            ServiceClientConfig { sharding: ShardingPolicy::Dynamic, ..Default::default() },
        )
        .unwrap();
    let mut n = 0;
    while let Ok(Some(_)) = it.next() {
        n += 1;
    }
    println!("demo consumed {n} batches through the service — OK");
}
