//! tf.data service: the paper's system contribution.
//!
//! A disaggregated input-data-processing service (§3):
//!
//! * [`dispatcher`] — metadata plane: dataset registry, worker/client
//!   registry, task assignment, dynamic split distribution, heartbeats.
//!   Performs **no data processing** (§3.1).
//! * [`worker`] — data plane: executes pipeline graphs, buffers batches,
//!   serves client fetch RPCs. Hosts the **ephemeral sliding-window
//!   cache** (§3.5) and the **coordinated-reads** round-robin scheduler
//!   (§3.6).
//! * [`client`] — accelerator-host side: registers pipelines, discovers
//!   workers, fetches batches in parallel into a client-side buffer.
//!
//! ## The wire data plane: versioned stream sessions
//!
//! The canonical client<->worker fetch path is a **negotiated stream
//! session** (`OpenStream` + session-scoped `Fetch`), with the older
//! RPCs retained as shims over the same serving machinery:
//!
//! * **Session lifecycle** — `OpenStream(job, client)` negotiates a
//!   protocol version (`min(client, worker)`, floor 1), a capability set
//!   (bitwise intersection of [`proto::stream_caps`]), and a response
//!   frame budget (`min` of both sides' `max_frame_len`), registers the
//!   consumer's cache cursor, and returns a session id. Sessions are
//!   worker-local soft state: they die with the task, with the
//!   consumer's dispatcher-reported release, or via `CloseStream`; a
//!   `Fetch` on a dead session errors and the client re-handshakes
//!   (worker restart therefore self-heals).
//! * **Capability matrix** — `DEFLATE`: whole-frame response
//!   compression; `CHUNKED_TRANSFER`: elements larger than the
//!   negotiated frame budget stream as continuation frames;
//!   `ADAPTIVE_BATCHING`: responses carry backpressure hints
//!   (ready-queue depth, window occupancy) and the client AIMD-tunes its
//!   `max_elements`/`max_bytes` per worker (additive increase while
//!   responses come back full with more ready, halve on empty
//!   long-polls) instead of static config. Dropping any bit degrades
//!   gracefully: no chunking -> explicit `element too large` errors, no
//!   deflate -> plain frames, no adaptive -> static budgets.
//! * **Fetch discipline** — independent mode: one `Fetch` drains up to
//!   the negotiated budgets from the task's ready queue under one lock,
//!   long-polling briefly when empty (the paper's §3.1 line-rate
//!   requirement); coordinated mode (§3.6): one `Fetch` carries exactly
//!   one round slot (`round = Some(r)`), preserving the
//!   one-slot-per-call contract.
//! * **Chunked transfer** — an element whose encoding exceeds the frame
//!   budget is popped from the cache into the session's chunk slot
//!   (tagged with a session-unique `chunk_seq`) and streamed as raw
//!   continuation frames; the client echoes its received offset, tagged
//!   with the element's seq, in each `Fetch`, making delivery idempotent
//!   under RPC retries, and the worker releases the element only when a
//!   matching-seq offset reaches its total length — an offset tagged
//!   with any other seq (e.g. a retried ack from the previous element)
//!   just restarts delivery from 0. This closes the historical
//!   silent-skip hole (cursor advancing before an over-cap write).
//! * **Legacy shims** — `GetElements` (old batched clients) and
//!   `GetElement` (old single-element clients; also
//!   `ServiceClientConfig::batching = false`) route into the same drain
//!   loop with fixed conservative budgets and no chunking: an over-cap
//!   element yields an explicit [`ServiceError::ElementTooLarge`] with
//!   the cursor untouched. A new client talking to an old worker
//!   downgrades automatically when `OpenStream` answers "unknown
//!   method". Coordinated rounds keep `GetElement` as their legacy shim.
//!
//! All paths are **one-copy end to end** on the worker: elements are
//! encoded once into the sliding window, batched frames are assembled in
//! a pooled buffer, and the RPC server writes `(head, frame)` with a
//! scatter-gather frame write ([`crate::rpc::Frame::write_parts_to`])
//! instead of copying the frame into a contiguous response payload.
//!
//! ## Raw-speed data plane
//!
//! The paper's §5 economics divide cluster cost by per-worker serve
//! rate, so the serve hot path is engineered, not just correct. Four
//! mechanisms, each locked in by a differential test battery
//! (`serve_batch_differential_*` in [`worker`], the seeded CRC/codec
//! suites in `tests/fault_injection.rs`) and gated by the
//! `micro_hotpath`/`getelements_throughput` smoke benches in CI:
//!
//! * **Sharded sliding cache** — the multi-consumer window splits into
//!   one `RwLock` element ring (append/trim, read-mostly under serve)
//!   plus 8 cursor shards (`client & 7`), each its own mutex, so k
//!   concurrent `Fetch`es from distinct consumers advance cursors
//!   without serializing on one cache lock. A `min_hint` atomic
//!   (invariant: hint <= true slowest cursor; refreshed exactly on
//!   trim, `fetch_min`-ed on registration) gates eager trims: a serve
//!   only pays the full all-shards min scan + ring write lock when its
//!   cursor *was* the slowest, which is sequentially identical to
//!   trimming after every op (the property the single-lock reference
//!   model in the differential test asserts). Lock order is
//!   meta -> shard -> ring; the publish condvar pairs only with meta.
//! * **Adaptive per-shape compression** — [`crate::wire::AdaptiveCodec`]
//!   buckets response frames by size class (log2), spends a few trial
//!   compressions per class, then settles a sticky per-class verdict:
//!   LZ for frames that compress >= 10% (`Compress`), straight bytes
//!   for ones that don't (`Skip`, counted as `worker/codec_skips`) —
//!   so incompressible image batches stop paying the compressor while
//!   zero-heavy record batches keep the wire savings. Classes re-probe
//!   every ~512 uses and flip (`worker/codec_switches`) on content
//!   drift. `assemble_batch_frame` consults the codec only when the
//!   session negotiated `DEFLATE` *and* the client asked for it.
//! * **Slice-by-16 CRC-32** — frame checksums
//!   ([`crate::util::crc32`]) fold 16 bytes per step through 16
//!   precomputed tables instead of byte-at-a-time; the scalar oracle
//!   stays compiled and the differential property test (plus the
//!   seeded suite in the CI fault matrix) pins bit-for-bit equality on
//!   one-shot, streaming, and misaligned inputs.
//! * **Vectored request reads** — the RPC server reads the 4-byte
//!   length prefix and the fixed header in one `read_vectored` syscall
//!   ([`crate::rpc`]'s frame reader) instead of two sequential
//!   `read_exact`s, trimming a syscall off every request on the serve
//!   path.
//!
//! ## Coordinated reads (§3.6): round leases + prefetch
//!
//! Coordinated mode serves training **rounds**: per round, one worker
//! hands all `num_consumers` clients same-bucket batches. The round
//! plane is pipelined end to end:
//!
//! * **Worker multi-round buffer** — the coordinated producer
//!   materializes and *pre-encodes* up to
//!   `WorkerConfig::round_prefetch_depth` rounds (default 2) ahead of
//!   consumption, blocking on a condvar at the bound; `Fetch` serves any
//!   buffered round. Rounds every consumer has moved past (possible only
//!   after a lease reassignment) are GC'd by consumer watermarks so they
//!   never pin the buffer.
//! * **Round leases** — ownership of a residue class
//!   (`round % num_workers`) is a lease renewed implicitly by worker
//!   heartbeats; the dispatcher's `worker_timeout` is the lease
//!   duration. `Dispatcher::tick` moves a silent owner's residues to
//!   survivors (`RoundAssignment` on their heartbeats, floored at the
//!   minimum client-reported `next_round`), the new owner
//!   re-materializes adopted rounds from its own pipeline (relaxed
//!   visitation under failure), and a revived zombie is handed the
//!   authoritative (possibly empty) lease view so split-brain rounds
//!   cannot violate the same-batch-per-round contract. Clients route
//!   round `r` via the residue-indexed `round_owner_addrs` from their
//!   heartbeats.
//! * **Client round prefetch** — the fetch engine runs up to
//!   `ServiceClientConfig::round_prefetch_depth` (default 2) rounds
//!   ahead of trainer demand into a bounded channel: the
//!   materialize+RPC+decode round-trip for round `r+1` overlaps the
//!   trainer consuming round `r` instead of sitting on the step critical
//!   path. With `concurrent_round_fetch` (default on) the window's
//!   rounds are fetched **concurrently across distinct owner workers**
//!   — one in-flight round per owner, completions reordered and
//!   delivered strictly in round order — so a k-worker topology overlaps
//!   k wire transfers and the round cadence approaches `fetch/k`. The
//!   §3.6 contract is untouched: every round slot is still fetched
//!   exactly once, delivered in order.
//!
//! ### Restart & recovery state machine
//!
//! The round plane's failure matrix (worker crash × dispatcher crash ×
//! client restart) is covered by journaling + leases + floors:
//!
//! * **Journal** — `CreateJob` records carry the job's fixed
//!   `worker_order` (the lease-table baseline) and every lease-table
//!   change from `Dispatcher::tick` appends a `RoundLeaseChanged`
//!   record (full residue→owner map, last-writer-wins on replay). The
//!   materialization *floor* is deliberately not journaled: it is
//!   rebuilt from the first post-restart client heartbeats
//!   (`ClientHeartbeatReq.next_round`).
//! * **Dispatcher restart** — replay rebuilds the lease table; replayed
//!   workers are restored *optimistically alive* with one
//!   `worker_timeout` of grace (a dispatcher restart does not kill
//!   workers), so a worker that truly died during the outage still
//!   transitions to dead and forfeits its residues — without the grace,
//!   its residues would be stranded forever. Workers keep producing and
//!   clients keep fetching through the outage (addresses are cached);
//!   on reconnect, heartbeats resume routing.
//! * **Worker crash** — `tick()` moves the dead owner's residues to
//!   survivors (stable round-robin, floored at the min client
//!   `next_round`); survivors re-materialize adopted rounds from their
//!   own pipelines (relaxed visitation under failure).
//! * **Revival re-balance** — once a revived home owner (same
//!   advertised address ⇒ same worker id) has stayed alive past
//!   `DispatcherConfig::revival_hysteresis`, `tick()` hands its home
//!   residues back (both loser and gainer receive their full updated
//!   lease views, floored as above) — so a recovered worker resumes
//!   serving instead of staying leaseless until the next failure, and a
//!   flapping worker cannot thrash leases inside the hysteresis window.
//!   `TaskDef.has_lease_view` makes an *empty* residue set
//!   authoritative: a revived worker never self-assigns its home
//!   residue while someone else holds the lease (no split-brain
//!   rounds).
//! * **Client restart** — round progress is recorded per consumer
//!   **slot** (`ClientHeartbeatReq.consumer_index`), not per client id,
//!   so a consumer replacement inherits its crashed predecessor's
//!   progress: its first heartbeat returns the slot-scoped
//!   `ClientHeartbeatResp.round_floor` and the round walk fast-forwards
//!   there instead of asking owners for rounds the slot already
//!   consumed. A fresh slot (staggered startup) sees floor 0 and is
//!   never skipped past rounds buffered for it; a just-started consumer
//!   reports the `u64::MAX` "unknown" sentinel, excluded from floors.
//!   Slot entries are leases: `tick()` prunes reports silent past
//!   `worker_timeout`, so a permanently-dead consumer cannot pin the
//!   lease-move floor forever.
//! * **Re-balance trust** — leases are only handed *to* workers with
//!   heartbeat evidence from their current incarnation: a
//!   journal-restored worker under failure-detection grace keeps what
//!   it holds but cannot gain residues until it actually heartbeats.
//!   Lease-view deliveries lost with a crashed dispatcher's in-memory
//!   queues are re-pushed on each worker's first post-restart heartbeat
//!   (the authoritative-view push), so a granted-but-undelivered
//!   residue can never answer WrongWorker forever.
//!
//! * **Two-phase live-to-live transfers** — a lease move between two
//!   *live* workers (revival re-balance, graceful drain) never flips the
//!   table directly. `tick()` only *plans* a handoff: a revocation for
//!   the residue is queued on (and re-delivered to) the loser's
//!   heartbeats while the lease keeps pointing at it. The loser applies
//!   the revocation — dropping its buffered rounds for that residue and
//!   refusing new ones — and **acks** on its next heartbeat; only that
//!   ack flips `residue_owners`, journals `RoundLeaseChanged`, and
//!   queues the gainer's grant. The loser therefore stops serving
//!   strictly before the gainer starts: no residue is ever co-held by
//!   two live owners (the former ≤ one-heartbeat co-hold relaxation is
//!   closed). A loser that dies mid-handshake cancels the handoff and
//!   falls back to the ordinary dead-owner flip, which is safe because a
//!   dead loser cannot serve.
//!
//! ### Durable control plane: snapshots, compaction, admission
//!
//! The journal alone makes restart cost proportional to the
//! dispatcher's *lifetime*; snapshots make it proportional to its
//! *state*. On disk the journal is a chain of CRC-framed segments:
//!
//! ```text
//! journal            genesis suffix (seq 0)
//! journal.snap-N     full-state checkpoint, one CRC-framed record
//! journal.suffix-N   records appended after snapshot N
//! ```
//!
//! * **Checkpoint** — `Dispatcher::snapshot_state()` serializes the
//!   replayable state (datasets, jobs, named jobs, workers, spill
//!   snapshots, id counters — canonical key-sorted order, soft/derived
//!   state excluded) into one `DispatcherSnapshot`.
//!   `Journal::install_snapshot` writes it temp-file + fsync + atomic
//!   rename, then starts a fresh empty suffix: records never straddle a
//!   checkpoint. Two (snapshot, suffix) generations are retained; older
//!   ones are deleted.
//! * **Compaction** — `tick()` (off the RPC hot path) cuts a checkpoint
//!   whenever the live suffix exceeds
//!   `DispatcherConfig::journal_compact_bytes` (default 4 MiB). Every
//!   journal append happens under the meta lock (write-ahead: journal
//!   first, then apply), so the checkpoint the compactor cuts agrees
//!   exactly with the journal position it supersedes.
//! * **Fallback ladder** — restore tries the newest snapshot first; a
//!   snapshot failing its CRC falls back to the previous one, then to
//!   full genesis replay (`dispatcher/restore_fallbacks` counts each
//!   rung). A mid-suffix CRC mismatch or torn tail keeps the longest
//!   valid prefix and stops that chain — corruption degrades recovery
//!   freshness, never availability.
//! * **Admission control** — the dispatcher sheds `GetOrCreateJob` (and
//!   only that: existing jobs keep running) once unfinished jobs reach
//!   `DispatcherConfig::admission_max_jobs`, answering a retryable
//!   [`ServiceError::Overloaded`] with a `retry_after_ms` hint
//!   (`DispatcherConfig::admission_retry_ms`). The client backs off
//!   with jitter around the hint and retries
//!   (`client/admission_retries`); the shed is lossless — no accepted
//!   job loses data.
//! * **Post-revoke grace** — a revoked residue's buffered rounds stay
//!   servable read-only for one heartbeat (`RoundTake::Grace`, counted
//!   as `worker/post_revoke_serves`) so a fetch racing the two-phase
//!   lease flip gets data instead of a `WrongWorker` bounce.
//!
//! ### Closed-loop autoscaling & graceful drain (§3.1)
//!
//! The [`scaling::ScalingController`] closes Autopilot's loop over live
//! signals. Sensor path: worker heartbeats report CPU
//! (`cpu_util_milli`), client heartbeats report the fraction of fetches
//! that found nothing buffered (`stall_fraction_milli`, maintained by
//! the client's fetch engine); `Dispatcher::scaling_snapshot` folds both
//! into one reading. Decide: the [`crate::orchestrator::Autoscaler`]
//! policy (hi/lo utilization band, starvation threshold, cooldown,
//! min/max bounds) at ~1 Hz. Actuate: scale-up adds workers
//! immediately; scale-down picks the least-loaded workers and walks each
//! through the **`Draining` state machine**:
//!
//! ```text
//! begin_worker_drain           worker heartbeat            orchestrator
//!  (journaled, counted)             loop                     reap loop
//!        |                           |                           |
//!  Draining: no new consumers   drain:true + revocations     drain_complete?
//!  routed, cannot gain leases,  -> revoke owned residues,    (ready + acks in
//!  tick() plans handoffs for    flush pending spill,         + no residue or
//!  every residue it owns        set drain_ready, ack     ->  pending handoff)
//!        |                           |                           |
//!        +--- revoke --- flush/handoff --- ack --- grant ---> remove worker,
//!                                                  finish_worker_drain
//! ```
//!
//! Each drain handoff is a two-phase transfer as above — the gainer's
//! grant activates only on the draining worker's ack — so scale-down is
//! stall-free for clients: rounds keep serving from the loser until the
//! instant the gainer owns them, and independent-mode consumers are
//! simply routed away from the draining worker on their next heartbeat.
//! Only after every lease is handed off, every revocation acked, and the
//! spill tier flushed does the orchestrator remove the worker and
//! journal the drain exit (`dispatcher/workers_drained`). A preemption
//! with advance notice ([`crate::orchestrator::failure`]'s
//! `DrainNotice`) runs the same machine and kills when the notice
//! expires whether or not the drain finished — a drain that completed in
//! time makes the kill a no-op.
//!
//! Accepted relaxations (bounded, documented): a consumer can address a
//! worker one to two heartbeats stale (route learned before a drain or
//! handoff landed) and sees `WrongWorker`/wait answers absorbed by the
//! client's round-prefetch depth, never an error; a drain that cannot
//! complete within ~10 s in the *blocking* [`crate::orchestrator::Cell`]
//! scale path (e.g. no eligible gainer remains) falls back to hard
//! removal with the §3.4 crash-recovery guarantees; a spot preemption
//! may still fire mid-drain (the notice is best-effort by nature); and a
//! consumer replacement joining after its predecessor's progress entry
//! expired (crashed consumer + pruned lease, e.g. the predecessor died
//! during a dispatcher outage) sees floor 0, asks an owner for a round
//! already consumed, and **skips forward** to the owner-reported next
//! available round (the `"round already consumed; next round N"` hint,
//! matched via [`ROUND_CONSUMED_PREFIX`], counted as
//! `client/rounds_skipped_forward`) — relaxed visitation, never a
//! terminal error surfaced to the trainer.
//!
//! ### Elastic consumer membership: the epoch state machine
//!
//! A coordinated job's consumer width is **epoch-versioned**: the job
//! starts at epoch 0 with its creation-time `num_consumers`, and each
//! `SET_JOB_CONSUMERS` call appends a `WidthEpoch` to the job's
//! schedule. The state machine:
//!
//! * **Barrier choice** — the dispatcher picks the new epoch's
//!   `barrier_round` as the first round no live consumer slot has
//!   fetched yet: `max(` every slot's recorded `next_round`, the
//!   previous epoch's barrier, the job's floor `)`. A width change is
//!   therefore always a *round* barrier: no round already shaped (or in
//!   flight) is ever re-keyed under a consumer's feet, and barriers are
//!   monotone across epochs. The record is journaled
//!   (`ConsumerSetChanged`) before it is published, so the schedule
//!   survives a dispatcher restart.
//! * **Worker re-key** — the full schedule is pushed to every worker on
//!   its next heartbeat (re-pushed to revived/unconfirmed workers, like
//!   lease views). The worker drops buffered rounds at or past the new
//!   barrier (`worker/rounds_rekeyed`) and re-materializes them at the
//!   new width using the existing floor machinery; application is
//!   idempotent (epochs at or below the last-applied epoch are
//!   ignored), so a duplicate push is harmless.
//! * **Client re-sync** — client heartbeats carry the current
//!   `membership_epoch`, `num_consumers`, and `width_barrier_round`. A
//!   grown slot (index >= old width) is activated with its floor forced
//!   up to its activation barrier, so it starts fetching exactly where
//!   its slot first exists. A shrunk slot (index >= new width) drains
//!   rounds below the barrier and then observes a clean end-of-sequence
//!   — never an error. Stale-width windows are bounded by one heartbeat
//!   interval: a worker that has not yet applied the epoch answers
//!   out-of-range slots with a *wait* (not an error), and in-order
//!   delivery on the client keeps the per-slot exactly-once contract.
//! * **Capability + downgrade matrix** — prefetch is gated on the
//!   negotiated [`proto::stream_caps::ROUND_PREFETCH`] bit. New client
//!   <-> new worker: pipelined (chunk slots keyed by `(round, seq)`
//!   allow in-flight transfers for several rounds on one session). New
//!   client <-> worker without the bit: sticky downgrade to lock-step
//!   demand-driven fetching (`client/round_prefetch_downgrades`). New
//!   client <-> pre-session worker: lock-step over the legacy
//!   `GetElement` round protocol. Old clients against new workers see
//!   the one-slot-per-call behavior unchanged.
//!
//! Bench: `cargo bench --bench coordinated_rounds` (prefetch on vs off
//! under skewed element sizes; `-- --smoke` in CI).
//!
//! ## Ephemeral data sharing (§3.5)
//!
//! The paper's second headline result: concurrent jobs running the
//! *same* input pipeline can be fed from one preprocessed stream,
//! cutting preprocessing cost from `k×` to ~`1×`. The subsystem spans
//! all three roles:
//!
//! * **Pipeline fingerprinting** — `RegisterDataset` assigns the dataset
//!   id from a canonical structural hash of the graph
//!   ([`crate::data::graph::GraphDef::fingerprint_full`]): stable across
//!   registration order and wire-format changes, blind to
//!   performance-only attributes (map parallelism, prefetch depth), and
//!   sensitive to op params, source file lists, and UDF names *and
//!   bodies* (clients may attach per-UDF body digests). Identical
//!   pipelines therefore collide on one id, which is what makes sharing
//!   discoverable.
//! * **Dispatcher sharing registry** — `GetOrCreateJob` with
//!   `sharing: auto` attaches the client to a live job with the same
//!   fingerprint and compatible settings instead of creating a k-th
//!   production; `sharing: off` (the client-side default — attaching
//!   mid-stream relaxes the visitation guarantee, so sharing is opt-in)
//!   always creates a dedicated job, and named jobs remain the explicit
//!   grouping mechanism. Joins and releases are journaled, so the
//!   sharing registry survives a dispatcher restart, and are pushed to
//!   workers as consumer updates on heartbeats.
//! * **Worker multi-consumer cache** — each independent-mode task owns a
//!   sliding window over its produced stream; N consumers hold
//!   independent cursors, elements are produced and encoded once, and
//!   the window is trimmed to an element capacity and a byte budget. A
//!   consumer that falls outside the window skips ahead (the paper's
//!   relaxed-visitation escape hatch) rather than stalling production;
//!   skips and shared productions are counted
//!   (`worker/relaxed_visitation_skips`, `worker/shared_elements_served`).
//! ## Spill tier & snapshots
//!
//! The sliding window is RAM-only in the paper; the [`spill`] subsystem
//! extends it with a storage-backed tier so eviction becomes tiering
//! instead of discard, and a completed epoch becomes a reusable
//! **fingerprint-keyed snapshot**. See [`spill`] for the on-store layout
//! (one append-only data object + one manifest object per job).
//!
//! **State machine (per independent-mode job with spill enabled):**
//!
//! * *live* — production fills the RAM window; elements evicted by the
//!   capacity/byte trim are offered to the job's [`spill::JobSpill`]
//!   (`policy: wanted` spills only ranges some registered cursor still
//!   needs; `policy: all` spills everything, enabling snapshots).
//! * *spilling* — evicted elements accumulate in a pending buffer and
//!   flush as CRC-checked segments; the per-job manifest is re-persisted
//!   after every flush, so the flushed prefix is durable ("committed
//!   prefix") and a replacement worker adopts it
//!   ([`spill::JobSpill::adopt_existing`]) instead of losing it.
//! * *snapshot-committed* — at end-of-sequence the worker finalizes its
//!   manifest (tail flush + `complete` flag) and reports it on
//!   heartbeats until acknowledged; once **every** worker in the job's
//!   `worker_order` has reported a complete manifest, the dispatcher
//!   merges them (worker order, renumbered into one sequence space),
//!   journals `SnapshotCommitted {fingerprint, epoch, manifest}`, and
//!   from then on a re-submitted identical pipeline (`sharing: auto`,
//!   same fingerprint) is created in **snapshot-serve** mode: each
//!   worker streams its round-robin slice of the snapshot's segments
//!   from the store (paying [`crate::storage::NetModel`] read costs
//!   when remote) instead of running the pipeline —
//!   `worker/elements_produced` stays ~0 for the second job.
//!
//! **Fallback matrix (always serve, degrade in cost then in
//! visitation):**
//!
//! | condition | behavior |
//! |---|---|
//! | cursor inside RAM window | serve from RAM (unchanged fast path) |
//! | cursor behind window, range spilled | replay from spill (`worker/spill_elements_served`), hand back to RAM at the window edge |
//! | cursor behind window, range not spilled | relaxed-visitation skip (the pre-spill behavior; counted) |
//! | snapshot segment reads clean | stream from store (`worker/snapshot_elements_streamed`) |
//! | snapshot segment missing/corrupt | CRC/read failure → live-production fallback for the remainder (`worker/snapshot_fallbacks`), skipping the already-streamed prefix |
//!
//! **Visitation contract:** spill `off` keeps the paper's relaxed
//! visitation exactly (late attachers skip the evicted prefix). Spill
//! `all` upgrades a late attacher to full-epoch replay — zero skips —
//! because every evicted element is readable from the tier; spill
//! `wanted` guarantees no *registered* cursor ever skips (its wanted
//! ranges are always spilled) but late attachers still skip the prefix
//! from before they registered. Exactly-once per cursor holds across
//! RAM→spill→RAM hand-backs: the cursor advances only as elements are
//! delivered, from whichever tier holds them.
//!
//! Accepted relaxations: spill *writes* are not charged network cost
//! (the paper's cost model prices reads; writes happen off the serve
//! path), snapshot fallback assumes deterministic re-production order
//! (true for all in-tree sources), and snapshot commit requires every
//! `worker_order` worker to report — a worker that dies *after* EOS but
//! *before* its manifest is acked simply means no snapshot for that
//! epoch (the next identical job re-produces and retries the commit).
//!
//! * [`scaling`] — the closed-loop autoscaling controller (§3.1).
//! * [`sharding`] — OFF / DYNAMIC / STATIC source-data sharding (§3.3).
//! * [`journal`] — dispatcher write-ahead journal + replay (§3.4).
//! * [`visitation`] — data-visitation-guarantee trackers used by tests
//!   (exactly-once / at-most-once / zero-once-or-more).
//! * [`spill`] — the storage-backed window tier + snapshot manifests.
//! * [`proto`] — the RPC schema all of the above speak.

pub mod client;
pub mod dispatcher;
pub mod journal;
pub mod proto;
pub mod scaling;
pub mod sharding;
pub mod spill;
pub mod visitation;
pub mod worker;

pub use client::{ServiceClient, ServiceClientConfig};
pub use dispatcher::Dispatcher;
pub use scaling::{ScalingConfig, ScalingController};
pub use proto::{CompressionMode, ProcessingMode, SharingMode, ShardingPolicy};
pub use worker::Worker;

/// Number of source shards in a pipeline graph (drives split tracking and
/// OFF-mode shuffled iteration).
pub fn graph_num_shards(graph: &crate::data::graph::GraphDef) -> usize {
    use crate::data::graph::Node;
    match graph.nodes.first() {
        Some(Node::SourceVision { spec }) | Some(Node::SourceText { spec }) => spec.shards.len(),
        _ => 1,
    }
}

/// Service-level errors.
#[derive(Debug)]
pub enum ServiceError {
    Rpc(crate::rpc::RpcError),
    Wire(crate::wire::WireError),
    Data(crate::data::DataError),
    Journal(String),
    UnknownDataset(u64),
    UnknownJob(u64),
    UnknownWorker(u64),
    /// A single encoded element exceeds the response-frame budget and the
    /// fetch path cannot chunk it (legacy RPCs, or a session that did not
    /// negotiate [`proto::stream_caps::CHUNKED_TRANSFER`]). The serving
    /// cursor is *not* advanced, so the failure is explicit and repeatable
    /// instead of a silent skip. The `Display` text is part of the wire
    /// contract: clients recognize the condition by the
    /// `"element too large"` prefix in the remote error string.
    ElementTooLarge { bytes: usize, cap: usize },
    /// The dispatcher's admission budget is spent: job *creation* is shed
    /// (attaches to existing jobs are still admitted) and the caller
    /// should retry after roughly `retry_after_ms` with jitter. The
    /// `Display` text is part of the wire contract: clients recognize the
    /// condition by the [`OVERLOADED_PREFIX`] in the remote error string
    /// and parse the hint from `"; retry after N ms"`.
    Overloaded { retry_after_ms: u64 },
    Other(String),
}

/// Stable prefix of [`ServiceError::ElementTooLarge`]'s remote error
/// string; the client matches on it to surface a terminal error instead
/// of retrying.
pub const ELEMENT_TOO_LARGE_PREFIX: &str = "element too large";

/// Stable prefix of the worker's "this round slot was already served /
/// consumed" remote error string. Part of the wire contract: the client
/// matches on it and **skips forward** to the `"; next round N"` hint
/// carried in the same message (relaxed visitation for replacement
/// consumers, `client/rounds_skipped_forward`) instead of surfacing a
/// terminal error.
pub const ROUND_CONSUMED_PREFIX: &str = "round already consumed";

/// Stable prefix of [`ServiceError::Overloaded`]'s remote error string.
/// Part of the wire contract: the client matches on it, parses the
/// `"; retry after N ms"` hint, and retries `GetOrCreateJob` with
/// jittered backoff (`client/admission_retries`) instead of surfacing a
/// terminal error.
pub const OVERLOADED_PREFIX: &str = "dispatcher overloaded";

impl std::fmt::Display for ServiceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServiceError::Rpc(e) => write!(f, "rpc: {e}"),
            ServiceError::Wire(e) => write!(f, "wire: {e}"),
            ServiceError::Data(e) => write!(f, "data: {e}"),
            ServiceError::Journal(msg) => write!(f, "journal: {msg}"),
            ServiceError::UnknownDataset(id) => write!(f, "unknown dataset {id}"),
            ServiceError::UnknownJob(id) => write!(f, "unknown job {id}"),
            ServiceError::UnknownWorker(id) => write!(f, "unknown worker {id}"),
            ServiceError::ElementTooLarge { bytes, cap } => write!(
                f,
                "{ELEMENT_TOO_LARGE_PREFIX}: {bytes} byte element exceeds the {cap} byte frame \
                 budget; use a chunked stream session (OpenStream with CHUNKED_TRANSFER)"
            ),
            ServiceError::Overloaded { retry_after_ms } => write!(
                f,
                "{OVERLOADED_PREFIX}: job admission budget spent; retry after {retry_after_ms} ms"
            ),
            ServiceError::Other(msg) => write!(f, "{msg}"),
        }
    }
}

impl std::error::Error for ServiceError {}

impl From<crate::rpc::RpcError> for ServiceError {
    fn from(e: crate::rpc::RpcError) -> ServiceError {
        ServiceError::Rpc(e)
    }
}

impl From<crate::wire::WireError> for ServiceError {
    fn from(e: crate::wire::WireError) -> ServiceError {
        ServiceError::Wire(e)
    }
}

impl From<crate::data::DataError> for ServiceError {
    fn from(e: crate::data::DataError) -> ServiceError {
        ServiceError::Data(e)
    }
}

pub type ServiceResult<T> = Result<T, ServiceError>;
