//! Runtime parallelism tuning — the AUTOTUNE stand-in (§3.2).
//!
//! tf.data's AUTOTUNE adjusts per-operator parallelism and buffer sizes at
//! runtime from observed processing times. We reproduce the core control
//! loop: each parallel-map stage records per-element work durations in an
//! [`AutotuneState`]; a hill-climbing controller periodically recomputes a
//! target parallelism per stage, bounded by a CPU budget, aiming to match
//! each stage's service rate to the consumer's demand rate.

use std::collections::HashMap;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

/// Per-stage measurement window.
#[derive(Debug, Default, Clone)]
struct StageStats {
    /// Work items completed in the current window.
    completed: u64,
    /// Total busy time across the window.
    busy: Duration,
    /// Current parallelism target.
    target: usize,
}

/// Shared autotune state, one per pipeline instance.
#[derive(Debug)]
pub struct AutotuneState {
    stages: Mutex<HashMap<usize, StageStats>>,
    /// Maximum total parallelism budget across stages (defaults to the
    /// machine's logical CPUs).
    budget: usize,
    default_parallelism: usize,
    /// Bumped on every [`AutotuneState::replan`]; elastic stages park
    /// surplus worker threads on this until the plan changes, so a
    /// running pipeline reacts to new targets instead of keeping its
    /// build-time pool size for its whole lifetime.
    plan_generation: Mutex<u64>,
    plan_changed: Condvar,
}

impl Default for AutotuneState {
    fn default() -> Self {
        let cpus = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        AutotuneState {
            stages: Mutex::new(HashMap::new()),
            budget: cpus,
            default_parallelism: 4,
            plan_generation: Mutex::new(0),
            plan_changed: Condvar::new(),
        }
    }
}

impl AutotuneState {
    pub fn with_budget(budget: usize) -> AutotuneState {
        AutotuneState {
            stages: Mutex::new(HashMap::new()),
            budget: budget.max(1),
            default_parallelism: 4.min(budget.max(1)),
            plan_generation: Mutex::new(0),
            plan_changed: Condvar::new(),
        }
    }

    /// Current plan generation (bumped by every replan). Elastic stage
    /// threads snapshot this before checking their activation condition,
    /// then sleep in [`AutotuneState::wait_replan`] — the classic
    /// check-then-wait pattern without a missed-wakeup window.
    pub fn plan_generation(&self) -> u64 {
        *self.plan_generation.lock().unwrap()
    }

    /// Block until the plan generation moves past `seen` (a replan
    /// happened) or `timeout` elapses; returns the current generation.
    pub fn wait_replan(&self, seen: u64, timeout: Duration) -> u64 {
        let deadline = std::time::Instant::now() + timeout;
        let mut gen = self.plan_generation.lock().unwrap();
        while *gen == seen {
            let now = std::time::Instant::now();
            if now >= deadline {
                break;
            }
            let (next, _) = self.plan_changed.wait_timeout(gen, deadline - now).unwrap();
            gen = next;
        }
        *gen
    }

    pub fn budget(&self) -> usize {
        self.budget
    }

    /// Record one completed work item for stage `idx`.
    pub fn record_work(&self, idx: usize, took: Duration) {
        let mut st = self.stages.lock().unwrap();
        let s = st.entry(idx).or_default();
        s.completed += 1;
        s.busy += took;
    }

    /// Current parallelism target for a stage (used at iterator build
    /// time; running stages keep their pool size for their lifetime, as
    /// tf.data does between plan revisions).
    pub fn target_parallelism(&self, idx: usize) -> usize {
        let st = self.stages.lock().unwrap();
        st.get(&idx).map(|s| s.target).filter(|&t| t > 0).unwrap_or(self.default_parallelism)
    }

    /// Re-plan all stage targets given a demand of `demand_eps` elements
    /// per second from the consumer. Returns the new targets.
    ///
    /// For each stage, required parallelism = demand × mean-work-time,
    /// rounded up, clamped to the CPU budget shared proportionally when
    /// oversubscribed.
    pub fn replan(&self, demand_eps: f64) -> Vec<(usize, usize)> {
        let mut st = self.stages.lock().unwrap();
        // Required parallelism per stage.
        let mut wants: Vec<(usize, f64)> = st
            .iter()
            .map(|(&idx, s)| {
                let mean = if s.completed > 0 {
                    s.busy.as_secs_f64() / s.completed as f64
                } else {
                    0.0
                };
                (idx, (demand_eps * mean).max(1.0))
            })
            .collect();
        wants.sort_by_key(|&(idx, _)| idx);
        let total: f64 = wants.iter().map(|&(_, w)| w).sum();
        let scale = if total > self.budget as f64 { self.budget as f64 / total } else { 1.0 };
        let mut out = Vec::with_capacity(wants.len());
        for (idx, want) in wants {
            let t = ((want * scale).ceil() as usize).max(1);
            if let Some(s) = st.get_mut(&idx) {
                s.target = t;
                s.completed = 0;
                s.busy = Duration::ZERO;
            }
            out.push((idx, t));
        }
        drop(st);
        // Wake parked elastic stage threads so scale-ups take effect now,
        // not at the next pipeline build.
        *self.plan_generation.lock().unwrap() += 1;
        self.plan_changed.notify_all();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_until_measured() {
        let a = AutotuneState::with_budget(8);
        assert_eq!(a.target_parallelism(0), 4);
    }

    #[test]
    fn replan_scales_with_work_time() {
        let a = AutotuneState::with_budget(64);
        // Stage 0: 10 ms per element. Stage 1: 1 ms per element.
        for _ in 0..10 {
            a.record_work(0, Duration::from_millis(10));
            a.record_work(1, Duration::from_millis(1));
        }
        // Demand of 400 eps -> stage0 wants 4, stage1 wants 1 (0.4 ceil).
        let plan = a.replan(400.0);
        let m: std::collections::HashMap<usize, usize> = plan.into_iter().collect();
        assert_eq!(m[&0], 4);
        assert_eq!(m[&1], 1);
        assert_eq!(a.target_parallelism(0), 4);
    }

    #[test]
    fn replan_respects_budget() {
        let a = AutotuneState::with_budget(8);
        for _ in 0..5 {
            a.record_work(0, Duration::from_millis(50));
            a.record_work(1, Duration::from_millis(50));
        }
        // Each wants 50 at demand 1000 eps; budget 8 splits 4/4.
        let plan = a.replan(1000.0);
        let total: usize = plan.iter().map(|&(_, t)| t).sum();
        assert!(total <= 8 + 1, "budget respected (±1 for ceil), got {total}");
    }

    #[test]
    fn replan_bumps_generation_and_wakes_waiters() {
        let a = std::sync::Arc::new(AutotuneState::with_budget(8));
        let gen0 = a.plan_generation();
        // Timeout path: no replan, generation unchanged.
        assert_eq!(a.wait_replan(gen0, Duration::from_millis(10)), gen0);
        // Wakeup path: a replan from another thread unblocks the wait
        // well before the long timeout.
        let a2 = a.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            a2.record_work(0, Duration::from_millis(1));
            a2.replan(100.0);
        });
        let t0 = std::time::Instant::now();
        let gen1 = a.wait_replan(gen0, Duration::from_secs(5));
        assert!(gen1 > gen0);
        assert!(t0.elapsed() < Duration::from_secs(2), "woken by replan, not timeout");
        h.join().unwrap();
    }

    #[test]
    fn replan_resets_window() {
        let a = AutotuneState::with_budget(8);
        a.record_work(0, Duration::from_millis(10));
        a.replan(100.0);
        // Window cleared: a replan with no new samples treats stage as idle.
        let plan = a.replan(100.0);
        assert_eq!(plan[0].1, 1);
    }
}
