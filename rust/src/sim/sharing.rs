//! Ephemeral data sharing cost model (§3.5, §4.3, Fig. 10).
//!
//! Three deployment modes for `k` concurrent hyperparameter-tuning jobs
//! running the *same* input pipeline:
//!
//! * **A** — one shared deployment, sharing enabled: each batch is
//!   produced once and served to all jobs from the sliding-window cache.
//! * **B** — one shared deployment, no sharing: the worker pool splits
//!   its capacity across `k` independent productions.
//! * **C** — `k` dedicated deployments: full speed for everyone, `k`× the
//!   preprocessing resources.
//!
//! Also models the worst-case sequential-sharing cost formula from §3.5:
//! `k·C − (k−1)·(cache/dataset)·C`.

use super::models::ModelSpec;

/// Inputs for the Fig. 10 experiment.
#[derive(Debug, Clone)]
pub struct SharingConfig {
    /// Workers per deployment (128 in the paper).
    pub workers: usize,
    /// Max concurrent jobs one deployment can feed at full speed without
    /// sharing (paper: preprocessing capacity supports 4 M4 jobs).
    pub capacity_jobs: f64,
}

impl Default for SharingConfig {
    fn default() -> Self {
        SharingConfig { workers: 128, capacity_jobs: 4.0 }
    }
}

/// Results for one (mode, k) cell of Fig. 10.
#[derive(Debug, Clone, Copy)]
pub struct SharingResult {
    /// Throughput each job achieves, as a fraction of its ideal.
    pub per_job_throughput_frac: f64,
    /// Total preprocessing cost, normalized to one dedicated deployment
    /// serving one job (the figure's y-axis).
    pub preprocessing_cost: f64,
    /// Storage-read connections (scales bandwidth usage; §4.3).
    pub storage_reads_rel: f64,
}

/// Mode A: shared deployment, sharing on.
pub fn mode_a(_model: &ModelSpec, _cfg: &SharingConfig, k: usize) -> SharingResult {
    // One production stream feeds all k jobs; no slowdown observed up to
    // 64 jobs in the paper.
    let _ = k;
    SharingResult { per_job_throughput_frac: 1.0, preprocessing_cost: 1.0, storage_reads_rel: 1.0 }
}

/// Mode B: shared deployment, sharing off — capacity splits across jobs.
///
/// Degradation is mildly sublinear in the overload factor (paper: 8 jobs
/// → 1.75× slower, 16 → 3×, vs the naive 2×/4×): oversubscribed workers
/// overlap I/O across the independent productions and batch RPC work,
/// recovering some throughput. We model slowdown = (k/capacity)^0.8,
/// which reproduces both reported points.
pub fn mode_b(_model: &ModelSpec, cfg: &SharingConfig, k: usize) -> SharingResult {
    let frac = (cfg.capacity_jobs / k as f64).min(1.0).powf(0.8);
    // Jobs run 1/frac longer; the deployment is fully busy the whole
    // time, so cost scales with job time (same pool, longer occupancy).
    SharingResult {
        per_job_throughput_frac: frac,
        preprocessing_cost: 1.0 / frac,
        storage_reads_rel: k as f64,
    }
}

/// Mode C: k dedicated deployments.
pub fn mode_c(_model: &ModelSpec, _cfg: &SharingConfig, k: usize) -> SharingResult {
    SharingResult {
        per_job_throughput_frac: 1.0,
        preprocessing_cost: k as f64,
        storage_reads_rel: k as f64,
    }
}

/// §3.5 worst-case sequential sharing: each job only reuses the final
/// cache window of its predecessor.
pub fn sequential_sharing_cost(k: usize, cache_size: f64, dataset_size: f64) -> f64 {
    let k = k as f64;
    k - (k - 1.0) * (cache_size / dataset_size)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::models::model;

    #[test]
    fn mode_a_is_flat_in_k() {
        let m = model("M4");
        let cfg = SharingConfig::default();
        for k in [1, 2, 4, 8, 16, 64] {
            let r = mode_a(m, &cfg, k);
            assert_eq!(r.per_job_throughput_frac, 1.0);
            assert_eq!(r.preprocessing_cost, 1.0);
        }
    }

    #[test]
    fn mode_b_degrades_beyond_capacity() {
        let m = model("M4");
        let cfg = SharingConfig::default();
        assert_eq!(mode_b(m, &cfg, 4).per_job_throughput_frac, 1.0);
        // Paper: 8 jobs -> 1.92 -> 1.09 b/s (1.75x slower); 16 -> 0.64 (3x).
        let r8 = mode_b(m, &cfg, 8);
        assert!((1.0 / r8.per_job_throughput_frac - 1.75).abs() < 0.3, "8 jobs ~1.75x slower");
        let r16 = mode_b(m, &cfg, 16);
        assert!((1.0 / r16.per_job_throughput_frac - 3.0).abs() < 0.3, "16 jobs ~3x slower");
    }

    #[test]
    fn mode_c_cost_linear() {
        let m = model("M4");
        let cfg = SharingConfig::default();
        for k in [1, 2, 4, 8, 16] {
            let r = mode_c(m, &cfg, k);
            assert_eq!(r.preprocessing_cost, k as f64);
            assert_eq!(r.per_job_throughput_frac, 1.0);
        }
    }

    #[test]
    fn sharing_reads_storage_once() {
        let m = model("M4");
        let cfg = SharingConfig::default();
        assert_eq!(mode_a(m, &cfg, 16).storage_reads_rel, 1.0);
        assert_eq!(mode_c(m, &cfg, 16).storage_reads_rel, 16.0);
    }

    #[test]
    fn sequential_worst_case_formula() {
        // cache == dataset: everything reused, cost 1.
        assert!((sequential_sharing_cost(5, 1.0, 1.0) - 1.0).abs() < 1e-9);
        // cache << dataset: no reuse, cost k.
        assert!((sequential_sharing_cost(5, 0.0, 1.0) - 5.0).abs() < 1e-9);
        // halfway
        assert!((sequential_sharing_cost(3, 0.5, 1.0) - 2.0).abs() < 1e-9);
    }
}
