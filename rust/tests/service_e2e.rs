//! End-to-end integration: dispatcher + workers + clients over real TCP.
//! Cluster scaffolding lives in the shared `common` harness.

mod common;

use std::sync::Arc;
use std::time::Duration;

use common::{start_dispatcher, start_worker};
use tfdatasvc::data::exec::ElemIter;
use tfdatasvc::data::graph::PipelineBuilder;
use tfdatasvc::data::udf::UdfRegistry;
use tfdatasvc::service::dispatcher::{Dispatcher, DispatcherConfig};
use tfdatasvc::service::proto::{CompressionMode, ProcessingMode, SharingMode, ShardingPolicy};
use tfdatasvc::service::visitation::{Guarantee, VisitationTracker};
use tfdatasvc::service::worker::{Worker, WorkerConfig};
use tfdatasvc::service::{ServiceClient, ServiceClientConfig};
use tfdatasvc::storage::dataset::{generate_text, generate_vision, TextGenConfig, VisionGenConfig};
use tfdatasvc::storage::ObjectStore;

#[test]
fn single_worker_dynamic_sharding_exactly_once() {
    let d = start_dispatcher();
    let store = ObjectStore::in_memory();
    let spec = generate_vision(
        &store,
        "ds",
        &VisionGenConfig { num_shards: 4, samples_per_shard: 8, ..Default::default() },
    );
    let total = spec.total_samples as u64;
    let _w = start_worker(&d, store);

    let graph = PipelineBuilder::source_vision(spec)
        .map("vision.normalize")
        .batch(4)
        .build();
    let client = ServiceClient::new(&d.addr());
    let mut it = client
        .distribute(
            &graph,
            ServiceClientConfig { sharding: ShardingPolicy::Dynamic, ..Default::default() },
        )
        .unwrap();

    let mut tracker = VisitationTracker::new();
    let mut batches = 0;
    while let Some(e) = it.next().unwrap() {
        tracker.observe(&e.ids);
        batches += 1;
    }
    assert_eq!(batches, 8);
    // No failures: dynamic sharding gives exactly-once.
    let report = tracker.verify(Guarantee::ExactlyOnce, total);
    assert!(report.ok, "{report:?}");
}

#[test]
fn multi_worker_dynamic_sharding_disjoint() {
    let d = start_dispatcher();
    let store = ObjectStore::in_memory();
    let spec = generate_vision(
        &store,
        "ds",
        &VisionGenConfig { num_shards: 8, samples_per_shard: 4, ..Default::default() },
    );
    let total = spec.total_samples as u64;
    let _w1 = start_worker(&d, store.clone());
    let _w2 = start_worker(&d, store.clone());
    let _w3 = start_worker(&d, store);

    let graph = PipelineBuilder::source_vision(spec).batch(2).build();
    let client = ServiceClient::new(&d.addr());
    let mut it = client
        .distribute(
            &graph,
            ServiceClientConfig { sharding: ShardingPolicy::Dynamic, ..Default::default() },
        )
        .unwrap();

    let mut tracker = VisitationTracker::new();
    while let Some(e) = it.next().unwrap() {
        tracker.observe(&e.ids);
    }
    let report = tracker.verify(Guarantee::ExactlyOnce, total);
    assert!(report.ok, "{report:?}");
}

#[test]
fn off_sharding_every_worker_full_dataset() {
    let d = start_dispatcher();
    let store = ObjectStore::in_memory();
    let spec = generate_vision(
        &store,
        "ds",
        &VisionGenConfig { num_shards: 2, samples_per_shard: 4, ..Default::default() },
    );
    let total = spec.total_samples as u64;
    let _w1 = start_worker(&d, store.clone());
    let _w2 = start_worker(&d, store);

    let graph = PipelineBuilder::source_vision(spec).batch(1).build();
    let client = ServiceClient::new(&d.addr());
    let mut it = client
        .distribute(&graph, ServiceClientConfig { sharding: ShardingPolicy::Off, ..Default::default() })
        .unwrap();

    let mut tracker = VisitationTracker::new();
    while let Some(e) = it.next().unwrap() {
        tracker.observe(&e.ids);
    }
    // OFF sharding with two workers: each sample seen twice overall.
    let report = tracker.verify(Guarantee::ZeroOnceOrMore, total);
    assert!(report.ok, "{report:?}");
    assert_eq!(report.total_observations, 2 * total);
    assert_eq!(report.unique_seen as u64, total);
}

#[test]
fn compression_roundtrips_through_service() {
    let d = start_dispatcher();
    let store = ObjectStore::in_memory();
    let spec = generate_vision(
        &store,
        "ds",
        &VisionGenConfig { num_shards: 1, samples_per_shard: 6, ..Default::default() },
    );
    let _w = start_worker(&d, store);
    let graph = PipelineBuilder::source_vision(spec).batch(3).build();
    let client = ServiceClient::new(&d.addr());
    let mut it = client
        .distribute(
            &graph,
            ServiceClientConfig {
                sharding: ShardingPolicy::Dynamic,
                compression: CompressionMode::Deflate,
                ..Default::default()
            },
        )
        .unwrap();
    let mut n = 0;
    while let Some(e) = it.next().unwrap() {
        assert_eq!(e.tensors[0].shape, vec![3, 32, 32, 3]);
        n += 1;
    }
    assert_eq!(n, 2);
}

#[test]
fn ephemeral_sharing_two_clients_one_named_job() {
    let d = start_dispatcher();
    let store = ObjectStore::in_memory();
    let spec = generate_vision(
        &store,
        "ds",
        &VisionGenConfig { num_shards: 2, samples_per_shard: 8, ..Default::default() },
    );
    let _w = start_worker(&d, store);
    let graph = PipelineBuilder::source_vision(spec).batch(4).build();

    let cfg = || ServiceClientConfig {
        sharding: ShardingPolicy::Dynamic,
        job_name: "hp-tuning".into(),
        ..Default::default()
    };
    let c1 = ServiceClient::new(&d.addr());
    let c2 = ServiceClient::new(&d.addr());
    let mut it1 = c1.distribute(&graph, cfg()).unwrap();
    let mut it2 = c2.distribute(&graph, cfg()).unwrap();
    assert_eq!(it1.job_id(), it2.job_id(), "named job shared");

    // Both clients consume the full stream: 4 batches each (shared cache,
    // per-client cursors).
    let drain = |it: &mut dyn ElemIter| {
        let mut ids = Vec::new();
        while let Some(e) = it.next().unwrap() {
            ids.extend(e.ids);
        }
        ids
    };
    let t1 = std::thread::spawn({
        let mut it = it1;
        move || {
            let ids = drain(&mut it);
            it.release();
            ids
        }
    });
    let ids2 = drain(&mut it2);
    let ids1 = t1.join().unwrap();
    // Each client saw every sample exactly once (window large enough).
    let mut s1 = ids1.clone();
    s1.sort_unstable();
    let mut s2 = ids2.clone();
    s2.sort_unstable();
    assert_eq!(s1, (0..16).collect::<Vec<u64>>());
    assert_eq!(s2, (0..16).collect::<Vec<u64>>());
}

#[test]
fn auto_sharing_k_jobs_one_shared_production() {
    // §3.5 end to end: k anonymous jobs running the *same* pipeline (by
    // structural fingerprint, no job name) converge on one shared stream.
    // Elements are produced once; every client drains the full epoch
    // exactly-once from its own cursor; one client releasing mid-epoch
    // leaves the others untouched.
    let d = start_dispatcher();
    let store = ObjectStore::in_memory();
    let spec = generate_vision(
        &store,
        "ds",
        &VisionGenConfig { num_shards: 4, samples_per_shard: 16, ..Default::default() },
    );
    let total = spec.total_samples as u64; // 64 samples, 16 batches of 4
    let epoch = total / 4;
    let mut wcfg = WorkerConfig::new(store, UdfRegistry::with_builtins());
    wcfg.cache_window = 4096; // retain the whole epoch: no eviction
    let w = Worker::start("127.0.0.1:0", &d.addr(), wcfg).unwrap();

    // ~3 ms of preprocessing per sample slows production enough that all
    // attaches land while the stream is still being produced.
    let graph = PipelineBuilder::source_vision(spec)
        .map("synthetic.burn:3000")
        .batch(4)
        .build();
    let mk = || ServiceClientConfig {
        sharding: ShardingPolicy::Dynamic,
        sharing: SharingMode::Auto,
        ..Default::default()
    };

    let clients: Vec<ServiceClient> = (0..4).map(|_| ServiceClient::new(&d.addr())).collect();
    let mut iters: Vec<_> = clients.iter().map(|c| c.distribute(&graph, mk()).unwrap()).collect();
    let job_id = iters[0].job_id();
    assert!(iters.iter().all(|it| it.job_id() == job_id), "one shared job for all k clients");
    assert!(!iters[0].attached(), "first client created the job");
    assert!(iters[1..].iter().all(|it| it.attached()), "later clients attached");
    assert_eq!(d.metrics().counter("dispatcher/sharing_attaches").get(), 3);

    // One consumer leaves mid-epoch...
    let mut quitter = iters.pop().unwrap();
    // ...while the remaining three drain the full epoch concurrently.
    let drainers: Vec<_> = iters
        .into_iter()
        .map(|mut it| {
            std::thread::spawn(move || {
                let mut ids = Vec::new();
                while let Some(e) = it.next().unwrap() {
                    ids.extend(e.ids);
                }
                it.release();
                ids
            })
        })
        .collect();
    for _ in 0..2 {
        assert!(quitter.next().unwrap().is_some(), "quitter got its two batches");
    }
    quitter.release(); // mid-epoch departure

    for h in drainers {
        let mut ids = h.join().unwrap();
        ids.sort_unstable();
        assert_eq!(ids, (0..total).collect::<Vec<u64>>(), "full epoch exactly-once per client");
    }

    // The sharing ledger: produced once, fetched ~3x (plus the quitter's
    // partial drain).
    let produced = w.metrics().counter("worker/elements_produced").get();
    assert!(
        produced <= epoch + epoch / 10,
        "single production for k clients: produced {produced}, epoch {epoch}"
    );
    // Most pushes see >= 2 registered cursors. Loose lower bound: if an
    // unluckily-timed heartbeat delivers the task before the other
    // attaches, the first ~2 batches can be pushed before the remaining
    // clients' first fetches lazily register their cursors.
    let shared = w.metrics().counter("worker/shared_elements_served").get();
    assert!(
        shared * 4 >= epoch && shared <= produced,
        "bulk of the stream produced shared: {shared}/{produced}"
    );
    let fetched: u64 =
        clients.iter().map(|c| c.metrics().counter("client/elements_fetched").get()).sum();
    assert!(
        fetched >= 3 * epoch + 2 && fetched <= 4 * epoch,
        "k-fold consumption of one production: fetched {fetched}, epoch {epoch}"
    );
    // Window held the whole epoch: nobody was forced to skip.
    assert_eq!(w.metrics().counter("worker/relaxed_visitation_skips").get(), 0);
}

#[test]
fn sharing_opt_out_runs_dedicated_productions() {
    // Explicit opt-out (§3.5): identical pipelines, sharing disabled —
    // two dedicated jobs, two productions.
    let d = start_dispatcher();
    let store = ObjectStore::in_memory();
    let spec = generate_vision(
        &store,
        "ds",
        &VisionGenConfig { num_shards: 2, samples_per_shard: 8, ..Default::default() },
    );
    let total = spec.total_samples as u64;
    let w = start_worker(&d, store);
    let graph = PipelineBuilder::source_vision(spec).batch(4).build();
    let mk = || ServiceClientConfig {
        sharding: ShardingPolicy::Dynamic,
        sharing: SharingMode::Off,
        ..Default::default()
    };
    let c1 = ServiceClient::new(&d.addr());
    let c2 = ServiceClient::new(&d.addr());
    let mut it1 = c1.distribute(&graph, mk()).unwrap();
    let mut it2 = c2.distribute(&graph, mk()).unwrap();
    assert_ne!(it1.job_id(), it2.job_id(), "opt-out keeps jobs dedicated");
    let mut n = 0u64;
    while let Some(_e) = it1.next().unwrap() {
        n += 1;
    }
    while let Some(_e) = it2.next().unwrap() {
        n += 1;
    }
    assert_eq!(n, 2 * total / 4, "both clients drained their own epoch");
    drop(it1);
    drop(it2);
    let produced = w.metrics().counter("worker/elements_produced").get();
    assert_eq!(produced, 2 * total / 4, "two dedicated productions");
}

#[test]
fn coordinated_reads_two_consumers_same_bucket_per_round() {
    let d = start_dispatcher();
    let store = ObjectStore::in_memory();
    let spec = generate_text(
        &store,
        "txt",
        &TextGenConfig { num_shards: 2, samples_per_shard: 64, ..Default::default() },
    );
    let _w1 = start_worker(&d, store.clone());
    let _w2 = start_worker(&d, store);

    let num_consumers = 2u32;
    // Fig. 7 pipeline: bucket by length, group into windows of
    // num_consumers, flat_map.
    let graph = PipelineBuilder::source_text(spec)
        .bucket_by_sequence_length(vec![64, 128, 256], 4)
        .group_by_window(num_consumers)
        .flat_map()
        .take(24) // 12 rounds
        .build();

    let mk = |ci: u32| ServiceClientConfig {
        sharding: ShardingPolicy::Off,
        mode: ProcessingMode::Coordinated,
        job_name: "coord".into(),
        num_consumers,
        consumer_index: ci,
        ..Default::default()
    };
    let c0 = ServiceClient::new(&d.addr());
    let c1 = ServiceClient::new(&d.addr());
    let mut it0 = c0.distribute(&graph, mk(0)).unwrap();
    let mut it1 = c1.distribute(&graph, mk(1)).unwrap();
    assert_eq!(it0.job_id(), it1.job_id());

    let h1 = std::thread::spawn(move || {
        let mut rounds = Vec::new();
        for _ in 0..8 {
            match it1.next() {
                Ok(Some(e)) => rounds.push((e.bucket, e.tensors[0].shape[1])),
                _ => break,
            }
        }
        rounds
    });
    let mut rounds0 = Vec::new();
    for _ in 0..8 {
        match it0.next() {
            Ok(Some(e)) => rounds0.push((e.bucket, e.tensors[0].shape[1])),
            _ => break,
        }
    }
    let rounds1 = h1.join().unwrap();
    assert!(!rounds0.is_empty());
    assert_eq!(rounds0.len(), rounds1.len());
    // The §3.6 property: per round, both consumers get batches from the
    // same sequence-length bucket.
    for (a, b) in rounds0.iter().zip(&rounds1) {
        assert_eq!(a.0, b.0, "same bucket per round: {rounds0:?} vs {rounds1:?}");
    }
}

#[test]
fn worker_failure_midstream_at_most_once() {
    let d = start_dispatcher();
    let store = ObjectStore::in_memory();
    let spec = generate_vision(
        &store,
        "ds",
        &VisionGenConfig { num_shards: 16, samples_per_shard: 4, ..Default::default() },
    );
    let total = spec.total_samples as u64;
    let w1 = start_worker(&d, store.clone());
    let _w2 = start_worker(&d, store);

    let graph = PipelineBuilder::source_vision(spec)
        .map("synthetic.burn:3000") // slow it down so the kill lands mid-stream
        .batch(4)
        .build();
    let client = ServiceClient::new(&d.addr());
    let mut it = client
        .distribute(
            &graph,
            ServiceClientConfig { sharding: ShardingPolicy::Dynamic, ..Default::default() },
        )
        .unwrap();

    let mut tracker = VisitationTracker::new();
    let mut consumed = 0;
    while let Some(e) = it.next().unwrap() {
        tracker.observe(&e.ids);
        consumed += 1;
        if consumed == 2 {
            w1.shutdown(); // preempt one worker mid-stream
        }
    }
    // At-most-once must hold; some samples may be lost with the worker.
    let report = tracker.verify(Guarantee::AtMostOnce, total);
    assert!(report.ok, "{report:?}");
    assert!(report.unique_seen > 0);
}

#[test]
fn late_worker_joins_running_job() {
    let d = start_dispatcher();
    let store = ObjectStore::in_memory();
    let spec = generate_vision(
        &store,
        "ds",
        &VisionGenConfig { num_shards: 8, samples_per_shard: 4, ..Default::default() },
    );
    let total = spec.total_samples as u64;
    let _w1 = start_worker(&d, store.clone());

    let graph = PipelineBuilder::source_vision(spec)
        .map("synthetic.burn:2000")
        .batch(4)
        .build();
    let client = ServiceClient::new(&d.addr());
    let mut it = client
        .distribute(
            &graph,
            ServiceClientConfig { sharding: ShardingPolicy::Dynamic, ..Default::default() },
        )
        .unwrap();

    // Scale out while the job runs (the paper's horizontal scaling story).
    let mut tracker = VisitationTracker::new();
    let mut late: Option<Worker> = None;
    let mut batches = 0;
    while let Some(e) = it.next().unwrap() {
        tracker.observe(&e.ids);
        batches += 1;
        if batches == 1 {
            late = Some(start_worker(&d, store.clone()));
        }
    }
    assert!(late.is_some());
    let report = tracker.verify(Guarantee::ExactlyOnce, total);
    assert!(report.ok, "{report:?}");
}

#[test]
fn batched_path_exactly_once_three_workers_dynamic_sharding() {
    // The legacy batched GetElements plane (an old client that never
    // handshakes, against a session-enabled worker) must preserve the
    // dynamic-sharding visitation guarantee: disjoint splits, every
    // sample exactly once, while actually batching (fewer RPCs than
    // elements).
    let d = start_dispatcher();
    let store = ObjectStore::in_memory();
    let spec = generate_vision(
        &store,
        "ds",
        &VisionGenConfig { num_shards: 12, samples_per_shard: 8, ..Default::default() },
    );
    let total = spec.total_samples as u64;
    let _w1 = start_worker(&d, store.clone());
    let _w2 = start_worker(&d, store.clone());
    let _w3 = start_worker(&d, store);

    let graph = PipelineBuilder::source_vision(spec).batch(4).build();
    let client = ServiceClient::new(&d.addr());
    let mut it = client
        .distribute(
            &graph,
            ServiceClientConfig {
                sharding: ShardingPolicy::Dynamic,
                batching: true,
                stream_sessions: false, // old-client <-> new-worker path
                ..Default::default()
            },
        )
        .unwrap();

    let mut tracker = VisitationTracker::new();
    let mut elements = 0u64;
    while let Some(e) = it.next().unwrap() {
        tracker.observe(&e.ids);
        elements += 1;
    }
    assert_eq!(elements, total / 4);
    let report = tracker.verify(Guarantee::ExactlyOnce, total);
    assert!(report.ok, "{report:?}");
    // The batched path was really taken, and it really batched.
    let batched_rpcs = client.metrics().counter("client/batched_rpcs").get();
    assert!(batched_rpcs > 0, "expected GetElements traffic");
    assert!(
        client.metrics().counter("client/elements_fetched").get() >= elements,
        "fetch accounting"
    );
}

#[test]
fn stream_session_path_exactly_once_three_workers() {
    // The canonical stream-session plane end to end: handshake per
    // worker, session-scoped Fetch with adaptive batching, exactly-once
    // under dynamic sharding across three workers.
    let d = start_dispatcher();
    let store = ObjectStore::in_memory();
    let spec = generate_vision(
        &store,
        "ds",
        &VisionGenConfig { num_shards: 12, samples_per_shard: 8, ..Default::default() },
    );
    let total = spec.total_samples as u64;
    let _w1 = start_worker(&d, store.clone());
    let _w2 = start_worker(&d, store.clone());
    let _w3 = start_worker(&d, store);

    let graph = PipelineBuilder::source_vision(spec).batch(4).build();
    let client = ServiceClient::new(&d.addr());
    let mut it = client
        .distribute(
            &graph,
            ServiceClientConfig { sharding: ShardingPolicy::Dynamic, ..Default::default() },
        )
        .unwrap();

    let mut tracker = VisitationTracker::new();
    let mut elements = 0u64;
    while let Some(e) = it.next().unwrap() {
        tracker.observe(&e.ids);
        elements += 1;
    }
    assert_eq!(elements, total / 4);
    let report = tracker.verify(Guarantee::ExactlyOnce, total);
    assert!(report.ok, "{report:?}");
    // The session plane was really taken: one negotiated session per
    // worker, all traffic through Fetch, none through the legacy RPCs.
    assert_eq!(client.metrics().counter("client/stream_sessions").get(), 3);
    assert!(client.metrics().counter("client/fetch_rpcs").get() > 0, "expected Fetch traffic");
    assert_eq!(client.metrics().counter("client/batched_rpcs").get(), 0);
    assert_eq!(client.metrics().counter("client/stream_handshake_downgrades").get(), 0);
}

/// Pattern-fill so reassembly errors (wrong order, duplicated or dropped
/// continuation frames) change the content, not just the length.
fn chunk_pattern(n: usize) -> Vec<u8> {
    let mut v = vec![0u8; n];
    for (i, chunk) in v.chunks_mut(4096).enumerate() {
        chunk.fill((i % 251) as u8);
    }
    v
}

#[test]
fn oversized_element_roundtrips_via_chunked_transfer() {
    // Acceptance: an element whose encoding exceeds the 64 MiB transport
    // frame cap round-trips losslessly as continuation frames. Before
    // the stream-session redesign this element was silently skipped
    // (cursor advanced before the over-cap write killed the connection).
    use tfdatasvc::data::element::{DType, Tensor};

    let d = start_dispatcher();
    let store = ObjectStore::in_memory();
    let udfs = UdfRegistry::with_builtins();
    // Row 1 inflates to > MAX_FRAME_LEN; rows 0 and 2 stay tiny, so the
    // stream exercises normal -> chunked -> normal transitions.
    let big_len: usize = 68 << 20; // 68 MiB > 64 MiB cap
    udfs.register_fn("test.inflate_middle", move |e| {
        if e.ids == [1] {
            Ok(tfdatasvc::data::Element::with_ids(
                vec![Tensor::new(DType::U8, vec![big_len], chunk_pattern(big_len))],
                e.ids.clone(),
            ))
        } else {
            Ok(e)
        }
    });
    let mut cfg = WorkerConfig::new(store, udfs);
    // The oversized element alone exceeds the default 64 MiB window byte
    // budget; give the window headroom so the two small neighbors are not
    // evicted (relaxed-visitation skipped) before the fetcher starts.
    cfg.cache_window_bytes = 256 << 20;
    let w = Worker::start("127.0.0.1:0", &d.addr(), cfg).unwrap();

    let graph = PipelineBuilder::source_range(3).map("test.inflate_middle").build();
    let client = ServiceClient::new(&d.addr());
    let mut it = client
        .distribute(
            &graph,
            ServiceClientConfig { sharding: ShardingPolicy::Dynamic, ..Default::default() },
        )
        .unwrap();

    let mut ids = Vec::new();
    let mut big_seen = 0;
    while let Some(e) = it.next().unwrap() {
        ids.extend(e.ids.clone());
        if e.ids == [1] {
            big_seen += 1;
            assert_eq!(e.tensors[0].data.len(), big_len);
            assert_eq!(e.tensors[0].data, chunk_pattern(big_len), "lossless reassembly");
        }
    }
    ids.sort_unstable();
    assert_eq!(ids, vec![0, 1, 2], "nothing skipped, nothing duplicated");
    assert_eq!(big_seen, 1);
    // It really went through the chunked path, in several frames.
    assert_eq!(client.metrics().counter("client/chunked_elements_fetched").get(), 1);
    assert!(client.metrics().counter("client/chunk_frames").get() >= 2);
    assert_eq!(w.metrics().counter("worker/chunked_elements_served").get(), 1);
}

#[test]
fn legacy_batched_client_gets_explicit_too_large_error() {
    // Satellite: the legacy GetElements plane cannot chunk, so an
    // over-cap element must surface an explicit `element too large`
    // error (cursor untouched server-side) instead of silently skipping.
    use tfdatasvc::data::element::{DType, Tensor};

    let d = start_dispatcher();
    let store = ObjectStore::in_memory();
    let udfs = UdfRegistry::with_builtins();
    let big_len: usize = 33 << 20; // > MAX_FRAME_LEN / 2 legacy budget
    udfs.register_fn("test.inflate", move |e| {
        Ok(tfdatasvc::data::Element::with_ids(
            vec![Tensor::new(DType::U8, vec![big_len], vec![7u8; big_len])],
            e.ids.clone(),
        ))
    });
    let cfg = WorkerConfig::new(store, udfs);
    let _w = Worker::start("127.0.0.1:0", &d.addr(), cfg).unwrap();

    let graph = PipelineBuilder::source_range(1).map("test.inflate").build();
    let client = ServiceClient::new(&d.addr());
    let mut it = client
        .distribute(
            &graph,
            ServiceClientConfig {
                sharding: ShardingPolicy::Dynamic,
                stream_sessions: false, // legacy client
                batching: true,
                ..Default::default()
            },
        )
        .unwrap();
    match it.next() {
        Err(e) => {
            let msg = e.to_string();
            assert!(msg.contains("element too large"), "explicit error, got: {msg}");
        }
        other => panic!("expected an explicit element-too-large error, got {other:?}"),
    }
}

#[test]
fn batched_path_worker_crash_keeps_relaxed_guarantee() {
    // Killing a worker mid-epoch under the batched plane must still
    // satisfy at-most-once: in-flight splits die with the worker, nothing
    // is duplicated.
    let d = start_dispatcher();
    let store = ObjectStore::in_memory();
    let spec = generate_vision(
        &store,
        "ds",
        &VisionGenConfig { num_shards: 16, samples_per_shard: 4, ..Default::default() },
    );
    let total = spec.total_samples as u64;
    let w1 = start_worker(&d, store.clone());
    let _w2 = start_worker(&d, store);

    let graph = PipelineBuilder::source_vision(spec)
        .map("synthetic.burn:3000") // slow production so the kill lands mid-stream
        .batch(4)
        .build();
    let client = ServiceClient::new(&d.addr());
    let mut it = client
        .distribute(
            &graph,
            ServiceClientConfig {
                sharding: ShardingPolicy::Dynamic,
                batching: true,
                // Small batches so the crash interleaves with fetching.
                batch_max_elements: 2,
                ..Default::default()
            },
        )
        .unwrap();

    let mut tracker = VisitationTracker::new();
    let mut consumed = 0;
    while let Some(e) = it.next().unwrap() {
        tracker.observe(&e.ids);
        consumed += 1;
        if consumed == 2 {
            w1.shutdown(); // preempt one worker mid-stream
        }
    }
    let report = tracker.verify(Guarantee::AtMostOnce, total);
    assert!(report.ok, "{report:?}");
    assert!(report.unique_seen > 0);
}

#[test]
fn dispatcher_restart_replays_journal_and_named_job_survives() {
    // §3.4: the dispatcher journals every state change; a restarted
    // dispatcher replays it, so a named (shared) job keeps its identity
    // and a fresh client can attach and drain the whole dataset.
    let dir = std::env::temp_dir().join(format!("tfdatasvc-e2e-journal-{}", std::process::id()));
    let jpath = dir.join("journal");
    let _ = std::fs::remove_file(&jpath);
    let cfg = DispatcherConfig { journal_path: Some(jpath.clone()), ..Default::default() };

    let store = ObjectStore::in_memory();
    let spec = generate_vision(
        &store,
        "ds",
        &VisionGenConfig { num_shards: 4, samples_per_shard: 8, ..Default::default() },
    );
    let total = spec.total_samples as u64;
    let graph = PipelineBuilder::source_vision(spec).batch(4).build();

    let mk_cfg = || ServiceClientConfig {
        sharding: ShardingPolicy::Dynamic,
        job_name: "persistent-e2e".into(),
        ..Default::default()
    };

    // First incarnation: create the named job (no workers yet — the
    // journal records metadata, not data-plane state).
    let d1 = Dispatcher::start("127.0.0.1:0", cfg.clone()).unwrap();
    let c1 = ServiceClient::new(&d1.addr());
    let it1 = c1.distribute(&graph, mk_cfg()).unwrap();
    let job_id = it1.job_id();
    drop(d1); // dispatcher crash

    // Second incarnation replays the journal.
    let d2 = Dispatcher::start("127.0.0.1:0", cfg).unwrap();
    let c2 = ServiceClient::new(&d2.addr());
    let mut it2 = c2.distribute(&graph, mk_cfg()).unwrap();
    assert_eq!(it2.job_id(), job_id, "named job survived the restart");

    // The replayed job is live, not a tombstone: a worker joining the new
    // dispatcher receives its task and serves the full epoch.
    let _w = start_worker(&d2, store);
    let mut tracker = VisitationTracker::new();
    while let Some(e) = it2.next().unwrap() {
        tracker.observe(&e.ids);
    }
    let report = tracker.verify(Guarantee::ExactlyOnce, total);
    assert!(report.ok, "{report:?}");

    drop(it1); // releases against the dead dispatcher are best-effort
    std::fs::remove_file(&jpath).ok();
}

#[test]
fn single_element_path_still_works_for_old_clients() {
    // Backward compatibility: batching=false + stream_sessions=false is
    // the oldest client shape — one element per GetElement RPC, no
    // handshake — and must drain a full epoch with the same guarantee
    // against a session-enabled worker (the RPC is a shim over the same
    // serve machinery).
    let d = start_dispatcher();
    let store = ObjectStore::in_memory();
    let spec = generate_vision(
        &store,
        "ds",
        &VisionGenConfig { num_shards: 4, samples_per_shard: 8, ..Default::default() },
    );
    let total = spec.total_samples as u64;
    let _w = start_worker(&d, store);

    let graph = PipelineBuilder::source_vision(spec).batch(4).build();
    let client = ServiceClient::new(&d.addr());
    let mut it = client
        .distribute(
            &graph,
            ServiceClientConfig {
                sharding: ShardingPolicy::Dynamic,
                batching: false,
                stream_sessions: false,
                ..Default::default()
            },
        )
        .unwrap();
    let mut tracker = VisitationTracker::new();
    while let Some(e) = it.next().unwrap() {
        tracker.observe(&e.ids);
    }
    let report = tracker.verify(Guarantee::ExactlyOnce, total);
    assert!(report.ok, "{report:?}");
    assert_eq!(client.metrics().counter("client/batched_rpcs").get(), 0);
    assert_eq!(client.metrics().counter("client/fetch_rpcs").get(), 0);
}

#[test]
fn dispatcher_is_not_on_the_data_path() {
    // §3.1: the dispatcher performs no data processing — it does not even
    // implement the GetElement method; element bytes flow client<->worker.
    use tfdatasvc::rpc::Pool;
    use tfdatasvc::service::proto::{worker_methods, CompressionMode, GetElementReq};
    use tfdatasvc::wire::Encode;
    let d = start_dispatcher();
    let pool = Pool::with_defaults();
    let req = GetElementReq {
        job_id: 1,
        client_id: 1,
        consumer_index: None,
        round: None,
        compression: CompressionMode::None,
    };
    let resp = pool.call(
        &d.addr(),
        worker_methods::GET_ELEMENT,
        &req.to_bytes(),
        Duration::from_secs(2),
    );
    match resp {
        Err(tfdatasvc::rpc::RpcError::Remote(msg)) => {
            assert!(msg.contains("unknown method"), "{msg}");
        }
        other => panic!("dispatcher must reject data-path RPCs, got {other:?}"),
    }
}

#[test]
fn overload_shed_is_retryable_and_lossless() {
    // Admission control: once the unfinished-job budget is spent, job
    // *creation* is shed with a retryable error carrying a backoff hint;
    // the client-side retry loop absorbs the shed window losslessly.
    use tfdatasvc::rpc::{call_typed, Pool, RpcError};
    use tfdatasvc::service::proto::{
        dispatcher_methods, GetOrCreateJobReq, GetOrCreateJobResp, RegisterDatasetReq,
        RegisterDatasetResp,
    };
    use tfdatasvc::service::OVERLOADED_PREFIX;

    let d = Dispatcher::start(
        "127.0.0.1:0",
        DispatcherConfig { admission_max_jobs: 1, admission_retry_ms: 20, ..Default::default() },
    )
    .unwrap();
    let _w = start_worker(&d, ObjectStore::in_memory());

    // First anonymous job spends the whole budget while it stays live.
    let holder = ServiceClient::new(&d.addr());
    let mut hold = holder
        .distribute(&PipelineBuilder::source_range(8).build(), ServiceClientConfig::default())
        .unwrap();

    // A raw GetOrCreateJob for a different pipeline must be shed with the
    // configured retry hint (attaches are exempt; creation is not).
    let pool = Pool::with_defaults();
    let reg: RegisterDatasetResp = call_typed(
        &pool,
        &d.addr(),
        dispatcher_methods::REGISTER_DATASET,
        &RegisterDatasetReq {
            graph: PipelineBuilder::source_range(9).build(),
            udf_digests: Vec::new(),
        },
        common::T,
    )
    .unwrap();
    let shed: Result<GetOrCreateJobResp, RpcError> = call_typed(
        &pool,
        &d.addr(),
        dispatcher_methods::GET_OR_CREATE_JOB,
        &GetOrCreateJobReq {
            dataset_id: reg.dataset_id,
            job_name: String::new(),
            sharding: ShardingPolicy::Off,
            mode: ProcessingMode::Independent,
            num_consumers: 0,
            sharing: SharingMode::Off,
        },
        common::T,
    );
    match shed {
        Err(RpcError::Remote(msg)) => {
            assert!(msg.contains(OVERLOADED_PREFIX), "{msg}");
            assert!(msg.contains("retry after 20 ms"), "{msg}");
        }
        other => panic!("expected overload shed, got {other:?}"),
    }
    assert!(d.metrics().counter("dispatcher/jobs_shed").get() >= 1);

    // Free the budget shortly after the retry loop starts spinning.
    let freer = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(250));
        hold.release();
    });

    // distribute() blocks through jittered retries until admitted, then
    // the job must still see every element exactly once.
    let client = ServiceClient::new(&d.addr());
    let mut it = client
        .distribute(&PipelineBuilder::source_range(9).build(), ServiceClientConfig::default())
        .unwrap();
    let mut tracker = VisitationTracker::new();
    while let Some(e) = it.next().unwrap() {
        tracker.observe(&e.ids);
    }
    let report = tracker.verify(Guarantee::ExactlyOnce, 9);
    assert!(report.ok, "{report:?}");
    assert!(
        client.metrics().counter("client/admission_retries").get() >= 1,
        "expected at least one client-side admission retry"
    );
    freer.join().unwrap();
}
