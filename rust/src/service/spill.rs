//! Spill-to-store sharing tier (§3.5 window × §4.2 cross-region store).
//!
//! The RAM sharing window ([`super::worker`]'s `SlidingCache`) is the
//! paper's ephemeral cache: once an element is evicted it is gone, so a
//! laggard or late fingerprint attacher can only *skip* (relaxed
//! visitation). This module makes eviction a tiering decision instead of
//! a discard: evicted-but-wanted elements are appended as encoded
//! **segments** to [`ObjectStore`] under a per-job key prefix, described
//! by a [`SpillManifest`] (fingerprint, epoch, per-segment sequence
//! range + CRC-32). The worker serve path then falls back
//! RAM → spill → skip, and a completed epoch's manifest doubles as a
//! **fingerprint-keyed snapshot** the dispatcher can hand to a
//! re-submitted identical pipeline, which streams the stored segments
//! (paying [`crate::storage::NetModel`] read costs when the store is
//! remote) instead of re-running the pipeline.
//!
//! Layout in the store, one data object + one manifest object per job:
//!
//! ```text
//! spill/job-{id}/data       append-only; concatenated segment bodies
//! spill/job-{id}/manifest   SpillManifest, rewritten after every flush
//! ```
//!
//! A segment body is `u32 element-count` followed by that many
//! length-prefixed encoded elements; its manifest entry records the
//! `(offset, len)` range inside the data object, the first sequence
//! number, and a CRC-32 over the body. Because the manifest is persisted
//! after every segment flush, a worker crash loses at most the unflushed
//! pending buffer — the flushed prefix stays readable by a replacement
//! worker ([`JobSpill::adopt_existing`]) and, after the dispatcher
//! merges per-worker manifests, by snapshot readers.

use std::sync::atomic::AtomicBool;
use std::sync::{Arc, Mutex};

use crate::metrics::{Counter, Registry};
use crate::storage::{ObjectStore, Region, StorageError, StorageResult};
use crate::util::crc32::Hasher;
use crate::wire::{Decode, Encode, Reader, Writer};
use crate::wire_struct;

/// What the window does with an element it evicts from RAM.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpillPolicy {
    /// No spill tier; eviction discards (the pre-spill behavior).
    Off,
    /// Spill only elements some registered cursor has not yet consumed,
    /// i.e. a laggard's un-replayed range. Cheapest; no snapshots.
    Wanted,
    /// Spill every produced element, so a late attacher can replay the
    /// full epoch and a completed epoch can be committed as a
    /// fingerprint-keyed snapshot.
    All,
}

/// Worker-side spill configuration (carried on `WorkerConfig`).
#[derive(Debug, Clone)]
pub struct SpillConfig {
    pub policy: SpillPolicy,
    /// Flush threshold: pending evicted bytes before a segment is cut.
    pub segment_bytes: usize,
}

impl Default for SpillConfig {
    fn default() -> SpillConfig {
        SpillConfig { policy: SpillPolicy::Off, segment_bytes: 256 << 10 }
    }
}

/// One flushed segment: a contiguous run of elements inside the per-job
/// data object.
#[derive(Debug, Clone, PartialEq)]
pub struct SegmentMeta {
    /// Data object this segment lives in (`spill/job-{id}/data`).
    pub key: String,
    /// Byte offset of the segment body inside the data object.
    pub offset: u64,
    /// Byte length of the segment body.
    pub len: u64,
    /// Sequence number of the first element in the segment.
    pub start_seq: u64,
    /// Number of elements in the segment (contiguous from `start_seq`).
    pub num_elements: u32,
    /// CRC-32 over the segment body; verified on every read.
    pub crc32: u32,
}

wire_struct!(SegmentMeta { key, offset, len, start_seq, num_elements, crc32 });

/// The durable description of a job's spilled output. Per-worker while
/// the job runs; the dispatcher merges worker manifests into one
/// fingerprint-keyed snapshot manifest at epoch completion.
#[derive(Debug, Clone, PartialEq)]
pub struct SpillManifest {
    /// Structural pipeline fingerprint (= dataset id) the data was
    /// produced from; the snapshot lookup key.
    pub fingerprint: u64,
    /// Producing job (worker manifests) or 0-padded merge parent.
    pub job_id: u64,
    /// Snapshot epoch: bumped by the dispatcher each time the same
    /// fingerprint commits again.
    pub epoch: u64,
    /// Total elements across all segments.
    pub total_elements: u64,
    /// True once the producing stream reached EOS and the tail was
    /// flushed; only complete manifests are merged into snapshots.
    pub complete: bool,
    pub segments: Vec<SegmentMeta>,
}

wire_struct!(SpillManifest { fingerprint, job_id, epoch, total_elements, complete, segments });

impl SpillManifest {
    /// Sequence number one past the last spilled element (0 when empty).
    pub fn end_seq(&self) -> u64 {
        self.segments
            .last()
            .map(|s| s.start_seq + s.num_elements as u64)
            .unwrap_or(0)
    }
}

/// Result of a spill-tier range read (see [`JobSpill::read_range`]).
#[derive(Debug)]
pub enum SpillRead {
    /// Elements decoded from spill. `next` is the cursor after the
    /// batch; `skipped` counts sequence numbers inside the requested
    /// range that are not in the tier (never written under
    /// [`SpillPolicy::Wanted`], or lost to a failed segment read) and
    /// were jumped over.
    Batch { batch: Vec<Arc<Vec<u8>>>, next: u64, skipped: u64 },
    /// The element at `seq` alone exceeds the session's hard frame cap
    /// and must go through the chunked path.
    Oversized { bytes: Arc<Vec<u8>>, seq: u64, skipped: u64 },
}

#[derive(Default)]
struct SpillInner {
    /// Evicted elements not yet flushed as a segment.
    pending: Vec<Arc<Vec<u8>>>,
    /// Sequence number of `pending[0]` (meaningless when empty).
    pending_start: u64,
    pending_bytes: usize,
    /// Flushed segments, ordered by `start_seq` (strictly increasing,
    /// possibly with gaps under [`SpillPolicy::Wanted`]).
    segments: Vec<SegmentMeta>,
    total_elements: u64,
    epoch: u64,
    complete: bool,
    /// Decoded elements of the most recently read segment, so a batch
    /// replay does one store read per segment, not per element. An
    /// empty Vec marks a segment whose read failed (a real segment is
    /// never empty), so corrupt segments are not re-fetched per element.
    read_cache: Option<(usize, Vec<Arc<Vec<u8>>>)>,
}

/// Per-job spill state: the write path (eviction → pending → segment)
/// and the read path (sequence → segment → decoded element).
pub struct JobSpill {
    store: Arc<ObjectStore>,
    region: Region,
    pub policy: SpillPolicy,
    segment_bytes: usize,
    job_id: u64,
    fingerprint: u64,
    data_key: String,
    manifest_key: String,
    state: Mutex<SpillInner>,
    /// Set once the dispatcher acknowledged this job's complete
    /// manifest, stopping heartbeat re-reports.
    pub acked: AtomicBool,
    segments_ctr: Arc<Counter>,
    elements_ctr: Arc<Counter>,
    read_failures_ctr: Arc<Counter>,
}

impl JobSpill {
    pub fn new(
        store: Arc<ObjectStore>,
        region: Region,
        cfg: &SpillConfig,
        job_id: u64,
        fingerprint: u64,
        metrics: &Registry,
    ) -> Arc<JobSpill> {
        Arc::new(JobSpill {
            store,
            region,
            policy: cfg.policy,
            segment_bytes: cfg.segment_bytes.max(1),
            job_id,
            fingerprint,
            data_key: data_key(job_id),
            manifest_key: manifest_key(job_id),
            state: Mutex::new(SpillInner::default()),
            acked: AtomicBool::new(false),
            segments_ctr: metrics.counter("worker/spill_segments_written"),
            elements_ctr: metrics.counter("worker/spill_elements_written"),
            read_failures_ctr: metrics.counter("worker/spill_segment_read_failures"),
        })
    }

    pub fn job_id(&self) -> u64 {
        self.job_id
    }

    /// Offer an evicted element to the tier. Sequence numbers at or
    /// past the current spill end are buffered (a gap closes the open
    /// segment first, keeping every segment seq-contiguous); numbers
    /// below it are already durable — a replacement worker re-producing
    /// an adopted prefix deterministically just skips them.
    pub fn offer(&self, seq: u64, bytes: Arc<Vec<u8>>) {
        let mut st = self.state.lock().unwrap();
        if st.complete || seq < end_of(&st) {
            return;
        }
        if !st.pending.is_empty() && seq != st.pending_start + st.pending.len() as u64 {
            self.flush_locked(&mut st);
        }
        if st.pending.is_empty() {
            st.pending_start = seq;
        }
        st.pending_bytes += bytes.len();
        st.pending.push(bytes);
        if st.pending_bytes >= self.segment_bytes {
            self.flush_locked(&mut st);
        }
    }

    fn flush_locked(&self, st: &mut SpillInner) {
        if st.pending.is_empty() {
            return;
        }
        let mut w = Writer::new();
        w.put_u32(st.pending.len() as u32);
        for e in &st.pending {
            w.put_bytes(e);
        }
        let body = w.into_bytes();
        let mut h = Hasher::new();
        h.update(&body);
        let crc32 = h.finalize();
        let offset = self.store.append(&self.data_key, &body);
        st.segments.push(SegmentMeta {
            key: self.data_key.clone(),
            offset,
            len: body.len() as u64,
            start_seq: st.pending_start,
            num_elements: st.pending.len() as u32,
            crc32,
        });
        st.total_elements += st.pending.len() as u64;
        self.segments_ctr.inc();
        self.elements_ctr.add(st.pending.len() as u64);
        st.pending.clear();
        st.pending_bytes = 0;
        // Committed prefix: persist the manifest after every segment so
        // a crash loses only the pending buffer.
        self.store.put(&self.manifest_key, self.manifest_locked(st).to_bytes());
    }

    fn manifest_locked(&self, st: &SpillInner) -> SpillManifest {
        SpillManifest {
            fingerprint: self.fingerprint,
            job_id: self.job_id,
            epoch: st.epoch,
            total_elements: st.total_elements,
            complete: st.complete,
            segments: st.segments.clone(),
        }
    }

    /// Current manifest (flushed segments only).
    pub fn manifest(&self) -> SpillManifest {
        self.manifest_locked(&self.state.lock().unwrap())
    }

    /// Force-flush the pending buffer as a segment (and persist the
    /// manifest) without closing the stream. A draining worker calls
    /// this before acking a round-lease revocation: everything it
    /// buffered becomes durable, so nothing is lost when the worker is
    /// removed mid-stream. No-op when the buffer is empty or the stream
    /// already finalized.
    pub fn flush_pending(&self) {
        let mut st = self.state.lock().unwrap();
        if !st.complete {
            self.flush_locked(&mut st);
        }
    }

    /// Close the stream: flush the pending tail and persist the
    /// manifest as complete. Idempotent.
    pub fn finalize(&self) -> SpillManifest {
        let mut st = self.state.lock().unwrap();
        if !st.complete {
            self.flush_locked(&mut st);
            st.complete = true;
            let m = self.manifest_locked(&st);
            self.store.put(&self.manifest_key, m.to_bytes());
            return m;
        }
        self.manifest_locked(&st)
    }

    pub fn is_complete(&self) -> bool {
        self.state.lock().unwrap().complete
    }

    /// Lowest spilled sequence number, if any.
    pub fn floor(&self) -> Option<u64> {
        let st = self.state.lock().unwrap();
        st.segments
            .first()
            .map(|s| s.start_seq)
            .or_else(|| (!st.pending.is_empty()).then_some(st.pending_start))
    }

    /// Whether `seq` falls inside the tier's spilled span. A `true`
    /// answer is a *maybe* under [`SpillPolicy::Wanted`] (gaps), which
    /// `read_range` reports as skips.
    pub fn may_cover(&self, seq: u64) -> bool {
        let st = self.state.lock().unwrap();
        let lo = st
            .segments
            .first()
            .map(|s| s.start_seq)
            .or_else(|| (!st.pending.is_empty()).then_some(st.pending_start));
        match lo {
            Some(lo) => seq >= lo && seq < end_of(&st),
            None => false,
        }
    }

    /// Adopt a predecessor's committed prefix: a replacement worker for
    /// the same job reads the persisted manifest so the flushed
    /// segments survive the crash. Its own (deterministic) reproduction
    /// then re-offers sequence numbers below the adopted end, which
    /// `offer` skips. Returns the number of adopted segments.
    pub fn adopt_existing(&self) -> usize {
        let Ok(bytes) = self.store.get_from(&self.region, &self.manifest_key) else {
            return 0;
        };
        let Ok(m) = SpillManifest::from_bytes(&bytes) else {
            return 0;
        };
        let mut st = self.state.lock().unwrap();
        if !st.segments.is_empty() || !st.pending.is_empty() {
            return 0;
        }
        let n = m.segments.len();
        st.segments = m.segments;
        st.total_elements = m.total_elements;
        st.epoch = m.epoch;
        st.complete = m.complete;
        n
    }

    /// Replay `[from, to)` from the tier, honoring the serve path's
    /// byte budget (`max_bytes`) and per-frame hard cap. Always makes
    /// progress when `from < to`: either ≥ 1 element is returned, an
    /// oversized element is surfaced for the chunked path, or ≥ 1
    /// missing sequence number is skipped.
    pub fn read_range(&self, from: u64, to: u64, max_bytes: usize, hard_cap: usize) -> SpillRead {
        let mut batch: Vec<Arc<Vec<u8>>> = Vec::new();
        let mut bytes_out = 0usize;
        let mut skipped = 0u64;
        let mut seq = from;
        while seq < to {
            match self.element_at(seq) {
                Some(e) => {
                    if e.len() > hard_cap && batch.is_empty() {
                        return SpillRead::Oversized { bytes: e, seq, skipped };
                    }
                    if !batch.is_empty() && (e.len() > hard_cap || bytes_out + e.len() > max_bytes)
                    {
                        break;
                    }
                    bytes_out += e.len();
                    batch.push(e);
                    seq += 1;
                }
                None => {
                    if !batch.is_empty() {
                        // Deliver what we have; the gap is the next
                        // call's first (empty-batch) step.
                        break;
                    }
                    skipped += 1;
                    seq += 1;
                }
            }
        }
        SpillRead::Batch { batch, next: seq, skipped }
    }

    fn element_at(&self, seq: u64) -> Option<Arc<Vec<u8>>> {
        let mut st = self.state.lock().unwrap();
        if !st.pending.is_empty() && seq >= st.pending_start {
            return st.pending.get((seq - st.pending_start) as usize).cloned();
        }
        let idx = st
            .segments
            .binary_search_by(|s| {
                if seq < s.start_seq {
                    std::cmp::Ordering::Greater
                } else if seq >= s.start_seq + s.num_elements as u64 {
                    std::cmp::Ordering::Less
                } else {
                    std::cmp::Ordering::Equal
                }
            })
            .ok()?;
        if st.read_cache.as_ref().map(|(i, _)| *i != idx).unwrap_or(true) {
            let seg = st.segments[idx].clone();
            let elems = match read_segment(&self.store, &self.region, &seg) {
                Ok(v) => v,
                Err(_) => {
                    self.read_failures_ctr.inc();
                    Vec::new()
                }
            };
            st.read_cache = Some((idx, elems));
        }
        let (_, elems) = st.read_cache.as_ref().unwrap();
        let off = (seq - st.segments[idx].start_seq) as usize;
        elems.get(off).cloned()
    }
}

fn end_of(st: &SpillInner) -> u64 {
    let seg_end = st
        .segments
        .last()
        .map(|s| s.start_seq + s.num_elements as u64)
        .unwrap_or(0);
    let pend_end = if st.pending.is_empty() {
        0
    } else {
        st.pending_start + st.pending.len() as u64
    };
    seg_end.max(pend_end)
}

/// Store key of a job's append-only segment data object.
pub fn data_key(job_id: u64) -> String {
    format!("spill/job-{job_id}/data")
}

/// Store key of a job's manifest object.
pub fn manifest_key(job_id: u64) -> String {
    format!("spill/job-{job_id}/manifest")
}

/// Read one segment's byte range and decode its elements, verifying
/// the manifest CRC before trusting the bytes. Shared by the laggard
/// replay path and the snapshot streamer.
pub fn read_segment(
    store: &ObjectStore,
    reader_region: &Region,
    seg: &SegmentMeta,
) -> StorageResult<Vec<Arc<Vec<u8>>>> {
    let body = store.read_range_from(reader_region, &seg.key, seg.offset, seg.len)?;
    let mut h = Hasher::new();
    h.update(&body);
    let crc = h.finalize();
    if crc != seg.crc32 {
        return Err(StorageError::Corrupt(format!(
            "segment {}@{}+{}: crc {crc:#010x} != manifest {:#010x}",
            seg.key, seg.offset, seg.len, seg.crc32
        )));
    }
    let mut r = Reader::new(&body);
    let n = r
        .get_u32()
        .map_err(|e| StorageError::Corrupt(format!("segment header: {e}")))?
        as usize;
    if n != seg.num_elements as usize {
        return Err(StorageError::Corrupt(format!(
            "segment {}@{}: {n} elements != manifest {}",
            seg.key, seg.offset, seg.num_elements
        )));
    }
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(Arc::new(
            r.get_bytes()
                .map_err(|e| StorageError::Corrupt(format!("segment element: {e}")))?,
        ));
    }
    Ok(out)
}

/// Merge complete per-worker manifests into one snapshot manifest.
/// Segments are concatenated in the given (worker-order) sequence and
/// renumbered into one contiguous snapshot sequence space — the
/// snapshot's element order interleaves workers in worker order, which
/// is a valid (deterministic) epoch order for an unordered dataset.
pub fn merge_manifests(
    fingerprint: u64,
    job_id: u64,
    epoch: u64,
    parts: &[SpillManifest],
) -> SpillManifest {
    let mut segments = Vec::new();
    let mut next_seq = 0u64;
    for part in parts {
        for seg in &part.segments {
            let mut seg = seg.clone();
            seg.start_seq = next_seq;
            next_seq += seg.num_elements as u64;
            segments.push(seg);
        }
    }
    SpillManifest {
        fingerprint,
        job_id,
        epoch,
        total_elements: next_seq,
        complete: true,
        segments,
    }
}

/// The slice of a snapshot manifest one worker serves: segments are
/// striped round-robin (`i % num_workers == worker_index`) and
/// renumbered contiguously so the worker's stream is dense from 0. A
/// worker index past `num_workers` (late registration) gets an empty
/// manifest and serves immediate EOS — no duplicated segments.
pub fn partition_manifest(
    m: &SpillManifest,
    worker_index: usize,
    num_workers: usize,
) -> SpillManifest {
    let nw = num_workers.max(1);
    let mut segments = Vec::new();
    let mut next_seq = 0u64;
    if worker_index < nw {
        for (i, seg) in m.segments.iter().enumerate() {
            if i % nw == worker_index {
                let mut seg = seg.clone();
                seg.start_seq = next_seq;
                next_seq += seg.num_elements as u64;
                segments.push(seg);
            }
        }
    }
    SpillManifest {
        fingerprint: m.fingerprint,
        job_id: m.job_id,
        epoch: m.epoch,
        total_elements: next_seq,
        complete: true,
        segments,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::NetModel;

    fn elem(tag: u8, len: usize) -> Arc<Vec<u8>> {
        Arc::new(vec![tag; len])
    }

    fn spill_with(policy: SpillPolicy, segment_bytes: usize) -> (Arc<ObjectStore>, Arc<JobSpill>) {
        let store = ObjectStore::in_memory();
        let cfg = SpillConfig { policy, segment_bytes };
        let spill = JobSpill::new(
            store.clone(),
            store.region().clone(),
            &cfg,
            7,
            0xfeed,
            &Registry::new(),
        );
        (store, spill)
    }

    #[test]
    fn offer_flush_read_roundtrip() {
        let (_store, sp) = spill_with(SpillPolicy::All, 8);
        for i in 0..10u64 {
            sp.offer(i, elem(i as u8, 4));
        }
        let m = sp.finalize();
        assert!(m.complete);
        assert_eq!(m.total_elements, 10);
        assert_eq!(m.end_seq(), 10);
        assert!(m.segments.len() >= 2, "8-byte budget must cut segments");
        match sp.read_range(0, 10, usize::MAX, usize::MAX) {
            SpillRead::Batch { batch, next, skipped } => {
                assert_eq!(next, 10);
                assert_eq!(skipped, 0);
                assert_eq!(batch.len(), 10);
                for (i, b) in batch.iter().enumerate() {
                    assert_eq!(**b, vec![i as u8; 4]);
                }
            }
            other => panic!("unexpected: {other:?}"),
        }
    }

    #[test]
    fn pending_tail_served_before_flush() {
        let (_store, sp) = spill_with(SpillPolicy::All, 1 << 20);
        sp.offer(3, elem(3, 4));
        sp.offer(4, elem(4, 4));
        assert_eq!(sp.floor(), Some(3));
        assert!(sp.may_cover(4));
        assert!(!sp.may_cover(5));
        match sp.read_range(3, 5, usize::MAX, usize::MAX) {
            SpillRead::Batch { batch, next, skipped } => {
                assert_eq!((batch.len(), next, skipped), (2, 5, 0));
            }
            other => panic!("unexpected: {other:?}"),
        }
    }

    #[test]
    fn gap_closes_segment_and_reads_skip() {
        let (_store, sp) = spill_with(SpillPolicy::Wanted, 1 << 20);
        sp.offer(0, elem(0, 4));
        sp.offer(1, elem(1, 4));
        sp.offer(5, elem(5, 4)); // gap: 2..5 never spilled
        sp.finalize();
        let m = sp.manifest();
        assert_eq!(m.segments.len(), 2);
        assert_eq!(m.segments[1].start_seq, 5);
        match sp.read_range(0, 6, usize::MAX, usize::MAX) {
            SpillRead::Batch { batch, next, skipped } => {
                assert_eq!((batch.len(), next, skipped), (2, 2, 0));
            }
            other => panic!("unexpected: {other:?}"),
        }
        // Next call starts at the gap: skips 2..5, serves 5.
        match sp.read_range(2, 6, usize::MAX, usize::MAX) {
            SpillRead::Batch { batch, next, skipped } => {
                assert_eq!((batch.len(), next, skipped), (1, 6, 3));
                assert_eq!(*batch[0], vec![5; 4]);
            }
            other => panic!("unexpected: {other:?}"),
        }
    }

    #[test]
    fn duplicate_and_stale_offers_ignored() {
        let (_store, sp) = spill_with(SpillPolicy::All, 1 << 20);
        sp.offer(0, elem(0, 4));
        sp.offer(1, elem(1, 4));
        sp.offer(0, elem(9, 4)); // re-produced prefix after adoption
        sp.offer(1, elem(9, 4));
        sp.offer(2, elem(2, 4));
        let m = sp.finalize();
        assert_eq!(m.total_elements, 3);
        match sp.read_range(0, 3, usize::MAX, usize::MAX) {
            SpillRead::Batch { batch, .. } => {
                assert_eq!(*batch[0], vec![0; 4]);
                assert_eq!(*batch[1], vec![1; 4]);
            }
            other => panic!("unexpected: {other:?}"),
        }
    }

    #[test]
    fn oversized_element_surfaced() {
        let (_store, sp) = spill_with(SpillPolicy::All, 1 << 20);
        sp.offer(0, elem(1, 100));
        sp.offer(1, elem(2, 4));
        sp.finalize();
        match sp.read_range(0, 2, usize::MAX, 10) {
            SpillRead::Oversized { bytes, seq, skipped } => {
                assert_eq!((bytes.len(), seq, skipped), (100, 0, 0));
            }
            other => panic!("unexpected: {other:?}"),
        }
        // Byte budget caps the batch without stalling.
        match sp.read_range(1, 2, 2, usize::MAX) {
            SpillRead::Batch { batch, next, .. } => {
                assert_eq!((batch.len(), next), (1, 2));
            }
            other => panic!("unexpected: {other:?}"),
        }
    }

    #[test]
    fn adopt_existing_recovers_committed_prefix() {
        let store = ObjectStore::in_memory();
        let cfg = SpillConfig { policy: SpillPolicy::All, segment_bytes: 8 };
        let reg = Registry::new();
        let sp =
            JobSpill::new(store.clone(), store.region().clone(), &cfg, 9, 0xabc, &reg);
        for i in 0..6u64 {
            sp.offer(i, elem(i as u8, 4));
        }
        // Crash before finalize: flushed segments + manifest survive,
        // the pending tail (if any) is lost.
        let committed = sp.manifest();
        drop(sp);
        let sp2 =
            JobSpill::new(store.clone(), store.region().clone(), &cfg, 9, 0xabc, &reg);
        let adopted = sp2.adopt_existing();
        assert_eq!(adopted, committed.segments.len());
        assert!(adopted > 0);
        assert_eq!(sp2.manifest().total_elements, committed.total_elements);
        // Deterministic re-production re-offers the prefix: ignored.
        for i in 0..8u64 {
            sp2.offer(i, elem(i as u8, 4));
        }
        let m = sp2.finalize();
        assert_eq!(m.total_elements, 8);
        match sp2.read_range(0, 8, usize::MAX, usize::MAX) {
            SpillRead::Batch { batch, next, skipped } => {
                assert_eq!((batch.len(), next, skipped), (8, 8, 0));
                for (i, b) in batch.iter().enumerate() {
                    assert_eq!(**b, vec![i as u8; 4]);
                }
            }
            other => panic!("unexpected: {other:?}"),
        }
    }

    #[test]
    fn corrupt_segment_detected_and_skipped() {
        let (store, sp) = spill_with(SpillPolicy::All, 8);
        for i in 0..4u64 {
            sp.offer(i, elem(i as u8, 4));
        }
        let m = sp.finalize();
        assert!(m.segments.len() >= 2);
        // Flip a byte inside the first segment's body.
        let key = data_key(7);
        let mut data = (*store.get(&key).unwrap()).clone();
        let victim = &m.segments[0];
        data[victim.offset as usize + 4] ^= 0xff;
        store.put(&key, data);
        let first_len = victim.num_elements as u64;
        match sp.read_range(0, 4, usize::MAX, usize::MAX) {
            SpillRead::Batch { batch, next, skipped } => {
                // The corrupt segment's span is skipped, the rest served.
                assert_eq!(skipped, first_len);
                assert_eq!(next as usize, first_len as usize + batch.len());
                assert!(!batch.is_empty());
            }
            other => panic!("unexpected: {other:?}"),
        }
        assert!(matches!(
            read_segment(&store, store.region(), victim),
            Err(StorageError::Corrupt(_))
        ));
    }

    #[test]
    fn manifest_wire_roundtrip() {
        let m = SpillManifest {
            fingerprint: 0xdead_beef,
            job_id: 3,
            epoch: 2,
            total_elements: 11,
            complete: true,
            segments: vec![SegmentMeta {
                key: "spill/job-3/data".into(),
                offset: 128,
                len: 64,
                start_seq: 5,
                num_elements: 11,
                crc32: 0x1234_5678,
            }],
        };
        let back = SpillManifest::from_bytes(&m.to_bytes()).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn merge_and_partition_are_inverse_in_coverage() {
        let seg = |start: u64, n: u32| SegmentMeta {
            key: "k".into(),
            offset: 0,
            len: 8,
            start_seq: start,
            num_elements: n,
            crc32: 0,
        };
        let a = SpillManifest {
            fingerprint: 1,
            job_id: 1,
            epoch: 0,
            total_elements: 5,
            complete: true,
            segments: vec![seg(0, 2), seg(2, 3)],
        };
        let b = SpillManifest {
            fingerprint: 1,
            job_id: 1,
            epoch: 0,
            total_elements: 4,
            complete: true,
            segments: vec![seg(0, 4)],
        };
        let merged = merge_manifests(1, 1, 1, &[a, b]);
        assert_eq!(merged.total_elements, 9);
        assert_eq!(merged.end_seq(), 9);
        assert_eq!(merged.epoch, 1);
        let starts: Vec<u64> = merged.segments.iter().map(|s| s.start_seq).collect();
        assert_eq!(starts, vec![0, 2, 5]);

        let p0 = partition_manifest(&merged, 0, 2);
        let p1 = partition_manifest(&merged, 1, 2);
        let late = partition_manifest(&merged, 2, 2);
        assert_eq!(
            p0.total_elements + p1.total_elements,
            merged.total_elements
        );
        assert_eq!(late.total_elements, 0);
        assert!(late.segments.is_empty());
        // Each partition is dense from 0.
        for p in [&p0, &p1] {
            let mut next = 0u64;
            for s in &p.segments {
                assert_eq!(s.start_seq, next);
                next += s.num_elements as u64;
            }
            assert_eq!(next, p.total_elements);
        }
    }

    #[test]
    fn remote_reads_pay_cross_region_cost() {
        let store = ObjectStore::new(Region::new("us"), NetModel::default());
        let cfg = SpillConfig { policy: SpillPolicy::All, segment_bytes: 1 << 20 };
        let sp = JobSpill::new(
            store.clone(),
            Region::new("us"),
            &cfg,
            1,
            1,
            &Registry::new(),
        );
        sp.offer(0, elem(1, 64));
        let m = sp.finalize();
        let before = store.stats.cross_region_reads.load(std::sync::atomic::Ordering::Relaxed);
        read_segment(&store, &Region::new("eu"), &m.segments[0]).unwrap();
        let after = store.stats.cross_region_reads.load(std::sync::atomic::Ordering::Relaxed);
        assert_eq!(after - before, 1);
    }
}
