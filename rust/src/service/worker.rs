//! tf.data service worker: the data plane (§3.1).
//!
//! A worker registers with the dispatcher, receives dataset-processing
//! tasks (pipeline graphs), executes them over the storage layer, buffers
//! results, and serves client `GetElement` RPCs. Workers are stateless
//! with respect to the dispatcher: a restarted worker re-registers and
//! receives its tasks again (§3.4).
//!
//! Two serving modes per task:
//!
//! * **Independent** — results flow into an ephemeral **sliding-window
//!   cache** ([`SlidingCache`], §3.5) with one cursor per client. Clients
//!   at the cache front drive production and eviction; laggards that fall
//!   off the back skip evicted batches (relaxed visitation).
//! * **Coordinated** ([`CoordinatedState`], §3.6) — the worker serves the
//!   rounds whose residue (`r % num_workers`) it currently holds the
//!   **lease** for (normally its own `worker_index`; a failed owner's
//!   residues are re-leased by the dispatcher). Per round it prepares
//!   `num_consumers` same-length-bucket batches (the upstream graph's
//!   `bucket_by_sequence_length` + `group_by_window` produce same-bucket
//!   runs), one per consumer slot, pre-encoded and buffered up to
//!   [`WorkerConfig::round_prefetch_depth`] rounds ahead of consumption.
//!   Coordination never spans workers — only rounds do.

use super::proto::*;
use super::sharding::{DynamicSplitProvider, ShuffledAllSplits};
use super::spill::{self, JobSpill, SpillConfig, SpillManifest, SpillPolicy, SpillRead};
use super::{ServiceError, ServiceResult};
use crate::data::exec::{Executor, ExecutorConfig, SplitProvider};
use crate::data::udf::UdfRegistry;
use crate::data::Element;
use crate::metrics::Registry;
use crate::rpc::{call_typed, Pool, RespBody, Server};
use crate::storage::{ObjectStore, Region};
use crate::util::chan;
use crate::wire::{BufPool, Decode, Encode, Writer};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::{Duration, Instant};

/// Worker tuning knobs.
#[derive(Clone)]
pub struct WorkerConfig {
    pub store: Arc<ObjectStore>,
    pub udfs: UdfRegistry,
    /// Region the worker's CPUs live in (storage read costs).
    pub region: Region,
    /// Producer output buffer depth (elements) per task.
    pub buffer_size: usize,
    /// Sliding-window cache capacity (elements) per task (§3.5).
    pub cache_window: usize,
    /// Byte budget for the sliding window (§3.5): the retained span is
    /// bounded by bytes as well as element count, so large batches cannot
    /// blow worker memory. A consumer whose cursor falls behind the
    /// budgeted window skips ahead (relaxed visitation) rather than
    /// stalling production.
    pub cache_window_bytes: usize,
    pub heartbeat_interval: Duration,
    /// How long GetElement blocks for data before telling the client to
    /// retry; also the upper bound on a GetElements long-poll.
    pub serve_timeout: Duration,
    /// Coordinated reads (§3.6): how many rounds the producer
    /// materializes — and pre-encodes — ahead of consumption. 2 means
    /// the round being consumed plus one fully buffered behind it, the
    /// round-prefetch pipeline's worker half. The producer blocks on a
    /// condvar (no polling) when the buffer is full.
    pub round_prefetch_depth: usize,
    /// Capability bits this worker grants in stream-session handshakes
    /// (the negotiated set is the intersection with the client's offer).
    /// Defaults to everything this build implements; masking bits off
    /// simulates older peers in tests and supports staged rollouts.
    pub stream_caps: u64,
    /// Eagerly evict sliding-window elements already consumed by every
    /// registered cursor (§3.5 window-sizing follow-up) instead of
    /// waiting for the capacity/byte-budget trim: steady-state window
    /// RAM shrinks to the consumer spread. Safe because consumer
    /// attaches are pushed to workers synchronously (UPDATE_CONSUMERS);
    /// a late lazy attacher starts at the live frontier instead of
    /// replaying the retained window.
    pub eager_window_eviction: bool,
    /// Address to register with the dispatcher instead of the data
    /// server's local bind address (a stable VIP / proxy / NAT front).
    /// Worker identity is keyed by this address, so a worker revived
    /// behind the same advertised address re-registers as the *same*
    /// logical worker and its round residues re-balance back to it
    /// (§3.6 revival). `None` = the local bind address.
    pub advertise_addr: Option<String>,
    /// Spill tier (ROADMAP spill-to-store item): what the sliding window
    /// does with elements it evicts from RAM. `Off` (the default) keeps
    /// the paper's pure-ephemeral cache; `Wanted` tiers un-replayed
    /// ranges to the object store so laggards catch up instead of
    /// skipping; `All` archives the whole stream, enabling full-epoch
    /// late-attach replay and fingerprint-keyed snapshot commits.
    pub spill: SpillConfig,
}

/// GetElements/Fetch defaults applied when a request leaves a knob at 0.
pub const DEFAULT_BATCH_MAX_ELEMENTS: u32 = 64;
pub const DEFAULT_BATCH_MAX_BYTES: u64 = 4 << 20;
pub const DEFAULT_BATCH_POLL_MS: u32 = 50;

/// Slack reserved under a response-frame budget for the fixed-size
/// response head, the RPC frame header, and per-element length prefixes.
/// An element (or batch) may fill the negotiated budget minus this.
pub const FRAME_HEADROOM: usize = 64 << 10;

/// Smallest negotiable response-frame budget: below this, chunked
/// transfer would degenerate into thousands of tiny continuation frames.
pub const MIN_STREAM_FRAME_LEN: usize = 128 << 10;

impl WorkerConfig {
    pub fn new(store: Arc<ObjectStore>, udfs: UdfRegistry) -> WorkerConfig {
        let region = store.region().clone();
        WorkerConfig {
            store,
            udfs,
            region,
            buffer_size: 8,
            cache_window: 16,
            cache_window_bytes: 64 << 20,
            heartbeat_interval: Duration::from_millis(100),
            serve_timeout: Duration::from_secs(5),
            round_prefetch_depth: 2,
            stream_caps: stream_caps::ALL,
            eager_window_eviction: true,
            advertise_addr: None,
            spill: SpillConfig::default(),
        }
    }
}

/// Ephemeral multi-consumer sliding-window cache (§3.5, Fig. 5).
///
/// N consumers hold independent cursors over one produced stream:
/// elements are produced (and encoded) once, each consumer's cursor walks
/// the retained window at its own pace, and the window is trimmed from
/// the back when it exceeds the element capacity or the byte budget. A
/// consumer whose cursor falls off the trimmed back skips ahead to the
/// oldest retained element instead of stalling production — the paper's
/// relaxed-visitation escape hatch — and every skipped element is
/// counted.
///
/// Concurrency layout (the ROADMAP raw-speed item): per-consumer cursor
/// **shards** over an epoch-sequenced element **ring**, instead of one
/// cache-wide mutex. See the field docs and `service/mod.rs` for the
/// shard/ring/meta lock discipline.
/// Cursor-shard count (power of two; client ids map to shards by low
/// bits). Contention is per *job*: a handful of concurrently fetching
/// sessions is the common case, so eight shards already makes cross-
/// session collisions rare — the win is that distinct sessions stop
/// serializing on one cache-wide mutex at all.
const CURSOR_SHARDS: usize = 8;

struct SlidingCache {
    /// The epoch-sequenced element ring itself. Serve paths share it via
    /// `read`; the producer (push) and the trimmer take `write`.
    /// Splitting the ring from the cursor state is what lets
    /// independent-mode fetches from distinct sessions run in parallel:
    /// a fetch holds only its own cursor shard plus a shared ring read
    /// lock, so two sessions copy bytes out of the window concurrently.
    ring: RwLock<RingState>,
    /// Per-consumer cursor state, sharded by client-id low bits so
    /// distinct sessions lock distinct shards.
    shards: [Mutex<CursorShard>; CURSOR_SHARDS],
    /// Small meta lock serializing the producer's accounting and the
    /// eviction scan (the only paths that read *all* shards). Lock order
    /// is `meta` → shard → `ring`; nothing acquires a shard or `meta`
    /// while holding the ring, so serve/push/trim cannot deadlock.
    meta: Mutex<()>,
    /// Paired with `meta` (publish/EOS wakeups — see `wait_for_publish`).
    cond: Condvar,
    capacity: usize,
    byte_budget: usize,
    /// Eagerly evict elements consumed by every registered cursor (see
    /// [`WorkerConfig::eager_window_eviction`]).
    eager: bool,
    /// Cumulative ledgers (formerly fields of the single locked state):
    /// atomics so serve paths on different shards bump them without
    /// rendezvous. Snapshot via [`SlidingCache::stats`].
    hits: AtomicU64,
    evictions: AtomicU64,
    produced: AtomicU64,
    /// Elements produced while >= 2 consumers were registered (the "1x
    /// production" half of the §3.5 sharing ledger).
    shared_produced: AtomicU64,
    /// Elements consumers skipped because they were evicted before being
    /// read (relaxed visitation).
    skipped: AtomicU64,
    /// Registered-cursor census (the producer reads it for the sharing
    /// ledger without scanning shards).
    num_cursors: AtomicUsize,
    /// Cached lower bound on the slowest registered cursor — the
    /// eager-trim gate. A serve pays the full shard scan + ring write
    /// only when the cursor it advanced sat at this watermark (its move
    /// may shift the trim frontier); everyone else skips trimming.
    /// `u64::MAX` means "unknown: recompute at the next opportunity".
    /// Soundness: the hint must never exceed the true minimum (a
    /// stale-high hint costs a spurious rescan; a stale-low one would
    /// strand evictable elements), hence `fetch_min` on registration and
    /// an exact store under `meta` in [`SlidingCache::trim_locked`].
    min_hint: AtomicU64,
    /// Registry counters fed directly by the cache (single source of
    /// truth for the §3.5 sharing ledger — call sites cannot forget the
    /// bump and diverge from the cache-internal stats).
    shared_ctr: Arc<crate::metrics::Counter>,
    skip_ctr: Arc<crate::metrics::Counter>,
    /// Per-job window-occupancy gauges, updated on every push/trim so the
    /// registry tracks live occupancy, not just status-poll snapshots.
    win_elems_gauge: Arc<crate::metrics::Gauge>,
    win_bytes_gauge: Arc<crate::metrics::Gauge>,
    /// Spill tier under the RAM window (`None` = eviction discards, the
    /// paper's pure-ephemeral behavior).
    spill: Option<Arc<JobSpill>>,
    /// Adaptive byte target the trim loop enforces (≤ `byte_budget`, the
    /// configured ceiling). It grows — doubling — only when eviction
    /// would drop an element a registered cursor still wants (cursor
    /// spread demands window), and decays whenever eager eviction
    /// empties the window (consumers in lockstep need almost none), so
    /// steady-state window RAM tracks demand, not the configured max.
    target_bytes: AtomicUsize,
    target_gauge: Arc<crate::metrics::Gauge>,
    /// Elements served out of the spill tier (the RAM → spill fallback).
    spill_served_ctr: Arc<crate::metrics::Counter>,
}

/// The produced stream's retained window (everything the producer and
/// trimmer edit under the ring write lock, and serves read under the
/// read lock).
struct RingState {
    /// `window[i]` holds sequence number `base_seq + i`, pre-encoded:
    /// encoding happens once at production time, so serving the same
    /// batch to k sharing clients costs k memcpys instead of k deep
    /// clones + k encodes (§Perf).
    window: std::collections::VecDeque<Arc<Vec<u8>>>,
    /// Total payload bytes currently retained in `window`.
    window_bytes: usize,
    base_seq: u64,
    /// Producer finished (end of dataset).
    eos: bool,
}

/// One cursor shard: the consumers whose client-id low bits land here.
#[derive(Default)]
struct CursorShard {
    /// Consumer -> next sequence number it will read. Entries appear via
    /// explicit registration (task creation / sharing attach) or lazily
    /// on first fetch, and leave when the dispatcher reports a release.
    cursors: HashMap<u64, u64>,
    /// Consumers the dispatcher has released. A straggler fetch RPC that
    /// raced the detach must not lazily resurrect its cursor (a phantom
    /// consumer would permanently inflate the sharing ledger): tombstoned
    /// consumers are answered with end-of-sequence instead. Client ids
    /// are never reused, so tombstones never block a real newcomer.
    removed: std::collections::HashSet<u64>,
}

/// Counter snapshot for status reporting and tests. The per-cache
/// `produced`/`shared_produced`/`skipped` are read by unit tests;
/// `WORKER_STATUS` reports the cumulative registry counters for those
/// quantities instead, so the sharing ledger outlives finished tasks.
#[derive(Debug, Clone, Copy, Default)]
struct CacheStats {
    hits: u64,
    evictions: u64,
    #[allow(dead_code)]
    produced: u64,
    window: usize,
    window_bytes: usize,
    #[allow(dead_code)]
    shared_produced: u64,
    #[allow(dead_code)]
    skipped: u64,
}

/// Single-element cache read. The production paths all serve through
/// [`SlidingCache::serve_batch`] now (the legacy RPCs are shims over the
/// same machinery); this narrow probe survives for unit tests of cursor
/// semantics.
#[cfg(test)]
enum CacheServe {
    Bytes(Arc<Vec<u8>>),
    /// Caller must produce a new element and call `push`.
    NeedProduce,
    Eos,
}

/// Outcome of a batched cache read ([`SlidingCache::serve_batch`]).
enum BatchServe {
    /// Up-to-budget batch (possibly empty) plus the end-of-sequence
    /// verdict decided inside the critical section.
    Batch(Vec<Arc<Vec<u8>>>, bool),
    /// The first visible element exceeds the hard frame cap and the
    /// caller can chunk: the cursor has advanced past it and the caller
    /// now owns delivery (it must hold the bytes until the consumer
    /// confirms receipt — see the stream-session chunk state).
    Oversized(Arc<Vec<u8>>),
    /// The first visible element exceeds the hard frame cap and the
    /// caller cannot chunk: the cursor is NOT advanced, so the condition
    /// is explicit and repeatable instead of a silent skip.
    TooLarge(usize),
    /// The cursor points below the RAM window and the spill tier may
    /// cover the range: the caller replays `[from, to)` via
    /// [`JobSpill::read_range`] *outside* the cache lock (store reads
    /// are slow) and then commits progress with
    /// [`SlidingCache::complete_spill`].
    Spill { from: u64, to: u64 },
}

impl SlidingCache {
    fn new(
        capacity: usize,
        byte_budget: usize,
        eager: bool,
        job_id: u64,
        spill: Option<Arc<JobSpill>>,
        metrics: &Registry,
    ) -> SlidingCache {
        let byte_budget = byte_budget.max(1);
        // The adaptive target starts at a fraction of the ceiling and
        // earns its way up: a stream whose consumers move in lockstep
        // never allocates the full configured window.
        let target = (byte_budget / 16).max(1);
        let target_gauge = metrics.gauge(&format!("worker/job/{job_id}/window_target_bytes"));
        target_gauge.set(target as i64);
        SlidingCache {
            ring: RwLock::new(RingState {
                window: Default::default(),
                window_bytes: 0,
                base_seq: 0,
                eos: false,
            }),
            shards: std::array::from_fn(|_| Mutex::new(CursorShard::default())),
            meta: Mutex::new(()),
            cond: Condvar::new(),
            capacity: capacity.max(1),
            byte_budget,
            eager,
            hits: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            produced: AtomicU64::new(0),
            shared_produced: AtomicU64::new(0),
            skipped: AtomicU64::new(0),
            num_cursors: AtomicUsize::new(0),
            min_hint: AtomicU64::new(u64::MAX),
            shared_ctr: metrics.counter("worker/shared_elements_served"),
            skip_ctr: metrics.counter("worker/relaxed_visitation_skips"),
            win_elems_gauge: metrics.gauge(&format!("worker/job/{job_id}/window_elements")),
            win_bytes_gauge: metrics.gauge(&format!("worker/job/{job_id}/window_bytes")),
            spill,
            target_bytes: AtomicUsize::new(target),
            target_gauge,
            spill_served_ctr: metrics.counter("worker/spill_elements_served"),
        }
    }

    /// Where a fresh cursor anchors. Without spill (or under
    /// [`SpillPolicy::Wanted`], whose tier only back-fills ranges some
    /// *existing* cursor missed) that is the oldest RAM-retained element
    /// — the paper's late-attacher semantics. Under [`SpillPolicy::All`]
    /// the whole history is replayable, so a late attacher anchors at
    /// the spill floor and replays the full epoch with zero skips.
    fn replay_anchor(&self, base: u64) -> u64 {
        match &self.spill {
            Some(sp) if sp.policy == SpillPolicy::All => {
                sp.floor().map(|f| f.min(base)).unwrap_or(base)
            }
            _ => base,
        }
    }

    /// Tier an evicted element into the spill store per policy: `All`
    /// archives everything (the snapshot feed), `Wanted` only elements
    /// some registered cursor has not consumed yet (laggard catch-up).
    fn spill_evicted(&self, seq: u64, bytes: &Arc<Vec<u8>>, wanted: bool) {
        let Some(sp) = &self.spill else { return };
        let keep = match sp.policy {
            SpillPolicy::Off => false,
            SpillPolicy::Wanted => wanted,
            SpillPolicy::All => true,
        };
        if keep {
            sp.offer(seq, bytes.clone());
        }
    }

    fn spill(&self) -> Option<&Arc<JobSpill>> {
        self.spill.as_ref()
    }

    fn is_eos(&self) -> bool {
        self.ring.read().unwrap().eos
    }

    /// The cursor shard owning `client` (low bits of the id).
    fn shard(&self, client: u64) -> &Mutex<CursorShard> {
        &self.shards[client as usize & (CURSOR_SHARDS - 1)]
    }

    /// Bookkeeping for a cursor just inserted at `anchor` (explicit
    /// registration, lazy first fetch, or a spill commit racing its
    /// registration): the census feeds the sharing ledger and the
    /// `fetch_min` keeps the eager-trim gate sound — the hint may only
    /// ever sit at or below the true minimum cursor.
    fn note_new_cursor(&self, anchor: u64) {
        self.num_cursors.fetch_add(1, Ordering::SeqCst);
        self.min_hint.fetch_min(anchor, Ordering::SeqCst);
    }

    /// Minimum registered cursor across every shard (`None` with no
    /// cursors). Locks shards one at a time, scan only — never called
    /// with the ring held.
    fn min_cursor_scan(&self) -> Option<u64> {
        let mut min: Option<u64> = None;
        for sh in &self.shards {
            let g = sh.lock().unwrap();
            for &c in g.cursors.values() {
                min = Some(match min {
                    Some(m) => m.min(c),
                    None => c,
                });
            }
        }
        min
    }

    /// Archive the retained RAM tail into the spill tier (end-of-epoch
    /// finalize): elements still in the window were never evicted, so
    /// the spill object is missing them. [`SpillPolicy::All`] only — a
    /// `Wanted` spill is a laggard catch-up buffer, not an epoch
    /// archive. Idempotent: `offer` ignores already-durable sequence
    /// numbers.
    fn flush_tail_to_spill(&self) {
        let Some(sp) = &self.spill else { return };
        if sp.policy != SpillPolicy::All {
            return;
        }
        let ring = self.ring.read().unwrap();
        for (i, bytes) in ring.window.iter().enumerate() {
            sp.offer(ring.base_seq + i as u64, bytes.clone());
        }
    }

    /// Commit a spill replay's progress: advance the cursor (forward
    /// only — a concurrent serve may have moved it further), credit
    /// served elements to the hit ledger and skipped ones (gaps /
    /// unreadable segments) to the relaxed-visitation ledger.
    fn complete_spill(&self, client: u64, upto: u64, served: u64, skipped: u64) {
        let prev = {
            let mut sh = self.shard(client).lock().unwrap();
            if sh.removed.contains(&client) {
                return;
            }
            match sh.cursors.get(&client).copied() {
                Some(cur) => {
                    if cur < upto {
                        sh.cursors.insert(client, upto);
                    }
                    cur
                }
                None => {
                    sh.cursors.insert(client, upto);
                    self.note_new_cursor(upto);
                    upto
                }
            }
        };
        self.hits.fetch_add(served, Ordering::SeqCst);
        self.spill_served_ctr.add(served);
        if skipped > 0 {
            self.skipped.fetch_add(skipped, Ordering::SeqCst);
            self.skip_ctr.add(skipped);
        }
        self.maybe_trim(prev);
    }

    /// Register a consumer's cursor at the oldest retained element. Done
    /// eagerly when the dispatcher announces the consumer (task
    /// creation, sharing attach push, or heartbeat fallback), and lazily
    /// on first fetch. Returns whether the cursor is newly registered
    /// (push + heartbeat may deliver the same attach; only one counts).
    fn register_consumer(&self, client: u64) -> bool {
        let mut sh = self.shard(client).lock().unwrap();
        if sh.removed.contains(&client) || sh.cursors.contains_key(&client) {
            return false;
        }
        let anchor = {
            let ring = self.ring.read().unwrap();
            self.replay_anchor(ring.base_seq)
        };
        sh.cursors.insert(client, anchor);
        self.note_new_cursor(anchor);
        true
    }

    /// Drop a released consumer's cursor (and tombstone the id) so it no
    /// longer counts toward the stream's consumer set. Returns whether
    /// the cursor existed.
    fn remove_consumer(&self, client: u64) -> bool {
        let meta = self.meta.lock().unwrap();
        let existed = {
            let mut sh = self.shard(client).lock().unwrap();
            sh.removed.insert(client);
            sh.cursors.remove(&client).is_some()
        };
        if existed {
            self.num_cursors.fetch_sub(1, Ordering::SeqCst);
        }
        // A departing laggard may have been the only cursor pinning the
        // back of the window.
        self.trim_locked(&meta);
        existed
    }

    /// Eager eviction (§3.5 window-sizing follow-up): drop elements
    /// every registered cursor has already consumed instead of holding
    /// them until the capacity/byte-budget trim. Steady-state window RAM
    /// then tracks the consumer spread, not the configured capacity. A
    /// consumer the dispatcher knows about registers its cursor before
    /// its first fetch (synchronous UPDATE_CONSUMERS push, task-creation
    /// consumer list, or the heartbeat fallback), so the minimum below
    /// cannot run ahead of a known consumer.
    /// Recompute the slowest-cursor watermark and (in eager mode) evict
    /// the consumed-by-all prefix. The caller must hold the meta lock —
    /// the guard parameter proves it — so concurrent trims cannot
    /// interleave their shard scans with the ring edit. Shards are
    /// locked one at a time (scan only); the ring write lock is taken
    /// with no shard lock held.
    fn trim_locked(&self, _meta: &std::sync::MutexGuard<'_, ()>) {
        let min = self.min_cursor_scan();
        self.min_hint.store(min.unwrap_or(u64::MAX), Ordering::SeqCst);
        let Some(min) = min else { return };
        if !self.eager {
            return;
        }
        let mut ring = self.ring.write().unwrap();
        if ring.base_seq >= min || ring.window.is_empty() {
            return;
        }
        while ring.base_seq < min && !ring.window.is_empty() {
            let old = ring.window.pop_front().expect("non-empty window");
            // Consumed-by-all, so no cursor wants it — only an `All`
            // spill (epoch archive) keeps it.
            let seq = ring.base_seq;
            self.spill_evicted(seq, &old, false);
            ring.window_bytes -= old.len();
            ring.base_seq += 1;
            self.evictions.fetch_add(1, Ordering::SeqCst);
        }
        self.win_elems_gauge.set(ring.window.len() as i64);
        self.win_bytes_gauge.set(ring.window_bytes as i64);
        if ring.window.is_empty() {
            // Adaptive window: the consumed-by-all prefix was the
            // whole window, so consumers are in lockstep — decay the
            // byte target toward its floor.
            let target = self.target_bytes.load(Ordering::Relaxed);
            let floor = (self.byte_budget / 16).max(1);
            if target > floor {
                let next = (target - target / 4).max(floor);
                self.target_bytes.store(next, Ordering::Relaxed);
                self.target_gauge.set(next as i64);
            }
        }
    }

    /// Post-serve trim gate. `prev` is the advanced cursor's value
    /// *before* the operation: only the watermark holder's advance can
    /// move the trim frontier, so a serve whose `prev` sits above the
    /// hint skips the shard scan and ring write entirely. Sequentially
    /// this evicts exactly when the old single-lock `trim_consumed`
    /// would have (the hint tracks the true minimum between trims), so
    /// the differential stress tests see identical eviction/skip
    /// ledgers; under concurrency a stale-high hint only costs a
    /// spurious rescan, never a missed trim.
    fn maybe_trim(&self, prev: u64) {
        if !self.eager {
            return;
        }
        if prev <= self.min_hint.load(Ordering::SeqCst) {
            let meta = self.meta.lock().unwrap();
            self.trim_locked(&meta);
        }
    }

    /// Registered consumer count (shared streams have >= 2).
    #[cfg(test)]
    fn num_consumers(&self) -> usize {
        let n: usize = self.shards.iter().map(|s| s.lock().unwrap().cursors.len()).sum();
        debug_assert_eq!(n, self.num_cursors.load(Ordering::SeqCst));
        n
    }

    /// Try to serve `client` from the cache. Cursor semantics: a new
    /// client starts at the oldest retained batch; a laggard whose cursor
    /// was evicted implicitly skips to the oldest retained batch (the
    /// clamp inside [`SlidingCache::serve_batch`] counts the skips).
    #[cfg(test)]
    fn serve(&self, client: u64) -> CacheServe {
        static NO_INFLIGHT: AtomicU64 = AtomicU64::new(0);
        match self.serve_batch(client, 1, usize::MAX, usize::MAX, false, &NO_INFLIGHT) {
            BatchServe::Batch(mut v, end) => match v.pop() {
                Some(e) => CacheServe::Bytes(e),
                None if end => CacheServe::Eos,
                None => CacheServe::NeedProduce,
            },
            BatchServe::Spill { .. } | BatchServe::Oversized(_) | BatchServe::TooLarge(_) => {
                unreachable!("single-element test serve hits no spill/chunk path")
            }
        }
    }

    /// Front-driven production: append a fresh element (already encoded
    /// once), then trim the back to the capacity/byte budget and wake
    /// blocked readers. Returns the registered consumer count at push
    /// time; the sharing ledger (cache stats + registry counter) is fed
    /// internally.
    #[cfg(test)]
    fn push(&self, e: Element) -> usize {
        self.push_encoded(vec![Arc::new(e.to_bytes())])
    }

    /// Batched variant of [`SlidingCache::push`]: install several
    /// pre-encoded elements under one lock acquisition (the GetElements
    /// drain path encodes outside the lock, then bulk-inserts).
    fn push_encoded(&self, encoded: Vec<Arc<Vec<u8>>>) -> usize {
        let _meta = self.meta.lock().unwrap();
        let consumers = self.num_cursors.load(Ordering::SeqCst);
        if encoded.is_empty() {
            return consumers;
        }
        if consumers >= 2 {
            self.shared_ctr.add(encoded.len() as u64);
            self.shared_produced.fetch_add(encoded.len() as u64, Ordering::SeqCst);
        }
        self.produced.fetch_add(encoded.len() as u64, Ordering::SeqCst);
        // One slowest-cursor snapshot covers the whole batch's `wanted`
        // decisions (the single-lock code rescanned the cursor map per
        // victim, but under the same lock serves couldn't move cursors
        // mid-push anyway; here a cursor advancing mid-push can only
        // turn a wanted victim unwanted, so the snapshot errs toward
        // retaining bytes).
        let min_cursor = self.min_cursor_scan();
        let mut ring = self.ring.write().unwrap();
        for bytes in encoded {
            ring.window_bytes += bytes.len();
            ring.window.push_back(bytes);
            // Trim: the window slides forward when it outgrows the
            // element capacity or the adaptive byte target. Eviction
            // does not wait for slow cursors — they replay from the
            // spill tier or skip ahead on their next fetch — but always
            // keeps the newest element so every consumer can progress.
            loop {
                let target = self.target_bytes.load(Ordering::Relaxed);
                let over_cap = ring.window.len() > self.capacity;
                let over_bytes = ring.window_bytes > target && ring.window.len() > 1;
                if !over_cap && !over_bytes {
                    break;
                }
                let victim_seq = ring.base_seq;
                let wanted = min_cursor.is_some_and(|m| m <= victim_seq);
                if !over_cap && wanted && target < self.byte_budget {
                    // Adaptive window: a registered cursor still wants
                    // the victim and the target has headroom under the
                    // configured ceiling — grow instead of evicting.
                    let next = target.saturating_mul(2).min(self.byte_budget);
                    self.target_bytes.store(next, Ordering::Relaxed);
                    self.target_gauge.set(next as i64);
                    continue;
                }
                let Some(old) = ring.window.pop_front() else { break };
                self.spill_evicted(victim_seq, &old, wanted);
                ring.window_bytes -= old.len();
                ring.base_seq += 1;
                self.evictions.fetch_add(1, Ordering::SeqCst);
            }
        }
        self.win_elems_gauge.set(ring.window.len() as i64);
        self.win_bytes_gauge.set(ring.window_bytes as i64);
        drop(ring);
        self.cond.notify_all();
        consumers
    }

    /// Occupancy snapshot for backpressure hints: elements still unread
    /// by `client`'s cursor, plus total window occupancy.
    fn occupancy(&self, client: u64) -> (usize, usize, usize) {
        let cursor = self.shard(client).lock().unwrap().cursors.get(&client).copied();
        let ring = self.ring.read().unwrap();
        let unread = match cursor {
            Some(cursor) => {
                let idx = cursor.saturating_sub(ring.base_seq) as usize;
                ring.window.len().saturating_sub(idx)
            }
            None => ring.window.len(),
        };
        (unread, ring.window.len(), ring.window_bytes)
    }

    /// Advance `client`'s cursor through up to `max_elements` /
    /// `max_bytes` of retained window holding only the client's cursor
    /// shard plus a shared ring *read* lock — distinct sessions serve
    /// concurrently. Always returns at least one element if any is
    /// visible to the cursor, even when it alone exceeds the soft byte
    /// budget — *unless* it also exceeds `hard_cap` (the response-frame
    /// ceiling), in which case the outcome depends on `chunk_oversized`:
    /// the element is handed to the caller for continuation-frame
    /// delivery (cursor advanced), or reported [`BatchServe::TooLarge`]
    /// with the cursor untouched. Laggard skips are counted by the clamp
    /// at the top of the serve.
    ///
    /// The end-of-sequence verdict is decided while the ring read lock
    /// is held: producer finished (`eos`), cursor consumed the whole
    /// window, *and* `in_flight` is zero. The last condition is what
    /// makes the verdict safe under sharing: a concurrent handler that
    /// popped the producer channel keeps `in_flight` non-zero until its
    /// `push_encoded` — whose ring *write* lock excludes this read —
    /// completes, so a zero reading here means the publish is visible
    /// and a true verdict can never race past an unpublished element.
    /// Once `eos` is set no new increments happen, so a zero reading
    /// under the read lock is terminal.
    fn serve_batch(
        &self,
        client: u64,
        max_elements: usize,
        max_bytes: usize,
        hard_cap: usize,
        chunk_oversized: bool,
        in_flight: &AtomicU64,
    ) -> BatchServe {
        let mut sh = self.shard(client).lock().unwrap();
        if sh.removed.contains(&client) {
            // Straggler RPC from a released consumer: its stream is over.
            return BatchServe::Batch(Vec::new(), true);
        }
        let ring = self.ring.read().unwrap();
        let base = ring.base_seq;
        let prev = match sh.cursors.get(&client).copied() {
            Some(c) => c,
            None => {
                let anchor = self.replay_anchor(base);
                sh.cursors.insert(client, anchor);
                self.note_new_cursor(anchor);
                anchor
            }
        };
        // A below-window cursor replays from the spill tier (outside
        // every cache lock) before clamping can count the range skipped.
        if let Some(sp) = &self.spill {
            if prev < base && sp.may_cover(prev) {
                return BatchServe::Spill { from: prev, to: base };
            }
        }
        let mut cursor = prev;
        if cursor < base {
            // Evicted range skipped (relaxed visitation escape hatch).
            self.skipped.fetch_add(base - cursor, Ordering::SeqCst);
            self.skip_ctr.add(base - cursor);
            sh.cursors.insert(client, base);
            cursor = base;
        }
        let mut out = Vec::new();
        let mut bytes = 0usize;
        while out.len() < max_elements {
            let idx = (cursor - base) as usize;
            if idx >= ring.window.len() {
                break;
            }
            let e = ring.window[idx].clone(); // Arc bump, no copy
            if e.len() > hard_cap {
                // A single element no response frame can carry.
                if !out.is_empty() {
                    // Serve what fits; the oversized element leads the
                    // next call, where the first-element handling below
                    // chunks it (or errors).
                    break;
                }
                if !chunk_oversized {
                    // The cursor stays put, but the clamp above may have
                    // raised it off an evicted range without a trim: mark
                    // the watermark unknown so the next operation
                    // recomputes it (the single-lock code likewise left
                    // the trim to the next call on this path).
                    drop(ring);
                    drop(sh);
                    self.min_hint.store(u64::MAX, Ordering::SeqCst);
                    return BatchServe::TooLarge(e.len());
                }
                sh.cursors.insert(client, cursor + 1);
                self.hits.fetch_add(1, Ordering::SeqCst);
                drop(ring);
                drop(sh);
                self.maybe_trim(prev);
                return BatchServe::Oversized(e);
            }
            if !out.is_empty() && bytes + e.len() > max_bytes {
                break;
            }
            bytes += e.len();
            cursor += 1;
            out.push(e);
        }
        self.hits.fetch_add(out.len() as u64, Ordering::SeqCst);
        sh.cursors.insert(client, cursor);
        let drained = (cursor - base) as usize >= ring.window.len();
        let end = ring.eos && drained && in_flight.load(Ordering::SeqCst) == 0;
        drop(ring);
        drop(sh);
        self.maybe_trim(prev);
        BatchServe::Batch(out, end)
    }

    fn set_eos(&self) {
        self.ring.write().unwrap().eos = true;
        // Touch the meta lock before notifying so a reader that just
        // checked its predicate and is entering `wait_for_publish`
        // cannot miss the wakeup.
        drop(self.meta.lock().unwrap());
        self.cond.notify_all();
    }

    /// Block briefly until another handler publishes into (or finishes)
    /// the window — used instead of a polling sleep when the producer
    /// channel has closed but a concurrent handler still holds
    /// popped-but-unpublished elements ([`SlidingCache::push_encoded`]
    /// notifies this condvar).
    fn wait_for_publish(&self, timeout: Duration) {
        let guard = self.meta.lock().unwrap();
        let _ = self.cond.wait_timeout(guard, timeout).unwrap();
    }

    fn stats(&self) -> CacheStats {
        let ring = self.ring.read().unwrap();
        CacheStats {
            hits: self.hits.load(Ordering::SeqCst),
            evictions: self.evictions.load(Ordering::SeqCst),
            produced: self.produced.load(Ordering::SeqCst),
            window: ring.window.len(),
            window_bytes: ring.window_bytes,
            shared_produced: self.shared_produced.load(Ordering::SeqCst),
            skipped: self.skipped.load(Ordering::SeqCst),
        }
    }
}

/// Multi-round coordinated-read state (§3.6) with round-lease prefetch.
///
/// The producer materializes — and **pre-encodes** — up to `depth`
/// rounds ahead of consumption, so round `r+1` is already on this worker
/// (encoded once, served as `Arc` clones) while the consumers are still
/// draining round `r`: the tf.data `prefetch` insight applied across the
/// wire. Consumers can read any buffered round.
///
/// Round ownership is a **lease** over residue classes
/// (`round % num_workers`), not a fixed assignment: normally just this
/// worker's index, renewed implicitly by its dispatcher heartbeats. When
/// an owner fails (silent past the dispatcher's `worker_timeout`), the
/// dispatcher reassigns its residues to survivors ([`RoundAssignment`]);
/// the new owner re-materializes the adopted rounds from its own
/// pipeline under the relaxed visitation guarantee, so prefetch never
/// turns an owner crash into a permanent stall.
///
/// Consumers asking for round `R` implicitly declare every round `< R`
/// consumed (their round walk is monotonic); rounds below the minimum
/// such watermark were abandoned during a reassignment (every consumer
/// moved past them before this worker materialized its copy) and are
/// GC'd so they cannot pin the bounded buffer forever.
struct CoordinatedState {
    inner: Mutex<CoordinatedInner>,
    /// Signaled when a round materializes, ownership changes, or eos.
    cond: Condvar,
    /// Signaled when buffer space frees (round consumed / abandoned) or
    /// ownership changes — the producer's backpressure wait.
    space: Condvar,
    num_workers: u64,
    /// Max rounds buffered ahead ([`WorkerConfig::round_prefetch_depth`]).
    depth: usize,
}

struct CoordinatedInner {
    /// round -> per-consumer pre-encoded slots (None once consumed).
    rounds: HashMap<u64, Vec<Option<Arc<Vec<u8>>>>>,
    /// Round residues this worker currently holds the lease for.
    owned: std::collections::BTreeSet<u64>,
    /// Next round label to materialize, per owned residue (invariant:
    /// every owned residue has an entry).
    next_by_residue: HashMap<u64, u64>,
    /// Per-consumer progress: the highest round each consumer has asked
    /// this worker for (bumped past on a successful take). Feeds the
    /// abandoned-round GC above. Grows on demand when a membership epoch
    /// widens the consumer set.
    watermarks: Vec<u64>,
    /// Membership-epoch width schedule: `(barrier_round, num_consumers)`
    /// sorted by barrier, never empty. A round's slot count is decided
    /// by the newest entry whose barrier it has reached
    /// ([`CoordinatedState::width_for`]).
    widths: Vec<(u64, u32)>,
    /// Newest membership epoch applied ([`set_width_schedule`] is
    /// idempotent over heartbeat redelivery).
    applied_epoch: u32,
    /// Elements staged toward the next round by the producer; the round
    /// installs once the staged prefix fills the round's width.
    staged: Vec<Arc<Vec<u8>>>,
    eos: bool,
    /// Consumer slots dropped unconsumed (abandoned rounds GC'd, or
    /// buffered rounds of a residue whose lease moved away).
    abandoned_slots: u64,
    /// Post-revoke grace: buffered rounds of residues just revoked, kept
    /// servable read-only until their deadline so consumers whose fetch
    /// raced the two-phase handoff get data instead of a `WrongWorker`
    /// bounce. Separate from `rounds` so grace entries neither count
    /// against the producer's prefetch depth nor get re-served as owned.
    grace: HashMap<u64, (Vec<Option<Arc<Vec<u8>>>>, Instant)>,
    /// How long revoked rounds stay in `grace` (zero disables the
    /// window; production tasks set one heartbeat interval).
    grace_ttl: Duration,
    /// Task removed / worker shutting down: unblock the producer.
    stopped: bool,
}

/// Outcome of a coordinated round read ([`CoordinatedState::take`]).
enum RoundTake {
    /// The consumer's pre-encoded slot for the round.
    Bytes(Arc<Vec<u8>>),
    /// A revoked residue's buffered slot, served read-only within the
    /// post-revoke grace window (the slot is not consumed: the gainer
    /// owns the round's lifecycle now, we only absorb the race).
    Grace(Arc<Vec<u8>>),
    /// This worker does not hold the round's lease.
    WrongWorker,
    Eos,
    /// Not materialized within the poll window: the client retries.
    Pending,
}

impl CoordinatedState {
    fn new(
        num_consumers: usize,
        worker_index: u64,
        num_workers: u64,
        owned_residues: &[u32],
        lease_view: bool,
        start_round: u64,
        depth: usize,
    ) -> CoordinatedState {
        let num_workers = num_workers.max(1);
        let mut owned: std::collections::BTreeSet<u64> =
            owned_residues.iter().map(|&r| r as u64 % num_workers).collect();
        if owned.is_empty() && !lease_view && worker_index < num_workers {
            // Pre-lease dispatchers send no residue set: fall back to the
            // fixed `worker_index` assignment. A late joiner
            // (worker_index == num_workers) starts with no lease and its
            // producer parks until granted one. With an authoritative
            // lease view (`lease_view`), an empty set really means
            // leaseless — a revived worker whose residues moved to
            // survivors must not self-assign its home residue and
            // materialize split-brain rounds.
            owned.insert(worker_index);
        }
        // Label from the dispatcher's floor (min round any consumer still
        // needs): a restarted worker rejoining mid-epoch must not crawl
        // from round 0 through abandoned labels.
        let next_by_residue = owned
            .iter()
            .map(|&r| {
                let mut aligned = (start_round / num_workers) * num_workers + r;
                if aligned < start_round {
                    aligned += num_workers;
                }
                (r, aligned)
            })
            .collect();
        CoordinatedState {
            inner: Mutex::new(CoordinatedInner {
                rounds: HashMap::new(),
                owned,
                next_by_residue,
                watermarks: vec![0; num_consumers.max(1)],
                widths: vec![(0, num_consumers.max(1) as u32)],
                applied_epoch: 0,
                staged: Vec::new(),
                eos: false,
                abandoned_slots: 0,
                grace: HashMap::new(),
                grace_ttl: Duration::ZERO,
                stopped: false,
            }),
            cond: Condvar::new(),
            space: Condvar::new(),
            num_workers,
            depth: depth.max(1),
        }
    }

    #[cfg(test)]
    fn owns_round(&self, round: u64) -> bool {
        self.inner.lock().unwrap().owned.contains(&(round % self.num_workers))
    }

    /// Rounds currently buffered (backpressure hint).
    fn buffered_rounds(&self) -> usize {
        self.inner.lock().unwrap().rounds.len()
    }

    /// Test-only direct install of a pre-grouped round (the production
    /// path stages elements through [`offer`], which regroups at the
    /// width-schedule boundary). Blocks on the space condvar while the
    /// buffer holds `depth` rounds or this worker owns no residues; the
    /// round label is the smallest unmaterialized round among owned
    /// residues, so output streams in increasing round order. Returns
    /// false when the task stopped.
    #[cfg(test)]
    fn install_round(&self, batches: Vec<Arc<Vec<u8>>>) -> bool {
        let mut st = self.inner.lock().unwrap();
        loop {
            if st.stopped {
                return false;
            }
            if !st.owned.is_empty() && st.rounds.len() < self.depth {
                break;
            }
            st = self.space.wait(st).unwrap();
        }
        let (residue, round) = st
            .owned
            .iter()
            .map(|&r| (r, st.next_by_residue[&r]))
            .min_by_key(|&(_, next)| next)
            .expect("non-empty owned set");
        st.rounds.insert(round, batches.into_iter().map(Some).collect());
        st.next_by_residue.insert(residue, round + self.num_workers);
        drop(st);
        self.cond.notify_all();
        true
    }

    /// Slot count of `round` under the membership schedule: the newest
    /// epoch whose barrier `round` has reached.
    fn width_for(widths: &[(u64, u32)], round: u64) -> usize {
        widths
            .iter()
            .rev()
            .find(|&&(barrier, _)| barrier <= round)
            .map(|&(_, w)| (w as usize).max(1))
            .unwrap_or(1)
    }

    /// Producer side: stage one pre-encoded element toward the next
    /// round. Rounds are grouped here — not in the producer — so each
    /// round's slot count is decided at install time from the width
    /// schedule, and a membership change between two rounds regroups
    /// the element stream without restarting the pipeline. Installs
    /// every round the staged prefix fills, blocking on the space
    /// condvar while the buffer holds `depth` rounds or this worker
    /// owns no residues (a leaseless worker cannot label rounds).
    /// Returns false when the task stopped.
    fn offer(&self, bytes: Arc<Vec<u8>>) -> bool {
        let mut st = self.inner.lock().unwrap();
        st.staged.push(bytes);
        let mut installed = false;
        loop {
            if st.stopped {
                return false;
            }
            if st.owned.is_empty() {
                st = self.space.wait(st).unwrap();
                continue;
            }
            let (residue, round) = st
                .owned
                .iter()
                .map(|&r| (r, st.next_by_residue[&r]))
                .min_by_key(|&(_, next)| next)
                .expect("non-empty owned set");
            let width = Self::width_for(&st.widths, round);
            if st.staged.len() < width {
                break;
            }
            if st.rounds.len() >= self.depth {
                st = self.space.wait(st).unwrap();
                continue;
            }
            let batch: Vec<Option<Arc<Vec<u8>>>> = st.staged.drain(..width).map(Some).collect();
            st.rounds.insert(round, batch);
            st.next_by_residue.insert(residue, round + self.num_workers);
            installed = true;
        }
        drop(st);
        if installed {
            self.cond.notify_all();
        }
        true
    }

    /// Apply an epoch-versioned consumer-membership schedule (§3.6
    /// elasticity; see the membership-epoch state machine in the module
    /// docs). Idempotent over heartbeat redelivery: a schedule applies
    /// only when its newest epoch is newer than the last one applied.
    /// Buffered rounds at or past the newest barrier were grouped under
    /// the previous width — they are dropped and the producer's round
    /// labels rolled back to the barrier so they re-materialize at the
    /// new width. Returns the number of rounds re-keyed that way (the
    /// caller meters `worker/rounds_rekeyed`).
    fn set_width_schedule(&self, epochs: &[WidthEpoch]) -> u64 {
        let Some(newest) = epochs.last() else { return 0 };
        let mut st = self.inner.lock().unwrap();
        if newest.epoch <= st.applied_epoch {
            return 0;
        }
        st.applied_epoch = newest.epoch;
        st.widths = epochs.iter().map(|e| (e.barrier_round, e.num_consumers.max(1))).collect();
        let barrier = newest.barrier_round;
        let dropped: Vec<u64> = st.rounds.keys().copied().filter(|&r| r >= barrier).collect();
        let rekeyed = dropped.len() as u64;
        for r in dropped {
            if let Some(slots) = st.rounds.remove(&r) {
                st.abandoned_slots += slots.iter().filter(|s| s.is_some()).count() as u64;
            }
        }
        // Roll materialization progress back to the barrier: labels the
        // producer advanced past it belonged to rounds dropped above.
        let nw = self.num_workers;
        for (&r, next) in st.next_by_residue.iter_mut() {
            if *next > barrier {
                let mut aligned = (barrier / nw) * nw + r;
                if aligned < barrier {
                    aligned += nw;
                }
                *next = aligned;
            }
        }
        // A partially-staged batch would splice pre-barrier elements
        // into a re-grouped round: drop it (relaxed visitation).
        st.staged.clear();
        let max_w = st.widths.iter().map(|&(_, w)| (w as usize).max(1)).max().unwrap_or(1);
        if st.watermarks.len() < max_w {
            st.watermarks.resize(max_w, 0);
        }
        drop(st);
        self.cond.notify_all();
        self.space.notify_all();
        rekeyed
    }

    fn set_eos(&self) {
        let mut st = self.inner.lock().unwrap();
        st.eos = true;
        self.cond.notify_all();
    }

    /// Unblock a producer parked on backpressure (task removal/shutdown).
    fn halt(&self) {
        let mut st = self.inner.lock().unwrap();
        st.stopped = true;
        self.cond.notify_all();
        self.space.notify_all();
    }

    /// Apply a round-lease update: `residues` replaces the owned set.
    /// Newly-adopted residues start materializing at the smallest round
    /// `>= start_round` in their class (the dispatcher's floor = the
    /// minimum round any consumer still needs); buffered rounds of
    /// residues no longer owned are dropped — their consumers now ask
    /// the new lease holder.
    fn set_owned(&self, residues: &[u64], start_round: u64) {
        let mut st = self.inner.lock().unwrap();
        let new: std::collections::BTreeSet<u64> =
            residues.iter().map(|&r| r % self.num_workers).collect();
        for &r in &new {
            // Smallest round >= start_round with round % num_workers == r.
            let mut aligned = (start_round / self.num_workers) * self.num_workers + r;
            if aligned < start_round {
                aligned += self.num_workers;
            }
            if st.owned.contains(&r) {
                // Residue retained across the update: keep its
                // materialization progress (resetting would re-label
                // rounds consumers already took).
                st.next_by_residue.entry(r).or_insert(aligned);
            } else {
                // Newly (re-)adopted: label from the dispatcher's floor —
                // the minimum round any consumer still needs. A stale
                // progress marker from a previous tenure must NOT
                // survive: its buffered rounds were dropped when the
                // lease moved away, so keeping it would answer consumers
                // "round already consumed" for rounds never delivered.
                st.next_by_residue.insert(r, aligned);
            }
        }
        let dropped: Vec<u64> = st
            .rounds
            .keys()
            .copied()
            .filter(|r| !new.contains(&(r % self.num_workers)))
            .collect();
        for r in dropped {
            if let Some(slots) = st.rounds.remove(&r) {
                st.abandoned_slots += slots.iter().filter(|s| s.is_some()).count() as u64;
            }
        }
        // A re-granted residue invalidates its grace copies: the lease
        // is authoritative again and grace data may lag what this
        // producer re-materializes.
        let regained: Vec<u64> = st
            .grace
            .keys()
            .copied()
            .filter(|r| new.contains(&(r % self.num_workers)))
            .collect();
        for r in regained {
            if let Some((slots, _)) = st.grace.remove(&r) {
                st.abandoned_slots += slots.iter().filter(|s| s.is_some()).count() as u64;
            }
        }
        st.owned = new;
        drop(st);
        self.cond.notify_all();
        self.space.notify_all();
    }

    /// Apply a phase-1 lease revocation (graceful drain / two-phase
    /// re-balance): drop `residues` from the owned set and discard their
    /// buffered rounds — consumers ask the gainer once the dispatcher
    /// flips the lease on our ack. Residues not currently owned are
    /// ignored (revocations are re-delivered at-least-once, so a
    /// duplicate must be a no-op that still acks). Returns how many
    /// residues were actually dropped.
    fn revoke(&self, residues: &[u64]) -> usize {
        let mut st = self.inner.lock().unwrap();
        let revoked: std::collections::BTreeSet<u64> =
            residues.iter().map(|&r| r % self.num_workers).collect();
        let before = st.owned.len();
        st.owned.retain(|r| !revoked.contains(r));
        let n = before - st.owned.len();
        if n == 0 {
            return 0;
        }
        for r in &revoked {
            // A stale progress marker must not survive a revocation: a
            // later re-grant labels from the dispatcher's floor.
            st.next_by_residue.remove(r);
        }
        let dropped: Vec<u64> = st
            .rounds
            .keys()
            .copied()
            .filter(|r| revoked.contains(&(r % self.num_workers)))
            .collect();
        let ttl = st.grace_ttl;
        let expires = Instant::now() + ttl;
        for r in dropped {
            if let Some(slots) = st.rounds.remove(&r) {
                if ttl > Duration::ZERO {
                    // Keep the buffered rounds servable read-only for one
                    // grace window: a consumer whose fetch raced the
                    // handoff still gets its slot instead of bouncing off
                    // `WrongWorker` while the dispatcher flips the lease.
                    st.grace.insert(r, (slots, expires));
                } else {
                    st.abandoned_slots +=
                        slots.iter().filter(|s| s.is_some()).count() as u64;
                }
            }
        }
        drop(st);
        self.cond.notify_all();
        self.space.notify_all();
        n
    }

    /// Set how long buffered rounds of a revoked residue stay servable
    /// read-only ([`RoundTake::Grace`]). Zero (the default) disables the
    /// window; production tasks set one heartbeat interval — the longest
    /// a consumer's routing table can lag the lease flip.
    fn set_revoke_grace(&self, ttl: Duration) {
        self.inner.lock().unwrap().grace_ttl = ttl;
    }

    /// Drop grace entries past their deadline, folding their unserved
    /// slots into the abandoned count. Caller holds the lock.
    fn expire_grace(st: &mut CoordinatedInner) {
        if st.grace.is_empty() {
            return;
        }
        let now = Instant::now();
        let mut freed = 0u64;
        st.grace.retain(|_, (slots, expires)| {
            if now >= *expires {
                freed += slots.iter().filter(|s| s.is_some()).count() as u64;
                false
            } else {
                true
            }
        });
        st.abandoned_slots += freed;
    }

    /// Drop buffered rounds every one of *their own* slot holders has
    /// moved past (see the type docs). Judged per round against the
    /// round's slot count rather than a global minimum watermark: after
    /// a shrink epoch a departed consumer's watermark freezes at the
    /// barrier, and it must not pin post-barrier rounds it holds no
    /// slot in. Caller holds the lock and notifies `space` if needed.
    fn gc_abandoned(st: &mut CoordinatedInner) -> bool {
        let stale: Vec<u64> = st
            .rounds
            .iter()
            .filter(|(&r, slots)| {
                (0..slots.len()).all(|c| st.watermarks.get(c).is_some_and(|&w| w > r))
            })
            .map(|(&r, _)| r)
            .collect();
        let any = !stale.is_empty();
        for r in stale {
            if let Some(slots) = st.rounds.remove(&r) {
                st.abandoned_slots += slots.iter().filter(|s| s.is_some()).count() as u64;
            }
        }
        any
    }

    /// Consumer side: take `consumer`'s slot for `round`, blocking up to
    /// `timeout` for the round to materialize.
    ///
    /// A consumer index past the round's width is a *wait*, never an
    /// error: a slot granted by a grow epoch the schedule hasn't reached
    /// this worker yet (or a round awaiting re-key) resolves within a
    /// heartbeat, and a shrunk slot's client stops asking on its own at
    /// the barrier. The two genuinely-consumed outcomes — the round was
    /// fully drained, or this slot was already taken (a replaced
    /// consumer re-walking its predecessor's progress) — answer with a
    /// [`super::ROUND_CONSUMED_PREFIX`] error carrying a
    /// `next round {n}` hint so the client can skip forward instead of
    /// surfacing a terminal failure.
    fn take(&self, round: u64, consumer: usize, timeout: Duration) -> ServiceResult<RoundTake> {
        let deadline = Instant::now() + timeout;
        let mut st = self.inner.lock().unwrap();
        if consumer >= st.watermarks.len() {
            // A grow epoch adds slots; track the newcomer's progress
            // from its first fetch.
            st.watermarks.resize(consumer + 1, 0);
        }
        // Asking for `round` implies every earlier round was consumed
        // (or abandoned) by this consumer: advance its watermark and GC
        // rounds nobody will ever fetch again.
        if st.watermarks[consumer] < round {
            st.watermarks[consumer] = round;
            if Self::gc_abandoned(&mut st) {
                self.space.notify_all();
            }
        }
        loop {
            if !st.owned.contains(&(round % self.num_workers)) {
                // Post-revoke grace: a fetch that raced the two-phase
                // handoff still finds its slot here for one window. The
                // slot is cloned, not consumed — the gainer owns the
                // round now and grace only absorbs the routing race.
                Self::expire_grace(&mut st);
                if let Some((slots, _)) = st.grace.get(&round) {
                    if let Some(Some(bytes)) = slots.get(consumer) {
                        return Ok(RoundTake::Grace(bytes.clone()));
                    }
                }
                return Ok(RoundTake::WrongWorker);
            }
            // `None` when the round is buffered but narrower than this
            // consumer's slot (its re-key to a grow epoch is pending):
            // treated like an unmaterialized round — wait.
            let buffered_wide_enough = st.rounds.get(&round).map(|s| consumer < s.len());
            if buffered_wide_enough == Some(true) {
                let slots = st.rounds.get_mut(&round).expect("round buffered");
                let e = slots[consumer].take();
                if slots.iter().all(Option::is_none) {
                    st.rounds.remove(&round);
                    self.space.notify_all();
                }
                return match e {
                    Some(bytes) => {
                        st.watermarks[consumer] = st.watermarks[consumer].max(round + 1);
                        Ok(RoundTake::Bytes(bytes))
                    }
                    None => Err(ServiceError::Other(format!(
                        "{}: consumer {consumer} fetched round {round} twice; next round {}",
                        super::ROUND_CONSUMED_PREFIX,
                        round + 1
                    ))),
                };
            }
            if buffered_wide_enough.is_none()
                && consumer < Self::width_for(&st.widths, round)
            {
                let next = st
                    .next_by_residue
                    .get(&(round % self.num_workers))
                    .copied()
                    .unwrap_or(round);
                if round < next {
                    // Materialized earlier and since fully consumed. A
                    // replacement consumer re-walking its dead
                    // predecessor's progress lands here: tell it where
                    // to resume rather than erroring terminally.
                    return Err(ServiceError::Other(format!(
                        "{}: round {round} fully consumed; next round {}",
                        super::ROUND_CONSUMED_PREFIX,
                        round + 1
                    )));
                }
            }
            if st.eos {
                return Ok(RoundTake::Eos);
            }
            if Instant::now() >= deadline {
                return Ok(RoundTake::Pending);
            }
            let wait = deadline.saturating_duration_since(Instant::now());
            let (next_st, _) = self.cond.wait_timeout(st, wait).unwrap();
            st = next_st;
            // A producer catching up after a lease change can install
            // rounds every consumer already moved past: collect them as
            // they appear so the bounded buffer never wedges on stale
            // labels while a consumer is waiting.
            if Self::gc_abandoned(&mut st) {
                self.space.notify_all();
            }
        }
    }
}

enum TaskState {
    Independent {
        cache: Arc<SlidingCache>,
        /// Producer output channel the serve path drains on demand.
        rx: chan::Receiver<Element>,
        /// Elements the producer has committed to the channel that have
        /// not yet been published to the cache. Incremented before the
        /// producer's send, decremented by serve paths *after* pushing
        /// into the cache — so a concurrent handler that popped the last
        /// element but has not published it yet keeps this non-zero, and
        /// no other handler can falsely declare end-of-sequence (which
        /// would silently truncate the stream for one client of a shared
        /// job).
        in_flight: Arc<AtomicU64>,
    },
    Coordinated(Arc<CoordinatedState>),
}

struct TaskRunner {
    #[allow(dead_code)]
    job_id: u64,
    state: TaskState,
    stop: Arc<AtomicBool>,
    /// Nanoseconds of producer busy time (CPU-utilization signal).
    busy_ns: Arc<AtomicU64>,
    /// This task's AUTOTUNE state: the replan controller in the
    /// heartbeat loop feeds observed production rate + backpressure into
    /// per-stage parallelism targets ([`replan_tasks`]).
    autotune: Arc<crate::data::autotune::AutotuneState>,
    /// Elements this task's producer has emitted (replan rate window).
    produced: Arc<AtomicU64>,
    /// `produced` at the previous replan tick.
    last_produced: AtomicU64,
}

impl TaskRunner {
    /// Stop the producer, including one parked on coordinated-round
    /// backpressure (the bounded buffer wait must not outlive the task).
    fn halt(&self) {
        self.stop.store(true, Ordering::SeqCst);
        if let TaskState::Coordinated(coord) = &self.state {
            coord.halt();
        }
    }
}

/// One negotiated client<->worker stream (the tentpole of the versioned
/// data plane). Created by `OpenStream`, scoped to a (job, client) pair,
/// and the unit of chunked-transfer state: an oversized element popped
/// from the cache parks here until the consumer's acknowledged offset
/// reaches its length, so the cache cursor advancing can never lose data.
struct StreamSession {
    job_id: u64,
    client_id: u64,
    /// Negotiated [`stream_caps`] intersection.
    caps: u64,
    /// Negotiated response-frame budget (bytes, <= `rpc::MAX_FRAME_LEN`).
    max_frame: usize,
    /// Coordinated mode: the consumer slot this session reads for.
    consumer_index: Option<u32>,
    /// Pending oversized elements mid chunked transfer, keyed by the
    /// round they came from ([`INDEPENDENT_CHUNK_KEY`] for the
    /// independent stream, which has no rounds). With round prefetch a
    /// session may have transfers for several rounds in flight at once —
    /// so the chunk slot is keyed by `(round, chunk_seq)` rather than
    /// being a scalar. Each parked element carries a session-unique
    /// `chunk_seq`: progress lives client-side as the
    /// `(chunk_seq, chunk_offset)` it echoes back, the seq tag keeps a
    /// retried ack from a previous, already-released element from
    /// touching a new one, and release acks are matched by seq across
    /// all parked rounds (the ack for round `r`'s element rides the
    /// first request for round `r+1`). The second field is the next seq
    /// to assign.
    chunk: Mutex<(HashMap<u64, (u64, Arc<Vec<u8>>)>, u64)>,
}

/// Chunk-slot key for the (round-less) independent stream.
const INDEPENDENT_CHUNK_KEY: u64 = u64::MAX;

impl StreamSession {
    /// Largest element-byte payload a response frame may carry.
    fn frame_budget(&self) -> usize {
        self.max_frame.min(crate::rpc::MAX_FRAME_LEN).saturating_sub(FRAME_HEADROOM)
    }

    /// Park an oversized element under `round_key` for
    /// continuation-frame delivery and return its fresh chunk seq.
    fn park_chunk(&self, round_key: u64, bytes: Arc<Vec<u8>>) -> u64 {
        let mut st = self.chunk.lock().unwrap();
        let seq = st.1;
        st.1 += 1;
        st.0.insert(round_key, (seq, bytes));
        seq
    }
}

struct WorkerShared {
    cfg: WorkerConfig,
    tasks: Mutex<HashMap<u64, Arc<TaskRunner>>>,
    /// Live stream sessions by id; entries die with their task, with the
    /// consumer's release, or via an explicit `CloseStream`.
    sessions: Mutex<HashMap<u64, Arc<StreamSession>>>,
    next_session_id: AtomicU64,
    metrics: Registry,
    pool: Arc<Pool>,
    dispatcher_addr: String,
    worker_id: AtomicU64,
    stop: AtomicBool,
    /// The dispatcher marked this worker `Draining` (two-phase graceful
    /// scale-down); mirrored from the last heartbeat response.
    draining: AtomicBool,
    /// Set once a `drain: true` heartbeat response has been fully
    /// processed (revocations applied, pending spill buffers flushed);
    /// reported back as `drain_ready` on the next heartbeat.
    drain_ready: AtomicBool,
    /// Revocation acks accumulated while processing heartbeat responses,
    /// delivered on the next heartbeat request. Acks fire on *every*
    /// receipt of a revocation — the dispatcher re-delivers until an ack
    /// lands, and revoking an already-released residue is a no-op that
    /// must still ack.
    revoke_acks: Mutex<Vec<LeaseRevoke>>,
    /// Recycled encode buffers for GetElements/Fetch frame assembly.
    frame_bufs: BufPool,
    /// Observed-ratio compression chooser for batch response frames
    /// (shared across tasks: the shape classes are payload-size buckets,
    /// so one task's probe verdicts carry to the next task of the same
    /// pipeline). See [`crate::wire::AdaptiveCodec`].
    codec: crate::wire::AdaptiveCodec,
}

/// A running worker: data server + heartbeat loop.
pub struct Worker {
    shared: Arc<WorkerShared>,
    server: Server,
    hb_thread: Option<std::thread::JoinHandle<()>>,
}

impl Worker {
    /// Start a worker, register with the dispatcher, and begin
    /// heartbeating. `addr` is the data-server bind address (port 0 ok).
    pub fn start(addr: &str, dispatcher_addr: &str, cfg: WorkerConfig) -> ServiceResult<Worker> {
        let pool = Arc::new(Pool::with_defaults());
        let shared = Arc::new(WorkerShared {
            cfg,
            tasks: Mutex::new(HashMap::new()),
            sessions: Mutex::new(HashMap::new()),
            next_session_id: AtomicU64::new(1),
            metrics: Registry::new(),
            pool,
            dispatcher_addr: dispatcher_addr.to_string(),
            worker_id: AtomicU64::new(0),
            stop: AtomicBool::new(false),
            draining: AtomicBool::new(false),
            drain_ready: AtomicBool::new(false),
            revoke_acks: Mutex::new(Vec::new()),
            frame_bufs: BufPool::new(8),
            codec: crate::wire::AdaptiveCodec::new(),
        });

        let s2 = shared.clone();
        let server = Server::bind(addr, move |method: u16, payload: &[u8]| {
            serve(&s2, method, payload).map_err(|e| e.to_string())
        })
        .map_err(|e| ServiceError::Other(format!("bind: {e}")))?;
        let my_addr = server.local_addr().to_string();
        // Register under the advertised (stable) address when configured:
        // the dispatcher keys worker identity by this, so a revival
        // behind the same front keeps the same worker id.
        let reg_addr = shared.cfg.advertise_addr.clone().unwrap_or_else(|| my_addr.clone());

        // Register: returns our id plus tasks for all active jobs.
        let resp: RegisterWorkerResp = call_typed(
            &shared.pool,
            dispatcher_addr,
            dispatcher_methods::REGISTER_WORKER,
            &RegisterWorkerReq { addr: reg_addr },
            Duration::from_secs(10),
        )?;
        shared.worker_id.store(resp.worker_id, Ordering::SeqCst);
        for task in resp.tasks {
            start_task(&shared, task);
        }

        // Heartbeat loop.
        let s3 = shared.clone();
        let hb = std::thread::Builder::new()
            .name(format!("worker-hb-{my_addr}"))
            .spawn(move || heartbeat_loop(s3))
            .ok();

        Ok(Worker { shared, server, hb_thread: hb })
    }

    pub fn addr(&self) -> String {
        self.server.local_addr().to_string()
    }

    pub fn worker_id(&self) -> u64 {
        self.shared.worker_id.load(Ordering::SeqCst)
    }

    /// Whether the dispatcher has marked this worker draining (mirrored
    /// from the last heartbeat response).
    pub fn is_draining(&self) -> bool {
        self.shared.draining.load(Ordering::SeqCst)
    }

    pub fn metrics(&self) -> &Registry {
        &self.shared.metrics
    }

    pub fn active_tasks(&self) -> Vec<u64> {
        self.shared.tasks.lock().unwrap().keys().copied().collect()
    }

    /// Stop producers, heartbeats, and the data server (worker preemption).
    pub fn shutdown(&self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        for t in self.shared.tasks.lock().unwrap().values() {
            t.halt();
        }
        self.server.shutdown();
    }
}

impl Drop for Worker {
    fn drop(&mut self) {
        self.shutdown();
        if let Some(t) = self.hb_thread.take() {
            let _ = t.join();
        }
    }
}

/// Register newly-attached consumers' cursors and drop released ones.
/// Shared by the heartbeat delivery path and the dispatcher's synchronous
/// [`worker_methods::UPDATE_CONSUMERS`] push; both may deliver the same
/// update — registration and tombstoning are idempotent, and the counters
/// only move on the first application. Returns how many updates landed on
/// a live independent-mode task.
fn apply_consumer_updates(
    shared: &Arc<WorkerShared>,
    attached: &[ConsumerUpdate],
    released: &[ConsumerUpdate],
) -> u32 {
    let mut applied = 0u32;
    for cu in attached {
        if let Some(t) = shared.tasks.lock().unwrap().get(&cu.job_id).cloned() {
            if let TaskState::Independent { cache, .. } = &t.state {
                if cache.register_consumer(cu.client_id) {
                    shared.metrics.counter("worker/consumers_attached").inc();
                    applied += 1;
                }
            }
        }
    }
    for cu in released {
        if let Some(t) = shared.tasks.lock().unwrap().get(&cu.job_id).cloned() {
            if let TaskState::Independent { cache, .. } = &t.state {
                if cache.remove_consumer(cu.client_id) {
                    shared.metrics.counter("worker/consumers_detached").inc();
                    applied += 1;
                }
            }
        }
        // A released consumer's stream sessions die with it; a straggler
        // Fetch then errors instead of resurrecting chunk state for a
        // departed client.
        shared
            .sessions
            .lock()
            .unwrap()
            .retain(|_, s| !(s.job_id == cu.job_id && s.client_id == cu.client_id));
    }
    applied
}

/// The AUTOTUNE replan controller (§3.2, wired into the worker): feed the
/// backpressure signals the data plane already collects — producer
/// ready-queue depth, window occupancy, buffered coordinated rounds —
/// into per-stage parallelism targets. Producer running ahead of
/// consumption plans for half the observed rate (freeing CPU for other
/// tasks on the worker); consumers starving plan for double (scaling the
/// map stages up within the CPU budget). Elastic stages apply the new
/// plan immediately (threads park/unpark on the plan generation).
fn replan_tasks(shared: &Arc<WorkerShared>, dt: f64) {
    if dt <= 0.0 {
        return;
    }
    let tasks: Vec<Arc<TaskRunner>> = shared.tasks.lock().unwrap().values().cloned().collect();
    for t in tasks {
        let produced = t.produced.load(Ordering::Relaxed);
        let last = t.last_produced.swap(produced, Ordering::Relaxed);
        if produced == last {
            // No progress this window: stalled or finished — a replan
            // would read an empty measurement window and plan blind.
            continue;
        }
        let rate = produced.saturating_sub(last) as f64 / dt;
        let (backlog, high) = match &t.state {
            TaskState::Independent { cache, rx, .. } => {
                let (_, window, _) = cache.occupancy(u64::MAX);
                (rx.len() + window, shared.cfg.buffer_size.max(1))
            }
            TaskState::Coordinated(coord) => {
                (coord.buffered_rounds(), shared.cfg.round_prefetch_depth.max(1))
            }
        };
        let demand = if backlog >= high {
            rate * 0.5
        } else if backlog == 0 {
            rate * 2.0 + 1.0
        } else {
            rate
        };
        t.autotune.replan(demand);
        shared.metrics.counter("worker/autotune_replans").inc();
    }
}

/// Gather completed spill manifests to report on the next heartbeat.
///
/// A full-epoch spill (`SpillPolicy::All`) can only be finalized once the
/// pipeline hit end-of-sequence AND every produced element reached the
/// window (in-flight count zero) — otherwise the manifest would certify a
/// prefix as a whole epoch. The producer channel is drained here so a job
/// whose consumers stopped fetching early still gets its tail spilled.
/// Manifests keep being re-reported until the dispatcher acks them, which
/// makes the commit protocol safe against lost heartbeats.
fn collect_spill_manifests(shared: &Arc<WorkerShared>) -> Vec<SpillManifest> {
    let tasks: Vec<Arc<TaskRunner>> =
        shared.tasks.lock().unwrap().values().cloned().collect();
    let mut out = Vec::new();
    for t in tasks {
        let TaskState::Independent { cache, rx, in_flight } = &t.state else { continue };
        let Some(sp) = cache.spill() else { continue };
        if sp.policy == SpillPolicy::All && !sp.is_complete() && cache.is_eos() {
            // Pull any produced-but-unpublished elements into the window
            // so the tail flush below sees the complete epoch.
            let mut fresh = Vec::new();
            while let Some(e) = rx.try_recv() {
                fresh.push(Arc::new(e.to_bytes()));
            }
            let drained = fresh.len() as u64;
            if drained > 0 {
                cache.push_encoded(fresh);
                in_flight.fetch_sub(drained, Ordering::SeqCst);
            }
            if in_flight.load(Ordering::SeqCst) == 0 {
                cache.flush_tail_to_spill();
                sp.finalize();
            }
        }
        if sp.is_complete() && !sp.acked.load(Ordering::SeqCst) {
            out.push(sp.manifest());
        }
    }
    out
}

fn heartbeat_loop(shared: Arc<WorkerShared>) {
    let mut last_busy = 0u64;
    let mut last_t = Instant::now();
    let mut last_replan = Instant::now();
    while !shared.stop.load(Ordering::SeqCst) {
        std::thread::sleep(shared.cfg.heartbeat_interval);
        if shared.stop.load(Ordering::SeqCst) {
            break;
        }
        // Periodic replan: a ~1s window is long enough for the stage
        // stats to hold real samples, short enough to track load shifts.
        if last_replan.elapsed() >= Duration::from_secs(1) {
            replan_tasks(&shared, last_replan.elapsed().as_secs_f64());
            last_replan = Instant::now();
        }
        let active: Vec<u64> = shared.tasks.lock().unwrap().keys().copied().collect();
        // CPU utilization signal: producer busy time per wallclock.
        let busy_now: u64 = shared
            .tasks
            .lock()
            .unwrap()
            .values()
            .map(|t| t.busy_ns.load(Ordering::Relaxed))
            .sum();
        let elapsed = last_t.elapsed().as_nanos().max(1) as u64;
        let util_milli = ((busy_now.saturating_sub(last_busy)) * 1000 / elapsed).min(8000) as u32;
        last_busy = busy_now;
        last_t = Instant::now();

        let req = WorkerHeartbeatReq {
            worker_id: shared.worker_id.load(Ordering::SeqCst),
            active_tasks: active,
            cpu_util_milli: util_milli,
            spill_manifests: collect_spill_manifests(&shared),
            // Acks for revocations applied while processing the previous
            // response. Losing this request is safe: the dispatcher
            // re-delivers the revocation and the re-application is a
            // no-op that re-acks.
            revoke_acks: std::mem::take(&mut *shared.revoke_acks.lock().unwrap()),
            drain_ready: shared.drain_ready.load(Ordering::SeqCst),
        };
        let resp: Result<WorkerHeartbeatResp, _> = call_typed(
            &shared.pool,
            &shared.dispatcher_addr,
            dispatcher_methods::WORKER_HEARTBEAT,
            &req,
            Duration::from_secs(5),
        );
        match resp {
            Ok(resp) => {
                for task in resp.new_tasks {
                    start_task(&shared, task);
                }
                // Consumer churn on shared streams (§3.5): tasks were
                // started above, so an attach delivered alongside its
                // task lands on a live cache. (The dispatcher also
                // pushes these synchronously via UPDATE_CONSUMERS; this
                // is the reliable fallback.)
                apply_consumer_updates(&shared, &resp.attached_clients, &resp.released_clients);
                // Round-lease updates (§3.6 fault tolerance): adopt a
                // failed owner's residues — the producer starts labeling
                // those rounds from the dispatcher's floor — or drop
                // residues the dispatcher moved away while this worker
                // was presumed dead.
                for ra in &resp.round_assignments {
                    if let Some(t) = shared.tasks.lock().unwrap().get(&ra.job_id).cloned() {
                        if let TaskState::Coordinated(coord) = &t.state {
                            let residues: Vec<u64> =
                                ra.owned_residues.iter().map(|&r| r as u64).collect();
                            coord.set_owned(&residues, ra.start_round);
                            shared.metrics.counter("worker/round_leases_updated").inc();
                        }
                    }
                }
                // Membership epochs (§3.6 elasticity): apply the
                // epoch-versioned width schedule. Buffered rounds at or
                // past the newest barrier re-key — dropped here,
                // re-materialized by the producer at the new width.
                for wu in &resp.width_updates {
                    if let Some(t) = shared.tasks.lock().unwrap().get(&wu.job_id).cloned() {
                        if let TaskState::Coordinated(coord) = &t.state {
                            let rekeyed = coord.set_width_schedule(&wu.width_epochs);
                            if rekeyed > 0 {
                                shared.metrics.counter("worker/rounds_rekeyed").add(rekeyed);
                            }
                            shared.metrics.counter("worker/width_updates_applied").inc();
                        }
                    }
                }
                // Phase-1 lease revocations (graceful drain / two-phase
                // revival re-balance): stop serving the residues *now*,
                // then ack on the next heartbeat — the dispatcher flips
                // the lease to the gainer only on the ack, so loser and
                // gainer never co-hold a residue.
                if !resp.round_revocations.is_empty() {
                    for rv in &resp.round_revocations {
                        if let Some(t) = shared.tasks.lock().unwrap().get(&rv.job_id).cloned() {
                            if let TaskState::Coordinated(coord) = &t.state {
                                let residues: Vec<u64> =
                                    rv.residues.iter().map(|&r| r as u64).collect();
                                let n = coord.revoke(&residues);
                                if n > 0 {
                                    shared
                                        .metrics
                                        .counter("worker/round_leases_revoked")
                                        .add(n as u64);
                                }
                            }
                        }
                        shared.revoke_acks.lock().unwrap().push(rv.clone());
                    }
                }
                // Draining: make everything buffered durable — force-
                // flush every job's pending spill buffer — then report
                // drain-ready on the next heartbeat. Re-run every
                // heartbeat while the flag holds (idempotent), so spill
                // produced after the first flush still lands.
                let was_draining = shared.draining.swap(resp.drain, Ordering::SeqCst);
                if resp.drain {
                    let drain_tasks: Vec<Arc<TaskRunner>> =
                        shared.tasks.lock().unwrap().values().cloned().collect();
                    for t in &drain_tasks {
                        if let TaskState::Independent { cache, .. } = &t.state {
                            if let Some(sp) = cache.spill() {
                                sp.flush_pending();
                            }
                        }
                    }
                    if !was_draining {
                        shared.metrics.counter("worker/drains_started").inc();
                    }
                    shared.drain_ready.store(true, Ordering::SeqCst);
                } else if was_draining {
                    // Drain canceled (or this incarnation re-admitted).
                    shared.drain_ready.store(false, Ordering::SeqCst);
                }
                // Spill-manifest acks: the dispatcher journaled (or already
                // knew about) these epochs — stop re-reporting them.
                for id in &resp.manifest_acks {
                    if let Some(t) = shared.tasks.lock().unwrap().get(id).cloned() {
                        if let TaskState::Independent { cache, .. } = &t.state {
                            if let Some(sp) = cache.spill() {
                                sp.acked.store(true, Ordering::SeqCst);
                            }
                        }
                    }
                }
                if !resp.removed_tasks.is_empty() {
                    let mut tasks = shared.tasks.lock().unwrap();
                    for id in &resp.removed_tasks {
                        if let Some(t) = tasks.remove(id) {
                            t.halt();
                            if let TaskState::Independent { cache, .. } = &t.state {
                                // The job is gone: zero its occupancy
                                // gauges so the registry doesn't report a
                                // phantom window forever.
                                cache.win_elems_gauge.set(0);
                                cache.win_bytes_gauge.set(0);
                            }
                        }
                    }
                    drop(tasks);
                    let removed: std::collections::HashSet<u64> =
                        resp.removed_tasks.iter().copied().collect();
                    shared.sessions.lock().unwrap().retain(|_, s| !removed.contains(&s.job_id));
                }
            }
            Err(_) => {
                // Dispatcher down: keep producing for active jobs (§3.4).
                shared.metrics.counter("worker/heartbeat_failures").inc();
            }
        }
    }
}

/// Spawn the producer thread(s) for a task and register its serving state.
fn start_task(shared: &Arc<WorkerShared>, task: TaskDef) {
    let mut tasks = shared.tasks.lock().unwrap();
    if let Some(existing) = tasks.get(&task.job_id) {
        // Already running (duplicate delivery is fine). One correction:
        // a worker that was declared dead and re-registered may get the
        // task again with a *different* lease set (its residues were
        // reassigned while it was presumed gone) — apply it so a zombie
        // owner stops materializing rounds the new lease holder serves.
        if let TaskState::Coordinated(coord) = &existing.state {
            let residues: Vec<u64> = task.owned_residues.iter().map(|&r| r as u64).collect();
            coord.set_owned(&residues, task.start_round);
            // Same reasoning for the width schedule: a membership epoch
            // published while this worker was presumed dead rides the
            // re-delivered task (idempotent when nothing changed).
            coord.set_width_schedule(&task.width_epochs);
        }
        return;
    }
    let stop = Arc::new(AtomicBool::new(false));
    let busy_ns = Arc::new(AtomicU64::new(0));
    let produced = Arc::new(AtomicU64::new(0));
    let worker_id = shared.worker_id.load(Ordering::SeqCst);

    // Split provider per sharding policy.
    let num_shards = super::graph_num_shards(&task.graph);
    let splits: Arc<dyn SplitProvider> = match task.sharding {
        ShardingPolicy::Off => ShuffledAllSplits::new(num_shards, worker_id ^ task.job_id),
        ShardingPolicy::Dynamic => DynamicSplitProvider::new(
            shared.pool.clone(),
            shared.dispatcher_addr.clone(),
            task.job_id,
            worker_id,
        ),
        ShardingPolicy::Static => {
            crate::data::exec::FixedSplits::new(task.static_shards.iter().map(|&s| s as usize).collect())
        }
    };
    let autotune = Arc::new(crate::data::autotune::AutotuneState::default());
    let exec_cfg = ExecutorConfig {
        store: shared.cfg.store.clone(),
        udfs: shared.cfg.udfs.clone(),
        region: shared.cfg.region.clone(),
        splits,
        autotune: autotune.clone(),
    };

    let state = match task.mode {
        ProcessingMode::Independent => {
            // Spill tier (policy-gated): elements evicted from the RAM
            // window tier into the object store instead of vanishing, so
            // laggards and late attachers replay instead of skipping. A
            // snapshot-serve task already reads from the store and never
            // spills.
            let spill_tier = (shared.cfg.spill.policy != SpillPolicy::Off
                && task.snapshot_manifest.is_none())
            .then(|| {
                let sp = JobSpill::new(
                    shared.cfg.store.clone(),
                    shared.cfg.region.clone(),
                    &shared.cfg.spill,
                    task.job_id,
                    task.dataset_id,
                    &shared.metrics,
                );
                // A replacement worker adopts its predecessor's
                // committed prefix before producing anything.
                sp.adopt_existing();
                sp
            });
            let cache = Arc::new(SlidingCache::new(
                shared.cfg.cache_window,
                shared.cfg.cache_window_bytes,
                shared.cfg.eager_window_eviction,
                task.job_id,
                spill_tier,
                &shared.metrics,
            ));
            // Register the consumers attached at task-creation time so
            // they count toward the stream's consumer set (and anchor at
            // the stream head) before their first fetch arrives; later
            // joins/leaves come via the dispatcher's synchronous push
            // (UPDATE_CONSUMERS) with heartbeat consumer updates as the
            // reliable fallback.
            for c in &task.consumers {
                cache.register_consumer(*c);
            }
            let (tx, rx) = chan::bounded::<Element>(shared.cfg.buffer_size);
            let in_flight = Arc::new(AtomicU64::new(0));
            let inflight_tx = in_flight.clone();
            let sink = move |e: Element| {
                // Count before the send so a popped-but-unpublished
                // element is never unaccounted (see TaskState docs).
                inflight_tx.fetch_add(1, Ordering::SeqCst);
                if tx.send(e).is_ok() {
                    true
                } else {
                    inflight_tx.fetch_sub(1, Ordering::SeqCst);
                    false
                }
            };
            let on_eos = {
                let cache = cache.clone();
                move || cache.set_eos()
            };
            match task.snapshot_manifest.clone() {
                Some(manifest) => {
                    // Snapshot serve: stream the committed epoch's
                    // segments from the store instead of running the
                    // pipeline (fingerprint-keyed snapshot reuse).
                    shared.metrics.counter("worker/snapshot_serves").inc();
                    spawn_snapshot_streamer(
                        shared,
                        &task,
                        exec_cfg,
                        stop.clone(),
                        busy_ns.clone(),
                        produced.clone(),
                        manifest,
                        sink,
                        on_eos,
                    );
                }
                None => {
                    spawn_producer(
                        shared,
                        &task,
                        exec_cfg,
                        stop.clone(),
                        busy_ns.clone(),
                        produced.clone(),
                        sink,
                        on_eos,
                    );
                }
            }
            TaskState::Independent { cache, rx, in_flight }
        }
        ProcessingMode::Coordinated => {
            let coord = Arc::new(CoordinatedState::new(
                task.num_consumers as usize,
                task.worker_index as u64,
                task.num_workers as u64,
                &task.owned_residues,
                task.has_lease_view,
                task.start_round,
                shared.cfg.round_prefetch_depth,
            ));
            // A task created (or re-delivered) after a membership change
            // carries the job's full epoch schedule; the initial
            // single-epoch schedule is a no-op here.
            coord.set_width_schedule(&task.width_epochs);
            // One heartbeat of post-revoke grace: the longest a
            // consumer's routing table can lag a two-phase lease flip.
            coord.set_revoke_grace(shared.cfg.heartbeat_interval);
            let c2 = coord.clone();
            spawn_producer(
                shared,
                &task,
                exec_cfg,
                stop.clone(),
                busy_ns.clone(),
                produced.clone(),
                move |e| {
                    // Pre-encode at production time (off the serve path):
                    // each consumer's fetch then hands out an Arc clone
                    // instead of encoding per request. Round grouping
                    // happens inside `offer`, where the width schedule
                    // decides each round's slot count at install time;
                    // it blocks on the bounded multi-round buffer
                    // (condvar backpressure, no polling) and returns
                    // false only when the task stopped.
                    c2.offer(Arc::new(e.to_bytes()))
                },
                {
                    let coord = coord.clone();
                    move || coord.set_eos()
                },
            );
            TaskState::Coordinated(coord)
        }
    };

    let runner = Arc::new(TaskRunner {
        job_id: task.job_id,
        state,
        stop,
        busy_ns,
        autotune,
        produced,
        last_produced: AtomicU64::new(0),
    });
    tasks.insert(task.job_id, runner);
    shared.metrics.counter("worker/tasks_started").inc();
}

/// Producer thread: run the pipeline, handing each element to `sink`
/// (returns false to stop), then `on_eos`.
fn spawn_producer(
    shared: &Arc<WorkerShared>,
    task: &TaskDef,
    exec_cfg: ExecutorConfig,
    stop: Arc<AtomicBool>,
    busy_ns: Arc<AtomicU64>,
    produced: Arc<AtomicU64>,
    mut sink: impl FnMut(Element) -> bool + Send + 'static,
    on_eos: impl FnOnce() + Send + 'static,
) {
    let graph = task.graph.clone();
    let metrics = shared.metrics.clone();
    let job_id = task.job_id;
    std::thread::Builder::new()
        .name(format!("producer-{job_id}"))
        .spawn(move || {
            let ex = Executor::new(exec_cfg);
            let mut it = match ex.iterate(&graph) {
                Ok(it) => it,
                Err(e) => {
                    metrics.counter("worker/pipeline_errors").inc();
                    eprintln!("job {job_id}: pipeline build failed: {e}");
                    on_eos();
                    return;
                }
            };
            loop {
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                let t0 = Instant::now();
                match it.next() {
                    Ok(Some(e)) => {
                        busy_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                        metrics.counter("worker/elements_produced").inc();
                        produced.fetch_add(1, Ordering::Relaxed);
                        if !sink(e) {
                            break;
                        }
                    }
                    Ok(None) => break,
                    Err(e) => {
                        metrics.counter("worker/pipeline_errors").inc();
                        eprintln!("job {job_id}: pipeline error: {e}");
                        break;
                    }
                }
            }
            on_eos();
        })
        .ok();
}

/// Snapshot-serve producer: stream this worker's slice of a committed
/// fingerprint-keyed snapshot straight out of the object store —
/// decoding each segment once, paying [`crate::storage::NetModel`] read
/// costs when the store is remote — instead of re-running the pipeline.
/// On an integrity failure (missing or corrupt segment) the task falls
/// back to live production: the pipeline runs from the top and the
/// already-streamed prefix is skipped, so every element is still
/// delivered exactly once.
fn spawn_snapshot_streamer(
    shared: &Arc<WorkerShared>,
    task: &TaskDef,
    exec_cfg: ExecutorConfig,
    stop: Arc<AtomicBool>,
    busy_ns: Arc<AtomicU64>,
    produced: Arc<AtomicU64>,
    manifest: SpillManifest,
    mut sink: impl FnMut(Element) -> bool + Send + 'static,
    on_eos: impl FnOnce() + Send + 'static,
) {
    let graph = task.graph.clone();
    let metrics = shared.metrics.clone();
    let job_id = task.job_id;
    let store = shared.cfg.store.clone();
    let region = shared.cfg.region.clone();
    std::thread::Builder::new()
        .name(format!("snapshot-{job_id}"))
        .spawn(move || {
            let streamed_ctr = metrics.counter("worker/snapshot_elements_streamed");
            let mut streamed = 0u64;
            let mut intact = true;
            'segments: for seg in &manifest.segments {
                if stop.load(Ordering::SeqCst) {
                    on_eos();
                    return;
                }
                let t0 = Instant::now();
                match spill::read_segment(&store, &region, seg) {
                    Ok(batch) => {
                        busy_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                        for bytes in batch {
                            let Ok(e) = Element::from_bytes(&bytes) else {
                                intact = false;
                                break 'segments;
                            };
                            streamed += 1;
                            streamed_ctr.inc();
                            produced.fetch_add(1, Ordering::Relaxed);
                            if !sink(e) {
                                on_eos();
                                return;
                            }
                        }
                    }
                    Err(e) => {
                        eprintln!("job {job_id}: snapshot segment unreadable: {e}");
                        intact = false;
                        break 'segments;
                    }
                }
            }
            if intact {
                on_eos();
                return;
            }
            // Live fallback: re-produce the epoch, skipping the prefix
            // already streamed so consumers see no duplicates.
            metrics.counter("worker/snapshot_fallbacks").inc();
            let ex = Executor::new(exec_cfg);
            let mut it = match ex.iterate(&graph) {
                Ok(it) => it,
                Err(e) => {
                    metrics.counter("worker/pipeline_errors").inc();
                    eprintln!("job {job_id}: snapshot fallback build failed: {e}");
                    on_eos();
                    return;
                }
            };
            let mut to_skip = streamed;
            loop {
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                let t0 = Instant::now();
                match it.next() {
                    Ok(Some(e)) => {
                        busy_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                        if to_skip > 0 {
                            to_skip -= 1;
                            continue;
                        }
                        metrics.counter("worker/elements_produced").inc();
                        produced.fetch_add(1, Ordering::Relaxed);
                        if !sink(e) {
                            break;
                        }
                    }
                    Ok(None) => break,
                    Err(e) => {
                        metrics.counter("worker/pipeline_errors").inc();
                        eprintln!("job {job_id}: snapshot fallback error: {e}");
                        break;
                    }
                }
            }
            on_eos();
        })
        .ok();
}

/// Data-server RPC demux. `Fetch`/`GetElements` responses come back as
/// `(head, frame)` write slices so the element frame flows to the socket
/// without an intermediate payload copy; everything else is head-only.
fn serve(shared: &Arc<WorkerShared>, method: u16, payload: &[u8]) -> ServiceResult<RespBody> {
    match method {
        worker_methods::GET_ELEMENT => {
            let req = GetElementReq::from_bytes(payload)?;
            Ok(get_element(shared, req)?.to_bytes().into())
        }
        worker_methods::GET_ELEMENTS => {
            let req = GetElementsReq::from_bytes(payload)?;
            get_elements(shared, req)
        }
        worker_methods::OPEN_STREAM => {
            let req = OpenStreamReq::from_bytes(payload)?;
            Ok(open_stream(shared, req)?.to_bytes().into())
        }
        worker_methods::FETCH => {
            let req = FetchReq::from_bytes(payload)?;
            fetch(shared, req)
        }
        worker_methods::CLOSE_STREAM => {
            let req = CloseStreamReq::from_bytes(payload)?;
            let closed = shared.sessions.lock().unwrap().remove(&req.session_id).is_some();
            if closed {
                shared.metrics.counter("worker/stream_sessions_closed").inc();
            }
            Ok(CloseStreamResp { closed }.to_bytes().into())
        }
        worker_methods::UPDATE_CONSUMERS => {
            let req = UpdateConsumersReq::from_bytes(payload)?;
            let applied = apply_consumer_updates(shared, &req.attached, &req.released);
            Ok(UpdateConsumersResp { applied }.to_bytes().into())
        }
        worker_methods::WORKER_STATUS => {
            let _ = WorkerStatusReq::from_bytes(payload)?;
            Ok(status(shared).to_bytes().into())
        }
        other => Err(ServiceError::Other(format!("worker: unknown method {other}"))),
    }
}

/// Stream-session handshake (the tentpole's entry point): validate the
/// job, negotiate `min(version)` / capability intersection / frame
/// budget, register the consumer's cursor, and mint a session id.
fn open_stream(shared: &Arc<WorkerShared>, req: OpenStreamReq) -> ServiceResult<OpenStreamResp> {
    if req.protocol_version == 0 {
        return Err(ServiceError::Other(
            "unsupported stream protocol version 0 (this worker speaks >= 1)".into(),
        ));
    }
    let runner = shared
        .tasks
        .lock()
        .unwrap()
        .get(&req.job_id)
        .cloned()
        .ok_or(ServiceError::UnknownJob(req.job_id))?;
    let mode = match &runner.state {
        TaskState::Independent { cache, .. } => {
            // The handshake is the session-plane consumer registration
            // (the legacy lazy-on-first-fetch path still exists for old
            // clients).
            cache.register_consumer(req.client_id);
            ProcessingMode::Independent
        }
        TaskState::Coordinated(_) => ProcessingMode::Coordinated,
    };
    let client_max = if req.max_frame_len == 0 {
        crate::rpc::MAX_FRAME_LEN
    } else {
        req.max_frame_len as usize
    };
    let session = Arc::new(StreamSession {
        job_id: req.job_id,
        client_id: req.client_id,
        caps: req.capabilities & shared.cfg.stream_caps & stream_caps::ALL,
        max_frame: client_max.clamp(MIN_STREAM_FRAME_LEN, crate::rpc::MAX_FRAME_LEN),
        consumer_index: req.consumer_index,
        chunk: Mutex::new((HashMap::new(), 1)),
    });
    let session_id = shared.next_session_id.fetch_add(1, Ordering::SeqCst);
    let resp = OpenStreamResp {
        session_id,
        protocol_version: req.protocol_version.min(STREAM_PROTOCOL_VERSION),
        capabilities: session.caps,
        max_frame_len: session.max_frame as u64,
        mode,
    };
    shared.sessions.lock().unwrap().insert(session_id, session);
    shared.metrics.counter("worker/stream_sessions_opened").inc();
    Ok(resp)
}

/// Budget/behavior knobs for one pass through the unified drain loop
/// ([`drain_and_serve`]). The legacy RPCs and the session `Fetch` differ
/// only in these values — they share the machinery.
struct FetchParams {
    max_elements: usize,
    max_bytes: usize,
    poll: Duration,
    /// Response-frame ceiling a single element may not exceed.
    hard_cap: usize,
    /// Whether an over-cap element is handed back for chunked delivery
    /// (sessions with `CHUNKED_TRANSFER`) or errors (legacy paths).
    chunk_oversized: bool,
}

/// Outcome of one drain pass.
enum Drained {
    Batch { batch: Vec<Arc<Vec<u8>>>, eos: bool },
    /// Over-cap element popped for continuation-frame delivery.
    Oversized(Arc<Vec<u8>>),
}

/// The canonical independent-mode serve path (§3.1 line-rate data
/// plane), shared by `Fetch`, `GetElements`, and independent
/// `GetElement`: move everything the producer has ready into the cache,
/// then advance this client's cursor through up to
/// `max_elements`/`max_bytes` of window in one lock acquisition. When
/// nothing is ready, long-poll up to `poll` instead of bouncing an empty
/// response straight back.
fn drain_and_serve(
    cache: &Arc<SlidingCache>,
    rx: &chan::Receiver<Element>,
    in_flight: &Arc<AtomicU64>,
    client_id: u64,
    p: &FetchParams,
) -> ServiceResult<Drained> {
    let deadline = Instant::now() + p.poll;
    loop {
        // Drain the producer channel into the cache: encode outside the
        // lock, bulk-insert under one acquisition, and only then release
        // the in-flight accounting (publish before decrement).
        let mut fresh = Vec::new();
        while fresh.len() < p.max_elements {
            match rx.try_recv() {
                Some(e) => fresh.push(Arc::new(e.to_bytes())),
                None => break,
            }
        }
        let drained = fresh.len() as u64;
        if drained > 0 {
            cache.push_encoded(fresh);
            in_flight.fetch_sub(drained, Ordering::SeqCst);
        }

        match cache.serve_batch(
            client_id,
            p.max_elements,
            p.max_bytes,
            p.hard_cap,
            p.chunk_oversized,
            in_flight,
        ) {
            BatchServe::Oversized(bytes) => return Ok(Drained::Oversized(bytes)),
            BatchServe::TooLarge(bytes) => {
                return Err(ServiceError::ElementTooLarge { bytes, cap: p.hard_cap })
            }
            BatchServe::Spill { from, to } => {
                // RAM → spill fallback: replay the evicted range from the
                // store (no cache lock held), then commit the cursor.
                let sp = cache.spill().expect("Spill outcome implies a spill tier").clone();
                match sp.read_range(from, to, p.max_bytes, p.hard_cap) {
                    SpillRead::Batch { batch, next, skipped } => {
                        cache.complete_spill(client_id, next, batch.len() as u64, skipped);
                        if !batch.is_empty() {
                            return Ok(Drained::Batch { batch, eos: false });
                        }
                        // Whole range was gaps/unreadable: the skips are
                        // booked; retry from RAM.
                    }
                    SpillRead::Oversized { bytes, seq, skipped } => {
                        if !p.chunk_oversized {
                            // Book progress up to (not past) the element
                            // so the error is explicit and repeatable.
                            cache.complete_spill(client_id, seq, 0, skipped);
                            return Err(ServiceError::ElementTooLarge {
                                bytes: bytes.len(),
                                cap: p.hard_cap,
                            });
                        }
                        cache.complete_spill(client_id, seq + 1, 1, skipped);
                        return Ok(Drained::Oversized(bytes));
                    }
                }
            }
            BatchServe::Batch(batch, end) => {
                if !batch.is_empty() || end {
                    return Ok(Drained::Batch { batch, eos: end });
                }
            }
        }
        // Not the end: production is pending, or a concurrent handler
        // still holds popped-but-unpublished elements. Long-poll on the
        // producer channel instead of bouncing an empty response.
        let wait = deadline.saturating_duration_since(Instant::now());
        if wait.is_zero() {
            return Ok(Drained::Batch { batch: Vec::new(), eos: false }); // poll window expired
        }
        match rx.recv_timeout(wait.min(Duration::from_millis(50))) {
            Ok(Some(e)) => {
                cache.push_encoded(vec![Arc::new(e.to_bytes())]);
                in_flight.fetch_sub(1, Ordering::SeqCst);
            }
            Ok(None) => {}
            Err(_) => {
                // Channel closed: recv returns instantly. Wait on the
                // cache condvar — notified by the concurrent handler's
                // publish — instead of pacing with a fixed sleep.
                cache.set_eos();
                cache.wait_for_publish(Duration::from_millis(10));
            }
        }
    }
}

fn get_element(shared: &Arc<WorkerShared>, req: GetElementReq) -> ServiceResult<GetElementResp> {
    let runner = shared
        .tasks
        .lock()
        .unwrap()
        .get(&req.job_id)
        .cloned()
        .ok_or(ServiceError::UnknownJob(req.job_id))?;

    let mut resp = match (&runner.state, req.consumer_index, req.round) {
        (TaskState::Coordinated(coord), Some(ci), Some(round)) => {
            // Legacy coordinated shim over the multi-round buffer: one
            // round slot per call, pre-encoded bytes cloned out.
            let (element, end_of_sequence, wrong_worker_for_round) =
                match coord.take(round, ci as usize, shared.cfg.serve_timeout)? {
                    RoundTake::Bytes(b) => (Some(b.as_ref().clone()), false, false),
                    RoundTake::Grace(b) => {
                        shared.metrics.counter("worker/post_revoke_serves").inc();
                        (Some(b.as_ref().clone()), false, false)
                    }
                    RoundTake::WrongWorker => (None, false, true),
                    RoundTake::Eos => (None, true, false),
                    RoundTake::Pending => (None, false, false),
                };
            GetElementResp { element, compressed: false, end_of_sequence, wrong_worker_for_round }
        }
        (TaskState::Coordinated(_), _, _) => {
            return Err(ServiceError::Other(
                "coordinated job requires consumer_index and round".into(),
            ))
        }
        (TaskState::Independent { cache, rx, in_flight }, _, _) => {
            // Legacy single-element shim: the same drain loop as the
            // session plane, with a one-element budget.
            let p = FetchParams {
                max_elements: 1,
                max_bytes: usize::MAX,
                poll: shared.cfg.serve_timeout,
                // Same conservative cap as the legacy batched shim: the
                // response wraps the element (and may deflate it, which
                // can expand), so leave the transport cap real margin.
                hard_cap: crate::rpc::MAX_FRAME_LEN / 2,
                chunk_oversized: false,
            };
            match drain_and_serve(cache, rx, in_flight, req.client_id, &p)? {
                Drained::Batch { mut batch, eos } => {
                    let element = batch.pop().map(|b| b.as_ref().clone());
                    GetElementResp {
                        // Deliver a final element before announcing the
                        // end: the contract is "eos implies no element".
                        end_of_sequence: eos && element.is_none(),
                        element,
                        compressed: false,
                        wrong_worker_for_round: false,
                    }
                }
                Drained::Oversized(_) => unreachable!("chunk_oversized = false"),
            }
        }
    };
    if req.compression == CompressionMode::Deflate {
        if let Some(bytes) = resp.element.take() {
            resp.element = Some(deflate(&bytes)?);
            resp.compressed = true;
        }
    }
    shared.metrics.counter("worker/get_element_calls").inc();
    Ok(resp)
}

/// Assemble a batch into a response frame (a wire-encoded `Vec<Vec<u8>>`)
/// in a recycled buffer; compress the whole frame at once (when asked)
/// so codec overhead amortizes across the batch. Empty frames skip the
/// pool: taking a high-water-sized buffer for a 4-byte count would waste
/// a large allocation per empty response. Returns `(frame, compressed)`.
///
/// A client asking for compression opts into the worker's observed-ratio
/// chooser ([`crate::wire::AdaptiveCodec`]) rather than an unconditional
/// deflate: frames whose shape class has settled on Skip ship raw at
/// memcpy speed (`worker/codec_skips`), and a re-probe that flips a
/// class's verdict is metered as `worker/codec_switches`. The
/// per-response `compressed` flag keeps every decision transparent to
/// the client.
fn assemble_batch_frame(
    shared: &Arc<WorkerShared>,
    batch: &[Arc<Vec<u8>>],
    want_compress: bool,
) -> (Vec<u8>, bool) {
    if batch.is_empty() {
        return (0u32.to_le_bytes().to_vec(), false);
    }
    let mut w = Writer::from_vec(shared.frame_bufs.take());
    w.put_u32(batch.len() as u32);
    for bytes in batch {
        w.put_bytes(bytes);
    }
    let raw_len = w.len();
    let z = if want_compress {
        match shared.codec.plan(raw_len) {
            crate::wire::CodecAction::Trial => {
                let z = crate::wire::compress(w.as_slice());
                if shared.codec.record_trial(raw_len, z.len()) {
                    shared.metrics.counter("worker/codec_switches").inc();
                }
                Some(z).filter(|z| z.len() < raw_len)
            }
            crate::wire::CodecAction::Compress => {
                Some(crate::wire::compress(w.as_slice())).filter(|z| z.len() < raw_len)
            }
            crate::wire::CodecAction::Skip => {
                shared.metrics.counter("worker/codec_skips").inc();
                None
            }
        }
    } else {
        None
    };
    match z {
        Some(z) => {
            shared.metrics.counter("worker/compression_bytes_saved").add((raw_len - z.len()) as u64);
            // The scratch buffer's job is done: recycle it.
            shared.frame_bufs.put(w.into_bytes());
            (z, true)
        }
        None => {
            // Zero-copy: the frame leaves as the response tail and cannot
            // come back to the pool — record the frame *size* (not the
            // buffer's possibly-doubled capacity) so future takes
            // pre-size to real frames and assembly stays one allocation.
            shared.frame_bufs.record_capacity(raw_len);
            (w.into_bytes(), false)
        }
    }
}

/// Legacy batched shim: routes into the same drain machinery as the
/// session `Fetch`, minus negotiation — so it cannot chunk, and an
/// element over the (conservative, half-transport-cap) frame budget
/// returns an explicit `element too large` error with the cursor
/// untouched instead of silently skipping (ROADMAP "oversized single
/// elements").
fn get_elements(shared: &Arc<WorkerShared>, req: GetElementsReq) -> ServiceResult<RespBody> {
    let runner = shared
        .tasks
        .lock()
        .unwrap()
        .get(&req.job_id)
        .cloned()
        .ok_or(ServiceError::UnknownJob(req.job_id))?;
    let (cache, rx, in_flight) = match &runner.state {
        TaskState::Independent { cache, rx, in_flight } => {
            (cache.clone(), rx.clone(), in_flight.clone())
        }
        TaskState::Coordinated(_) => {
            return Err(ServiceError::Other(
                "GetElements requires an independent-mode job; coordinated reads use GetElement"
                    .into(),
            ))
        }
    };
    // Budget clamped well under the transport frame cap: the cursor
    // advances under the cache lock *before* the response is written, so
    // an over-cap frame must be impossible by construction here.
    let hard_cap = crate::rpc::MAX_FRAME_LEN / 2;
    let poll_ms = if req.poll_ms == 0 { DEFAULT_BATCH_POLL_MS } else { req.poll_ms };
    let p = FetchParams {
        max_elements: (if req.max_elements == 0 { DEFAULT_BATCH_MAX_ELEMENTS } else { req.max_elements })
            as usize,
        max_bytes: (if req.max_bytes == 0 { DEFAULT_BATCH_MAX_BYTES } else { req.max_bytes })
            .min(hard_cap as u64) as usize,
        poll: Duration::from_millis(poll_ms as u64).min(shared.cfg.serve_timeout),
        hard_cap,
        chunk_oversized: false,
    };
    let (batch, end_of_sequence) =
        match drain_and_serve(&cache, &rx, &in_flight, req.client_id, &p)? {
            Drained::Batch { batch, eos } => (batch, eos),
            Drained::Oversized(_) => unreachable!("chunk_oversized = false"),
        };

    let (frame, compressed) =
        assemble_batch_frame(shared, &batch, req.compression == CompressionMode::Deflate);

    let calls = shared.metrics.counter("worker/get_elements_calls");
    calls.inc();
    let served = shared.metrics.counter("worker/batched_elements_served");
    served.add(batch.len() as u64);
    shared
        .metrics
        .gauge("worker/elements_per_rpc")
        .set((served.get() / calls.get().max(1)) as i64);

    // (head, frame) write slices: the frame is moved, not copied, and the
    // RPC server writes both with one scatter-gather frame write.
    let (head, tail) =
        encode_get_elements_resp_parts(batch.len() as u32, compressed, end_of_sequence, frame);
    Ok(RespBody::parts(head, tail))
}

/// Session-scoped `Fetch`: the canonical data-plane RPC. Independent
/// sessions drain batches (with continuation frames for oversized
/// elements); coordinated sessions read one round slot per call (§3.6).
/// Every response carries backpressure hints for the client's AIMD loop.
fn fetch(shared: &Arc<WorkerShared>, req: FetchReq) -> ServiceResult<RespBody> {
    let session = shared
        .sessions
        .lock()
        .unwrap()
        .get(&req.session_id)
        .cloned()
        .ok_or_else(|| {
            ServiceError::Other(format!(
                "unknown stream session {} (expired or never opened); re-handshake with OpenStream",
                req.session_id
            ))
        })?;
    let runner = shared
        .tasks
        .lock()
        .unwrap()
        .get(&session.job_id)
        .cloned()
        .ok_or(ServiceError::UnknownJob(session.job_id))?;
    let frame_budget = session.frame_budget();
    let poll_ms = if req.poll_ms == 0 { DEFAULT_BATCH_POLL_MS } else { req.poll_ms };
    let poll = Duration::from_millis(poll_ms as u64).min(shared.cfg.serve_timeout);
    let chunked = session.caps & stream_caps::CHUNKED_TRANSFER != 0;
    let want_compress =
        req.compression == CompressionMode::Deflate && session.caps & stream_caps::DEFLATE != 0;

    let mut resp = FetchResp {
        num_elements: 0,
        compressed: false,
        end_of_sequence: false,
        wrong_worker_for_round: false,
        chunk_seq: 0,
        chunk_offset: 0,
        chunk_total_len: 0,
        ready_elements: 0,
        window_elements: 0,
        window_bytes: 0,
        frame: Vec::new(),
    };

    // Pending oversized elements go first: the client drives delivery by
    // echoing back how much it has (`chunk_seq` + `chunk_offset`), which
    // makes continuation frames idempotent under RPC retries. Only once
    // an offset *tagged with the matching seq* reaches the total length
    // is the element released — the ack may ride a request for a
    // *different* round (the client has moved on), so release matches by
    // seq across all parked rounds. An offset tagged with a seq no
    // parked element carries is about an already-released element (a
    // retried ack): delivery of the requested round's parked element
    // restarts from 0 instead.
    let round_key = req.round.unwrap_or(INDEPENDENT_CHUNK_KEY);
    {
        let mut pending = session.chunk.lock().unwrap();
        if req.chunk_seq != 0 {
            let acked: Vec<u64> = pending
                .0
                .iter()
                .filter(|(_, (seq, bytes))| {
                    *seq == req.chunk_seq && req.chunk_offset as usize >= bytes.len()
                })
                .map(|(&k, _)| k)
                .collect();
            for k in acked {
                pending.0.remove(&k);
                shared.metrics.counter("worker/chunked_elements_served").inc();
            }
        }
        if let Some((seq, bytes)) = pending.0.get(&round_key) {
            // A fully-acked element was released above, so a matching
            // seq here implies offset < len (the clamp is belt only).
            let start = if req.chunk_seq == *seq { req.chunk_offset as usize } else { 0 };
            let start = start.min(bytes.len().saturating_sub(1));
            let end = (start + frame_budget).min(bytes.len());
            resp.chunk_seq = *seq;
            resp.chunk_offset = start as u64;
            resp.chunk_total_len = bytes.len() as u64;
            resp.frame = bytes[start..end].to_vec();
            shared.metrics.counter("worker/chunk_frames_served").inc();
            return finish_fetch(shared, &session, &runner, resp);
        }
    }

    match &runner.state {
        TaskState::Coordinated(coord) => {
            let round = req.round.ok_or_else(|| {
                ServiceError::Other("coordinated Fetch requires a round".into())
            })?;
            let ci = session.consumer_index.ok_or_else(|| {
                ServiceError::Other(
                    "coordinated session opened without a consumer_index".into(),
                )
            })?;
            let taken = match coord.take(round, ci as usize, poll)? {
                RoundTake::Grace(b) => {
                    // Served from the post-revoke grace window: same
                    // delivery path as an owned round, just counted.
                    shared.metrics.counter("worker/post_revoke_serves").inc();
                    RoundTake::Bytes(b)
                }
                other => other,
            };
            match taken {
                RoundTake::Grace(_) => unreachable!("folded into Bytes above"),
                RoundTake::WrongWorker => {
                    resp.wrong_worker_for_round = true;
                    resp.frame = 0u32.to_le_bytes().to_vec();
                }
                RoundTake::Eos => {
                    resp.end_of_sequence = true;
                    resp.frame = 0u32.to_le_bytes().to_vec();
                }
                RoundTake::Pending => {
                    resp.frame = 0u32.to_le_bytes().to_vec();
                }
                RoundTake::Bytes(bytes) => {
                    if bytes.len() > frame_budget {
                        if !chunked {
                            return Err(ServiceError::ElementTooLarge {
                                bytes: bytes.len(),
                                cap: frame_budget,
                            });
                        }
                        resp.chunk_seq = session.park_chunk(round_key, bytes.clone());
                        resp.chunk_total_len = bytes.len() as u64;
                        resp.frame = bytes[..frame_budget.min(bytes.len())].to_vec();
                        shared.metrics.counter("worker/chunk_frames_served").inc();
                    } else {
                        let batch = [bytes];
                        let (frame, compressed) =
                            assemble_batch_frame(shared, &batch, want_compress);
                        resp.num_elements = 1;
                        resp.frame = frame;
                        resp.compressed = compressed;
                    }
                }
            }
        }
        TaskState::Independent { cache, rx, in_flight } => {
            let p = FetchParams {
                max_elements: (if req.max_elements == 0 {
                    DEFAULT_BATCH_MAX_ELEMENTS
                } else {
                    req.max_elements
                }) as usize,
                max_bytes: (if req.max_bytes == 0 { DEFAULT_BATCH_MAX_BYTES } else { req.max_bytes })
                    .min(frame_budget as u64) as usize,
                poll,
                hard_cap: frame_budget,
                chunk_oversized: chunked,
            };
            match drain_and_serve(cache, rx, in_flight, session.client_id, &p)? {
                Drained::Batch { batch, eos } => {
                    let (frame, compressed) = assemble_batch_frame(shared, &batch, want_compress);
                    resp.num_elements = batch.len() as u32;
                    resp.frame = frame;
                    resp.compressed = compressed;
                    resp.end_of_sequence = eos;
                    let served = shared.metrics.counter("worker/batched_elements_served");
                    served.add(batch.len() as u64);
                }
                Drained::Oversized(bytes) => {
                    resp.chunk_seq = session.park_chunk(round_key, bytes.clone());
                    resp.chunk_total_len = bytes.len() as u64;
                    resp.frame = bytes[..frame_budget.min(bytes.len())].to_vec();
                    shared.metrics.counter("worker/chunk_frames_served").inc();
                }
            }
        }
    }
    finish_fetch(shared, &session, &runner, resp)
}

/// Attach backpressure hints, bump counters, and emit the `(head, frame)`
/// scatter-gather response body.
fn finish_fetch(
    shared: &Arc<WorkerShared>,
    session: &StreamSession,
    runner: &TaskRunner,
    mut resp: FetchResp,
) -> ServiceResult<RespBody> {
    match &runner.state {
        TaskState::Independent { cache, rx, .. } => {
            let (unread, win, win_bytes) = cache.occupancy(session.client_id);
            resp.ready_elements = (unread + rx.len()).min(u32::MAX as usize) as u32;
            resp.window_elements = win.min(u32::MAX as usize) as u32;
            resp.window_bytes = win_bytes as u64;
        }
        TaskState::Coordinated(coord) => {
            // Rounds materialized ahead of consumption: the prefetching
            // client's signal that fetching further ahead will not block.
            resp.ready_elements = coord.buffered_rounds().min(u32::MAX as usize) as u32;
        }
    }
    shared.metrics.counter("worker/fetch_calls").inc();
    let (head, tail) = encode_fetch_resp_parts(resp);
    Ok(RespBody::parts(head, tail))
}

fn status(shared: &Arc<WorkerShared>) -> WorkerStatusResp {
    let tasks = shared.tasks.lock().unwrap();
    let mut buffered = 0u64;
    let mut hits = 0u64;
    let mut evictions = 0u64;
    let mut window_stats = Vec::new();
    for (job_id, t) in tasks.iter() {
        if let TaskState::Independent { cache, .. } = &t.state {
            let s = cache.stats();
            hits += s.hits;
            evictions += s.evictions;
            buffered += s.window as u64;
            window_stats.push(JobWindowStat {
                job_id: *job_id,
                elements: s.window as u64,
                bytes: s.window_bytes as u64,
            });
        }
    }
    window_stats.sort_by_key(|s| s.job_id);
    WorkerStatusResp {
        active_tasks: tasks.keys().copied().collect(),
        buffered_elements: buffered,
        elements_produced: shared.metrics.counter("worker/elements_produced").get(),
        cache_hits: hits,
        cache_evictions: evictions,
        // Cumulative (registry-fed) like elements_produced, so the §3.5
        // sharing ledger survives a finished job's task being dropped —
        // unlike the live-cache sums above, which reflect current tasks.
        shared_elements_served: shared.metrics.counter("worker/shared_elements_served").get(),
        relaxed_skips: shared.metrics.counter("worker/relaxed_visitation_skips").get(),
        window_stats,
        spill_segments_written: shared.metrics.counter("worker/spill_segments_written").get(),
        spill_elements_served: shared.metrics.counter("worker/spill_elements_served").get(),
        snapshot_serves: shared.metrics.counter("worker/snapshot_serves").get(),
    }
}

/// Compress an element payload with the in-tree wire codec (the format is
/// internal to the service — both ends are this crate — so there is no
/// deflate-compat requirement; see [`crate::wire::compress`]).
fn deflate(bytes: &[u8]) -> ServiceResult<Vec<u8>> {
    Ok(crate::wire::compress(bytes))
}

/// Inverse of [`deflate`] (client side).
pub fn inflate(bytes: &[u8]) -> ServiceResult<Vec<u8>> {
    Ok(crate::wire::decompress(bytes)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::element::Tensor;

    fn elem(v: i32) -> Element {
        Element::with_ids(vec![Tensor::scalar_i32(v)], vec![v as u64])
    }

    /// Fresh cache over a throwaway registry; returns both so tests can
    /// assert the registry-side ledger the cache feeds. Eager eviction
    /// off: these tests pin the retained-window replay semantics.
    fn cache(capacity: usize, byte_budget: usize) -> (SlidingCache, Registry) {
        let m = Registry::new();
        (SlidingCache::new(capacity, byte_budget, false, 0, None, &m), m)
    }

    /// Cache with eager consumed-by-all eviction on (the default worker
    /// configuration).
    fn cache_eager(capacity: usize, byte_budget: usize) -> (SlidingCache, Registry) {
        let m = Registry::new();
        (SlidingCache::new(capacity, byte_budget, true, 0, None, &m), m)
    }

    fn skips_of(m: &Registry) -> u64 {
        m.counter("worker/relaxed_visitation_skips").get()
    }

    /// serve_batch with no frame cap (the common-case shape most tests
    /// exercise): panics on the oversize outcomes.
    fn sb(
        c: &SlidingCache,
        client: u64,
        max_elements: usize,
        max_bytes: usize,
        in_flight: &AtomicU64,
    ) -> (Vec<Arc<Vec<u8>>>, bool) {
        match c.serve_batch(client, max_elements, max_bytes, usize::MAX, false, in_flight) {
            BatchServe::Batch(b, eos) => (b, eos),
            _ => panic!("unexpected oversize outcome with an unbounded cap"),
        }
    }

    #[test]
    fn sliding_cache_serves_in_order() {
        let (c, _m) = cache(4, usize::MAX);
        for i in 0..3 {
            c.push(elem(i));
        }
        for i in 0..3 {
            match c.serve(1) {
                CacheServe::Bytes(b) => {
                    let e = Element::from_bytes(&b).unwrap();
                    assert_eq!(e.tensors[0].as_i32(), vec![i]);
                }
                _ => panic!("expected element"),
            }
        }
        assert!(matches!(c.serve(1), CacheServe::NeedProduce));
        c.set_eos();
        assert!(matches!(c.serve(1), CacheServe::Eos));
    }

    #[test]
    fn sliding_cache_shares_across_clients() {
        let (c, _m) = cache(8, usize::MAX);
        for i in 0..4 {
            c.push(elem(i));
        }
        // Two clients each see all four cached elements: one production,
        // two consumptions — the §3.5 CPU saving.
        for client in [1, 2] {
            for i in 0..4 {
                match c.serve(client) {
                    CacheServe::Bytes(b) => {
                        let e = Element::from_bytes(&b).unwrap();
                        assert_eq!(e.tensors[0].as_i32(), vec![i]);
                    }
                    _ => panic!(),
                }
            }
        }
        let s = c.stats();
        assert_eq!(s.hits, 8);
        assert_eq!(s.produced, 4);
        assert_eq!(s.evictions, 0);
    }

    #[test]
    fn sliding_cache_evicts_and_laggard_skips() {
        let (c, m) = cache(2, usize::MAX);
        for i in 0..5 {
            c.push(elem(i)); // window holds {3, 4} afterwards
        }
        let s = c.stats();
        assert_eq!(s.evictions, 3);
        assert_eq!(s.window, 2);
        // A client that never read anything starts at the oldest retained
        // element (3), silently skipping 0..2 (Fig. 5's evicted batches).
        match c.serve(9) {
            CacheServe::Bytes(b) => {
                let e = Element::from_bytes(&b).unwrap();
                assert_eq!(e.tensors[0].as_i32(), vec![3]);
            }
            _ => panic!(),
        }
        // A brand-new cursor is not a laggard: nothing counted skipped.
        assert_eq!(c.stats().skipped, 0);
        assert_eq!(skips_of(&m), 0);
        // A cursor that existed before the eviction IS a laggard.
        c.register_consumer(7); // anchors at base (3) — reads 3, 4
        let _ = c.serve(7);
        let _ = c.serve(7);
        c.push(elem(5));
        c.push(elem(6)); // window {5, 6}: cursor 7 (at seq 5) unaffected
        c.push(elem(7)); // window {6, 7}: seq 5 evicted under cursor 7
        match c.serve(7) {
            CacheServe::Bytes(b) => {
                let e = Element::from_bytes(&b).unwrap();
                assert_eq!(e.tensors[0].as_i32(), vec![6]);
            }
            _ => panic!(),
        }
        // Element 5 was evicted unread: one skip, in both ledgers.
        assert_eq!(c.stats().skipped, 1);
        assert_eq!(skips_of(&m), 1);
    }

    #[test]
    fn sliding_cache_byte_budget_bounds_window() {
        let one = Arc::new(elem(0).to_bytes());
        let sz = one.len();
        // Budget fits ~3 encoded elements; element capacity is generous.
        let (c, _m) = cache(100, 3 * sz);
        c.push_encoded((0..10).map(|i| Arc::new(elem(i).to_bytes())).collect());
        let s = c.stats();
        assert!(s.window <= 3, "byte budget trims the window, got {}", s.window);
        assert_eq!(s.evictions as usize + s.window, 10);
        // A single element larger than the whole budget is still retained
        // (progress guarantee: the newest element never gets evicted).
        let (c2, _m2) = cache(100, 1);
        c2.push(elem(7));
        assert_eq!(c2.stats().window, 1);
    }

    #[test]
    fn registered_laggard_skip_is_counted() {
        let (c, m) = cache(2, usize::MAX);
        c.register_consumer(5); // cursor pinned at seq 0
        c.push_encoded((0..6).map(|i| Arc::new(elem(i).to_bytes())).collect());
        // Window retains {4, 5}; consumer 5 fell off the back and must
        // skip 0..=3 (4 elements) — the relaxed-visitation escape hatch.
        let (batch, _) = sb(&c, 5, 64, usize::MAX, &AtomicU64::new(0));
        assert_eq!(batch.len(), 2);
        let e = Element::from_bytes(&batch[0]).unwrap();
        assert_eq!(e.tensors[0].as_i32(), vec![4]);
        assert_eq!(c.stats().skipped, 4);
        assert_eq!(skips_of(&m), 4, "registry ledger matches cache stats");
    }

    #[test]
    fn consumer_registration_drives_shared_accounting() {
        let (c, m) = cache(16, usize::MAX);
        let shared = m.counter("worker/shared_elements_served");
        assert_eq!(c.push(elem(0)), 0, "no consumers yet");
        c.register_consumer(1);
        assert_eq!(c.push(elem(1)), 1);
        c.register_consumer(2);
        assert_eq!(c.push(elem(2)), 2, "now shared");
        assert_eq!(c.num_consumers(), 2);
        let s = c.stats();
        assert_eq!(s.produced, 3);
        assert_eq!(s.shared_produced, 1, "only the push with >=2 consumers");
        assert_eq!(shared.get(), 1, "registry ledger matches cache stats");
        // Release one consumer: back to dedicated accounting.
        assert!(c.remove_consumer(2));
        assert!(!c.remove_consumer(2), "double release is a no-op");
        assert_eq!(c.push(elem(3)), 1);
        assert_eq!(c.stats().shared_produced, 1);
        assert_eq!(shared.get(), 1);
        // Registration is idempotent and anchors at the stream head.
        c.register_consumer(1);
        assert_eq!(c.num_consumers(), 1);
    }

    #[test]
    fn removed_consumer_is_tombstoned() {
        let (c, _m) = cache(16, usize::MAX);
        c.register_consumer(1);
        for i in 0..4 {
            c.push(elem(i));
        }
        // Consumer 1 reads two, then releases mid-stream.
        let (batch, _) = sb(&c, 1, 2, usize::MAX, &AtomicU64::new(0));
        assert_eq!(batch.len(), 2);
        assert!(c.remove_consumer(1));
        assert!(!c.remove_consumer(1), "double release is a no-op");
        // A straggler RPC racing the detach gets end-of-sequence; it must
        // not resurrect the cursor (a phantom consumer would permanently
        // inflate the sharing ledger).
        let (batch, end) = sb(&c, 1, 64, usize::MAX, &AtomicU64::new(0));
        assert!(batch.is_empty() && end);
        assert!(matches!(c.serve(1), CacheServe::Eos));
        c.register_consumer(1);
        assert_eq!(c.num_consumers(), 0, "tombstoned id cannot re-register");
    }

    #[test]
    fn serve_batch_drains_window_in_one_call() {
        let quiet = AtomicU64::new(0);
        let (c, _m) = cache(16, usize::MAX);
        c.push_encoded((0..10).map(|i| Arc::new(elem(i).to_bytes())).collect());
        let (batch, eos) = sb(&c, 1, 64, usize::MAX, &quiet);
        assert_eq!(batch.len(), 10);
        assert!(!eos, "producer not finished");
        for (i, b) in batch.iter().enumerate() {
            let e = Element::from_bytes(b).unwrap();
            assert_eq!(e.tensors[0].as_i32(), vec![i as i32]);
        }
        // Cursor advanced: nothing left, still not EOS.
        let (rest, eos) = sb(&c, 1, 64, usize::MAX, &quiet);
        assert!(rest.is_empty() && !eos);
        c.set_eos();
        let (_, eos) = sb(&c, 1, 64, usize::MAX, &quiet);
        assert!(eos);
        // A second client replays the shared window independently.
        let (batch2, _) = sb(&c, 2, 4, usize::MAX, &quiet);
        assert_eq!(batch2.len(), 4);
    }

    #[test]
    fn serve_batch_withholds_eos_while_elements_unpublished() {
        // A concurrent handler popped the channel but has not published:
        // in_flight > 0 must veto the end-of-sequence verdict even when
        // the producer finished and this cursor drained the window.
        let in_flight = AtomicU64::new(1);
        let (c, _m) = cache(4, usize::MAX);
        c.set_eos();
        let (batch, eos) = sb(&c, 1, 64, usize::MAX, &in_flight);
        assert!(batch.is_empty());
        assert!(!eos, "unpublished element must block EOS");
        in_flight.store(0, Ordering::SeqCst);
        let (_, eos) = sb(&c, 1, 64, usize::MAX, &in_flight);
        assert!(eos);
    }

    #[test]
    fn serve_batch_respects_element_and_byte_budgets() {
        let quiet = AtomicU64::new(0);
        let (c, _m) = cache(32, usize::MAX);
        c.push_encoded((0..8).map(|i| Arc::new(elem(i).to_bytes())).collect());
        let (batch, _) = sb(&c, 1, 3, usize::MAX, &quiet);
        assert_eq!(batch.len(), 3, "element cap");
        let elem_len = batch[0].len();
        // Byte budget allows exactly two more.
        let (batch, _) = sb(&c, 1, 64, 2 * elem_len, &quiet);
        assert_eq!(batch.len(), 2, "byte cap");
        // A budget smaller than one element still returns one (progress).
        let (batch, _) = sb(&c, 1, 64, 1, &quiet);
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn serve_batch_laggard_skips_evicted_range() {
        let quiet = AtomicU64::new(0);
        let (c, m) = cache(2, usize::MAX);
        c.push_encoded((0..5).map(|i| Arc::new(elem(i).to_bytes())).collect());
        // Window retains {3, 4}; a fresh client starts there.
        let (batch, _) = sb(&c, 9, 64, usize::MAX, &quiet);
        assert_eq!(batch.len(), 2);
        assert_eq!(skips_of(&m), 0, "fresh cursor, not a laggard");
        let e = Element::from_bytes(&batch[0]).unwrap();
        assert_eq!(e.tensors[0].as_i32(), vec![3]);
    }

    #[test]
    fn serve_batch_oversized_outcomes() {
        let quiet = AtomicU64::new(0);
        let (c, _m) = cache(16, usize::MAX);
        let small = elem(1).to_bytes();
        let cap = small.len(); // cap sized so `small` fits but `big` won't
        let big = vec![0u8; cap * 3];
        c.push_encoded(vec![Arc::new(big.clone()), Arc::new(small.clone())]);

        // Without chunking the cursor must NOT advance: the error is
        // explicit and repeatable (the legacy-shim contract).
        for _ in 0..2 {
            match c.serve_batch(1, 64, usize::MAX, cap, false, &quiet) {
                BatchServe::TooLarge(n) => assert_eq!(n, big.len()),
                _ => panic!("expected TooLarge"),
            }
        }
        // With chunking the element is handed over and the cursor moves
        // past it; the next call serves the small element normally.
        match c.serve_batch(1, 64, usize::MAX, cap, true, &quiet) {
            BatchServe::Oversized(b) => assert_eq!(*b, big),
            _ => panic!("expected Oversized"),
        }
        let (batch, _) = sb(&c, 1, 64, usize::MAX, &quiet);
        assert_eq!(batch.len(), 1);
        assert_eq!(*batch[0], small);
        // An oversized element later in the window stops the batch early
        // (it is only special when it is the *first* visible element).
        c.push_encoded(vec![Arc::new(small.clone()), Arc::new(big.clone())]);
        match c.serve_batch(1, 64, usize::MAX, cap, true, &quiet) {
            BatchServe::Batch(b, _) => assert_eq!(b.len(), 1, "stops before the big one"),
            _ => panic!("expected Batch"),
        }
        match c.serve_batch(1, 64, usize::MAX, cap, true, &quiet) {
            BatchServe::Oversized(b) => assert_eq!(*b, big),
            _ => panic!("expected Oversized"),
        }
    }

    #[test]
    fn occupancy_tracks_cursor_and_window() {
        let (c, _m) = cache(16, usize::MAX);
        c.register_consumer(1);
        c.push_encoded((0..4).map(|i| Arc::new(elem(i).to_bytes())).collect());
        let sz = elem(0).to_bytes().len();
        let (unread, win, win_bytes) = c.occupancy(1);
        assert_eq!((unread, win), (4, 4));
        assert_eq!(win_bytes, 4 * sz);
        let _ = sb(&c, 1, 3, usize::MAX, &AtomicU64::new(0));
        let (unread, win, _) = c.occupancy(1);
        assert_eq!((unread, win), (1, 4));
        // An unknown cursor sees the whole window.
        let (unread, _, _) = c.occupancy(99);
        assert_eq!(unread, 4);
        // Stats expose byte occupancy too (the status/gauge satellite).
        assert_eq!(c.stats().window_bytes, 4 * sz);
    }

    /// Encode a round's batches the way the producer now does.
    fn round_of(vals: &[i32]) -> Vec<Arc<Vec<u8>>> {
        vals.iter().map(|&v| Arc::new(elem(v).to_bytes())).collect()
    }

    fn take_bytes(c: &CoordinatedState, round: u64, consumer: usize) -> Element {
        match c.take(round, consumer, Duration::from_millis(200)).unwrap() {
            RoundTake::Bytes(b) => Element::from_bytes(&b).unwrap(),
            _ => panic!("expected round bytes"),
        }
    }

    #[test]
    fn coordinated_round_ownership() {
        let c = CoordinatedState::new(2, 1, 4, &[], false, 0, 2);
        assert!(!c.owns_round(0));
        assert!(c.owns_round(1));
        assert!(c.owns_round(5));
        let r = c.take(0, 0, Duration::from_millis(10)).unwrap();
        assert!(matches!(r, RoundTake::WrongWorker));
    }

    #[test]
    fn coordinated_round_serves_each_consumer_once() {
        let c = CoordinatedState::new(2, 0, 1, &[], false, 0, 2);
        assert!(c.install_round(round_of(&[10, 11])));
        let ea = take_bytes(&c, 0, 0);
        let eb = take_bytes(&c, 0, 1);
        assert_eq!(ea.tensors[0].as_i32(), vec![10]);
        assert_eq!(eb.tensors[0].as_i32(), vec![11]);
        // Double-fetch is an error.
        assert!(c.take(0, 0, Duration::from_millis(10)).is_err());
    }

    #[test]
    fn coordinated_eos_after_last_round() {
        let c = CoordinatedState::new(1, 0, 1, &[], false, 0, 2);
        assert!(c.install_round(round_of(&[1])));
        c.set_eos();
        let e = take_bytes(&c, 0, 0);
        assert_eq!(e.tensors[0].as_i32(), vec![1]);
        let r2 = c.take(1, 0, Duration::from_millis(50)).unwrap();
        assert!(matches!(r2, RoundTake::Eos));
    }

    #[test]
    fn coordinated_buffers_rounds_ahead_with_bounded_depth() {
        // Depth 2: two rounds buffer ahead of consumption; the third
        // install blocks (condvar, not polling) until a round drains.
        let c = Arc::new(CoordinatedState::new(1, 0, 1, &[], false, 0, 2));
        assert!(c.install_round(round_of(&[0])));
        assert!(c.install_round(round_of(&[1])));
        assert_eq!(c.buffered_rounds(), 2);
        let c2 = c.clone();
        let (tx, rx) = std::sync::mpsc::channel();
        let h = std::thread::spawn(move || {
            let ok = c2.install_round(round_of(&[2])); // blocks at depth
            tx.send(()).unwrap();
            ok
        });
        assert!(
            rx.recv_timeout(Duration::from_millis(100)).is_err(),
            "third install must block while the buffer is full"
        );
        // Consuming round 0 frees a slot and wakes the producer.
        let e = take_bytes(&c, 0, 0);
        assert_eq!(e.tensors[0].as_i32(), vec![0]);
        assert!(rx.recv_timeout(Duration::from_secs(2)).is_ok(), "space wait woke");
        assert!(h.join().unwrap());
        // Rounds are served from the buffer in order.
        assert_eq!(take_bytes(&c, 1, 0).tensors[0].as_i32(), vec![1]);
        assert_eq!(take_bytes(&c, 2, 0).tensors[0].as_i32(), vec![2]);
    }

    #[test]
    fn coordinated_halt_unblocks_parked_producer() {
        let c = Arc::new(CoordinatedState::new(1, 0, 1, &[], false, 0, 1));
        assert!(c.install_round(round_of(&[0])));
        let c2 = c.clone();
        let h = std::thread::spawn(move || c2.install_round(round_of(&[1])));
        std::thread::sleep(Duration::from_millis(30));
        c.halt();
        assert!(!h.join().unwrap(), "halted install reports stop");
    }

    #[test]
    fn coordinated_lease_adoption_labels_from_floor() {
        // Worker 0 of 2 owns residue 0; it adopts residue 1 (the dead
        // owner's) with floor 3: the first adopted label is the smallest
        // round >= 3 in residue 1, i.e. round 3.
        let c = CoordinatedState::new(1, 0, 2, &[], false, 0, 8);
        assert!(c.install_round(round_of(&[0]))); // round 0
        assert!(c.install_round(round_of(&[2]))); // round 2
        c.set_owned(&[0, 1], 3);
        assert!(c.owns_round(1), "residue 1 adopted");
        assert!(c.install_round(round_of(&[3]))); // round 3 (adopted residue)
        assert!(c.install_round(round_of(&[4]))); // round 4 (residue 0)
        assert_eq!(take_bytes(&c, 3, 0).tensors[0].as_i32(), vec![3]);
        assert_eq!(take_bytes(&c, 4, 0).tensors[0].as_i32(), vec![4]);
        // Dropping a residue discards its buffered rounds.
        let c2 = CoordinatedState::new(1, 0, 2, &[], false, 0, 8);
        assert!(c2.install_round(round_of(&[0])));
        c2.set_owned(&[1], 0);
        assert!(!c2.owns_round(0), "residue 0 released");
        assert_eq!(c2.buffered_rounds(), 0, "stale rounds dropped with the lease");
        assert!(matches!(c2.take(0, 0, Duration::from_millis(10)).unwrap(), RoundTake::WrongWorker));
    }

    #[test]
    fn coordinated_watermark_gc_drops_abandoned_rounds() {
        // Rounds every consumer has moved past (possible only after a
        // lease reassignment) are GC'd so they cannot pin the buffer.
        let c = CoordinatedState::new(1, 0, 1, &[], false, 0, 8);
        for i in 0..3 {
            assert!(c.install_round(round_of(&[i])));
        }
        // The consumer starts at round 2 (it consumed 0 and 1 from the
        // previous lease holder before it died).
        assert_eq!(take_bytes(&c, 2, 0).tensors[0].as_i32(), vec![2]);
        assert_eq!(c.buffered_rounds(), 0, "abandoned rounds 0 and 1 GC'd");
        assert_eq!(c.inner.lock().unwrap().abandoned_slots, 2);
        // Re-asking an abandoned round is a protocol violation.
        assert!(c.take(0, 0, Duration::from_millis(10)).is_err());
    }

    #[test]
    fn coordinated_regrant_resets_stale_progress() {
        // A worker materialized ahead, lost the lease (buffered rounds
        // dropped with it), then got it back: labeling must restart at
        // the dispatcher floor, not the stale progress marker —
        // otherwise consumers get "round already consumed" for rounds
        // that were never delivered.
        let c = CoordinatedState::new(1, 0, 1, &[], false, 0, 8);
        for i in 0..3 {
            assert!(c.install_round(round_of(&[i])));
        }
        c.set_owned(&[], 0); // lease moves away: buffer dropped
        assert_eq!(c.buffered_rounds(), 0);
        c.set_owned(&[0], 1); // re-granted, floor 1 (min consumer need)
        assert!(c.install_round(round_of(&[10]))); // labeled round 1
        assert_eq!(take_bytes(&c, 1, 0).tensors[0].as_i32(), vec![10]);
    }

    #[test]
    fn coordinated_post_revoke_grace_serves_buffered_rounds() {
        // With a grace window armed, a fetch racing the two-phase
        // handoff is served read-only from the revoked buffer instead
        // of bouncing off WrongWorker.
        let c = CoordinatedState::new(1, 0, 1, &[], false, 0, 8);
        c.set_revoke_grace(Duration::from_secs(30));
        assert!(c.install_round(round_of(&[7])));
        assert_eq!(c.revoke(&[0]), 1);
        match c.take(0, 0, Duration::from_millis(10)).unwrap() {
            RoundTake::Grace(b) => {
                assert_eq!(Element::from_bytes(&b).unwrap().tensors[0].as_i32(), vec![7]);
            }
            _ => panic!("expected a grace serve"),
        }
        // Read-only: a retried fetch is served again, not consumed.
        assert!(matches!(
            c.take(0, 0, Duration::from_millis(10)).unwrap(),
            RoundTake::Grace(_)
        ));
        // A consumer with no slot in the grace round still bounces.
        assert!(matches!(
            c.take(0, 5, Duration::from_millis(10)).unwrap(),
            RoundTake::WrongWorker
        ));
        // A re-grant invalidates grace copies: the lease is
        // authoritative again and the producer re-materializes.
        c.set_owned(&[0], 0);
        assert!(c.inner.lock().unwrap().grace.is_empty(), "grace dropped on re-grant");
    }

    #[test]
    fn coordinated_revoke_grace_expires() {
        let c = CoordinatedState::new(1, 0, 1, &[], false, 0, 8);
        c.set_revoke_grace(Duration::from_millis(1));
        assert!(c.install_round(round_of(&[7])));
        assert_eq!(c.revoke(&[0]), 1);
        std::thread::sleep(Duration::from_millis(10));
        assert!(matches!(
            c.take(0, 0, Duration::from_millis(5)).unwrap(),
            RoundTake::WrongWorker
        ));
        let st = c.inner.lock().unwrap();
        assert!(st.grace.is_empty(), "expired entry dropped");
        assert_eq!(st.abandoned_slots, 1, "unserved grace slot booked as abandoned");
    }

    #[test]
    fn coordinated_restart_labels_from_task_floor() {
        // A restarted worker re-receiving its task mid-epoch labels from
        // the TaskDef floor instead of crawling up from round 0.
        let c = CoordinatedState::new(1, 0, 2, &[0], true, 6, 4);
        assert!(c.install_round(round_of(&[1])));
        assert_eq!(take_bytes(&c, 6, 0).tensors[0].as_i32(), vec![1]);
    }

    #[test]
    fn coordinated_lease_view_empty_set_means_leaseless() {
        // Authoritative lease view (post-lease dispatchers): an empty
        // residue set really is leaseless — a revived worker whose
        // residues moved to survivors must NOT fall back to its home
        // worker_index and materialize split-brain rounds. The pre-lease
        // fallback (lease_view = false) keeps the old behavior.
        let c = CoordinatedState::new(1, 0, 2, &[], true, 0, 2);
        assert!(!c.owns_round(0), "no self-assignment under a lease view");
        assert!(matches!(
            c.take(0, 0, Duration::from_millis(10)).unwrap(),
            RoundTake::WrongWorker
        ));
        // A later grant (revival re-balance via heartbeat) restores it,
        // labeling from the dispatcher floor.
        c.set_owned(&[0], 4);
        assert!(c.owns_round(0));
        assert!(c.install_round(round_of(&[7]))); // labeled round 4
        assert_eq!(take_bytes(&c, 4, 0).tensors[0].as_i32(), vec![7]);
    }

    #[test]
    fn coordinated_width_schedule_rekeys_and_regroups() {
        let c = CoordinatedState::new(2, 0, 1, &[], false, 0, 8);
        // Producer staging via `offer`: width 2 groups elements in pairs.
        for v in [0, 1, 2, 3] {
            assert!(c.offer(Arc::new(elem(v).to_bytes())));
        }
        assert_eq!(c.buffered_rounds(), 2, "rounds 0 and 1 at width 2");
        assert_eq!(take_bytes(&c, 0, 0).tensors[0].as_i32(), vec![0]);
        assert_eq!(take_bytes(&c, 0, 1).tensors[0].as_i32(), vec![1]);
        // Grow to 3 consumers at barrier 1: buffered round 1 was grouped
        // under the old width and must re-key.
        let schedule = [
            WidthEpoch { epoch: 0, barrier_round: 0, num_consumers: 2 },
            WidthEpoch { epoch: 1, barrier_round: 1, num_consumers: 3 },
        ];
        assert_eq!(c.set_width_schedule(&schedule), 1);
        assert_eq!(c.buffered_rounds(), 0, "post-barrier round dropped for re-key");
        // Heartbeat redelivery of the same schedule is a no-op.
        assert_eq!(c.set_width_schedule(&schedule), 0);
        // The producer regroups from the barrier at the new width.
        for v in [10, 11, 12] {
            assert!(c.offer(Arc::new(elem(v).to_bytes())));
        }
        assert_eq!(take_bytes(&c, 1, 0).tensors[0].as_i32(), vec![10]);
        assert_eq!(take_bytes(&c, 1, 1).tensors[0].as_i32(), vec![11]);
        assert_eq!(take_bytes(&c, 1, 2).tensors[0].as_i32(), vec![12]);
    }

    #[test]
    fn coordinated_consumed_errors_carry_skip_hint() {
        // Both consumed outcomes answer with the stable prefix and a
        // parseable `next round {n}` hint (the client's skip-forward
        // protocol), not a terminal free-form error.
        let c = CoordinatedState::new(1, 0, 1, &[], false, 0, 8);
        assert!(c.install_round(round_of(&[0])));
        assert!(c.install_round(round_of(&[1])));
        // Fully-consumed round: the consumer starts at round 1, so round
        // 0 is abandoned and GC'd; re-asking names the next round.
        assert_eq!(take_bytes(&c, 1, 0).tensors[0].as_i32(), vec![1]);
        let err = c.take(0, 0, Duration::from_millis(10)).unwrap_err().to_string();
        assert!(err.contains(crate::service::ROUND_CONSUMED_PREFIX), "{err}");
        assert!(err.contains("next round 1"), "{err}");
        // Slot-already-taken (a replacement re-walking its predecessor's
        // progress): same protocol.
        let c2 = CoordinatedState::new(2, 0, 1, &[], false, 0, 8);
        assert!(c2.install_round(round_of(&[5, 6])));
        assert_eq!(take_bytes(&c2, 0, 1).tensors[0].as_i32(), vec![6]);
        let err2 = c2.take(0, 1, Duration::from_millis(10)).unwrap_err().to_string();
        assert!(err2.contains(crate::service::ROUND_CONSUMED_PREFIX), "{err2}");
        assert!(err2.contains("next round 1"), "{err2}");
    }

    #[test]
    fn chunk_slots_keyed_by_round() {
        let s = StreamSession {
            job_id: 1,
            client_id: 1,
            caps: stream_caps::ALL,
            max_frame: MIN_STREAM_FRAME_LEN,
            consumer_index: Some(0),
            chunk: Mutex::new((HashMap::new(), 1)),
        };
        // Transfers for two rounds park side by side with distinct seqs
        // (the multi-round session slot of the prefetch pipeline).
        let a = s.park_chunk(4, Arc::new(vec![1u8; 8]));
        let b = s.park_chunk(5, Arc::new(vec![2u8; 8]));
        assert_ne!(a, b);
        let st = s.chunk.lock().unwrap();
        assert_eq!(st.0.len(), 2);
        assert_eq!(st.0[&4].0, a);
        assert_eq!(st.0[&5].0, b);
    }

    #[test]
    fn eager_eviction_tracks_slowest_registered_cursor() {
        let quiet = AtomicU64::new(0);
        let (c, m) = cache_eager(100, usize::MAX);
        c.register_consumer(1);
        c.register_consumer(2);
        c.push_encoded((0..8).map(|i| Arc::new(elem(i).to_bytes())).collect());
        // Consumer 1 races ahead: nothing evicts while 2 is at the head.
        let (b1, _) = sb(&c, 1, 64, usize::MAX, &quiet);
        assert_eq!(b1.len(), 8);
        assert_eq!(c.stats().window, 8, "slowest registered cursor pins the window");
        // Consumer 2 reads 5: the consumed-by-all prefix evicts eagerly.
        let (b2, _) = sb(&c, 2, 5, usize::MAX, &quiet);
        assert_eq!(b2.len(), 5);
        assert_eq!(c.stats().window, 3, "consumed-by-all prefix evicted");
        // Eager eviction never outruns a registered cursor: no skips.
        assert_eq!(skips_of(&m), 0);
        // The laggard departing releases the rest of the tail.
        assert!(c.remove_consumer(2));
        assert_eq!(c.stats().window, 0, "departing laggard releases the tail");
        // A late lazy attacher starts at the live frontier — relaxed
        // visitation by design, but not *counted* as a laggard skip.
        c.push(elem(9));
        let (b3, _) = sb(&c, 3, 64, usize::MAX, &quiet);
        assert_eq!(b3.len(), 1);
        assert_eq!(skips_of(&m), 0, "a fresh cursor is not a laggard");
    }

    #[test]
    fn deflate_inflate_roundtrip() {
        let data = vec![7u8; 10_000];
        let z = deflate(&data).unwrap();
        assert!(z.len() < data.len() / 2);
        assert_eq!(inflate(&z).unwrap(), data);
    }

    /// Cache wired to an in-memory spill tier (the storage-backed window).
    fn cache_spilled(
        capacity: usize,
        byte_budget: usize,
        policy: SpillPolicy,
    ) -> (SlidingCache, Registry, Arc<JobSpill>) {
        let m = Registry::new();
        let store = crate::storage::ObjectStore::in_memory();
        let cfg = SpillConfig { policy, segment_bytes: 64 };
        let sp = JobSpill::new(store.clone(), store.region().clone(), &cfg, 0, 1, &m);
        let c = SlidingCache::new(capacity, byte_budget, false, 0, Some(sp.clone()), &m);
        (c, m, sp)
    }

    /// Drain everything currently visible to `client`, following RAM →
    /// spill fallbacks the way `drain_and_serve` does; returns the
    /// decoded payload values in delivery order.
    fn drain_all(c: &SlidingCache, client: u64, step: usize) -> Vec<i32> {
        let quiet = AtomicU64::new(0);
        let mut out = Vec::new();
        loop {
            match c.serve_batch(client, step, usize::MAX, usize::MAX, false, &quiet) {
                BatchServe::Spill { from, to } => {
                    let sp = c.spill().expect("spill outcome implies a tier").clone();
                    match sp.read_range(from, to, usize::MAX, usize::MAX) {
                        SpillRead::Batch { batch, next, skipped } => {
                            c.complete_spill(client, next, batch.len() as u64, skipped);
                            for b in &batch {
                                let e = Element::from_bytes(b).unwrap();
                                out.push(e.tensors[0].as_i32()[0]);
                            }
                        }
                        SpillRead::Oversized { .. } => panic!("no oversized elements here"),
                    }
                }
                BatchServe::Batch(batch, _) => {
                    if batch.is_empty() {
                        return out;
                    }
                    for b in &batch {
                        let e = Element::from_bytes(b).unwrap();
                        out.push(e.tensors[0].as_i32()[0]);
                    }
                }
                _ => panic!("unexpected oversize outcome"),
            }
        }
    }

    #[test]
    fn spill_late_attacher_replays_full_epoch() {
        // Full-epoch retention (SpillPolicy::All): a client that attaches
        // after most of the epoch was evicted from RAM replays everything
        // from the store — zero relaxed-visitation skips.
        let (c, m, _sp) = cache_spilled(2, usize::MAX, SpillPolicy::All);
        for i in 0..10 {
            c.push(elem(i));
        }
        assert!(c.stats().evictions >= 8, "tiny window must have evicted");
        let got = drain_all(&c, 9, 64);
        assert_eq!(got, (0..10).collect::<Vec<_>>());
        assert_eq!(skips_of(&m), 0, "spill replay leaves no skips");
        assert!(m.counter("worker/spill_segments_written").get() > 0);
        assert!(m.counter("worker/spill_elements_served").get() >= 8);
    }

    #[test]
    fn spill_wanted_policy_preserves_laggard_not_attacher() {
        // SpillPolicy::Wanted spills only elements some registered cursor
        // still needs: the laggard replays losslessly, while a fresh
        // attacher still anchors at the retained head (frontier join).
        let (c, m, _sp) = cache_spilled(2, usize::MAX, SpillPolicy::Wanted);
        c.register_consumer(1);
        for i in 0..8 {
            c.push(elem(i));
        }
        let got = drain_all(&c, 1, 3);
        assert_eq!(got, (0..8).collect::<Vec<_>>());
        assert_eq!(skips_of(&m), 0);
        // Fresh client: no full-epoch anchor under Wanted — it starts at
        // the oldest *retained* element, same as the RAM-only tier.
        let base = 8 - c.stats().window as i32;
        let got2 = drain_all(&c, 2, 64);
        assert_eq!(got2, (base..8).collect::<Vec<_>>());
    }

    #[test]
    fn spill_manifest_commits_full_epoch_with_tail_flush() {
        // Evicted prefix + in-RAM tail: flushing the tail at EOS yields a
        // complete manifest covering every element exactly once.
        let (c, _m, sp) = cache_spilled(3, usize::MAX, SpillPolicy::All);
        for i in 0..9 {
            c.push(elem(i));
        }
        c.set_eos();
        assert!(!sp.is_complete());
        c.flush_tail_to_spill();
        let man = sp.finalize();
        assert!(man.complete);
        assert_eq!(man.total_elements, 9);
        assert_eq!(man.end_seq(), 9);
    }

    #[test]
    fn adaptive_window_target_grows_with_demand_and_decays_idle() {
        // The byte target starts at budget/16 and only climbs toward the
        // full budget while a registered cursor still wants the prefix;
        // once eager eviction keeps the window empty it decays back.
        let quiet = AtomicU64::new(0);
        let m = Registry::new();
        let c = SlidingCache::new(100, 1 << 16, true, 7, None, &m);
        let gauge = m.gauge("worker/job/7/window_target_bytes");
        assert_eq!(gauge.get(), 4096, "initial target is budget/16");
        c.register_consumer(1);
        c.register_consumer(2);
        // 5 KiB of unconsumed window with a cursor pinned at the head:
        // the target doubles instead of evicting wanted elements.
        c.push_encoded((0..5).map(|_| Arc::new(vec![7u8; 1024])).collect());
        assert_eq!(gauge.get(), 8192, "cursor spread grew the target");
        assert_eq!(c.stats().evictions, 0, "wanted prefix was not evicted");
        // Both consumers drain; eager eviction empties the window and the
        // target decays multiplicatively toward the floor.
        let _ = sb(&c, 1, 64, usize::MAX, &quiet);
        let _ = sb(&c, 2, 64, usize::MAX, &quiet);
        assert_eq!(c.stats().window, 0);
        assert_eq!(gauge.get(), 6144, "idle window decays the target");
        assert_eq!(skips_of(&m), 0);
    }

    #[test]
    fn adaptive_target_caps_unwanted_backlog() {
        // No registered cursors: nothing "wants" the prefix, so the window
        // is bounded by the small initial target instead of the full
        // budget — eager-writer memory stays modest.
        let m = Registry::new();
        let c = SlidingCache::new(1000, 1 << 16, false, 0, None, &m);
        c.push_encoded((0..16).map(|_| Arc::new(vec![1u8; 1024])).collect());
        let s = c.stats();
        assert!(
            s.window_bytes <= 4096 + 1024,
            "window {} exceeds the unwanted target",
            s.window_bytes
        );
        assert!(s.evictions > 0);
    }

    #[test]
    fn spill_replay_exactly_once_under_random_schedules() {
        // Property: under SpillPolicy::All, any interleaving of produce /
        // serve / late-attach sees every client receive the full epoch
        // exactly once — the RAM → spill → RAM hand-back never skips or
        // duplicates — and the relaxed-visitation skip counter stays 0.
        for seed in 0..8u64 {
            let mut rng = crate::util::rng::Rng::new(0xC0FFEE ^ seed);
            let total = 40 + rng.below(40) as i32;
            let capacity = 1 + rng.below_usize(4);
            let (c, m, _sp) = cache_spilled(capacity, usize::MAX, SpillPolicy::All);
            let quiet = AtomicU64::new(0);
            let mut clients: Vec<u64> = vec![1];
            c.register_consumer(1);
            let mut got: HashMap<u64, Vec<i32>> = HashMap::new();
            got.insert(1, Vec::new());
            let mut next_val = 0i32;
            for _step in 0..100_000 {
                let done = next_val >= total
                    && clients.iter().all(|cl| got[cl].len() == total as usize);
                if done {
                    break;
                }
                match rng.below(4) {
                    0 if next_val < total => {
                        for _ in 0..=rng.below(4) {
                            if next_val >= total {
                                break;
                            }
                            c.push(elem(next_val));
                            next_val += 1;
                        }
                    }
                    1 if clients.len() < 5 && next_val > total / 2 => {
                        let id = clients.len() as u64 + 1;
                        c.register_consumer(id);
                        clients.push(id);
                        got.insert(id, Vec::new());
                    }
                    _ => {
                        let cl = *rng.choice(&clients);
                        let want = 1 + rng.below_usize(8);
                        match c.serve_batch(cl, want, usize::MAX, usize::MAX, false, &quiet) {
                            BatchServe::Spill { from, to } => {
                                let sp = c.spill().unwrap().clone();
                                match sp.read_range(from, to, usize::MAX, usize::MAX) {
                                    SpillRead::Batch { batch, next, skipped } => {
                                        c.complete_spill(
                                            cl,
                                            next,
                                            batch.len() as u64,
                                            skipped,
                                        );
                                        let sink = got.get_mut(&cl).unwrap();
                                        for b in &batch {
                                            let e = Element::from_bytes(b).unwrap();
                                            sink.push(e.tensors[0].as_i32()[0]);
                                        }
                                    }
                                    SpillRead::Oversized { .. } => panic!("tiny elements"),
                                }
                            }
                            BatchServe::Batch(batch, _) => {
                                let sink = got.get_mut(&cl).unwrap();
                                for b in &batch {
                                    let e = Element::from_bytes(b).unwrap();
                                    sink.push(e.tensors[0].as_i32()[0]);
                                }
                            }
                            _ => panic!("unexpected oversize outcome"),
                        }
                    }
                }
            }
            let want: Vec<i32> = (0..total).collect();
            for cl in &clients {
                assert_eq!(
                    got[cl], want,
                    "seed {seed}: client {cl} must see the epoch exactly once"
                );
            }
            assert_eq!(skips_of(&m), 0, "seed {seed}: no relaxed skips under All");
        }
    }

    use crate::util::rng::Rng;

    /// Single-lock reference model of the sliding cache: the pre-sharding
    /// implementation (one big critical section around cursors + window +
    /// ledgers), including the adaptive byte-target state machine. The
    /// differential tests below replay one recorded schedule against this
    /// model and against the sharded implementation and demand identical
    /// deliveries, EOS verdicts, and ledger totals.
    #[derive(Default)]
    struct RefCache {
        capacity: usize,
        byte_budget: usize,
        eager: bool,
        target_bytes: usize,
        window: VecDeque<Arc<Vec<u8>>>,
        window_bytes: usize,
        base_seq: u64,
        eos: bool,
        cursors: HashMap<u64, u64>,
        removed: std::collections::HashSet<u64>,
        hits: u64,
        evictions: u64,
        produced: u64,
        shared_produced: u64,
        skipped: u64,
    }

    impl RefCache {
        fn new(capacity: usize, byte_budget: usize, eager: bool) -> RefCache {
            let byte_budget = byte_budget.max(1);
            RefCache {
                capacity: capacity.max(1),
                byte_budget,
                eager,
                target_bytes: (byte_budget / 16).max(1),
                ..Default::default()
            }
        }

        fn min_cursor(&self) -> Option<u64> {
            self.cursors.values().copied().min()
        }

        fn register(&mut self, client: u64) {
            if self.removed.contains(&client) || self.cursors.contains_key(&client) {
                return;
            }
            self.cursors.insert(client, self.base_seq);
        }

        fn remove(&mut self, client: u64) {
            self.removed.insert(client);
            self.cursors.remove(&client);
            self.trim();
        }

        fn push_encoded(&mut self, encoded: &[Arc<Vec<u8>>]) {
            if encoded.is_empty() {
                return;
            }
            if self.cursors.len() >= 2 {
                self.shared_produced += encoded.len() as u64;
            }
            self.produced += encoded.len() as u64;
            let min_cursor = self.min_cursor();
            for bytes in encoded {
                self.window_bytes += bytes.len();
                self.window.push_back(bytes.clone());
                loop {
                    let over_cap = self.window.len() > self.capacity;
                    let over_bytes =
                        self.window_bytes > self.target_bytes && self.window.len() > 1;
                    if !over_cap && !over_bytes {
                        break;
                    }
                    let wanted = min_cursor.is_some_and(|m| m <= self.base_seq);
                    if !over_cap && wanted && self.target_bytes < self.byte_budget {
                        self.target_bytes =
                            self.target_bytes.saturating_mul(2).min(self.byte_budget);
                        continue;
                    }
                    let Some(old) = self.window.pop_front() else { break };
                    self.window_bytes -= old.len();
                    self.base_seq += 1;
                    self.evictions += 1;
                }
            }
        }

        /// Consumed-by-all eviction plus idle target decay. The sharded
        /// implementation gates this behind the `min_hint` watermark;
        /// unconditional re-trimming is sequentially equivalent because a
        /// trim below an unchanged minimum is a no-op.
        fn trim(&mut self) {
            let Some(min) = self.min_cursor() else { return };
            if !self.eager || self.base_seq >= min || self.window.is_empty() {
                return;
            }
            while self.base_seq < min && !self.window.is_empty() {
                let old = self.window.pop_front().expect("non-empty window");
                self.window_bytes -= old.len();
                self.base_seq += 1;
                self.evictions += 1;
            }
            if self.window.is_empty() {
                let floor = (self.byte_budget / 16).max(1);
                if self.target_bytes > floor {
                    self.target_bytes = (self.target_bytes - self.target_bytes / 4).max(floor);
                }
            }
        }

        fn serve_batch(&mut self, client: u64, max_elements: usize) -> (Vec<Arc<Vec<u8>>>, bool) {
            if self.removed.contains(&client) {
                return (Vec::new(), true);
            }
            let base = self.base_seq;
            let mut cursor = *self.cursors.entry(client).or_insert(base);
            if cursor < base {
                self.skipped += base - cursor;
                cursor = base;
            }
            let mut out = Vec::new();
            while out.len() < max_elements {
                let idx = (cursor - base) as usize;
                if idx >= self.window.len() {
                    break;
                }
                out.push(self.window[idx].clone());
                cursor += 1;
            }
            self.hits += out.len() as u64;
            self.cursors.insert(client, cursor);
            let drained = (cursor - base) as usize >= self.window.len();
            let end = self.eos && drained;
            self.trim();
            (out, end)
        }
    }

    /// One step of a recorded cache schedule. `Push`/`Register`/`Remove`/
    /// `Eos` belong to the producer/control thread, `Serve` to the owning
    /// consumer thread.
    #[derive(Clone)]
    enum DiffOp {
        Push(Vec<Arc<Vec<u8>>>),
        Register(u64),
        Remove(u64),
        Serve { client: u64, max: usize },
        Eos,
    }

    /// Seeds for the differential battery: two fixed plus the CI fault
    /// seed (the 3-seed matrix reruns this suite under fresh schedules).
    fn diff_seeds() -> [u64; 3] {
        let env = std::env::var("TFDATASVC_FAULT_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(20260728);
        [17, 42, env]
    }

    fn gen_diff_schedule(rng: &mut Rng, clients: &[u64], ops: usize) -> Vec<DiffOp> {
        let mut sched = Vec::with_capacity(ops + clients.len() + 1);
        let mut next = 0i32;
        for _ in 0..ops {
            match rng.below(10) {
                0..=3 => {
                    let n = 1 + rng.below(4);
                    let batch = (0..n)
                        .map(|_| {
                            let v = next;
                            next += 1;
                            Arc::new(elem(v).to_bytes())
                        })
                        .collect();
                    sched.push(DiffOp::Push(batch));
                }
                4 => sched.push(DiffOp::Register(*rng.choice(clients))),
                5 if rng.below(4) == 0 => sched.push(DiffOp::Remove(*rng.choice(clients))),
                _ => sched.push(DiffOp::Serve {
                    client: *rng.choice(clients),
                    max: 1 + rng.below_usize(6),
                }),
            }
        }
        sched.push(DiffOp::Eos);
        // Drain serves so every surviving cursor reaches an EOS verdict.
        for &c in clients {
            sched.push(DiffOp::Serve { client: c, max: usize::MAX });
        }
        sched
    }

    fn decode_vals(batch: &[Arc<Vec<u8>>]) -> Vec<i32> {
        batch
            .iter()
            .map(|b| Element::from_bytes(b).unwrap().tensors[0].as_i32()[0])
            .collect()
    }

    /// Tentpole lock-in: replay a recorded schedule (a) sequentially
    /// against the single-lock reference model and (b) across real
    /// threads against the sharded cache, with a turnstile (a shared op
    /// index each thread spins on) forcing the exact recorded order. Per
    /// the shard rewrite's sequential-equivalence argument, every serve's
    /// delivered elements, every EOS verdict, and every ledger total
    /// (hits / evictions / skips / shared) must match the reference —
    /// any divergence in cursor clamping, eager-trim gating, or the
    /// adaptive byte target shows up as a transcript mismatch here.
    #[test]
    fn serve_batch_differential_matches_single_lock_reference() {
        let sz = elem(0).to_bytes().len();
        // (capacity, byte_budget, eager): plain bounded window, eager
        // consumed-by-all eviction, and a tight byte budget that drives
        // the adaptive target through grow + decay.
        let configs = [(8usize, usize::MAX, false), (8, usize::MAX, true), (100, 6 * sz, true)];
        let clients: Vec<u64> = vec![1, 2, 3, 4, 5];
        for seed in diff_seeds() {
            for &(cap, budget, eager) in &configs {
                let mut rng = Rng::new(0xD1FF_0000 ^ seed ^ (cap as u64) ^ (budget as u64));
                let sched = gen_diff_schedule(&mut rng, &clients, 600);

                // (a) Sequential replay against the reference model.
                let mut reference = RefCache::new(cap, budget, eager);
                let mut want: Vec<(usize, Vec<i32>, bool)> = Vec::new();
                for (idx, op) in sched.iter().enumerate() {
                    match op {
                        DiffOp::Push(batch) => reference.push_encoded(batch),
                        DiffOp::Register(c) => reference.register(*c),
                        DiffOp::Remove(c) => {
                            reference.remove(*c);
                        }
                        DiffOp::Serve { client, max } => {
                            let (batch, end) = reference.serve_batch(*client, *max);
                            want.push((idx, decode_vals(&batch), end));
                        }
                        DiffOp::Eos => reference.eos = true,
                    }
                }

                // (b) Turnstile replay against the sharded cache: thread 0
                // owns production/control ops, consumer threads own the
                // serves for their clients — same global order, but every
                // hand-off crosses a real thread boundary.
                let m = Registry::new();
                let c = SlidingCache::new(cap, budget, eager, 0, None, &m);
                let quiet = AtomicU64::new(0);
                let turnstile = AtomicUsize::new(0);
                let n_serve_threads = 3usize;
                let owner = |op: &DiffOp| -> usize {
                    match op {
                        DiffOp::Serve { client, .. } => 1 + (*client as usize % n_serve_threads),
                        _ => 0,
                    }
                };
                let mut got: Vec<(usize, Vec<i32>, bool)> = Vec::new();
                std::thread::scope(|s| {
                    let mut handles = Vec::new();
                    for t in 0..=n_serve_threads {
                        let my_ops: Vec<(usize, DiffOp)> = sched
                            .iter()
                            .enumerate()
                            .filter(|(_, op)| owner(op) == t)
                            .map(|(i, op)| (i, op.clone()))
                            .collect();
                        let (c, quiet, turnstile) = (&c, &quiet, &turnstile);
                        handles.push(s.spawn(move || {
                            let mut serves = Vec::new();
                            for (idx, op) in my_ops {
                                while turnstile.load(Ordering::Acquire) != idx {
                                    std::thread::yield_now();
                                }
                                match op {
                                    DiffOp::Push(batch) => {
                                        c.push_encoded(batch);
                                    }
                                    DiffOp::Register(cl) => {
                                        c.register_consumer(cl);
                                    }
                                    DiffOp::Remove(cl) => {
                                        c.remove_consumer(cl);
                                    }
                                    DiffOp::Serve { client, max } => {
                                        let (batch, end) = match c.serve_batch(
                                            client,
                                            max,
                                            usize::MAX,
                                            usize::MAX,
                                            false,
                                            quiet,
                                        ) {
                                            BatchServe::Batch(b, e) => (b, e),
                                            _ => panic!("no spill/oversize in this schedule"),
                                        };
                                        serves.push((idx, decode_vals(&batch), end));
                                    }
                                    DiffOp::Eos => c.set_eos(),
                                }
                                turnstile.store(idx + 1, Ordering::Release);
                            }
                            serves
                        }));
                    }
                    for h in handles {
                        got.extend(h.join().expect("replay thread"));
                    }
                });
                got.sort_by_key(|(idx, _, _)| *idx);

                let tag = format!("seed {seed} cap {cap} budget {budget} eager {eager}");
                assert_eq!(got, want, "serve transcript diverged: {tag}");
                let s = c.stats();
                assert_eq!(s.hits, reference.hits, "hits: {tag}");
                assert_eq!(s.evictions, reference.evictions, "evictions: {tag}");
                assert_eq!(s.produced, reference.produced, "produced: {tag}");
                assert_eq!(s.skipped, reference.skipped, "skips: {tag}");
                assert_eq!(
                    s.shared_produced, reference.shared_produced,
                    "shared ledger: {tag}"
                );
                assert_eq!(s.window, reference.window.len(), "window: {tag}");
                assert_eq!(s.window_bytes, reference.window_bytes, "window bytes: {tag}");
                assert_eq!(skips_of(&m), reference.skipped, "registry skips: {tag}");
            }
        }
    }

    /// Unsynchronized counterpart of the turnstile test: one producer and
    /// four consumers hammer the sharded cache with no schedule at all,
    /// then the accounting invariants are checked.
    ///
    /// Phase 1 (lossless config: capacity covers the epoch, eager): every
    /// consumer must see the full stream exactly once, in order, with
    /// zero relaxed-visitation skips. Phase 2 (tiny bounded window,
    /// laggard consumers): deliveries stay strictly increasing per
    /// consumer (no duplicate, no reorder) and every cursor unit is
    /// accounted as exactly one hit or one skip:
    /// `hits + skipped == consumers * produced`.
    #[test]
    fn serve_batch_chaos_preserves_exactly_once_and_ledgers() {
        let total = 400i32;
        let consumers = 4u64;
        let run = |capacity: usize, eager: bool, lag: bool| -> (Vec<Vec<i32>>, CacheStats, Registry) {
            let m = Registry::new();
            let c = SlidingCache::new(capacity, usize::MAX, eager, 0, None, &m);
            for cl in 1..=consumers {
                c.register_consumer(cl);
            }
            let quiet = AtomicU64::new(0);
            let mut per_client: Vec<Vec<i32>> = Vec::new();
            std::thread::scope(|s| {
                s.spawn(|| {
                    let mut rng = Rng::new(0xCAFE ^ capacity as u64);
                    let mut next = 0i32;
                    while next < total {
                        let n = (1 + rng.below(8) as i32).min(total - next);
                        let batch = (0..n)
                            .map(|i| Arc::new(elem(next + i).to_bytes()))
                            .collect();
                        next += n;
                        c.push_encoded(batch);
                        if rng.below(4) == 0 {
                            std::thread::yield_now();
                        }
                    }
                    c.set_eos();
                });
                let handles: Vec<_> = (1..=consumers)
                    .map(|cl| {
                        let (c, quiet) = (&c, &quiet);
                        s.spawn(move || {
                            let mut rng = Rng::new(0xFEED ^ cl);
                            let mut got = Vec::new();
                            loop {
                                let want = 1 + rng.below_usize(7);
                                match c.serve_batch(cl, want, usize::MAX, usize::MAX, false, quiet)
                                {
                                    BatchServe::Batch(batch, end) => {
                                        for b in &batch {
                                            got.push(
                                                Element::from_bytes(b).unwrap().tensors[0]
                                                    .as_i32()[0],
                                            );
                                        }
                                        if end {
                                            break;
                                        }
                                        if batch.is_empty() {
                                            c.wait_for_publish(Duration::from_millis(1));
                                        }
                                    }
                                    _ => panic!("no spill/oversize in this run"),
                                }
                                if lag && rng.below(8) == 0 {
                                    std::thread::sleep(Duration::from_micros(rng.below(200)));
                                }
                            }
                            got
                        })
                    })
                    .collect();
                per_client = handles.into_iter().map(|h| h.join().expect("consumer")).collect();
            });
            let stats = c.stats();
            (per_client, stats, m)
        };

        // Phase 1: nothing can be evicted from under a cursor.
        let (per, s, m) = run(total as usize + 1, true, false);
        let want: Vec<i32> = (0..total).collect();
        for (i, got) in per.iter().enumerate() {
            assert_eq!(got, &want, "consumer {i} must see the epoch exactly once");
        }
        assert_eq!(s.produced, total as u64);
        assert_eq!(s.hits, consumers * total as u64);
        assert_eq!(s.skipped, 0);
        assert_eq!(skips_of(&m), 0);

        // Phase 2: tiny window forces relaxed-visitation skips; the
        // hit/skip split must still account for every cursor step.
        let (per, s, m) = run(4, false, true);
        for (i, got) in per.iter().enumerate() {
            assert!(
                got.windows(2).all(|w| w[0] < w[1]),
                "consumer {i}: deliveries must be strictly increasing (exactly-once)"
            );
        }
        let delivered: u64 = per.iter().map(|v| v.len() as u64).sum();
        assert_eq!(s.produced, total as u64);
        assert_eq!(s.hits, delivered);
        assert_eq!(s.skipped, consumers * total as u64 - delivered);
        assert_eq!(skips_of(&m), s.skipped);
        assert_eq!(s.evictions as usize + s.window, total as usize);
    }
}
