//! User-defined functions, referenced by name from pipeline graphs.
//!
//! The paper's data transformations are "user-defined functions" executing
//! on general-purpose CPUs (§2). Here a UDF is any
//! `Fn(Element) -> Result<Element, String>`; graphs carry only the *name*,
//! and each worker resolves names against its local registry — exactly how
//! serialized tf.data graphs reference captured functions.
//!
//! Composite names `"a+b"` apply `a` then `b`; the map-fusion optimization
//! (see [`super::optimize`]) rewrites `map(a).map(b)` into `map("a+b")`.
//!
//! The registry ships with native preprocessing UDFs for the synthetic
//! vision/NLP corpora plus a calibrated `synthetic.burn:<µs>` UDF used by
//! benches to dial in the paper's per-model preprocessing costs. The XLA
//! UDFs (running the AOT Pallas kernels) are registered by
//! [`crate::runtime`] at worker startup.

use super::element::{DType, Element, Tensor};
use std::collections::HashMap;
use std::sync::{Arc, RwLock};

/// A named element transformation. Predicate UDFs (for `filter`) signal
/// "keep" by returning an element whose first tensor's first byte is
/// nonzero.
pub trait Udf: Send + Sync {
    fn call(&self, elem: Element) -> Result<Element, String>;
}

impl<F> Udf for F
where
    F: Fn(Element) -> Result<Element, String> + Send + Sync,
{
    fn call(&self, elem: Element) -> Result<Element, String> {
        self(elem)
    }
}

/// Thread-safe name → UDF registry.
#[derive(Clone, Default)]
pub struct UdfRegistry {
    inner: Arc<RwLock<HashMap<String, Arc<dyn Udf>>>>,
    /// Optional body digests, mixed into pipeline fingerprints (§3.5): a
    /// re-implemented UDF under the same name gets a new digest, so jobs
    /// running the old and new bodies never share ephemeral data.
    digests: Arc<RwLock<HashMap<String, u64>>>,
}

impl UdfRegistry {
    pub fn empty() -> UdfRegistry {
        UdfRegistry::default()
    }

    /// Registry pre-populated with the native preprocessing UDFs.
    pub fn with_builtins() -> UdfRegistry {
        let r = UdfRegistry::default();
        register_builtins(&r);
        r
    }

    pub fn register(&self, name: &str, udf: Arc<dyn Udf>) {
        self.inner.write().unwrap().insert(name.to_string(), udf);
    }

    pub fn register_fn<F>(&self, name: &str, f: F)
    where
        F: Fn(Element) -> Result<Element, String> + Send + Sync + 'static,
    {
        self.register(name, Arc::new(f));
    }

    /// Register alongside a body digest (any stable hash of the UDF's
    /// implementation — version tag, source hash, artifact checksum).
    pub fn register_fn_digest<F>(&self, name: &str, digest: u64, f: F)
    where
        F: Fn(Element) -> Result<Element, String> + Send + Sync + 'static,
    {
        self.register_fn(name, f);
        self.set_digest(name, digest);
    }

    /// Attach (or replace) the body digest for an already-registered name.
    pub fn set_digest(&self, name: &str, digest: u64) {
        self.digests.write().unwrap().insert(name.to_string(), digest);
    }

    /// Body digest for a (possibly composite `a+b`) name. A composite has
    /// a digest only when every part does; parts are combined
    /// order-sensitively so `a+b` and `b+a` differ.
    pub fn digest(&self, name: &str) -> Option<u64> {
        let map = self.digests.read().unwrap();
        if let Some(&d) = map.get(name) {
            return Some(d);
        }
        if name.contains('+') {
            let mut acc = 0xcbf2_9ce4_8422_2325u64; // FNV offset basis
            for p in name.split('+') {
                let d = map.get(p)?;
                acc = (acc ^ d).wrapping_mul(0x0000_0100_0000_01b3);
            }
            return Some(acc);
        }
        None
    }

    pub fn contains(&self, name: &str) -> bool {
        self.resolve(name).is_some()
    }

    /// Resolve a (possibly composite `a+b`) name to a callable.
    pub fn resolve(&self, name: &str) -> Option<Arc<dyn Udf>> {
        if let Some(u) = self.resolve_simple(name) {
            return Some(u);
        }
        // Composite chain.
        if name.contains('+') {
            let mut parts = Vec::new();
            for p in name.split('+') {
                parts.push(self.resolve_simple(p)?);
            }
            return Some(Arc::new(move |mut e: Element| {
                for p in &parts {
                    e = p.call(e)?;
                }
                Ok(e)
            }));
        }
        None
    }

    fn resolve_simple(&self, name: &str) -> Option<Arc<dyn Udf>> {
        if let Some(u) = self.inner.read().unwrap().get(name) {
            return Some(u.clone());
        }
        if let Some(us) = name.strip_prefix("synthetic.burn:") {
            let us: u64 = us.parse().ok()?;
            return Some(Arc::new(move |e| Ok(burn_cpu(e, us))));
        }
        None
    }

    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.inner.read().unwrap().keys().cloned().collect();
        v.sort();
        v
    }
}

/// Spin the CPU for ~`us` microseconds (calibrated load stand-in for
/// expensive augmentations; benches use this to make jobs input-bound).
fn burn_cpu(elem: Element, us: u64) -> Element {
    let start = std::time::Instant::now();
    let mut acc = 0u64;
    while start.elapsed().as_micros() < us as u128 {
        // Real work so the optimizer cannot elide the loop.
        acc = acc.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        std::hint::black_box(acc);
    }
    elem
}

/// Register the native UDF set.
fn register_builtins(r: &UdfRegistry) {
    // -- generic --
    r.register_fn("identity", Ok);

    // -- vision: u8 HWC pixels -> f32 normalized to [0,1] --
    r.register_fn("vision.normalize", |e: Element| {
        let mut out = Vec::with_capacity(e.tensors.len());
        for t in &e.tensors {
            if t.dtype == DType::U8 {
                let vals: Vec<f32> = t.as_u8().iter().map(|&b| b as f32 / 255.0).collect();
                out.push(Tensor::from_f32(t.shape.clone(), &vals));
            } else {
                out.push(t.clone());
            }
        }
        Ok(Element { tensors: out, ids: e.ids, bucket: e.bucket })
    });

    // -- vision: deterministic per-sample flip + brightness (AutoAugment
    // stand-in; randomness keyed by the sample id so it is reproducible) --
    r.register_fn("vision.augment", |e: Element| {
        let seed = e.ids.first().copied().unwrap_or(0);
        let mut rng = crate::util::rng::Rng::new(seed ^ 0x0a06_5eed);
        let flip = rng.chance(0.5);
        let brightness = rng.uniform(0.8, 1.2) as f32;
        let mut out = Vec::with_capacity(e.tensors.len());
        for t in &e.tensors {
            if t.dtype == DType::F32 && t.rank() == 3 {
                let (h, w_, c) = (t.shape[0], t.shape[1], t.shape[2]);
                let vals = t.as_f32();
                let mut new = vec![0f32; vals.len()];
                for y in 0..h {
                    for x in 0..w_ {
                        let sx = if flip { w_ - 1 - x } else { x };
                        for ch in 0..c {
                            let v = vals[(y * w_ + sx) * c + ch] * brightness;
                            new[(y * w_ + x) * c + ch] = v.clamp(0.0, 1.0);
                        }
                    }
                }
                out.push(Tensor::from_f32(t.shape.clone(), &new));
            } else {
                out.push(t.clone());
            }
        }
        Ok(Element { tensors: out, ids: e.ids, bucket: e.bucket })
    });

    // -- nlp: clamp token sequences to 512 and convert u32 -> i32 ids --
    r.register_fn("nlp.truncate", |e: Element| {
        let mut out = Vec::with_capacity(e.tensors.len());
        for t in &e.tensors {
            if t.dtype == DType::U32 && t.rank() == 1 {
                let toks = t.as_u32();
                let n = toks.len().min(512);
                out.push(Tensor::from_u32(vec![n], &toks[..n]));
            } else {
                out.push(t.clone());
            }
        }
        Ok(Element { tensors: out, ids: e.ids, bucket: e.bucket })
    });

    // -- filters --
    // keep samples whose first tensor has even length (test predicate)
    r.register_fn("filter.even_len", |e: Element| {
        let keep = e.tensors.first().map(|t| t.shape.first().copied().unwrap_or(1) % 2 == 0).unwrap_or(false);
        predicate_result(e, keep)
    });
    // keep nonzero-labeled samples (expects a u32 scalar as 2nd tensor)
    r.register_fn("filter.label_nonzero", |e: Element| {
        let keep = e.tensors.get(1).map(|t| t.as_u32()[0] != 0).unwrap_or(true);
        predicate_result(e, keep)
    });

    // Body digests for every builtin: the version tag stands in for a
    // source hash. Bump a UDF's tag when its behavior changes so pipelines
    // running old and new bodies stop fingerprint-colliding.
    for (name, version) in [
        ("identity", "v1"),
        ("vision.normalize", "v1"),
        ("vision.augment", "v1"),
        ("nlp.truncate", "v1"),
        ("filter.even_len", "v1"),
        ("filter.label_nonzero", "v1"),
    ] {
        let h = crate::util::sha256::sha256(format!("{name}:{version}").as_bytes());
        r.set_digest(name, u64::from_le_bytes(h[..8].try_into().unwrap()));
    }
}

/// Encode a filter verdict: element passes through with a marker tensor
/// prepended? No — predicates return the *original* element plus the
/// verdict in `bucket` (0 = drop, 1 = keep); the filter iterator strips it.
pub(crate) fn predicate_result(mut e: Element, keep: bool) -> Result<Element, String> {
    e.bucket = Some(keep as u32);
    Ok(e)
}

/// Read a predicate verdict produced by [`predicate_result`].
pub(crate) fn predicate_verdict(e: &Element) -> bool {
    e.bucket == Some(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vision_elem() -> Element {
        Element::with_ids(
            vec![
                Tensor::from_u8(vec![2, 2, 1], vec![0, 64, 128, 255]),
                Tensor::scalar_u32(3),
            ],
            vec![11],
        )
    }

    #[test]
    fn normalize_scales_to_unit() {
        let r = UdfRegistry::with_builtins();
        let out = r.resolve("vision.normalize").unwrap().call(vision_elem()).unwrap();
        let px = out.tensors[0].as_f32();
        assert!((px[3] - 1.0).abs() < 1e-6);
        assert!((px[1] - 64.0 / 255.0).abs() < 1e-6);
        // label untouched, ids preserved
        assert_eq!(out.tensors[1].as_u32(), vec![3]);
        assert_eq!(out.ids, vec![11]);
    }

    #[test]
    fn augment_is_deterministic_per_id() {
        let r = UdfRegistry::with_builtins();
        let norm = r.resolve("vision.normalize").unwrap();
        let aug = r.resolve("vision.augment").unwrap();
        let a = aug.call(norm.call(vision_elem()).unwrap()).unwrap();
        let b = aug.call(norm.call(vision_elem()).unwrap()).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn truncate_clamps_length() {
        let r = UdfRegistry::with_builtins();
        let toks: Vec<u32> = (0..600).collect();
        let e = Element::new(vec![Tensor::from_u32(vec![600], &toks)]);
        let out = r.resolve("nlp.truncate").unwrap().call(e).unwrap();
        assert_eq!(out.tensors[0].shape, vec![512]);
    }

    #[test]
    fn composite_resolution_chains() {
        let r = UdfRegistry::with_builtins();
        let chained = r.resolve("vision.normalize+vision.augment").unwrap();
        let direct = {
            let n = r.resolve("vision.normalize").unwrap();
            let a = r.resolve("vision.augment").unwrap();
            a.call(n.call(vision_elem()).unwrap()).unwrap()
        };
        assert_eq!(chained.call(vision_elem()).unwrap(), direct);
    }

    #[test]
    fn composite_with_missing_part_fails() {
        let r = UdfRegistry::with_builtins();
        assert!(r.resolve("vision.normalize+nope").is_none());
    }

    #[test]
    fn burn_udf_parses_and_burns() {
        let r = UdfRegistry::with_builtins();
        let u = r.resolve("synthetic.burn:2000").unwrap();
        let t0 = std::time::Instant::now();
        u.call(Element::new(vec![])).unwrap();
        assert!(t0.elapsed().as_micros() >= 2000);
        assert!(r.resolve("synthetic.burn:notanumber").is_none());
    }

    #[test]
    fn unknown_name_is_none() {
        let r = UdfRegistry::with_builtins();
        assert!(r.resolve("no.such.udf").is_none());
    }

    #[test]
    fn custom_registration_wins() {
        let r = UdfRegistry::with_builtins();
        r.register_fn("double", |mut e: Element| {
            let v = e.tensors[0].as_f32().iter().map(|x| x * 2.0).collect::<Vec<_>>();
            e.tensors[0] = Tensor::from_f32(e.tensors[0].shape.clone(), &v);
            Ok(e)
        });
        let e = Element::new(vec![Tensor::from_f32(vec![1], &[21.0])]);
        let out = r.resolve("double").unwrap().call(e).unwrap();
        assert_eq!(out.tensors[0].as_f32(), vec![42.0]);
    }

    #[test]
    fn digests_cover_builtins_and_composites() {
        let r = UdfRegistry::with_builtins();
        let n = r.digest("vision.normalize").expect("builtin digest");
        let a = r.digest("vision.augment").expect("builtin digest");
        assert_ne!(n, a);
        // Composite digest exists and is order-sensitive.
        let na = r.digest("vision.normalize+vision.augment").unwrap();
        let an = r.digest("vision.augment+vision.normalize").unwrap();
        assert_ne!(na, an);
        // Unknown part -> no digest; custom registration gets one.
        assert!(r.digest("vision.normalize+nope").is_none());
        r.register_fn_digest("custom", 42, Ok);
        assert_eq!(r.digest("custom"), Some(42));
        r.set_digest("custom", 43); // body changed
        assert_eq!(r.digest("custom"), Some(43));
    }

    #[test]
    fn predicate_verdict_roundtrip() {
        let e = Element::new(vec![]);
        let kept = predicate_result(e.clone(), true).unwrap();
        assert!(predicate_verdict(&kept));
        let dropped = predicate_result(e, false).unwrap();
        assert!(!predicate_verdict(&dropped));
    }
}
