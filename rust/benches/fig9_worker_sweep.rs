//! Fig. 9: M1 across worker pool sizes {8,16,32,64,128,256,512,640}:
//! (a) job-time speedup vs colocated with the ideal line, (b) cost
//! savings. Paper anchors: 8 workers -> 0.55x (slower than colocated!),
//! 16 -> 1.14x, 64 -> 4.1x, 128 -> 8.6x, 512 -> 12.3x (ideal), 640 ->
//! same time, slightly higher cost.

use tfdatasvc::metrics::write_csv_rows;
use tfdatasvc::sim::cost::CostModel;
use tfdatasvc::sim::des::{simulate_job, JobSimConfig};
use tfdatasvc::sim::models::model;

fn main() {
    let m = model("M1");
    let colo = simulate_job(m, &JobSimConfig::default());
    let ideal_speedup = m.ideal_bps / colo.throughput_bps;
    let cm = CostModel::production_like();
    let clients = m.accelerators as f64 / 8.0;
    let t_colo = 10.0;
    let colo_cost = cm.job_cost(t_colo, 0.0, 0.0, 0.0, clients, 96.0, 335.0, 8.0).total;

    println!("=== Fig 9: M1 worker-count sweep (colocated: {:.2} b/s; ideal {ideal_speedup:.1}x) ===", colo.throughput_bps);
    println!("{:>8} {:>10} {:>9} {:>11} {:>10} {:>10}", "workers", "b/s", "speedup", "worker util", "cost", "saving");
    let mut rows = Vec::new();
    let mut prev_bps = 0.0;
    for n in [8usize, 16, 32, 64, 128, 256, 512, 640] {
        let r = simulate_job(m, &JobSimConfig { n_workers: n, ..Default::default() });
        let speedup = r.throughput_bps / colo.throughput_bps;
        let t_dis = t_colo / speedup;
        let cost = cm
            .job_cost(
                t_dis,
                n as f64,
                m.worker_cpu_cores * r.worker_utilization,
                8.0,
                clients,
                96.0,
                335.0,
                8.0,
            )
            .total;
        let saving = colo_cost / cost;
        println!(
            "{:>8} {:>10.2} {:>8.2}x {:>10.0}% {:>10.1} {:>9.2}x",
            n,
            r.throughput_bps,
            speedup,
            r.worker_utilization * 100.0,
            cost,
            saving
        );
        rows.push(vec![
            n.to_string(),
            format!("{:.3}", r.throughput_bps),
            format!("{speedup:.3}"),
            format!("{saving:.3}"),
        ]);
        assert!(r.throughput_bps >= prev_bps - 1e-6, "throughput must be monotone");
        prev_bps = r.throughput_bps;
    }
    // Shape assertions from the paper.
    let at = |n: usize| {
        simulate_job(m, &JobSimConfig { n_workers: n, ..Default::default() }).throughput_bps
            / colo.throughput_bps
    };
    assert!(at(8) < 1.0, "8 workers slower than colocated");
    assert!(at(16) > 1.0, "16 workers faster than colocated");
    assert!(at(512) > 0.95 * ideal_speedup, "512 workers reach ideal");
    let (s512, s640) = (at(512), at(640));
    assert!((s640 - s512).abs() / s512 < 0.02, "over-provisioning does not change job time");
    write_csv_rows("out/fig9.csv", "workers,bps,speedup,cost_saving", &rows).unwrap();
    println!("fig9 OK -> out/fig9.csv");
}
