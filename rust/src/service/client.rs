//! The service client: accelerator-host side of tf.data service.
//!
//! [`ServiceClient::distribute`] is the Rust analogue of Fig. 4's
//! `ds.distribute(...)`: it optimizes and registers the pipeline with the
//! dispatcher, joins (or creates) a job, discovers workers via heartbeats,
//! and returns an iterator that fetches preprocessed batches over RPC.
//!
//! * Independent mode: one fetcher thread per worker pulls into a bounded
//!   client-side buffer ("clients can request data from multiple workers
//!   in parallel", §3.1).
//! * Coordinated mode: the client walks rounds 0, 1, 2, …, asking the
//!   worker that owns each round for its `consumer_index` slot (§3.6).

use super::proto::*;
use super::worker::inflate;
use super::{ServiceError, ServiceResult};
use crate::data::exec::ElemIter;
use crate::data::graph::GraphDef;
use crate::data::optimize::{optimize, OptimizeOptions};
use crate::data::{DataResult, Element};
use crate::metrics::Registry;
use crate::rpc::{call_typed, Pool};
use crate::util::chan;
use crate::wire::Decode;
use std::collections::HashSet;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Per-job client configuration (the `distribute(...)` kwargs).
#[derive(Debug, Clone)]
pub struct ServiceClientConfig {
    pub sharding: ShardingPolicy,
    pub mode: ProcessingMode,
    /// Shared job name; empty = anonymous job (subject to `sharing`).
    pub job_name: String,
    /// Cross-job ephemeral sharing (§3.5). `Auto`: an anonymous
    /// independent-mode job attaches to a live job running the exact same
    /// pipeline (by structural fingerprint) instead of re-producing it —
    /// note this trades the visitation guarantee for cost: a client
    /// attaching mid-stream starts at the oldest *retained* window
    /// element (relaxed visitation), so opt in only when that is
    /// acceptable (e.g. hyperparameter sweeps). `Off` (default): always
    /// create a dedicated production with the full guarantee.
    pub sharing: SharingMode,
    /// Coordinated mode: total consumers and this client's slot.
    pub num_consumers: u32,
    pub consumer_index: u32,
    pub compression: CompressionMode,
    /// Client-side buffer depth (elements).
    pub buffer_size: usize,
    /// Max parallel fetchers (one per worker up to this cap).
    pub max_fetchers: usize,
    pub request_timeout: Duration,
    /// How often to refresh the worker list from the dispatcher.
    pub heartbeat_interval: Duration,
    /// Fetch via the batched streaming `GetElements` RPC (default). Only
    /// applies to independent mode; coordinated reads always use the
    /// single-element round protocol. Set false to force the legacy
    /// one-element-per-RPC path.
    pub batching: bool,
    /// Max elements per batched response; 0 = worker default.
    pub batch_max_elements: u32,
    /// Per-response byte budget (flow control: bounds per-worker client
    /// memory to ~2x this with the request pipeline); 0 = worker default.
    pub batch_max_bytes: u64,
    /// Worker-side long-poll window when its buffer is empty; 0 = worker
    /// default.
    pub batch_poll_ms: u32,
}

impl Default for ServiceClientConfig {
    fn default() -> Self {
        ServiceClientConfig {
            sharding: ShardingPolicy::Off,
            mode: ProcessingMode::Independent,
            job_name: String::new(),
            sharing: SharingMode::Off,
            num_consumers: 0,
            consumer_index: 0,
            compression: CompressionMode::None,
            buffer_size: 16,
            max_fetchers: 8,
            request_timeout: Duration::from_secs(10),
            heartbeat_interval: Duration::from_millis(100),
            batching: true,
            batch_max_elements: 0,
            batch_max_bytes: 1 << 20,
            batch_poll_ms: 0,
        }
    }
}

/// Handle for talking to one tf.data service deployment.
pub struct ServiceClient {
    dispatcher_addr: String,
    pool: Arc<Pool>,
    metrics: Registry,
    /// When set, every registration resolves referenced UDF names against
    /// this registry and ships their body digests, so the one-call
    /// `distribute` flow gets fingerprint protection against same-name /
    /// different-body UDFs without the explicit two-step API.
    udfs: Option<crate::data::udf::UdfRegistry>,
}

impl ServiceClient {
    pub fn new(dispatcher_addr: &str) -> ServiceClient {
        ServiceClient {
            dispatcher_addr: dispatcher_addr.to_string(),
            pool: Arc::new(Pool::with_defaults()),
            metrics: Registry::new(),
            udfs: None,
        }
    }

    /// A client that mixes UDF body digests from `udfs` into every
    /// pipeline fingerprint it registers (see `RegisterDatasetReq`).
    pub fn with_udfs(dispatcher_addr: &str, udfs: crate::data::udf::UdfRegistry) -> ServiceClient {
        ServiceClient { udfs: Some(udfs), ..ServiceClient::new(dispatcher_addr) }
    }

    pub fn metrics(&self) -> &Registry {
        &self.metrics
    }

    /// Register `graph` (after static optimization, §3.2) and return the
    /// dataset id (= canonical pipeline fingerprint). Uses the client's
    /// UDF registry (if constructed via [`ServiceClient::with_udfs`]) for
    /// body digests.
    pub fn register_dataset(&self, graph: &GraphDef) -> ServiceResult<u64> {
        self.register_dataset_with_udfs(graph, self.udfs.as_ref())
    }

    /// [`ServiceClient::register_dataset`] carrying body digests for the
    /// UDFs the graph references, resolved from `udfs`: two clients whose
    /// registries hold different implementations under one name then get
    /// different fingerprints and never share ephemeral data.
    pub fn register_dataset_with_udfs(
        &self,
        graph: &GraphDef,
        udfs: Option<&crate::data::udf::UdfRegistry>,
    ) -> ServiceResult<u64> {
        let optimized = optimize(graph, &OptimizeOptions::default());
        let mut udf_digests = Vec::new();
        if let Some(reg) = udfs {
            for node in &optimized.nodes {
                use crate::data::graph::Node;
                let name = match node {
                    Node::Map { udf, .. } | Node::Filter { udf } => udf,
                    _ => continue,
                };
                if let Some(digest) = reg.digest(name) {
                    udf_digests.push(UdfDigest { name: name.clone(), digest });
                }
            }
        }
        let resp: RegisterDatasetResp = call_typed(
            &self.pool,
            &self.dispatcher_addr,
            dispatcher_methods::REGISTER_DATASET,
            &RegisterDatasetReq { graph: optimized, udf_digests },
            Duration::from_secs(10),
        )?;
        Ok(resp.dataset_id)
    }

    /// The full `distribute` flow: register + join job + start fetching.
    pub fn distribute(&self, graph: &GraphDef, cfg: ServiceClientConfig) -> ServiceResult<DistributedIter> {
        let dataset_id = self.register_dataset(graph)?;
        self.distribute_dataset(dataset_id, cfg)
    }

    /// Join (or create) a job over an already-registered dataset.
    pub fn distribute_dataset(
        &self,
        dataset_id: u64,
        cfg: ServiceClientConfig,
    ) -> ServiceResult<DistributedIter> {
        let job: GetOrCreateJobResp = call_typed(
            &self.pool,
            &self.dispatcher_addr,
            dispatcher_methods::GET_OR_CREATE_JOB,
            &GetOrCreateJobReq {
                dataset_id,
                job_name: cfg.job_name.clone(),
                sharding: cfg.sharding,
                mode: cfg.mode,
                num_consumers: cfg.num_consumers,
                sharing: cfg.sharing,
            },
            Duration::from_secs(10),
        )?;
        // Anonymous attaches are fingerprint (§3.5) sharing; named joins
        // are explicit grouping — mirror the dispatcher's counter split.
        if job.attached && cfg.job_name.is_empty() {
            self.metrics.counter("client/shared_attaches").inc();
        }
        DistributedIter::start(
            self.dispatcher_addr.clone(),
            self.pool.clone(),
            job.job_id,
            job.client_id,
            job.attached,
            cfg,
            self.metrics.clone(),
        )
    }
}

/// Iterator over a distributed job's elements.
pub struct DistributedIter {
    mode: ProcessingMode,
    // Independent mode:
    rx: Option<chan::Receiver<ServiceResult<Element>>>,
    /// Sender handle used only to force-close the buffer on release, so
    /// fetchers blocked on a full buffer unwedge when the consumer stops
    /// mid-stream instead of leaking.
    tx_close: Option<chan::Sender<ServiceResult<Element>>>,
    // Coordinated mode:
    coord: Option<CoordFetcher>,
    // Common:
    job_id: u64,
    client_id: u64,
    /// Whether this client attached to an already-live job (§3.5 sharing)
    /// instead of creating a new production.
    attached: bool,
    dispatcher_addr: String,
    pool: Arc<Pool>,
    stop: Arc<AtomicBool>,
    released: bool,
}

struct CoordFetcher {
    workers: Arc<Mutex<Vec<String>>>,
    round: u64,
    consumer_index: u32,
    compression: CompressionMode,
    timeout: Duration,
}

struct FetchShared {
    job_id: u64,
    client_id: u64,
    compression: CompressionMode,
    timeout: Duration,
    pool: Arc<Pool>,
    tx: chan::Sender<ServiceResult<Element>>,
    stop: Arc<AtomicBool>,
    metrics: Registry,
    /// Workers that reported end_of_sequence.
    finished_workers: Mutex<HashSet<String>>,
    active_fetchers: AtomicU64,
    // Batched-path knobs (see ServiceClientConfig).
    batching: bool,
    batch_max_elements: u32,
    batch_max_bytes: u64,
    batch_poll_ms: u32,
}

impl DistributedIter {
    fn start(
        dispatcher_addr: String,
        pool: Arc<Pool>,
        job_id: u64,
        client_id: u64,
        attached: bool,
        cfg: ServiceClientConfig,
        metrics: Registry,
    ) -> ServiceResult<DistributedIter> {
        let stop = Arc::new(AtomicBool::new(false));
        match cfg.mode {
            ProcessingMode::Coordinated => {
                // Discover workers once (the order is fixed per job); keep
                // refreshing in the background for late joiners.
                let workers = Arc::new(Mutex::new(Vec::new()));
                let w2 = workers.clone();
                let pool2 = pool.clone();
                let da = dispatcher_addr.clone();
                let stop2 = stop.clone();
                let hb = cfg.heartbeat_interval;
                std::thread::Builder::new()
                    .name("svc-client-hb".into())
                    .spawn(move || {
                        while !stop2.load(Ordering::SeqCst) {
                            if let Ok(resp) = heartbeat(&pool2, &da, job_id, client_id) {
                                *w2.lock().unwrap() = resp.worker_addrs;
                            }
                            std::thread::sleep(hb);
                        }
                    })
                    .ok();
                // Wait for at least one worker to appear.
                let deadline = Instant::now() + Duration::from_secs(10);
                loop {
                    if !workers.lock().unwrap().is_empty() {
                        break;
                    }
                    if Instant::now() > deadline {
                        return Err(ServiceError::Other("no workers for coordinated job".into()));
                    }
                    std::thread::sleep(Duration::from_millis(10));
                }
                Ok(DistributedIter {
                    mode: cfg.mode,
                    rx: None,
                    tx_close: None,
                    coord: Some(CoordFetcher {
                        workers,
                        round: 0,
                        consumer_index: cfg.consumer_index,
                        compression: cfg.compression,
                        timeout: cfg.request_timeout,
                    }),
                    job_id,
                    client_id,
                    attached,
                    dispatcher_addr,
                    pool,
                    stop,
                    released: false,
                })
            }
            ProcessingMode::Independent => {
                let (tx, rx) = chan::bounded::<ServiceResult<Element>>(cfg.buffer_size);
                let tx_close = tx.clone();
                let shared = Arc::new(FetchShared {
                    job_id,
                    client_id,
                    compression: cfg.compression,
                    timeout: cfg.request_timeout,
                    pool: pool.clone(),
                    tx,
                    stop: stop.clone(),
                    metrics: metrics.clone(),
                    finished_workers: Mutex::new(HashSet::new()),
                    active_fetchers: AtomicU64::new(0),
                    batching: cfg.batching,
                    batch_max_elements: cfg.batch_max_elements,
                    batch_max_bytes: cfg.batch_max_bytes,
                    batch_poll_ms: cfg.batch_poll_ms,
                });
                // Supervisor: heartbeat the dispatcher, spawn a fetcher per
                // (newly discovered) worker, close the channel when done.
                let da = dispatcher_addr.clone();
                let max_fetchers = cfg.max_fetchers;
                let hb = cfg.heartbeat_interval;
                std::thread::Builder::new()
                    .name("svc-client-supervisor".into())
                    .spawn(move || {
                        let mut known: HashSet<String> = HashSet::new();
                        loop {
                            if shared.stop.load(Ordering::SeqCst) {
                                break;
                            }
                            match heartbeat(&shared.pool, &da, job_id, client_id) {
                                Ok(resp) => {
                                    for addr in resp.worker_addrs {
                                        if known.len() >= max_fetchers {
                                            break;
                                        }
                                        if known.insert(addr.clone()) {
                                            if shared.batching {
                                                spawn_batched_fetcher(shared.clone(), addr);
                                            } else {
                                                spawn_fetcher(shared.clone(), addr);
                                            }
                                        }
                                    }
                                    let all_finished = !known.is_empty()
                                        && shared.finished_workers.lock().unwrap().len() == known.len();
                                    if resp.job_finished || all_finished {
                                        break;
                                    }
                                }
                                Err(_) => {
                                    // Dispatcher down: keep fetching from
                                    // known workers (§3.4).
                                }
                            }
                            std::thread::sleep(hb);
                        }
                        // Wait for fetchers to drain, then close.
                        while shared.active_fetchers.load(Ordering::SeqCst) > 0 {
                            std::thread::sleep(Duration::from_millis(5));
                        }
                        shared.tx.close();
                    })
                    .ok();
                Ok(DistributedIter {
                    mode: cfg.mode,
                    rx: Some(rx),
                    tx_close: Some(tx_close),
                    coord: None,
                    job_id,
                    client_id,
                    attached,
                    dispatcher_addr,
                    pool,
                    stop,
                    released: false,
                })
            }
        }
    }

    pub fn job_id(&self) -> u64 {
        self.job_id
    }

    /// This client's consumer identity within the job (the cursor key on
    /// the worker's multi-consumer cache).
    pub fn client_id(&self) -> u64 {
        self.client_id
    }

    /// True when `distribute` attached to an already-live job — via the
    /// §3.5 fingerprint match (anonymous + `sharing: auto`) or an
    /// explicit job-name join — instead of starting a new production.
    pub fn attached(&self) -> bool {
        self.attached
    }

    /// Tell the dispatcher this client is done (job GC'd when the last
    /// client releases).
    pub fn release(&mut self) {
        if self.released {
            return;
        }
        self.released = true;
        self.stop.store(true, Ordering::SeqCst);
        // Unwedge fetchers blocked on a full buffer: a consumer stopping
        // mid-stream must not leak fetcher threads.
        if let Some(tx) = &self.tx_close {
            tx.close();
        }
        let _: Result<ReleaseJobResp, _> = call_typed(
            &self.pool,
            &self.dispatcher_addr,
            dispatcher_methods::RELEASE_JOB,
            &ReleaseJobReq { job_id: self.job_id, client_id: self.client_id },
            Duration::from_secs(5),
        );
    }
}

impl Drop for DistributedIter {
    fn drop(&mut self) {
        self.release();
    }
}

fn heartbeat(pool: &Pool, dispatcher: &str, job_id: u64, client_id: u64) -> ServiceResult<ClientHeartbeatResp> {
    Ok(call_typed(
        pool,
        dispatcher,
        dispatcher_methods::CLIENT_HEARTBEAT,
        &ClientHeartbeatReq { job_id, client_id },
        Duration::from_secs(5),
    )?)
}

fn spawn_fetcher(shared: Arc<FetchShared>, addr: String) {
    shared.active_fetchers.fetch_add(1, Ordering::SeqCst);
    let outer = shared.clone();
    let spawned = std::thread::Builder::new()
        .name(format!("svc-fetch-{addr}"))
        .spawn(move || {
            // Transient-failure budget: the worker may not have received
            // the task yet (it arrives on its next heartbeat), or may be
            // restarting. Only after sustained failure do we give up.
            let mut consecutive_errors = 0u32;
            const MAX_CONSECUTIVE_ERRORS: u32 = 25;
            loop {
                if shared.stop.load(Ordering::SeqCst) {
                    break;
                }
                let req = GetElementReq {
                    job_id: shared.job_id,
                    client_id: shared.client_id,
                    consumer_index: None,
                    round: None,
                    compression: shared.compression,
                };
                let resp: Result<GetElementResp, _> = call_typed(
                    &shared.pool,
                    &addr,
                    worker_methods::GET_ELEMENT,
                    &req,
                    shared.timeout,
                );
                shared.metrics.counter("client/rpcs").inc();
                match resp {
                    Ok(r) => {
                        consecutive_errors = 0;
                        if r.end_of_sequence {
                            shared.finished_workers.lock().unwrap().insert(addr.clone());
                            break;
                        }
                        match r.element {
                            Some(bytes) => {
                                let decoded = decode_element(&bytes, r.compressed);
                                shared.metrics.counter("client/elements_fetched").inc();
                                shared
                                    .metrics
                                    .counter("client/bytes_fetched")
                                    .add(bytes.len() as u64);
                                if shared.tx.send(decoded).is_err() {
                                    break;
                                }
                            }
                            None => {
                                // Worker had nothing ready: brief backoff.
                                std::thread::sleep(Duration::from_millis(1));
                            }
                        }
                    }
                    Err(e) => {
                        // Transient: the task may not have reached the
                        // worker yet, or the worker is restarting. Retry
                        // with backoff; give up only after sustained
                        // failure (preemption). The supervisor keeps the
                        // job going on surviving workers.
                        shared.metrics.counter("client/fetch_errors").inc();
                        let _ = e;
                        consecutive_errors += 1;
                        if consecutive_errors >= MAX_CONSECUTIVE_ERRORS {
                            shared.finished_workers.lock().unwrap().insert(addr.clone());
                            break;
                        }
                        std::thread::sleep(Duration::from_millis(20));
                    }
                }
            }
            shared.active_fetchers.fetch_sub(1, Ordering::SeqCst);
        });
    if spawned.is_err() {
        // Spawn failure must not wedge the supervisor's drain wait.
        outer.active_fetchers.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Batched streaming fetcher: one pipeline per worker. A dedicated
/// requester thread keeps the next `GetElements` RPC in flight while this
/// thread decodes the previous response frame and drains it into the
/// bounded client buffer — so RPC latency overlaps decode + consumption.
/// The internal depth-1 channel plus the request byte budget bound
/// per-worker client memory to roughly two response frames.
fn spawn_batched_fetcher(shared: Arc<FetchShared>, addr: String) {
    shared.active_fetchers.fetch_add(1, Ordering::SeqCst);
    let s2 = shared.clone();
    let spawned = std::thread::Builder::new()
        .name(format!("svc-fetchb-{addr}"))
        .spawn(move || {
            batched_fetch_loop(&s2, &addr);
            s2.active_fetchers.fetch_sub(1, Ordering::SeqCst);
        });
    if spawned.is_err() {
        // Spawn failure must not wedge the supervisor's drain wait.
        shared.active_fetchers.fetch_sub(1, Ordering::SeqCst);
    }
}

fn batched_fetch_loop(shared: &Arc<FetchShared>, addr: &str) {
    let (btx, brx) = chan::bounded::<GetElementsResp>(1);
    // Kept by the drain side solely to force-close the pipeline if it
    // exits early (consumer gone): the blocked requester then unblocks.
    let pipeline_close = btx.clone();

    let req_shared = shared.clone();
    let req_addr = addr.to_string();
    let requester = std::thread::Builder::new()
        .name(format!("svc-fetchb-req-{addr}"))
        .spawn(move || {
            let mut consecutive_errors = 0u32;
            const MAX_CONSECUTIVE_ERRORS: u32 = 25;
            loop {
                if req_shared.stop.load(Ordering::SeqCst) {
                    break;
                }
                let req = GetElementsReq {
                    job_id: req_shared.job_id,
                    client_id: req_shared.client_id,
                    max_elements: req_shared.batch_max_elements,
                    max_bytes: req_shared.batch_max_bytes,
                    poll_ms: req_shared.batch_poll_ms,
                    compression: req_shared.compression,
                };
                let resp: Result<GetElementsResp, _> = call_typed(
                    &req_shared.pool,
                    &req_addr,
                    worker_methods::GET_ELEMENTS,
                    &req,
                    req_shared.timeout,
                );
                req_shared.metrics.counter("client/rpcs").inc();
                match resp {
                    Ok(r) => {
                        consecutive_errors = 0;
                        req_shared.metrics.counter("client/batched_rpcs").inc();
                        let eos = r.end_of_sequence;
                        if btx.send(r).is_err() {
                            break; // drain side gone
                        }
                        if eos {
                            break;
                        }
                    }
                    Err(e) => {
                        // Transient: the task may not have reached the
                        // worker yet, or the worker is restarting. Retry
                        // with backoff; give up only after sustained
                        // failure (preemption).
                        req_shared.metrics.counter("client/fetch_errors").inc();
                        let _ = e;
                        consecutive_errors += 1;
                        if consecutive_errors >= MAX_CONSECUTIVE_ERRORS {
                            req_shared
                                .finished_workers
                                .lock()
                                .unwrap()
                                .insert(req_addr.clone());
                            break;
                        }
                        std::thread::sleep(Duration::from_millis(20));
                    }
                }
            }
            // Unblock the drain side whichever way this loop exited.
            btx.close();
        });

    while let Ok(resp) = brx.recv() {
        let eos = resp.end_of_sequence;
        shared.metrics.counter("client/bytes_fetched").add(resp.frame.len() as u64);
        match decode_batch(resp) {
            Ok(elements) => {
                let mut consumer_gone = false;
                for e in elements {
                    shared.metrics.counter("client/elements_fetched").inc();
                    if shared.tx.send(Ok(e)).is_err() {
                        consumer_gone = true;
                        break;
                    }
                }
                if consumer_gone {
                    break;
                }
            }
            Err(e) => {
                if shared.tx.send(Err(e)).is_err() {
                    break;
                }
            }
        }
        if eos {
            shared.finished_workers.lock().unwrap().insert(addr.to_string());
            break;
        }
    }
    pipeline_close.close();
    if let Ok(h) = requester {
        let _ = h.join();
    }
}

/// Client side of the frame contract: decompress (if needed), split the
/// frame into element payloads, decode each.
fn decode_batch(resp: GetElementsResp) -> ServiceResult<Vec<Element>> {
    let plain = if resp.compressed { inflate(&resp.frame)? } else { resp.frame };
    let payloads = Vec::<Vec<u8>>::from_bytes(&plain)?;
    if payloads.len() != resp.num_elements as usize {
        return Err(ServiceError::Other(format!(
            "batched frame carried {} elements, header said {}",
            payloads.len(),
            resp.num_elements
        )));
    }
    payloads
        .iter()
        .map(|b| Element::from_bytes(b).map_err(ServiceError::from))
        .collect()
}

fn decode_element(bytes: &[u8], compressed: bool) -> ServiceResult<Element> {
    let plain;
    let slice = if compressed {
        plain = inflate(bytes)?;
        &plain[..]
    } else {
        bytes
    };
    Ok(Element::from_bytes(slice)?)
}

impl ElemIter for DistributedIter {
    fn next(&mut self) -> DataResult<Option<Element>> {
        match self.mode {
            ProcessingMode::Independent => {
                let rx = self.rx.as_ref().expect("independent iter has rx");
                match rx.recv() {
                    Ok(Ok(e)) => Ok(Some(e)),
                    Ok(Err(e)) => Err(crate::data::DataError::Other(e.to_string())),
                    Err(_) => Ok(None),
                }
            }
            ProcessingMode::Coordinated => {
                let coord = self.coord.as_mut().expect("coordinated iter");
                let deadline = Instant::now() + coord.timeout;
                loop {
                    let workers = coord.workers.lock().unwrap().clone();
                    if workers.is_empty() {
                        return Ok(None);
                    }
                    let owner = &workers[(coord.round % workers.len() as u64) as usize];
                    let req = GetElementReq {
                        job_id: self.job_id,
                        client_id: self.client_id,
                        consumer_index: Some(coord.consumer_index),
                        round: Some(coord.round),
                        compression: coord.compression,
                    };
                    let resp: Result<GetElementResp, _> =
                        call_typed(&self.pool, owner, worker_methods::GET_ELEMENT, &req, coord.timeout);
                    match resp {
                        Ok(r) if r.end_of_sequence => return Ok(None),
                        Ok(r) => match r.element {
                            Some(bytes) => {
                                coord.round += 1;
                                let e = decode_element(&bytes, r.compressed)
                                    .map_err(|e| crate::data::DataError::Other(e.to_string()))?;
                                return Ok(Some(e));
                            }
                            None => {
                                if Instant::now() > deadline {
                                    return Err(crate::data::DataError::Other(format!(
                                        "coordinated round {} timed out",
                                        coord.round
                                    )));
                                }
                                std::thread::sleep(Duration::from_millis(2));
                            }
                        },
                        Err(e) => {
                            if Instant::now() > deadline {
                                return Err(crate::data::DataError::Other(e.to_string()));
                            }
                            std::thread::sleep(Duration::from_millis(10));
                        }
                    }
                }
            }
        }
    }
}
