//! tf.data service reproduction: disaggregated ML input data processing.
pub mod data;
pub mod metrics;
pub mod orchestrator;
pub mod rpc;
pub mod runtime;
pub mod service;
pub mod sim;
pub mod storage;
pub mod train;
pub mod util;
pub mod wire;
