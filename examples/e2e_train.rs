//! End-to-end validation: train a real transformer LM through the full
//! three-layer stack for a few hundred steps and log the loss curve.
//!
//! Layers exercised on every step:
//!   L3 (this binary + service): dispatcher, worker pool, RPC data path,
//!       dynamic sharding, client-side fetchers;
//!   L2/L1 (AOT artifacts): the worker runs the `preprocess_nlp` JAX
//!       graph per batch; the client runs the `train_step` graph (fwd +
//!       bwd + SGD with the fused-FFN Pallas kernel) via PJRT.
//!
//! Requires `make artifacts` first. Run:
//!   cargo run --release --example e2e_train -- --steps 300
//!
//! Results are recorded in EXPERIMENTS.md §End-to-end.

use std::sync::Arc;
use tfdatasvc::data::element::{DType, Tensor};
use tfdatasvc::data::exec::ElemIter;
use tfdatasvc::data::graph::PipelineBuilder;
use tfdatasvc::data::udf::UdfRegistry;
use tfdatasvc::orchestrator::Cell;
use tfdatasvc::runtime::{default_artifacts_dir, udfs::register_xla_udfs, Engine};
use tfdatasvc::service::dispatcher::DispatcherConfig;
use tfdatasvc::service::proto::ShardingPolicy;
use tfdatasvc::service::{ServiceClient, ServiceClientConfig};
use tfdatasvc::storage::dataset::{generate_text_patterned, TextGenConfig};
use tfdatasvc::storage::ObjectStore;
use tfdatasvc::train::PjrtTrainStep;
use tfdatasvc::util::cli::Args;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args = Args::from_env();
    let steps = args.u64_or("steps", 300);
    let n_workers = args.usize_or("workers", 2);
    let lr = args.f64_or("lr", 0.1) as f32;

    // --- Load the AOT artifacts (fails fast if `make artifacts` wasn't run).
    let engine = Engine::load(default_artifacts_dir())?;
    let m = engine.manifest().clone();
    let (batch, seq) = (m.model_batch, m.model_seq);
    println!(
        "model: {} params, batch {batch}, seq {seq} (AOT artifacts verified)",
        m.param_count
    );

    // --- Source corpus: periodic byte sequences the LM can learn (loss
    // should fall well below the ln(255)=5.54 uniform-entropy floor).
    let store = ObjectStore::in_memory();
    let spec = generate_text_patterned(
        &store,
        "datasets/corpus",
        &TextGenConfig {
            num_shards: 8,
            samples_per_shard: 256,
            vocab: 255, // byte-level; keep 0 as PAD
            min_len: seq + 1,
            max_len: seq + 1, // fixed-length LM windows
            ..Default::default()
        },
    );

    // --- Service deployment. Workers run the XLA preprocessing UDF.
    let udfs = UdfRegistry::with_builtins();
    register_xla_udfs(&udfs, &engine);
    let cell = Arc::new(Cell::new(store, udfs, DispatcherConfig::default())?);
    cell.scale_to(n_workers)?;
    println!("service: dispatcher {} + {n_workers} workers", cell.dispatcher_addr());

    // --- Distributed input pipeline: tokens -> LM windows of seq+1.
    let ds = PipelineBuilder::source_text(spec)
        .shuffle(512, 7)
        .batch(batch as u32)
        .prefetch(2)
        .repeat(0) // loop the corpus for as many steps as we need
        .build();
    let client = ServiceClient::new(&cell.dispatcher_addr());
    let mut it = client.distribute(
        &ds,
        ServiceClientConfig { sharding: ShardingPolicy::Off, ..Default::default() },
    )?;

    // --- The real PJRT train loop.
    let mut trainer = PjrtTrainStep::new(engine, lr).map_err(|e| format!("trainer: {e}"))?;
    println!("training {steps} steps at lr {lr} ...");
    let t0 = std::time::Instant::now();
    let mut step = 0u64;
    while step < steps {
        let Some(elem) = it.next()? else { break };
        // Batch tokens arrive as u32[batch, seq+1]; train_step wants i32.
        let toks_u32 = &elem.tensors[0];
        assert_eq!(toks_u32.dtype, DType::U32);
        assert_eq!(toks_u32.shape, vec![batch, seq + 1]);
        let toks: Vec<i32> = toks_u32.as_u32().iter().map(|&t| (t % 256) as i32).collect();
        let loss = trainer
            .step(Tensor::from_i32(vec![batch, seq + 1], &toks))
            .map_err(|e| format!("train step: {e}"))?;
        step += 1;
        if step == 1 || step % 50 == 0 {
            println!("step {step:>4}: loss {loss:.4}");
        }
    }
    let wall = t0.elapsed();
    let first = *trainer.losses.first().unwrap();
    let min10: f32 = {
        let tail = &trainer.losses[trainer.losses.len().saturating_sub(10)..];
        tail.iter().copied().sum::<f32>() / tail.len() as f32
    };
    println!(
        "done: {step} steps in {:.1}s ({:.2} steps/s), loss {first:.4} -> {min10:.4}",
        wall.as_secs_f64(),
        step as f64 / wall.as_secs_f64()
    );
    assert!(min10 < first * 0.8, "loss must drop by >20% ({first:.3} -> {min10:.3})");
    println!("e2e_train OK");
    Ok(())
}
