//! Property-based tests (hand-rolled: proptest is not vendored).
//!
//! Each property runs many randomized trials from a seeded RNG, so
//! failures are reproducible. Invariants covered: wire-format roundtrips
//! for arbitrary values, pipeline semantics against a reference
//! interpreter, split-tracker disjointness/at-most-once under random
//! worker churn, coordinated-round ownership, and optimizer semantic
//! equivalence.

mod common;

use tfdatasvc::data::element::{DType, Element, Tensor};
use tfdatasvc::data::exec::{ElemIter, Executor, ExecutorConfig};
use tfdatasvc::data::graph::{GraphDef, Node, PipelineBuilder};
use tfdatasvc::data::optimize::{optimize, OptimizeOptions};
use tfdatasvc::data::udf::UdfRegistry;
use tfdatasvc::service::dispatcher::{
    plan_drain_handoffs, plan_home_handoffs, reassign_dead_residues, Dispatcher, DispatcherConfig,
};
use tfdatasvc::service::journal::{
    DispatcherSnapshot, Journal, JournalRecord, SnapshotJob, SnapshotNamedJob, SnapshotWorker,
};
use tfdatasvc::service::proto::{ProcessingMode, SharingMode, ShardingPolicy, WidthEpoch};
use tfdatasvc::service::sharding::{static_assignment, SplitTracker};
use tfdatasvc::service::spill::{SegmentMeta, SpillManifest};
use tfdatasvc::storage::ObjectStore;
use tfdatasvc::util::rng::Rng;
use tfdatasvc::wire::{Decode, Encode};

const TRIALS: usize = 200;

fn rand_tensor(rng: &mut Rng) -> Tensor {
    let rank = rng.below(3) as usize;
    let shape: Vec<usize> = (0..rank).map(|_| rng.below(5) as usize + 1).collect();
    let n: usize = shape.iter().product();
    match rng.below(4) {
        0 => Tensor::from_f32(shape, &(0..n).map(|i| i as f32 * 0.5).collect::<Vec<_>>()),
        1 => Tensor::from_i32(shape, &(0..n).map(|i| i as i32 - 3).collect::<Vec<_>>()),
        2 => Tensor::from_u32(shape, &(0..n).map(|i| i as u32).collect::<Vec<_>>()),
        _ => Tensor::from_u8(shape, (0..n).map(|i| i as u8).collect()),
    }
}

fn rand_element(rng: &mut Rng) -> Element {
    let arity = rng.below(3) as usize + 1;
    let tensors = (0..arity).map(|_| rand_tensor(rng)).collect();
    let ids = (0..rng.below(4)).map(|_| rng.next_u64() % 1000).collect();
    let mut e = Element::with_ids(tensors, ids);
    if rng.chance(0.3) {
        e.bucket = Some(rng.next_u32() % 8);
    }
    e
}

#[test]
fn prop_element_wire_roundtrip() {
    let mut rng = Rng::new(0x9_0001);
    for _ in 0..TRIALS {
        let e = rand_element(&mut rng);
        let back = Element::from_bytes(&e.to_bytes()).expect("decode");
        assert_eq!(e, back);
    }
}

fn rand_graph(rng: &mut Rng) -> GraphDef {
    let n = rng.below(200) + 1;
    let mut b = PipelineBuilder::source_range(n);
    // At most one (terminal-ish) batch node: re-batching a ragged partial
    // batch is a shape error in tf.data too.
    let mut batched = false;
    for _ in 0..rng.below(5) {
        b = match rng.below(6) {
            0 if !batched => b.take(rng.below(2 * n) + 1),
            1 if !batched => b.skip(rng.below(n)),
            2 if !batched => b.shuffle(rng.next_u32() % 32 + 2, rng.next_u64()),
            3 if !batched => {
                batched = true;
                b.batch_partial(rng.next_u32() % 7 + 1)
            }
            4 if !batched => b.repeat(rng.next_u32() % 3 + 1),
            _ => b.map("identity"),
        };
    }
    b.build()
}

/// Reference interpreter over plain vectors for the operator subset used
/// by `rand_graph`.
fn reference_eval(graph: &GraphDef) -> Vec<Vec<i32>> {
    // Element stream as Vec<i32> values; batches become multi-value rows.
    let mut stream: Vec<Vec<i32>> = Vec::new();
    fn eval(nodes: &[Node], rng_seed_stack: &mut Vec<u64>) -> Vec<Vec<i32>> {
        let mut cur: Vec<Vec<i32>> = Vec::new();
        for node in nodes {
            match node {
                Node::SourceRange { n } => {
                    cur = (0..*n as i32).map(|v| vec![v]).collect();
                }
                Node::Take { n } => cur.truncate(*n as usize),
                Node::Skip { n } => {
                    cur.drain(..(*n as usize).min(cur.len()));
                }
                Node::Shuffle { buffer, seed } => {
                    // Mirror the executor's sliding-buffer shuffle.
                    cur = shuffle_ref(&cur, *buffer as usize, *seed);
                    rng_seed_stack.push(*seed);
                }
                Node::Batch { size, .. } => {
                    let mut out = Vec::new();
                    for chunk in cur.chunks(*size as usize) {
                        out.push(chunk.iter().flatten().copied().collect());
                    }
                    cur = out;
                }
                Node::Repeat { n } => {
                    let prefix_out = cur.clone();
                    let mut all = Vec::new();
                    for _ in 0..*n {
                        all.extend(prefix_out.clone());
                    }
                    cur = all;
                }
                Node::Map { .. } => {} // identity only
                _ => unreachable!("rand_graph subset"),
            }
        }
        cur
    }
    fn shuffle_ref(items: &[Vec<i32>], cap: usize, seed: u64) -> Vec<Vec<i32>> {
        let cap = cap.max(1);
        let mut rng = Rng::new(seed);
        let mut buf: Vec<Vec<i32>> = Vec::new();
        let mut out = Vec::new();
        let mut it = items.iter().cloned();
        for _ in 0..cap {
            match it.next() {
                Some(v) => buf.push(v),
                None => break,
            }
        }
        if buf.is_empty() {
            return out;
        }
        loop {
            if buf.is_empty() {
                break;
            }
            let idx = rng.below_usize(buf.len());
            match it.next() {
                Some(mut v) => {
                    std::mem::swap(&mut buf[idx], &mut v);
                    out.push(v);
                }
                None => out.push(buf.swap_remove(idx)),
            }
        }
        out
    }
    let mut stack = Vec::new();
    stream.extend(eval(&graph.nodes, &mut stack));
    stream
}

#[test]
fn prop_pipeline_matches_reference_interpreter() {
    let mut rng = Rng::new(0x9_0002);
    let ex = Executor::new(ExecutorConfig::local(
        ObjectStore::in_memory(),
        UdfRegistry::with_builtins(),
        0,
    ));
    for trial in 0..TRIALS {
        let g = rand_graph(&mut rng);
        let got: Vec<Vec<i32>> = ex
            .collect(&g)
            .unwrap_or_else(|e| panic!("trial {trial}: exec failed on {g:?}: {e}"))
            .iter()
            .map(|e| {
                e.tensors[0]
                    .as_i32()
            })
            .collect();
        let want = reference_eval(&g);
        assert_eq!(got, want, "trial {trial}: graph {g:?}");
    }
}

#[test]
fn prop_split_tracker_disjoint_under_churn() {
    let mut rng = Rng::new(0x9_0003);
    for trial in 0..TRIALS {
        let num_shards = rng.below(64) as usize + 1;
        let num_workers = rng.below(8) + 1;
        let t = SplitTracker::new(num_shards, rng.next_u64());
        let mut seen = std::collections::HashSet::new();
        let mut lost_total = 0usize;
        let mut alive: Vec<u64> = (0..num_workers).collect();
        loop {
            if alive.is_empty() {
                break;
            }
            // Random worker pulls; occasionally a worker dies.
            let w = *rng.choice(&alive);
            match t.next_split(w) {
                Some(s) => {
                    assert!(seen.insert(s), "trial {trial}: split {s} handed out twice");
                }
                None => break,
            }
            if rng.chance(0.05) && alive.len() > 1 {
                let dead = alive.swap_remove(rng.below_usize(alive.len()));
                lost_total += t.worker_failed(dead).len();
            }
        }
        // at-most-once accounting: everything handed out is either
        // completed, lost, or still assigned to a live worker.
        let completed = t.completed().len();
        let lost = t.lost().len();
        assert_eq!(lost, lost_total);
        assert!(completed + lost <= num_shards);
        assert!(seen.len() <= num_shards);
    }
}

#[test]
fn prop_static_assignment_partitions_and_balances() {
    let mut rng = Rng::new(0x9_0004);
    for _ in 0..TRIALS {
        let shards = rng.below(100) as usize;
        let workers = rng.below(10) as usize + 1;
        let a = static_assignment(shards, workers);
        assert_eq!(a.len(), workers);
        let mut all: Vec<u64> = a.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..shards as u64).collect::<Vec<_>>(), "partition exact");
        let lens: Vec<usize> = a.iter().map(|v| v.len()).collect();
        assert!(lens.iter().max().unwrap() - lens.iter().min().unwrap() <= 1, "balanced");
    }
}

#[test]
fn prop_round_ownership_is_a_partition() {
    // Every round is owned by exactly one worker index.
    let mut rng = Rng::new(0x9_0005);
    for _ in 0..TRIALS {
        let num_workers = rng.below(12) + 1;
        for round in 0..64u64 {
            let owners: Vec<u64> =
                (0..num_workers).filter(|w| round % num_workers == *w).collect();
            assert_eq!(owners.len(), 1, "round {round} owners {owners:?}");
        }
    }
}

#[test]
fn prop_optimizer_preserves_semantics() {
    let mut rng = Rng::new(0x9_0006);
    let ex = Executor::new(ExecutorConfig::local(
        ObjectStore::in_memory(),
        UdfRegistry::with_builtins(),
        0,
    ));
    for trial in 0..TRIALS {
        let g = rand_graph(&mut rng);
        let o = optimize(&g, &OptimizeOptions::default());
        let a: Vec<Vec<i32>> = ex.collect(&g).unwrap().iter().map(|e| e.tensors[0].as_i32()).collect();
        let b: Vec<Vec<i32>> = ex.collect(&o).unwrap().iter().map(|e| e.tensors[0].as_i32()).collect();
        assert_eq!(a, b, "trial {trial}: optimize changed semantics of {g:?}");
    }
}

#[test]
fn prop_graph_wire_roundtrip_random() {
    let mut rng = Rng::new(0x9_0007);
    for _ in 0..TRIALS {
        let g = rand_graph(&mut rng);
        assert_eq!(GraphDef::from_bytes(&g.to_bytes()).unwrap(), g);
        // Fingerprint is stable under re-encode.
        assert_eq!(g.fingerprint(), GraphDef::from_bytes(&g.to_bytes()).unwrap().fingerprint());
    }
}

// ----------------------------------------------------- round-lease model

/// Worker-side label model of a lease-table change: residues a worker no
/// longer owns lose their labels (the buffered rounds died with the
/// lease); a newly adopted residue labels from the floor, at the
/// smallest round in its class `>= floor`. Asserts the §3.6 recovery
/// invariant inline: no label ever drops below the floor — a consumed
/// round is never re-labeled.
fn apply_lease_table(
    owners: &[u64],
    labels: &mut std::collections::HashMap<(u64, u64), u64>,
    floor: u64,
    m: u64,
) {
    for (i, &o) in owners.iter().enumerate() {
        let r = i as u64;
        for w in 0..m {
            if w != o {
                labels.remove(&(w, r));
            }
        }
        let mut a = (floor / m) * m + r;
        if a < floor {
            a += m;
        }
        let label = *labels.entry((o, r)).or_insert(a);
        assert!(label >= floor, "consumed round re-labeled below the floor: {label} < {floor}");
    }
}

/// Model of the dispatcher's lease plane: the dead-owner flip plus the
/// *two-phase* live-to-live movers (revival re-balance, graceful drain),
/// driven by the exact pure transitions `Dispatcher::tick` ships
/// ([`reassign_dead_residues`], [`plan_home_handoffs`],
/// [`plan_drain_handoffs`]). A planned handoff only marks the residue
/// pending; the flip happens when the loser's heartbeat *acks* — after
/// the loser dropped its labels — mirroring `complete_lease_handoffs`
/// (including the gainer-fitness fallback at ack time).
struct LeaseModel {
    m: u64,
    worker_order: Vec<u64>,
    owners: Vec<u64>,
    alive: Vec<bool>,
    draining: Vec<bool>,
    /// Per-residue planned handoff `(loser, gainer)` awaiting the
    /// loser's revoke ack.
    pending: Vec<Option<(u64, u64)>>,
    labels: std::collections::HashMap<(u64, u64), u64>,
}

impl LeaseModel {
    fn new(m: u64) -> LeaseModel {
        LeaseModel {
            m,
            worker_order: (0..m).collect(),
            owners: (0..m).collect(),
            alive: vec![true; m as usize],
            draining: vec![false; m as usize],
            pending: vec![None; m as usize],
            labels: (0..m).map(|w| ((w, w), w)).collect(),
        }
    }

    /// Alive, non-draining: may gain leases.
    fn fit(&self, w: u64) -> bool {
        self.alive[w as usize] && !self.draining[w as usize]
    }

    /// One `Dispatcher::tick`: cancel dead-loser handoffs, flip dead
    /// owners directly (safe: a dead loser cannot co-hold), plan the
    /// two-phase moves, reap drained workers that hold nothing.
    fn tick(&mut self, floor: u64, trial: usize) {
        for p in self.pending.iter_mut() {
            if let Some((l, _)) = *p {
                if !self.alive[l as usize] {
                    *p = None;
                }
            }
        }
        let alive_v = self.alive.clone();
        reassign_dead_residues(&mut self.owners, &|w: u64| alive_v[w as usize]);
        let drain_v = self.draining.clone();
        let eligible = |w: u64| alive_v[w as usize] && !drain_v[w as usize];
        let pending_now: Vec<bool> = self.pending.iter().map(|p| p.is_some()).collect();
        for (i, l, g) in
            plan_home_handoffs(&self.owners, &self.worker_order, &eligible, &|i| pending_now[i])
        {
            if !self.alive[l as usize] {
                // Dead holder: the dispatcher flips directly (a corpse
                // cannot ack — and cannot co-hold).
                self.owners[i] = g;
            } else {
                self.pending[i] = Some((l, g));
            }
        }
        apply_lease_table(&self.owners, &mut self.labels, floor, self.m);
        let candidates: Vec<u64> = (0..self.m).filter(|&w| eligible(w)).collect();
        let pending_now: Vec<bool> = self.pending.iter().map(|p| p.is_some()).collect();
        for (i, l, g) in plan_drain_handoffs(
            &self.owners,
            &self.worker_order,
            &|w: u64| drain_v[w as usize],
            &candidates,
            &|i| pending_now[i],
        ) {
            self.pending[i] = Some((l, g));
        }
        // Reap: a draining worker that owns nothing and has no ack
        // outstanding is `drain_complete` — removed with nothing on it.
        for w in 0..self.m {
            if self.alive[w as usize]
                && self.draining[w as usize]
                && !self.owners.contains(&w)
                && !self.pending.iter().any(|p| matches!(p, Some((l, _)) if *l == w))
            {
                self.alive[w as usize] = false;
                self.draining[w as usize] = false;
                assert!(
                    !self.labels.keys().any(|&(lw, _)| lw == w),
                    "trial {trial}: reaped worker {w} still held labels"
                );
            }
        }
    }

    /// The loser's heartbeat: apply every queued revocation (drop the
    /// label — buffered rounds die with it) and ack, which flips the
    /// lease to the gainer (re-checking its fitness, as
    /// `complete_lease_handoffs` does).
    fn ack(&mut self, w: u64, floor: u64) {
        let mut completed = false;
        for i in 0..self.pending.len() {
            let Some((l, g)) = self.pending[i] else { continue };
            if l != w {
                continue;
            }
            // Revoke strictly before the flip: the loser stops serving
            // before the gainer starts.
            self.labels.remove(&(w, i as u64));
            let gainer = if self.fit(g) {
                g
            } else {
                (0..self.m).find(|&x| self.fit(x)).unwrap_or(l)
            };
            self.owners[i] = gainer;
            self.pending[i] = None;
            completed = true;
        }
        if completed {
            apply_lease_table(&self.owners, &mut self.labels, floor, self.m);
        }
    }

    /// The headline invariants, checked after every step: every residue
    /// is leased to an alive worker, and **no residue is ever co-held**
    /// — only its current owner may hold a serving label for it.
    fn assert_invariants(&self, trial: usize) {
        for (i, &o) in self.owners.iter().enumerate() {
            assert!(self.alive[o as usize], "trial {trial}: residue {i} leased to dead {o}");
            for w in 0..self.m {
                if w != o {
                    assert!(
                        !self.labels.contains_key(&(w, i as u64)),
                        "trial {trial}: residue {i} co-held by {w} and owner {o}"
                    );
                }
            }
        }
    }
}

/// Random kill / revive / drain / heartbeat / advance schedules against
/// the shipped lease transitions. Invariants: residues only ever point
/// at alive workers, **no residue is ever co-held by two live owners**
/// (the two-phase revoke-ack-grant guarantee), the owner's label equals
/// the consumer's round at every serve (nothing below a floor is ever
/// re-served), every round up to the final consumer position was served
/// exactly once, and after quiescing every eligible home owner holds its
/// home residue while drained workers hold nothing.
#[test]
fn prop_round_lease_invariants_under_kill_revive_drain() {
    use std::collections::HashMap;
    let mut rng = Rng::new(0x9_000b);
    for trial in 0..TRIALS {
        let m = rng.below(6) + 1;
        let mut model = LeaseModel::new(m);
        let mut consumer_round = 0u64;
        let mut served: HashMap<u64, u64> = HashMap::new(); // round -> server

        for _step in 0..250 {
            let alive_count = model.alive.iter().filter(|&&a| a).count();
            let fit_count = (0..m).filter(|&w| model.fit(w)).count();
            let roll = rng.f64();
            if roll < 0.12 && alive_count >= 2 {
                // Kill an alive worker (preemption without notice).
                let ups: Vec<u64> = (0..m).filter(|&w| model.alive[w as usize]).collect();
                let w = *rng.choice(&ups);
                model.alive[w as usize] = false;
            } else if roll < 0.24 && alive_count < m as usize {
                // Revive: re-registration resets any half-finished drain.
                let downs: Vec<u64> = (0..m).filter(|&w| !model.alive[w as usize]).collect();
                let w = *rng.choice(&downs);
                model.alive[w as usize] = true;
                model.draining[w as usize] = false;
            } else if roll < 0.32 && fit_count >= 2 {
                // Begin a graceful drain (scale-down victim).
                let fits: Vec<u64> = (0..m).filter(|&w| model.fit(w)).collect();
                let w = *rng.choice(&fits);
                model.draining[w as usize] = true;
            } else if roll < 0.55 && alive_count > 0 {
                // A random worker heartbeats: revokes + acks its pendings.
                let ups: Vec<u64> = (0..m).filter(|&w| model.alive[w as usize]).collect();
                let w = *rng.choice(&ups);
                model.ack(w, consumer_round);
            } else {
                // Consumer advances one round through the current table.
                let r = consumer_round % m;
                let o = model.owners[r as usize];
                assert!(
                    model.alive[o as usize],
                    "trial {trial}: residue {r} leased to dead worker {o}"
                );
                let label = model
                    .labels
                    .get(&(o, r))
                    .copied()
                    .unwrap_or_else(|| panic!("trial {trial}: owner {o} has no label for {r}"));
                // The owner's next label is exactly the round the
                // consumer needs: never below (a consumed round
                // re-labeled), never above (an unserved round skipped).
                assert_eq!(label, consumer_round, "trial {trial}");
                model.labels.insert((o, r), consumer_round + m);
                assert!(
                    served.insert(consumer_round, o).is_none(),
                    "trial {trial}: round {consumer_round} served twice"
                );
                consumer_round += 1;
            }
            model.tick(consumer_round, trial);
            model.assert_invariants(trial);
        }
        // Quiesce: keep ticking and heartbeating until every planned
        // handoff has acked and flipped.
        for _ in 0..8 {
            model.tick(consumer_round, trial);
            for w in 0..m {
                if model.alive[w as usize] {
                    model.ack(w, consumer_round);
                }
            }
            model.assert_invariants(trial);
        }
        assert!(
            model.pending.iter().all(|p| p.is_none()),
            "trial {trial}: handoffs left pending after quiesce"
        );
        let any_fit = (0..m).any(|w| model.fit(w));
        for (i, &o) in model.owners.iter().enumerate() {
            let home = model.worker_order[i];
            if model.fit(home) {
                assert_eq!(o, home, "trial {trial}: eligible home {home} lost residue {i} to {o}");
            }
            if any_fit {
                assert!(
                    !model.draining[o as usize],
                    "trial {trial}: residue {i} stuck on draining worker {o}"
                );
            }
        }
        // Eventual service: every round up to the final position was
        // served exactly once (sequential consumption + the uniqueness
        // assert above make the count sufficient).
        assert_eq!(served.len() as u64, consumer_round, "trial {trial}");
    }
}

// ----------------------------------------------------------- journal fuzz

fn rand_manifest(rng: &mut Rng) -> SpillManifest {
    let mut start_seq = 0u64;
    let segments = (0..rng.below(5))
        .map(|_| {
            let num_elements = rng.next_u32() % 64 + 1;
            let seg = SegmentMeta {
                key: rng.ident(16),
                offset: rng.next_u64() % (1 << 30),
                len: rng.next_u64() % (1 << 20),
                start_seq,
                num_elements,
                crc32: rng.next_u32(),
            };
            start_seq += num_elements as u64;
            seg
        })
        .collect();
    SpillManifest {
        fingerprint: rng.next_u64(),
        job_id: rng.next_u64(),
        epoch: rng.next_u64() % 16,
        total_elements: start_seq,
        complete: rng.chance(0.8),
        segments,
    }
}

fn rand_journal_record(rng: &mut Rng) -> JournalRecord {
    match rng.below(11) {
        0 => JournalRecord::RegisterDataset { dataset_id: rng.next_u64(), graph: rand_graph(rng) },
        1 => JournalRecord::CreateJob {
            job_id: rng.next_u64(),
            dataset_id: rng.next_u64(),
            job_name: if rng.chance(0.5) { String::new() } else { rng.ident(8) },
            sharding: *rng.choice(&[
                ShardingPolicy::Off,
                ShardingPolicy::Dynamic,
                ShardingPolicy::Static,
            ]),
            mode: *rng.choice(&[ProcessingMode::Independent, ProcessingMode::Coordinated]),
            num_consumers: rng.next_u32() % 8,
            sharing: *rng.choice(&[SharingMode::Auto, SharingMode::Off]),
            worker_order: (0..rng.below(6)).map(|_| rng.next_u64()).collect(),
            snapshot: rng.chance(0.25),
        },
        2 => JournalRecord::RegisterWorker { worker_id: rng.next_u64(), addr: rng.ident(12) },
        3 => JournalRecord::ClientJoined { job_id: rng.next_u64(), client_id: rng.next_u64() },
        4 => JournalRecord::ClientReleased { job_id: rng.next_u64(), client_id: rng.next_u64() },
        5 => JournalRecord::JobFinished { job_id: rng.next_u64() },
        6 => JournalRecord::RoundLeaseChanged {
            job_id: rng.next_u64(),
            residue_owners: (0..rng.below(8)).map(|_| rng.next_u64()).collect(),
        },
        7 => JournalRecord::SnapshotCommitted {
            fingerprint: rng.next_u64(),
            epoch: rng.next_u64() % 16,
            manifest: rand_manifest(rng),
        },
        8 => JournalRecord::ConsumerSetChanged {
            job_id: rng.next_u64(),
            epoch: rng.next_u32(),
            barrier_round: rng.next_u64(),
            num_consumers: rng.next_u32() % 16,
        },
        9 => JournalRecord::SpillSnapshotGced { job_id: rng.next_u64() },
        _ => JournalRecord::WorkerDrainChanged {
            worker_id: rng.next_u64(),
            draining: rng.chance(0.5),
        },
    }
}

/// Every `JournalRecord` variant survives encode -> decode -> re-encode
/// byte-identically (replay determinism: a journal rewritten from its
/// decoded records is the same journal).
#[test]
fn prop_journal_records_roundtrip_byte_identical() {
    let mut rng = Rng::new(0x9_0009);
    let mut variants_seen = std::collections::HashSet::new();
    for trial in 0..TRIALS {
        let rec = rand_journal_record(&mut rng);
        variants_seen.insert(std::mem::discriminant(&rec));
        let bytes = rec.to_bytes();
        let back = JournalRecord::from_bytes(&bytes)
            .unwrap_or_else(|e| panic!("trial {trial}: decode failed: {e}"));
        assert_eq!(back, rec, "trial {trial}");
        assert_eq!(back.to_bytes(), bytes, "trial {trial}: re-encode byte-identical");
    }
    assert_eq!(variants_seen.len(), 11, "generator covered every record variant");
}

/// `SpillManifest` (the snapshot-commit payload) roundtrips
/// byte-identically on its own wire framing, including the empty and
/// incomplete shapes.
#[test]
fn prop_spill_manifest_roundtrips_byte_identical() {
    let mut rng = Rng::new(0x9_000b);
    for trial in 0..TRIALS {
        let m = rand_manifest(&mut rng);
        let bytes = m.to_bytes();
        let back = SpillManifest::from_bytes(&bytes)
            .unwrap_or_else(|e| panic!("trial {trial}: decode failed: {e}"));
        assert_eq!(back, m, "trial {trial}");
        assert_eq!(back.to_bytes(), bytes, "trial {trial}: re-encode byte-identical");
    }
}

/// A journal truncated anywhere in its tail (crash mid-append) replays
/// the longest prefix of whole records instead of erroring — fuzzed over
/// random and boundary-exact truncation points.
#[test]
fn prop_journal_truncated_tail_recovers_longest_prefix() {
    let mut rng = Rng::new(0x9_000a);
    for trial in 0..24 {
        let recs: Vec<JournalRecord> =
            (0..rng.below(8) + 2).map(|_| rand_journal_record(&mut rng)).collect();
        let p = common::journal_path(&format!("prop-trunc-{trial}"));
        {
            let j = Journal::open(&p).unwrap();
            for r in &recs {
                j.append(r).unwrap();
            }
        }
        let bytes = std::fs::read(&p).unwrap();
        // Frame sizes: 8-byte (len, crc) header + body.
        let frames: Vec<usize> = recs.iter().map(|r| 8 + r.to_bytes().len()).collect();
        assert_eq!(frames.iter().sum::<usize>(), bytes.len());
        // Random truncation points plus every frame boundary (+/- 1).
        let mut cuts: Vec<usize> = (0..16).map(|_| rng.below_usize(bytes.len() + 1)).collect();
        let mut acc = 0usize;
        for f in &frames {
            acc += f;
            cuts.push(acc);
            cuts.push(acc - 1);
        }
        for cut in cuts {
            let cut = cut.min(bytes.len());
            std::fs::write(&p, &bytes[..cut]).unwrap();
            let replayed = Journal::replay(&p)
                .unwrap_or_else(|e| panic!("trial {trial} cut {cut}: replay errored: {e}"));
            let mut fit = 0usize;
            let mut used = 0usize;
            for f in &frames {
                if used + f <= cut {
                    used += f;
                    fit += 1;
                } else {
                    break;
                }
            }
            assert_eq!(replayed, recs[..fit], "trial {trial} cut {cut}");
        }
        std::fs::remove_file(&p).ok();
    }
}

// ------------------------------------------ snapshot / restore properties

/// Remove the journal base file and every sibling segment
/// (`{base}.snap-*`, `{base}.suffix-*`, stale `.tmp`s).
fn remove_journal_files(base: &std::path::Path) {
    let _ = std::fs::remove_file(base);
    if let (Some(dir), Some(name)) = (base.parent(), base.file_name().and_then(|n| n.to_str())) {
        if let Ok(entries) = std::fs::read_dir(dir) {
            for e in entries.flatten() {
                if let Some(f) = e.file_name().to_str() {
                    if f.starts_with(&format!("{name}.")) {
                        let _ = std::fs::remove_file(e.path());
                    }
                }
            }
        }
    }
}

fn rand_snapshot(rng: &mut Rng) -> DispatcherSnapshot {
    DispatcherSnapshot {
        datasets: (0..rng.below(3)).map(|i| (i, rand_graph(rng))).collect(),
        jobs: (0..rng.below(4))
            .map(|i| SnapshotJob {
                job_id: i + 1,
                dataset_id: rng.next_u64(),
                job_name: if rng.chance(0.5) { String::new() } else { rng.ident(6) },
                sharding: *rng.choice(&[
                    ShardingPolicy::Off,
                    ShardingPolicy::Dynamic,
                    ShardingPolicy::Static,
                ]),
                mode: *rng.choice(&[ProcessingMode::Independent, ProcessingMode::Coordinated]),
                num_consumers: rng.next_u32() % 8,
                sharing: *rng.choice(&[SharingMode::Auto, SharingMode::Off]),
                worker_order: (0..rng.below(5)).map(|_| rng.next_u64()).collect(),
                residue_owners: (0..rng.below(5)).map(|_| rng.next_u64()).collect(),
                clients: {
                    let mut v: Vec<u64> = (0..rng.below(4)).map(|_| rng.next_u64()).collect();
                    v.sort_unstable();
                    v
                },
                finished: rng.chance(0.2),
                width_epochs: (0..rng.below(3) + 1)
                    .map(|e| WidthEpoch {
                        epoch: e as u32,
                        barrier_round: rng.next_u64() % 1000,
                        num_consumers: rng.next_u32() % 8,
                    })
                    .collect(),
                snapshot_serve: rng.chance(0.3),
                snapshot_committed: rng.chance(0.3),
            })
            .collect(),
        named_jobs: (0..rng.below(3))
            .map(|_| SnapshotNamedJob {
                dataset_id: rng.next_u64(),
                job_name: rng.ident(5),
                job_id: rng.next_u64(),
            })
            .collect(),
        workers: (0..rng.below(4))
            .map(|i| SnapshotWorker {
                worker_id: i + 1,
                addr: rng.ident(10),
                draining: rng.chance(0.3),
            })
            .collect(),
        spill_snapshots: (0..rng.below(3)).map(|_| (rng.next_u64(), rand_manifest(rng))).collect(),
        next_worker_id: rng.next_u64(),
        next_job_id: rng.next_u64(),
        next_client_id: rng.next_u64(),
    }
}

/// `DispatcherSnapshot` (the checkpoint payload) roundtrips
/// byte-identically — the restore-equivalence property below depends on
/// the encoding being canonical.
#[test]
fn prop_dispatcher_snapshot_roundtrips_byte_identical() {
    let mut rng = Rng::new(0x9_000c);
    for trial in 0..50 {
        let snap = rand_snapshot(&mut rng);
        let bytes = snap.to_bytes();
        let back = DispatcherSnapshot::from_bytes(&bytes)
            .unwrap_or_else(|e| panic!("trial {trial}: decode failed: {e}"));
        assert_eq!(back, snap, "trial {trial}");
        assert_eq!(back.to_bytes(), bytes, "trial {trial}: re-encode byte-identical");
    }
}

/// Restoring from (snapshot + suffix) rebuilds **byte-identical**
/// dispatcher state to replaying the full journal from genesis — over
/// random record histories and random compaction cuts. This is the
/// correctness contract of compaction: a checkpoint may change how the
/// history is stored, never what it rebuilds.
#[test]
fn prop_restore_equivalence_snapshot_plus_suffix_matches_full_replay() {
    let mut rng = Rng::new(0x9_000d);
    for trial in 0..6 {
        let recs: Vec<JournalRecord> =
            (0..rng.below(30) + 10).map(|_| rand_journal_record(&mut rng)).collect();
        let cut = rng.below_usize(recs.len() - 1) + 1; // 1..len: both sides non-trivial
        let cfg = |p: &std::path::Path| DispatcherConfig {
            journal_path: Some(p.to_path_buf()),
            ..DispatcherConfig::default()
        };

        // Path A: full genesis replay.
        let pa = common::journal_path(&format!("prop-equiv-a-{trial}"));
        remove_journal_files(&pa);
        {
            let j = Journal::open(&pa).unwrap();
            for r in &recs {
                j.append(r).unwrap();
            }
        }
        let full = {
            let d = Dispatcher::start("127.0.0.1:0", cfg(&pa)).unwrap();
            d.snapshot_state().to_bytes()
        };

        // Path B: replay a prefix, cut a checkpoint, append the rest,
        // restart (restore = snapshot + suffix replay).
        let pb = common::journal_path(&format!("prop-equiv-b-{trial}"));
        remove_journal_files(&pb);
        {
            let j = Journal::open(&pb).unwrap();
            for r in &recs[..cut] {
                j.append(r).unwrap();
            }
        }
        {
            let d = Dispatcher::start("127.0.0.1:0", cfg(&pb)).unwrap();
            assert_eq!(d.compact_now(), Some(1), "trial {trial}: checkpoint cut");
        }
        {
            let j = Journal::open(&pb).unwrap();
            assert_eq!(j.snapshot_seq(), 1, "trial {trial}: appends land past the checkpoint");
            for r in &recs[cut..] {
                j.append(r).unwrap();
            }
        }
        let compacted = {
            let d = Dispatcher::start("127.0.0.1:0", cfg(&pb)).unwrap();
            d.snapshot_state().to_bytes()
        };
        assert_eq!(compacted, full, "trial {trial} cut {cut}: restore equivalence");
        remove_journal_files(&pa);
        remove_journal_files(&pb);
    }
}

/// Corruption never makes `Journal::restore` error — a CRC-bad snapshot
/// falls back down the ladder to full genesis replay, and a corrupt or
/// torn suffix keeps its longest valid record prefix — fuzzed over
/// random histories and corruption points.
#[test]
fn prop_restore_survives_snapshot_and_suffix_corruption() {
    let mut rng = Rng::new(0x9_000e);
    for trial in 0..10 {
        let pre: Vec<JournalRecord> =
            (0..rng.below(6) + 2).map(|_| rand_journal_record(&mut rng)).collect();
        let post: Vec<JournalRecord> =
            (0..rng.below(6) + 2).map(|_| rand_journal_record(&mut rng)).collect();
        let snap = rand_snapshot(&mut rng);
        let p = common::journal_path(&format!("prop-corrupt-{trial}"));
        remove_journal_files(&p);
        {
            let j = Journal::open(&p).unwrap();
            for r in &pre {
                j.append(r).unwrap();
            }
            assert_eq!(j.install_snapshot(&snap).unwrap(), 1);
            for r in &post {
                j.append(r).unwrap();
            }
        }
        let side = |ext: &str| {
            let mut name = p.file_name().unwrap().to_os_string();
            name.push(ext);
            p.with_file_name(name)
        };

        // Pristine: newest snapshot + its suffix; genesis superseded.
        let ok = Journal::restore(&p).unwrap();
        assert_eq!(ok.snapshot.as_ref(), Some(&snap), "trial {trial}");
        assert_eq!(ok.records, post, "trial {trial}");
        assert_eq!(ok.fallbacks, 0, "trial {trial}");

        // Flip a snapshot *body* byte: CRC rejects it, restore falls
        // back to full genesis replay and loses nothing.
        let snap_file = side(".snap-1");
        let snap_bytes = std::fs::read(&snap_file).unwrap();
        let mut bad = snap_bytes.clone();
        let i = 8 + rng.below_usize(bad.len() - 8);
        bad[i] ^= 0xff;
        std::fs::write(&snap_file, &bad).unwrap();
        let r = Journal::restore(&p).unwrap();
        assert!(r.snapshot.is_none(), "trial {trial}: corrupt snapshot skipped");
        assert!(r.fallbacks >= 1, "trial {trial}: fallback counted");
        let all: Vec<JournalRecord> = pre.iter().chain(post.iter()).cloned().collect();
        assert_eq!(r.records, all, "trial {trial}: genesis replay covers the history");
        std::fs::write(&snap_file, &snap_bytes).unwrap();

        // Flip a suffix body byte: the longest valid prefix survives on
        // top of the (intact) snapshot, and the corruption is counted.
        let suffix_file = side(".suffix-1");
        let sbytes = std::fs::read(&suffix_file).unwrap();
        let frames: Vec<usize> = post.iter().map(|r| 8 + r.to_bytes().len()).collect();
        let k = rng.below_usize(post.len());
        let frame_start: usize = frames[..k].iter().sum();
        let body_len = frames[k] - 8;
        let mut bad = sbytes.clone();
        bad[frame_start + 8 + rng.below_usize(body_len)] ^= 0xff;
        std::fs::write(&suffix_file, &bad).unwrap();
        let r = Journal::restore(&p).unwrap();
        assert_eq!(r.snapshot.as_ref(), Some(&snap), "trial {trial}");
        assert_eq!(r.records, post[..k], "trial {trial}: longest valid prefix");
        assert!(r.fallbacks >= 1, "trial {trial}: suffix corruption counted");

        // Truncate the suffix mid-frame (crash torn tail): whole records
        // before the cut survive; a torn tail is repair, not corruption.
        let cut = rng.below_usize(sbytes.len());
        std::fs::write(&suffix_file, &sbytes[..cut]).unwrap();
        let r = Journal::restore(&p).unwrap();
        assert_eq!(r.snapshot.as_ref(), Some(&snap), "trial {trial}");
        let mut fit = 0usize;
        let mut used = 0usize;
        for f in &frames {
            if used + f <= cut {
                used += f;
                fit += 1;
            } else {
                break;
            }
        }
        assert_eq!(r.records, post[..fit], "trial {trial} cut {cut}");
        remove_journal_files(&p);
    }
}

#[test]
fn prop_padded_batch_never_loses_tokens() {
    let mut rng = Rng::new(0x9_0008);
    for _ in 0..50 {
        let n = rng.below(30) as usize + 2;
        let tensors: Vec<Tensor> = (0..n)
            .map(|_| {
                let len = rng.below(20) as usize + 1;
                Tensor::from_u32(vec![len], &(1..=len as u32).collect::<Vec<_>>())
            })
            .collect();
        let padded = Tensor::stack_padded(&tensors, &0u32.to_le_bytes()).unwrap();
        assert_eq!(padded.dtype, DType::U32);
        let max_len = tensors.iter().map(|t| t.shape[0]).max().unwrap();
        assert_eq!(padded.shape, vec![n, max_len]);
        let vals = padded.as_u32();
        for (i, t) in tensors.iter().enumerate() {
            let row = &vals[i * max_len..(i + 1) * max_len];
            assert_eq!(&row[..t.shape[0]], t.as_u32().as_slice(), "payload preserved");
            assert!(row[t.shape[0]..].iter().all(|&v| v == 0), "padding is zero");
        }
    }
}
