//! tf.data service: the paper's system contribution.
//!
//! A disaggregated input-data-processing service (§3):
//!
//! * [`dispatcher`] — metadata plane: dataset registry, worker/client
//!   registry, task assignment, dynamic split distribution, heartbeats.
//!   Performs **no data processing** (§3.1).
//! * [`worker`] — data plane: executes pipeline graphs, buffers batches,
//!   serves client fetch RPCs. Hosts the **ephemeral sliding-window
//!   cache** (§3.5) and the **coordinated-reads** round-robin scheduler
//!   (§3.6).
//! * [`client`] — accelerator-host side: registers pipelines, discovers
//!   workers, fetches batches in parallel into a client-side buffer.
//!
//! ## The wire data plane
//!
//! Two fetch paths exist between client and worker:
//!
//! * **Batched streaming (`GetElements`)** — the default for
//!   independent-mode jobs. Each RPC drains up to
//!   `max_elements`/`max_bytes` of the task's ready queue in one
//!   worker-side lock acquisition, long-polls briefly when the buffer is
//!   empty instead of bouncing empty responses, and compresses the whole
//!   response frame at once so the codec overhead amortizes across the
//!   batch. The client pipelines requests: the next `GetElements` call is
//!   in flight while the previous batch drains into the bounded client
//!   buffer, with the byte budget bounding per-worker memory. This is
//!   what keeps per-element RPC overhead off the hot path (the paper's
//!   line-rate requirement, §3.1).
//! * **Single-element (`GetElement`)** — retained for coordinated-reads
//!   rounds (§3.6, where one round slot moves per call by design) and
//!   for old clients; also reachable by setting
//!   `ServiceClientConfig::batching = false`.
//!
//! Both paths are **one-copy end to end** on the worker: elements are
//! encoded once into the sliding window, batched frames are assembled in
//! a pooled buffer, and the RPC server writes `(head, frame)` with a
//! scatter-gather frame write ([`crate::rpc::Frame::write_parts_to`])
//! instead of copying the frame into a contiguous response payload.
//!
//! ## Ephemeral data sharing (§3.5)
//!
//! The paper's second headline result: concurrent jobs running the
//! *same* input pipeline can be fed from one preprocessed stream,
//! cutting preprocessing cost from `k×` to ~`1×`. The subsystem spans
//! all three roles:
//!
//! * **Pipeline fingerprinting** — `RegisterDataset` assigns the dataset
//!   id from a canonical structural hash of the graph
//!   ([`crate::data::graph::GraphDef::fingerprint_full`]): stable across
//!   registration order and wire-format changes, blind to
//!   performance-only attributes (map parallelism, prefetch depth), and
//!   sensitive to op params, source file lists, and UDF names *and
//!   bodies* (clients may attach per-UDF body digests). Identical
//!   pipelines therefore collide on one id, which is what makes sharing
//!   discoverable.
//! * **Dispatcher sharing registry** — `GetOrCreateJob` with
//!   `sharing: auto` attaches the client to a live job with the same
//!   fingerprint and compatible settings instead of creating a k-th
//!   production; `sharing: off` (the client-side default — attaching
//!   mid-stream relaxes the visitation guarantee, so sharing is opt-in)
//!   always creates a dedicated job, and named jobs remain the explicit
//!   grouping mechanism. Joins and releases are journaled, so the
//!   sharing registry survives a dispatcher restart, and are pushed to
//!   workers as consumer updates on heartbeats.
//! * **Worker multi-consumer cache** — each independent-mode task owns a
//!   sliding window over its produced stream; N consumers hold
//!   independent cursors, elements are produced and encoded once, and
//!   the window is trimmed to an element capacity and a byte budget. A
//!   consumer that falls outside the window skips ahead (the paper's
//!   relaxed-visitation escape hatch) rather than stalling production;
//!   skips and shared productions are counted
//!   (`worker/relaxed_visitation_skips`, `worker/shared_elements_served`).
//! * [`sharding`] — OFF / DYNAMIC / STATIC source-data sharding (§3.3).
//! * [`journal`] — dispatcher write-ahead journal + replay (§3.4).
//! * [`visitation`] — data-visitation-guarantee trackers used by tests
//!   (exactly-once / at-most-once / zero-once-or-more).
//! * [`proto`] — the RPC schema all of the above speak.

pub mod client;
pub mod dispatcher;
pub mod journal;
pub mod proto;
pub mod sharding;
pub mod visitation;
pub mod worker;

pub use client::{ServiceClient, ServiceClientConfig};
pub use dispatcher::Dispatcher;
pub use proto::{CompressionMode, ProcessingMode, SharingMode, ShardingPolicy};
pub use worker::Worker;

/// Number of source shards in a pipeline graph (drives split tracking and
/// OFF-mode shuffled iteration).
pub fn graph_num_shards(graph: &crate::data::graph::GraphDef) -> usize {
    use crate::data::graph::Node;
    match graph.nodes.first() {
        Some(Node::SourceVision { spec }) | Some(Node::SourceText { spec }) => spec.shards.len(),
        _ => 1,
    }
}

/// Service-level errors.
#[derive(Debug)]
pub enum ServiceError {
    Rpc(crate::rpc::RpcError),
    Wire(crate::wire::WireError),
    Data(crate::data::DataError),
    Journal(String),
    UnknownDataset(u64),
    UnknownJob(u64),
    UnknownWorker(u64),
    Other(String),
}

impl std::fmt::Display for ServiceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServiceError::Rpc(e) => write!(f, "rpc: {e}"),
            ServiceError::Wire(e) => write!(f, "wire: {e}"),
            ServiceError::Data(e) => write!(f, "data: {e}"),
            ServiceError::Journal(msg) => write!(f, "journal: {msg}"),
            ServiceError::UnknownDataset(id) => write!(f, "unknown dataset {id}"),
            ServiceError::UnknownJob(id) => write!(f, "unknown job {id}"),
            ServiceError::UnknownWorker(id) => write!(f, "unknown worker {id}"),
            ServiceError::Other(msg) => write!(f, "{msg}"),
        }
    }
}

impl std::error::Error for ServiceError {}

impl From<crate::rpc::RpcError> for ServiceError {
    fn from(e: crate::rpc::RpcError) -> ServiceError {
        ServiceError::Rpc(e)
    }
}

impl From<crate::wire::WireError> for ServiceError {
    fn from(e: crate::wire::WireError) -> ServiceError {
        ServiceError::Wire(e)
    }
}

impl From<crate::data::DataError> for ServiceError {
    fn from(e: crate::data::DataError) -> ServiceError {
        ServiceError::Data(e)
    }
}

pub type ServiceResult<T> = Result<T, ServiceError>;
