//! Durable control plane restore path (§3.4): dispatcher restart cost as
//! journal history grows, with and without snapshot compaction, plus the
//! cost of shedding `GetOrCreateJob` under admission control.
//!
//! Three sections:
//! 1. **Full replay**: a dispatcher restarted over a long churn history
//!    (job create/join/release/finish cycles) replays every record.
//! 2. **Snapshot-compacted restore**: after `compact_now()` the same
//!    restart decodes one snapshot plus a fresh suffix — the replayed
//!    record count must drop by >= 10x (the acceptance bar).
//! 3. **Overload shed**: with the admission budget spent, rejected job
//!    creations are measured round-trip; sheds journal nothing, so the
//!    rejection path stays cheap under overload.
//!
//! `--smoke` shrinks the history for CI. Results land in
//! `out/bench_restore.json` and the repo-root baseline `BENCH_restore.json`.

use std::path::PathBuf;
use std::time::{Duration, Instant};

use tfdatasvc::data::graph::PipelineBuilder;
use tfdatasvc::metrics::write_json_file;
use tfdatasvc::rpc::{call_typed, Pool, RpcError};
use tfdatasvc::service::dispatcher::{Dispatcher, DispatcherConfig};
use tfdatasvc::service::journal::{Journal, JournalRecord};
use tfdatasvc::service::proto::{
    dispatcher_methods, GetOrCreateJobReq, GetOrCreateJobResp, ProcessingMode,
    RegisterDatasetReq, RegisterDatasetResp, ShardingPolicy, SharingMode,
};
use tfdatasvc::service::OVERLOADED_PREFIX;
use tfdatasvc::util::json::obj;

const T: Duration = Duration::from_secs(5);

/// Fresh journal path in the bench temp dir; removes the base file *and*
/// every `{base}.snap-N` / `{base}.suffix-N` sibling a previous run left.
fn journal_path(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("tfdatasvc-bench-journals");
    std::fs::create_dir_all(&dir).unwrap();
    let fname = format!("{name}-{}", std::process::id());
    if let Ok(entries) = std::fs::read_dir(&dir) {
        for e in entries.flatten() {
            if e.file_name().to_string_lossy().starts_with(&fname) {
                let _ = std::fs::remove_file(e.path());
            }
        }
    }
    dir.join(fname)
}

/// Write a churn history: `jobs` full create/join/release/finish cycles
/// (5 records each) over one registered dataset and worker, then one job
/// left live so the compacted snapshot is non-trivial.
fn write_churn_history(path: &PathBuf, jobs: u64) -> u64 {
    let j = Journal::open(path).unwrap();
    let mut n = 0u64;
    let mut put = |rec: &JournalRecord| {
        j.append(rec).unwrap();
        n += 1;
    };
    put(&JournalRecord::RegisterWorker { worker_id: 1, addr: "127.0.0.1:1".into() });
    put(&JournalRecord::RegisterDataset {
        dataset_id: 7,
        graph: PipelineBuilder::source_range(64).build(),
    });
    for i in 0..jobs {
        let job_id = i + 1;
        put(&JournalRecord::CreateJob {
            job_id,
            dataset_id: 7,
            job_name: String::new(),
            sharding: ShardingPolicy::Dynamic,
            mode: ProcessingMode::Independent,
            num_consumers: 0,
            sharing: SharingMode::Off,
            worker_order: vec![1],
            snapshot: false,
        });
        put(&JournalRecord::ClientJoined { job_id, client_id: i + 1 });
        put(&JournalRecord::ClientReleased { job_id, client_id: i + 1 });
        put(&JournalRecord::JobFinished { job_id });
    }
    // One live job survives into the snapshot.
    put(&JournalRecord::CreateJob {
        job_id: jobs + 1,
        dataset_id: 7,
        job_name: "live".into(),
        sharding: ShardingPolicy::Dynamic,
        mode: ProcessingMode::Independent,
        num_consumers: 0,
        sharing: SharingMode::Off,
        worker_order: vec![1],
        snapshot: false,
    });
    put(&JournalRecord::ClientJoined { job_id: jobs + 1, client_id: jobs + 1 });
    n
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");

    // ---- 1 + 2: restore latency, full replay vs compacted ----
    let jobs = if smoke { 500 } else { 2500 };
    let jpath = journal_path("restore-path");
    let history = write_churn_history(&jpath, jobs);
    println!("=== Restore path ({history} journal records, {jobs} churned jobs) ===");
    if !smoke {
        assert!(history >= 10_000, "full run must exercise a >=10k-record history");
    }

    let cfg = DispatcherConfig { journal_path: Some(jpath.clone()), ..Default::default() };
    let t0 = Instant::now();
    let d = Dispatcher::start("127.0.0.1:0", cfg.clone()).unwrap();
    let t_full = t0.elapsed();
    let replayed_full = d.metrics().counter("dispatcher/restore_records_replayed").get();
    assert_eq!(replayed_full, history, "full replay must visit every record");
    let seq = d.compact_now().expect("compaction must install a snapshot");
    assert!(d.metrics().counter("dispatcher/snapshots_written").get() >= 1);
    drop(d); // server shutdown; journal released

    let t1 = Instant::now();
    let d2 = Dispatcher::start("127.0.0.1:0", cfg).unwrap();
    let t_snap = t1.elapsed();
    let replayed_snap = d2.metrics().counter("dispatcher/restore_records_replayed").get();
    assert_eq!(
        d2.metrics().counter("dispatcher/restore_fallbacks").get(),
        0,
        "pristine snapshot restore must not fall back"
    );
    assert!(
        replayed_snap * 10 <= replayed_full,
        "compaction must cut replayed records >=10x ({replayed_snap} vs {replayed_full})"
    );
    let reduction = replayed_full as f64 / (replayed_snap.max(1)) as f64;
    println!(
        "full replay:      {t_full:?} ({replayed_full} records)\n\
         compacted (seq {seq}): {t_snap:?} ({replayed_snap} records replayed, {reduction:.0}x fewer)"
    );
    drop(d2);

    // ---- 3: overload shed round-trip cost ----
    let d = Dispatcher::start(
        "127.0.0.1:0",
        DispatcherConfig { admission_max_jobs: 1, admission_retry_ms: 25, ..Default::default() },
    )
    .unwrap();
    let pool = Pool::with_defaults();
    let reg: RegisterDatasetResp = call_typed(
        &pool,
        &d.addr(),
        dispatcher_methods::REGISTER_DATASET,
        &RegisterDatasetReq { graph: PipelineBuilder::source_range(16).build(), udf_digests: Vec::new() },
        T,
    )
    .unwrap();
    let job_req = GetOrCreateJobReq {
        dataset_id: reg.dataset_id,
        job_name: String::new(),
        sharding: ShardingPolicy::Off,
        mode: ProcessingMode::Independent,
        num_consumers: 0,
        sharing: SharingMode::Off,
    };
    // Spend the one-job budget, then hammer the shed path.
    let _admitted: GetOrCreateJobResp =
        call_typed(&pool, &d.addr(), dispatcher_methods::GET_OR_CREATE_JOB, &job_req, T).unwrap();
    let sheds: u64 = if smoke { 50 } else { 500 };
    let t2 = Instant::now();
    for _ in 0..sheds {
        let r: Result<GetOrCreateJobResp, RpcError> =
            call_typed(&pool, &d.addr(), dispatcher_methods::GET_OR_CREATE_JOB, &job_req, T);
        match r {
            Err(RpcError::Remote(msg)) if msg.contains(OVERLOADED_PREFIX) => {}
            other => panic!("expected overload shed, got {other:?}"),
        }
    }
    let t_shed = t2.elapsed();
    assert_eq!(d.metrics().counter("dispatcher/jobs_shed").get(), sheds);
    let shed_us = t_shed.as_secs_f64() * 1e6 / sheds as f64;
    println!("overload shed:    {sheds} rejections in {t_shed:?} ({shed_us:.0} us/call round-trip)");

    let bench_json = obj([
        ("bench", "restore_path".into()),
        ("smoke", smoke.into()),
        (
            "restore",
            obj([
                ("history_records", history.into()),
                ("full_replay_ms", (t_full.as_secs_f64() * 1e3).into()),
                ("full_replay_records", replayed_full.into()),
                ("snapshot_restore_ms", (t_snap.as_secs_f64() * 1e3).into()),
                ("snapshot_restore_records", replayed_snap.into()),
                ("replay_reduction_x", reduction.into()),
            ]),
        ),
        (
            "overload_shed",
            obj([
                ("sheds", sheds.into()),
                ("total_ms", (t_shed.as_secs_f64() * 1e3).into()),
                ("shed_us_per_call", shed_us.into()),
            ]),
        ),
    ]);
    write_json_file("out/bench_restore.json", &bench_json).unwrap();
    // Repo-root mirror under the stable name the roadmap tracks (CI
    // regenerates it every run; the checked-in copy is the latest
    // accepted baseline).
    write_json_file("BENCH_restore.json", &bench_json).unwrap();
    println!("restore_path OK -> out/bench_restore.json + BENCH_restore.json");
}
