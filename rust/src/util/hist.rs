//! Histogram / CDF / percentile utilities for metrics and figure output.
//!
//! The benches print paper-figure series (CDFs for Fig 1 and Fig 12a,
//! percentiles for latency tables) using these helpers.

/// A simple sample accumulator with percentile/CDF queries.
#[derive(Debug, Default, Clone)]
pub struct Samples {
    xs: Vec<f64>,
    sorted: bool,
}

impl Samples {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn from_vec(xs: Vec<f64>) -> Self {
        Samples { xs, sorted: false }
    }

    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        self.xs.iter().sum::<f64>() / self.xs.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.xs.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn std(&self) -> f64 {
        let m = self.mean();
        if self.xs.len() < 2 {
            return 0.0;
        }
        (self.xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (self.xs.len() - 1) as f64)
            .sqrt()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
    }

    /// Percentile in [0, 100], linear interpolation between order stats.
    pub fn percentile(&mut self, p: f64) -> f64 {
        assert!((0.0..=100.0).contains(&p));
        self.ensure_sorted();
        if self.xs.is_empty() {
            return f64::NAN;
        }
        let n = self.xs.len();
        if n == 1 {
            return self.xs[0];
        }
        let rank = p / 100.0 * (n - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        let frac = rank - lo as f64;
        self.xs[lo] * (1.0 - frac) + self.xs[hi] * frac
    }

    pub fn median(&mut self) -> f64 {
        self.percentile(50.0)
    }

    /// Empirical CDF evaluated at `q` points equally spaced over the data
    /// range; returns (x, F(x)) pairs. Used to print Fig-1/Fig-12a series.
    pub fn cdf_points(&mut self, q: usize) -> Vec<(f64, f64)> {
        self.ensure_sorted();
        if self.xs.is_empty() || q == 0 {
            return vec![];
        }
        let (lo, hi) = (self.xs[0], *self.xs.last().unwrap());
        let n = self.xs.len() as f64;
        (0..=q)
            .map(|i| {
                let x = lo + (hi - lo) * i as f64 / q as f64;
                let cnt = self.xs.partition_point(|&v| v <= x);
                (x, cnt as f64 / n)
            })
            .collect()
    }

    /// Fraction of samples <= x.
    pub fn cdf_at(&mut self, x: f64) -> f64 {
        self.ensure_sorted();
        if self.xs.is_empty() {
            return f64::NAN;
        }
        self.xs.partition_point(|&v| v <= x) as f64 / self.xs.len() as f64
    }
}

/// Fixed-bin histogram (for burstiness timelines and worker-size dists).
#[derive(Debug, Clone)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    bins: Vec<u64>,
    pub underflow: u64,
    pub overflow: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Histogram { lo, hi, bins: vec![0; nbins], underflow: 0, overflow: 0 }
    }

    pub fn add(&mut self, x: f64) {
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let n = self.bins.len();
            let idx = ((x - self.lo) / (self.hi - self.lo) * n as f64) as usize;
            self.bins[idx.min(n - 1)] += 1;
        }
    }

    pub fn counts(&self) -> &[u64] {
        &self.bins
    }

    pub fn total(&self) -> u64 {
        self.bins.iter().sum::<u64>() + self.underflow + self.overflow
    }

    pub fn bin_center(&self, i: usize) -> f64 {
        let w = (self.hi - self.lo) / self.bins.len() as f64;
        self.lo + w * (i as f64 + 0.5)
    }
}

/// Format a (x, y) series as an aligned two-column table for bench output.
pub fn format_series(name: &str, pts: &[(f64, f64)]) -> String {
    let mut s = format!("# {name}\n");
    for (x, y) in pts {
        s.push_str(&format!("{x:>12.4}  {y:>8.4}\n"));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_basics() {
        let mut s = Samples::from_vec((1..=100).map(|i| i as f64).collect());
        assert!((s.percentile(0.0) - 1.0).abs() < 1e-9);
        assert!((s.percentile(100.0) - 100.0).abs() < 1e-9);
        assert!((s.median() - 50.5).abs() < 1e-9);
        assert!((s.percentile(99.0) - 99.01).abs() < 0.02);
    }

    #[test]
    fn mean_std() {
        let s = Samples::from_vec(vec![2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((s.mean() - 5.0).abs() < 1e-9);
        assert!((s.std() - 2.138).abs() < 0.01);
    }

    #[test]
    fn cdf_monotone_and_bounded() {
        let mut s = Samples::from_vec(vec![1.0, 2.0, 2.0, 3.0, 10.0]);
        let pts = s.cdf_points(20);
        for w in pts.windows(2) {
            assert!(w[1].1 >= w[0].1);
        }
        assert!((pts.last().unwrap().1 - 1.0).abs() < 1e-9);
        assert!((s.cdf_at(2.0) - 0.6).abs() < 1e-9);
    }

    #[test]
    fn single_sample() {
        let mut s = Samples::from_vec(vec![42.0]);
        assert_eq!(s.percentile(37.0), 42.0);
        assert_eq!(s.median(), 42.0);
    }

    #[test]
    fn histogram_binning() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.add(i as f64 + 0.5);
        }
        h.add(-1.0);
        h.add(11.0);
        assert_eq!(h.counts(), &[1; 10]);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 1);
        assert_eq!(h.total(), 12);
        assert!((h.bin_center(0) - 0.5).abs() < 1e-9);
    }
}
