//! Pipelined coordinated reads (§3.6) end to end: round-lease prefetch,
//! owner failure with lease reassignment, chunked oversized rounds, and
//! the lock-step downgrade against a peer that does not grant
//! `ROUND_PREFETCH`. Cluster scaffolding lives in the shared `common`
//! harness.

mod common;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use tfdatasvc::data::element::{DType, Tensor};
use tfdatasvc::data::exec::ElemIter;
use tfdatasvc::data::graph::PipelineBuilder;
use tfdatasvc::data::udf::UdfRegistry;
use tfdatasvc::data::Element;
use tfdatasvc::service::dispatcher::{Dispatcher, DispatcherConfig};
use tfdatasvc::service::proto::stream_caps;
use tfdatasvc::service::visitation::{Guarantee, RoundTracker, VisitationTracker};
use tfdatasvc::service::worker::{Worker, WorkerConfig, MIN_STREAM_FRAME_LEN};
use tfdatasvc::service::{ServiceClient, ServiceClientConfig};
use tfdatasvc::storage::dataset::{generate_text, TextGenConfig};
use tfdatasvc::storage::ObjectStore;

fn coord_cfg(num_consumers: u32, ci: u32) -> ServiceClientConfig {
    common::coord_cfg("coord-prefetch", num_consumers, ci)
}

/// Two consumers, two workers, prefetch on (the default): the §3.6
/// contract — same bucket for every consumer per round, each round slot
/// delivered exactly once — must hold end to end while the client engine
/// runs ahead of the trainer.
#[test]
fn prefetch_preserves_same_bucket_per_round() {
    let d = Dispatcher::start("127.0.0.1:0", DispatcherConfig::default()).unwrap();
    let store = ObjectStore::in_memory();
    let spec = generate_text(
        &store,
        "txt",
        &TextGenConfig { num_shards: 2, samples_per_shard: 64, ..Default::default() },
    );
    let _w1 =
        Worker::start("127.0.0.1:0", &d.addr(), WorkerConfig::new(store.clone(), UdfRegistry::with_builtins()))
            .unwrap();
    let _w2 =
        Worker::start("127.0.0.1:0", &d.addr(), WorkerConfig::new(store, UdfRegistry::with_builtins()))
            .unwrap();

    let num_consumers = 2u32;
    let graph = PipelineBuilder::source_text(spec)
        .bucket_by_sequence_length(vec![64, 128, 256], 4)
        .group_by_window(num_consumers)
        .flat_map()
        .take(24) // 12 rounds per worker
        .build();

    let c0 = ServiceClient::new(&d.addr());
    let c1 = ServiceClient::new(&d.addr());
    let mut it0 = c0.distribute(&graph, coord_cfg(num_consumers, 0)).unwrap();
    let mut it1 = c1.distribute(&graph, coord_cfg(num_consumers, 1)).unwrap();
    assert_eq!(it0.job_id(), it1.job_id());

    let drain = |it: &mut dyn ElemIter, cap: usize| {
        let mut sigs = Vec::new();
        for _ in 0..cap {
            match it.next() {
                Ok(Some(e)) => sigs.push(e.bucket.unwrap_or(0) as u64),
                Ok(None) => break,
                Err(e) => panic!("round fetch failed: {e}"),
            }
        }
        sigs
    };
    let h1 = std::thread::spawn(move || {
        let sigs = drain(&mut it1, 64);
        it1.release();
        sigs
    });
    let sigs0 = drain(&mut it0, 64);
    let sigs1 = h1.join().unwrap();
    it0.release();

    assert!(!sigs0.is_empty());
    assert_eq!(sigs0.len(), sigs1.len(), "both consumers drained the same round count");
    let mut tracker = RoundTracker::new();
    for (round, (&a, &b)) in sigs0.iter().zip(&sigs1).enumerate() {
        tracker.observe(round as u64, 0, a);
        tracker.observe(round as u64, 1, b);
    }
    let report = tracker.report();
    assert_eq!(report.mismatched_rounds, 0, "same bucket per round: {report:?}");
    assert_eq!(report.duplicate_deliveries, 0);
    // The engine really ran ahead of the trainer on at least one side.
    let prefetched = c0.metrics().counter("client/rounds_prefetched").get()
        + c1.metrics().counter("client/rounds_prefetched").get();
    assert!(prefetched > 0, "round prefetch was active");
    assert_eq!(c0.metrics().counter("client/round_prefetch_downgrades").get(), 0);
}

/// Owner failure mid-epoch with prefetch enabled: the dead owner's round
/// residues are reassigned (lease expiry via dispatcher tick), the
/// surviving worker re-materializes them from its own pipeline, and the
/// consumer keeps draining — monotonic rounds, each exactly once, no
/// permanent stall.
#[test]
fn owner_crash_reassigns_round_lease_and_rounds_keep_flowing() {
    let d = Arc::new(
        Dispatcher::start(
            "127.0.0.1:0",
            DispatcherConfig { worker_timeout: Duration::from_millis(300), ..Default::default() },
        )
        .unwrap(),
    );
    let store = ObjectStore::in_memory();
    let total_rows = 400u64;
    let graph = PipelineBuilder::source_range(total_rows).build();
    let w1 = Worker::start(
        "127.0.0.1:0",
        &d.addr(),
        WorkerConfig::new(store.clone(), UdfRegistry::with_builtins()),
    )
    .unwrap();
    let w2 = Worker::start(
        "127.0.0.1:0",
        &d.addr(),
        WorkerConfig::new(store, UdfRegistry::with_builtins()),
    )
    .unwrap();

    // Lease expiry needs the dispatcher control loop: tick periodically
    // (the orchestrator's job in production).
    let ticking = Arc::new(AtomicBool::new(true));
    let ticker = {
        let d = d.clone();
        let ticking = ticking.clone();
        std::thread::spawn(move || {
            while ticking.load(Ordering::SeqCst) {
                d.tick();
                std::thread::sleep(Duration::from_millis(50));
            }
        })
    };

    let client = ServiceClient::new(&d.addr());
    let mut it = client.distribute(&graph, coord_cfg(1, 0)).unwrap();

    let mut tracker = VisitationTracker::new();
    let mut rounds = 0u64;
    for _ in 0..30 {
        let e = it.next().expect("round survives owner crash").expect("stream not over");
        tracker.observe(&e.ids);
        rounds += 1;
        if rounds == 6 {
            // Kill the second worker mid-epoch: its residue stalls until
            // the lease moves.
            w2.shutdown();
        }
    }
    assert_eq!(rounds, 30, "rounds kept flowing across the owner crash");
    // Off-sharding coordinated reads promise zero-once-or-more on the
    // sample ids; the round sequence itself is monotonic by construction
    // and completed above (no duplicate or lost round index).
    let report = tracker.verify(Guarantee::ZeroOnceOrMore, total_rows);
    assert!(report.ok, "{report:?}");
    // The lease machinery really fired.
    assert!(
        d.metrics().counter("dispatcher/round_leases_reassigned").get() >= 1,
        "dispatcher reassigned the dead owner's residues"
    );
    assert!(
        w1.metrics().counter("worker/round_leases_updated").get() >= 1,
        "survivor adopted the lease"
    );
    it.release();
    ticking.store(false, Ordering::SeqCst);
    ticker.join().unwrap();
}

/// A chunked (> frame budget) element inside a prefetched round: the
/// multi-round chunk slot reassembles it losslessly while the engine
/// pipelines rounds.
#[test]
fn chunked_element_inside_prefetched_round() {
    let d = Dispatcher::start("127.0.0.1:0", DispatcherConfig::default()).unwrap();
    let store = ObjectStore::in_memory();
    let udfs = UdfRegistry::with_builtins();
    let big_len: usize = 600 << 10; // several 128 KiB continuation frames
    udfs.register_fn("test.inflate", move |e| {
        let fill = (e.ids[0] % 251) as u8;
        Ok(Element::with_ids(
            vec![Tensor::new(DType::U8, vec![big_len], vec![fill; big_len])],
            e.ids.clone(),
        ))
    });
    let _w = Worker::start("127.0.0.1:0", &d.addr(), WorkerConfig::new(store, udfs)).unwrap();

    let rounds = 6u64;
    let graph = PipelineBuilder::source_range(rounds).map("test.inflate").build();
    let client = ServiceClient::new(&d.addr());
    let mut it = client
        .distribute(
            &graph,
            ServiceClientConfig {
                max_frame_len: MIN_STREAM_FRAME_LEN as u64,
                ..coord_cfg(1, 0)
            },
        )
        .unwrap();

    let mut got = Vec::new();
    while let Some(e) = it.next().unwrap() {
        let fill = (e.ids[0] % 251) as u8;
        assert_eq!(e.tensors[0].data.len(), big_len);
        assert_eq!(e.tensors[0].data, vec![fill; big_len], "lossless reassembly");
        got.push(e.ids[0]);
    }
    assert_eq!(got, (0..rounds).collect::<Vec<_>>(), "all rounds, in order");
    assert_eq!(
        client.metrics().counter("client/chunked_elements_fetched").get(),
        rounds,
        "every round travelled chunked"
    );
    assert!(client.metrics().counter("client/chunk_frames").get() >= 2 * rounds);
    it.release();
}

/// A peer that does not grant `ROUND_PREFETCH` (an "older" worker,
/// simulated by masking the capability) downgrades the client to
/// lock-step — and the epoch still drains with the §3.6 discipline.
#[test]
fn no_prefetch_peer_downgrades_to_lockstep() {
    let d = Dispatcher::start("127.0.0.1:0", DispatcherConfig::default()).unwrap();
    let store = ObjectStore::in_memory();
    let mut wcfg = WorkerConfig::new(store, UdfRegistry::with_builtins());
    wcfg.stream_caps = stream_caps::ALL & !stream_caps::ROUND_PREFETCH;
    let _w = Worker::start("127.0.0.1:0", &d.addr(), wcfg).unwrap();

    let rounds = 10u64;
    let graph = PipelineBuilder::source_range(rounds).build();
    let client = ServiceClient::new(&d.addr());
    let mut it = client.distribute(&graph, coord_cfg(1, 0)).unwrap();
    let mut n = 0u64;
    while let Some(e) = it.next().unwrap() {
        assert_eq!(e.ids, vec![n]);
        n += 1;
    }
    assert_eq!(n, rounds, "lock-step still drains the epoch");
    assert_eq!(
        client.metrics().counter("client/round_prefetch_downgrades").get(),
        1,
        "capability miss downgraded the engine"
    );
    // At most the pre-handshake round can have been fetched ahead.
    assert!(client.metrics().counter("client/rounds_prefetched").get() <= 1);
    it.release();
}

/// Oldest client shape: no stream sessions at all — the engine drives the
/// legacy `GetElement` round protocol in lock-step.
#[test]
fn legacy_round_protocol_still_drains() {
    let d = Dispatcher::start("127.0.0.1:0", DispatcherConfig::default()).unwrap();
    let store = ObjectStore::in_memory();
    let _w =
        Worker::start("127.0.0.1:0", &d.addr(), WorkerConfig::new(store, UdfRegistry::with_builtins()))
            .unwrap();
    let rounds = 8u64;
    let graph = PipelineBuilder::source_range(rounds).build();
    let client = ServiceClient::new(&d.addr());
    let mut it = client
        .distribute(
            &graph,
            ServiceClientConfig { stream_sessions: false, ..coord_cfg(1, 0) },
        )
        .unwrap();
    let mut n = 0u64;
    while let Some(_e) = it.next().unwrap() {
        n += 1;
    }
    assert_eq!(n, rounds);
    assert_eq!(client.metrics().counter("client/fetch_rpcs").get(), 0, "legacy plane only");
    it.release();
}
