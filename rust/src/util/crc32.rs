//! CRC-32 (IEEE 802.3, reflected, polynomial 0xEDB88320).
//!
//! In-tree replacement for the usual `crc32fast` dependency (the build is
//! fully offline). The [`Hasher`] API matches it: `new` / `update` /
//! `finalize`. Used by the dispatcher journal, the storage record
//! framing, and the spill-segment framing to detect torn or corrupted
//! writes — which makes it a per-record cost on every hot path, so the
//! main loop is **slicing-by-16**: sixteen `const`-built lookup tables
//! let each iteration fold 16 input bytes into the running CRC with 16
//! independent table loads (no byte-serial dependency chain), roughly
//! 4-8x the byte-at-a-time loop on typical hardware.
//!
//! The byte-at-a-time path ([`crc32_scalar`] / [`update_scalar`]) stays
//! compiled as the differential-test oracle: the slice-by-16 tables are
//! derived from the scalar table, and the property tests assert the two
//! implementations agree on seeded random buffers at every length.

/// `TABLES[0]` is the classic byte-at-a-time table; `TABLES[k]` maps a
/// byte to its CRC contribution from `k` positions deeper in the input:
/// `TABLES[k][b] = advance(TABLES[k-1][b])` where `advance` pushes one
/// zero byte through the register.
const fn make_tables() -> [[u32; 256]; 16] {
    let mut t = [[0u32; 256]; 16];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut bit = 0;
        while bit < 8 {
            crc = if crc & 1 != 0 { (crc >> 1) ^ 0xEDB8_8320 } else { crc >> 1 };
            bit += 1;
        }
        t[0][i] = crc;
        i += 1;
    }
    let mut k = 1;
    while k < 16 {
        let mut i = 0;
        while i < 256 {
            let prev = t[k - 1][i];
            t[k][i] = (prev >> 8) ^ t[0][(prev & 0xFF) as usize];
            i += 1;
        }
        k += 1;
    }
    t
}

static TABLES: [[u32; 256]; 16] = make_tables();

/// Incremental CRC-32 state.
#[derive(Debug, Clone)]
pub struct Hasher {
    state: u32,
}

impl Default for Hasher {
    fn default() -> Self {
        Hasher::new()
    }
}

impl Hasher {
    pub fn new() -> Hasher {
        Hasher { state: 0xFFFF_FFFF }
    }

    /// Fold `bytes` into the running CRC: slice-by-16 over the aligned
    /// middle, byte-at-a-time over the tail. Splitting the input across
    /// multiple `update` calls at any boundary yields the same digest as
    /// one call (the register carries all the state).
    pub fn update(&mut self, bytes: &[u8]) {
        let mut crc = self.state;
        let mut chunks = bytes.chunks_exact(16);
        for c in &mut chunks {
            // XOR the register into the first word, then combine all 16
            // bytes via their distance-indexed tables. Byte j of the
            // chunk is 15-j positions from the chunk's end, hence table
            // 15-j.
            let a = crc ^ u32::from_le_bytes([c[0], c[1], c[2], c[3]]);
            crc = TABLES[15][(a & 0xFF) as usize]
                ^ TABLES[14][((a >> 8) & 0xFF) as usize]
                ^ TABLES[13][((a >> 16) & 0xFF) as usize]
                ^ TABLES[12][((a >> 24) & 0xFF) as usize]
                ^ TABLES[11][c[4] as usize]
                ^ TABLES[10][c[5] as usize]
                ^ TABLES[9][c[6] as usize]
                ^ TABLES[8][c[7] as usize]
                ^ TABLES[7][c[8] as usize]
                ^ TABLES[6][c[9] as usize]
                ^ TABLES[5][c[10] as usize]
                ^ TABLES[4][c[11] as usize]
                ^ TABLES[3][c[12] as usize]
                ^ TABLES[2][c[13] as usize]
                ^ TABLES[1][c[14] as usize]
                ^ TABLES[0][c[15] as usize];
        }
        for &b in chunks.remainder() {
            crc = (crc >> 8) ^ TABLES[0][((crc ^ b as u32) & 0xFF) as usize];
        }
        self.state = crc;
    }

    pub fn finalize(self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

/// One-shot convenience (slice-by-16 fast path).
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut h = Hasher::new();
    h.update(bytes);
    h.finalize()
}

/// Byte-at-a-time register step over `TABLES[0]` — the original scalar
/// loop, kept compiled as the oracle for the slice-by-16 fast path.
pub fn update_scalar(state: u32, bytes: &[u8]) -> u32 {
    let mut crc = state;
    for &b in bytes {
        crc = (crc >> 8) ^ TABLES[0][((crc ^ b as u32) & 0xFF) as usize];
    }
    crc
}

/// One-shot scalar CRC-32 (test oracle; also benchmarked against the
/// fast path in `micro_hotpath`).
pub fn crc32_scalar(bytes: &[u8]) -> u32 {
    update_scalar(0xFFFF_FFFF, bytes) ^ 0xFFFF_FFFF
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn known_vectors() {
        // zlib.crc32 reference values.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        let all: Vec<u8> = (0u8..=255).collect();
        assert_eq!(crc32(&all), 0x2905_8C73);
        // The oracle must agree on the reference vectors too.
        assert_eq!(crc32_scalar(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32_scalar(&all), 0x2905_8C73);
    }

    #[test]
    fn incremental_matches_oneshot() {
        let data = b"the quick brown fox jumps over the lazy dog";
        let mut h = Hasher::new();
        h.update(&data[..10]);
        h.update(&data[10..]);
        assert_eq!(h.finalize(), crc32(data));
    }

    #[test]
    fn detects_single_bit_flip() {
        let mut data = vec![7u8; 64];
        let a = crc32(&data);
        data[33] ^= 1;
        assert_ne!(a, crc32(&data));
    }

    /// Differential property: slice-by-16 equals the scalar oracle on a
    /// seeded random buffer at every length 0..=4096. Lengths below 16
    /// never enter the fast loop, 16..31 run exactly one fold, and every
    /// tail residue 0..15 is covered many times over.
    #[test]
    fn slice16_matches_scalar_oracle_all_lengths() {
        let mut rng = Rng::new(0xC4C3_2025);
        let buf: Vec<u8> = (0..4096).map(|_| rng.next_u32() as u8).collect();
        for len in 0..=buf.len() {
            assert_eq!(crc32(&buf[..len]), crc32_scalar(&buf[..len]), "len {len}");
        }
    }

    /// Fold-boundary lengths (around one and two 16-byte chunks) across
    /// several independently seeded buffers, including misaligned slice
    /// starts — the fast path must be position-independent.
    #[test]
    fn slice16_matches_scalar_oracle_boundary_lengths() {
        for seed in 0..16u64 {
            let mut rng = Rng::new(0xB0DA_0001 ^ seed);
            let buf: Vec<u8> = (0..64 + 3).map(|_| rng.next_u32() as u8).collect();
            for &len in &[15usize, 16, 17, 31, 32, 33] {
                for start in 0..3 {
                    let s = &buf[start..start + len];
                    assert_eq!(
                        crc32(s),
                        crc32_scalar(s),
                        "seed {seed} len {len} start {start}"
                    );
                }
            }
        }
    }

    /// Streaming digests equal one-shot digests no matter where the
    /// input is split — including splits inside a 16-byte chunk, which
    /// force the fast path to re-enter through the scalar tail.
    #[test]
    fn streaming_matches_oneshot_at_random_splits() {
        let mut rng = Rng::new(0x57EA_44D1);
        let buf: Vec<u8> = (0..2048).map(|_| rng.next_u32() as u8).collect();
        let oneshot = crc32(&buf);
        assert_eq!(oneshot, crc32_scalar(&buf));
        let fixed = [0usize, 1, 15, 16, 17, 31, 32, 33, 1024, 2047, 2048];
        let random = (0..32).map(|_| rng.below_usize(buf.len() + 1));
        for split in fixed.into_iter().chain(random) {
            let mut h = Hasher::new();
            h.update(&buf[..split]);
            h.update(&buf[split..]);
            assert_eq!(h.finalize(), oneshot, "split {split}");
            // Three-way split: both cut points inside the buffer.
            let second = split + rng.below_usize(buf.len() - split + 1);
            let mut h3 = Hasher::new();
            h3.update(&buf[..split]);
            h3.update(&buf[split..second]);
            h3.update(&buf[second..]);
            assert_eq!(h3.finalize(), oneshot, "splits {split}/{second}");
        }
    }
}
