//! RPC schema: method ids and message types for dispatcher and worker.
//!
//! Mirrors the tf.data service proto surface: dataset registration,
//! job creation, worker registration + heartbeats, dynamic split
//! distribution, and the client-facing `GetElement`.

use crate::data::graph::GraphDef;
use crate::service::spill::SpillManifest;
use crate::wire::{Decode, Encode, Reader, WireError, WireResult, Writer};
use crate::wire_struct;

// ------------------------------------------------------------- method ids

/// Dispatcher-served methods.
pub mod dispatcher_methods {
    pub const REGISTER_DATASET: u16 = 1;
    pub const GET_OR_CREATE_JOB: u16 = 2;
    pub const CLIENT_HEARTBEAT: u16 = 3;
    pub const REGISTER_WORKER: u16 = 4;
    pub const WORKER_HEARTBEAT: u16 = 5;
    pub const GET_SPLIT: u16 = 6;
    pub const RELEASE_JOB: u16 = 7;
    /// Change a coordinated job's consumer width mid-job (elastic
    /// membership): journals a `ConsumerSetChanged` record and answers
    /// with the membership epoch + barrier round where the new width
    /// takes effect.
    pub const SET_JOB_CONSUMERS: u16 = 8;
}

/// Worker-served methods.
pub mod worker_methods {
    pub const GET_ELEMENT: u16 = 32;
    pub const WORKER_STATUS: u16 = 33;
    /// Batched streaming fetch (legacy shim; see [`OPEN_STREAM`]).
    pub const GET_ELEMENTS: u16 = 34;
    /// Stream-session handshake: protocol version + capability
    /// negotiation, returns a session id for [`FETCH`].
    pub const OPEN_STREAM: u16 = 35;
    /// Session-scoped fetch: the canonical data-plane RPC (batch drain in
    /// independent mode, one round slot in coordinated mode, continuation
    /// frames for oversized elements).
    pub const FETCH: u16 = 36;
    /// Tear down a stream session (best-effort; sessions also die with
    /// their task or a consumer release).
    pub const CLOSE_STREAM: u16 = 37;
    /// Dispatcher-pushed consumer attach/detach (synchronous counterpart
    /// of the heartbeat consumer updates): lets the sliding window evict
    /// eagerly without racing a new consumer's registration.
    pub const UPDATE_CONSUMERS: u16 = 38;
}

// ------------------------------------------------- stream-session protocol

/// Highest stream-session protocol version this build speaks. The
/// handshake negotiates `min(client, worker)`; version 1 is the floor, so
/// any two builds that both know `OpenStream` can interoperate.
pub const STREAM_PROTOCOL_VERSION: u32 = 1;

/// Capability bits exchanged in the [`OpenStreamReq`]/[`OpenStreamResp`]
/// handshake. The negotiated set is the bitwise intersection: either side
/// may unilaterally drop a capability and the wire contract degrades
/// gracefully (no chunking -> explicit `element too large` errors, no
/// deflate -> plain frames, no adaptive batching -> static budgets).
pub mod stream_caps {
    /// Whole-frame deflate compression of fetch responses.
    pub const DEFLATE: u64 = 1 << 0;
    /// Oversized elements stream as continuation frames (chunked
    /// transfer) instead of erroring.
    pub const CHUNKED_TRANSFER: u64 = 1 << 1;
    /// Responses carry backpressure hints and the client may vary its
    /// per-fetch budgets (AIMD) instead of using static config.
    pub const ADAPTIVE_BATCHING: u64 = 1 << 2;
    /// Coordinated reads (§3.6): the worker keeps a bounded multi-round
    /// buffer and keys in-flight chunked transfers by round, so a client
    /// may fetch round `r+1` while round `r` is still being consumed
    /// (pipelined coordinated reads). A client must fall back to
    /// lock-step (fetch a round only when the trainer demands it)
    /// against a session that did not grant this bit.
    pub const ROUND_PREFETCH: u64 = 1 << 3;
    /// Everything this build implements.
    pub const ALL: u64 = DEFLATE | CHUNKED_TRANSFER | ADAPTIVE_BATCHING | ROUND_PREFETCH;
}

// ------------------------------------------------------------ enum types

/// Source-data sharding policy (§3.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardingPolicy {
    /// No sharding: every worker processes the whole dataset in its own
    /// random order (zero-once-or-more visitation).
    Off,
    /// Disjoint first-come-first-served splits from the dispatcher
    /// (at-most-once under failures, exactly-once without).
    Dynamic,
    /// Splits pre-assigned round-robin at job start.
    Static,
}

impl Encode for ShardingPolicy {
    fn encode(&self, w: &mut Writer) {
        w.put_u8(match self {
            ShardingPolicy::Off => 0,
            ShardingPolicy::Dynamic => 1,
            ShardingPolicy::Static => 2,
        });
    }
}

impl Decode for ShardingPolicy {
    fn decode(r: &mut Reader) -> WireResult<Self> {
        Ok(match r.get_u8()? {
            0 => ShardingPolicy::Off,
            1 => ShardingPolicy::Dynamic,
            2 => ShardingPolicy::Static,
            tag => return Err(WireError::BadTag { tag, ty: "ShardingPolicy" }),
        })
    }
}

/// How clients consume the job's output.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ProcessingMode {
    /// Each client pulls batches from any worker as fast as it can.
    Independent,
    /// Coordinated reads (§3.6): per training round, one worker feeds all
    /// `num_consumers` clients same-bucket batches, round-robin across
    /// workers.
    Coordinated,
}

impl Encode for ProcessingMode {
    fn encode(&self, w: &mut Writer) {
        w.put_u8(match self {
            ProcessingMode::Independent => 0,
            ProcessingMode::Coordinated => 1,
        });
    }
}

impl Decode for ProcessingMode {
    fn decode(r: &mut Reader) -> WireResult<Self> {
        Ok(match r.get_u8()? {
            0 => ProcessingMode::Independent,
            1 => ProcessingMode::Coordinated,
            tag => return Err(WireError::BadTag { tag, ty: "ProcessingMode" }),
        })
    }
}

/// Cross-job ephemeral data sharing policy (§3.5).
///
/// With `Auto`, `GetOrCreateJob` may attach the client to an already-live
/// job whose dataset has the same pipeline fingerprint and compatible
/// processing settings, so k identical jobs consume one production stream.
/// `Off` is the explicit opt-out: always create a dedicated job even when
/// an identical pipeline is live (e.g. the job mutates per-epoch RNG state
/// it must own, or isolation is required for benchmarking).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SharingMode {
    Auto,
    Off,
}

impl Encode for SharingMode {
    fn encode(&self, w: &mut Writer) {
        w.put_u8(match self {
            SharingMode::Auto => 0,
            SharingMode::Off => 1,
        });
    }
}

impl Decode for SharingMode {
    fn decode(r: &mut Reader) -> WireResult<Self> {
        Ok(match r.get_u8()? {
            0 => SharingMode::Auto,
            1 => SharingMode::Off,
            tag => return Err(WireError::BadTag { tag, ty: "SharingMode" }),
        })
    }
}

/// Element payload compression between worker and client (§3.1: useful in
/// bandwidth-constrained deployments, wasteful otherwise).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CompressionMode {
    None,
    Deflate,
}

impl Encode for CompressionMode {
    fn encode(&self, w: &mut Writer) {
        w.put_u8(match self {
            CompressionMode::None => 0,
            CompressionMode::Deflate => 1,
        });
    }
}

impl Decode for CompressionMode {
    fn decode(r: &mut Reader) -> WireResult<Self> {
        Ok(match r.get_u8()? {
            0 => CompressionMode::None,
            1 => CompressionMode::Deflate,
            tag => return Err(WireError::BadTag { tag, ty: "CompressionMode" }),
        })
    }
}

// -------------------------------------------------------------- messages

/// Digest of one UDF *body* the client expects workers to run, mixed into
/// the pipeline fingerprint at registration time: two pipelines that map
/// the same UDF *name* over different implementations must not share data.
#[derive(Debug, Clone, PartialEq)]
pub struct UdfDigest {
    pub name: String,
    pub digest: u64,
}
wire_struct!(UdfDigest { name, digest });

#[derive(Debug, Clone, PartialEq)]
pub struct RegisterDatasetReq {
    /// Serialized, already-optimized pipeline graph.
    pub graph: GraphDef,
    /// Body digests for UDFs referenced by the graph (may be empty; names
    /// without a digest contribute only their name to the fingerprint).
    pub udf_digests: Vec<UdfDigest>,
}
wire_struct!(RegisterDatasetReq { graph, udf_digests });

#[derive(Debug, Clone, PartialEq)]
pub struct RegisterDatasetResp {
    /// Dataset id = canonical pipeline fingerprint (identical pipelines
    /// share an id, which is what makes ephemeral sharing discoverable).
    pub dataset_id: u64,
    /// Full 256-bit structural fingerprint the id was truncated from.
    pub fingerprint: Vec<u8>,
}
wire_struct!(RegisterDatasetResp { dataset_id, fingerprint });

/// Job creation request. Under overload (the dispatcher's unfinished-job
/// budget `DispatcherConfig::admission_max_jobs` is spent) the dispatcher
/// sheds this RPC — and only this RPC; existing jobs keep running — with
/// a retryable [`super::ServiceError::Overloaded`] carrying a
/// `retry_after_ms` hint the client honors with jittered backoff.
#[derive(Debug, Clone, PartialEq)]
pub struct GetOrCreateJobReq {
    pub dataset_id: u64,
    /// Jobs with the same non-empty name attach to one shared job
    /// (explicit grouping); empty = anonymous job, eligible for
    /// fingerprint-based auto sharing when `sharing` is `Auto`.
    pub job_name: String,
    pub sharding: ShardingPolicy,
    pub mode: ProcessingMode,
    /// Number of coordinated consumers (0 for independent mode).
    pub num_consumers: u32,
    /// Cross-job ephemeral sharing policy (§3.5).
    pub sharing: SharingMode,
}
wire_struct!(GetOrCreateJobReq { dataset_id, job_name, sharding, mode, num_consumers, sharing });

#[derive(Debug, Clone, PartialEq)]
pub struct GetOrCreateJobResp {
    pub job_id: u64,
    /// Client handle within the job (used to GC per-client state); doubles
    /// as the consumer/cursor identity on the worker data plane.
    pub client_id: u64,
    /// True when the client was attached to an already-live job (named or
    /// fingerprint-matched) instead of creating a new production.
    pub attached: bool,
    /// True when the job serves a committed fingerprint-keyed snapshot
    /// from storage instead of running the pipeline (spill tier): the
    /// stream's cost is store reads, not preprocessing CPU.
    pub snapshot: bool,
}
wire_struct!(GetOrCreateJobResp { job_id, client_id, attached, snapshot });

#[derive(Debug, Clone, PartialEq)]
pub struct ClientHeartbeatReq {
    pub job_id: u64,
    pub client_id: u64,
    /// Coordinated mode: the next round this consumer will fetch. The
    /// dispatcher uses the minimum over a job's consumer slots as the
    /// materialization floor when a round lease is reassigned after an
    /// owner failure (the new owner never labels rounds every consumer
    /// has already moved past). `u64::MAX` = progress unknown (a
    /// just-started consumer that has not yet fast-forwarded to its
    /// slot floor) and is excluded from the minimum. Independent-mode
    /// clients send 0.
    pub next_round: u64,
    /// Coordinated mode: the consumer slot this client occupies. The
    /// slot — not the client id — is the durable identity for round
    /// progress, so a consumer replacement (new client id, same slot)
    /// inherits its predecessor's floor. Independent-mode clients
    /// send 0.
    pub consumer_index: u32,
    /// Fraction of trainer `next()` calls since the last heartbeat that
    /// found no element ready (the trainer stalled on input), in
    /// thousandths [0, 1000]. 0 when no fetches happened in the window
    /// (a busy trainer is not a starved one). Autoscaler input: the
    /// dispatcher aggregates these into the job-level client-starvation
    /// signal (§3.1 right-sizing).
    pub stall_fraction_milli: u32,
}
wire_struct!(ClientHeartbeatReq {
    job_id,
    client_id,
    next_round,
    consumer_index,
    stall_fraction_milli
});

#[derive(Debug, Clone, PartialEq)]
pub struct ClientHeartbeatResp {
    /// Addresses of workers currently running this job's task.
    pub worker_addrs: Vec<String>,
    pub job_finished: bool,
    /// Coordinated mode: current round-lease holders, indexed by residue
    /// (`round % num_workers`), so clients route round `r` to
    /// `round_owner_addrs[r % len]` even after a lease was reassigned.
    /// Empty for independent jobs (and from pre-lease dispatchers, where
    /// clients fall back to `worker_addrs[r % len]`).
    pub round_owner_addrs: Vec<String>,
    /// Coordinated mode: the requesting consumer's **slot-scoped**
    /// materialization floor — the slot's last recorded `next_round`
    /// (its crashed predecessor's report, inherited because slots, not
    /// client ids, are the durable progress identity), or 0 when the
    /// slot has no recorded progress. A consumer whose round walk
    /// starts fresh against a mid-epoch job (restart / slot takeover)
    /// fast-forwards here instead of asking owners for rounds its slot
    /// has already consumed; a fresh slot in a staggered startup sees 0
    /// and is never skipped past rounds still buffered for it.
    pub round_floor: u64,
    /// Coordinated mode: the job's current membership epoch (elastic
    /// consumer width; 0 for a job that never resized). A client
    /// comparing this against the epoch it last saw knows the consumer
    /// set changed and re-syncs instead of fetching mis-shaped rounds.
    pub membership_epoch: u32,
    /// Coordinated mode: the consumer width of the current epoch. A
    /// consumer whose slot index is >= this width has been shrunk away:
    /// it drains up to the barrier and then observes end-of-sequence.
    pub num_consumers: u32,
    /// Coordinated mode: the current epoch's barrier round — the first
    /// round served at `num_consumers` width.
    pub width_barrier_round: u64,
}
wire_struct!(ClientHeartbeatResp {
    worker_addrs,
    job_finished,
    round_owner_addrs,
    round_floor,
    membership_epoch,
    num_consumers,
    width_barrier_round
});

#[derive(Debug, Clone, PartialEq)]
pub struct ReleaseJobReq {
    pub job_id: u64,
    pub client_id: u64,
}
wire_struct!(ReleaseJobReq { job_id, client_id });

#[derive(Debug, Clone, PartialEq)]
pub struct ReleaseJobResp {
    pub released: bool,
}
wire_struct!(ReleaseJobResp { released });

#[derive(Debug, Clone, PartialEq)]
pub struct RegisterWorkerReq {
    /// Address the worker's data server listens on.
    pub addr: String,
}
wire_struct!(RegisterWorkerReq { addr });

#[derive(Debug, Clone, PartialEq)]
pub struct RegisterWorkerResp {
    pub worker_id: u64,
    /// Tasks for all currently-active jobs.
    pub tasks: Vec<TaskDef>,
}
wire_struct!(RegisterWorkerResp { worker_id, tasks });

#[derive(Debug, Clone, PartialEq)]
pub struct WorkerHeartbeatReq {
    pub worker_id: u64,
    /// Task (= job) ids the worker is currently running.
    pub active_tasks: Vec<u64>,
    /// Mean CPU utilization since last heartbeat, [0, 1] (autoscaler input).
    pub cpu_util_milli: u32,
    /// Complete per-job spill manifests not yet acknowledged by the
    /// dispatcher (spill tier): reported when a task with spill enabled
    /// reaches end-of-sequence with its tail flushed, and re-reported
    /// every heartbeat until an ack arrives, so a dispatcher restart
    /// between report and commit cannot lose an epoch's snapshot.
    pub spill_manifests: Vec<SpillManifest>,
    /// Acknowledged lease revocations (two-phase drain / re-balance):
    /// residues from [`WorkerHeartbeatResp::round_revocations`] this
    /// worker has fully released — buffered rounds dropped, pending
    /// spill flushed. Only after the ack does the dispatcher commit the
    /// gainer's grant, so loser and gainer never co-hold a residue.
    pub revoke_acks: Vec<LeaseRevoke>,
    /// Draining handshake: true once a worker told to drain
    /// ([`WorkerHeartbeatResp::drain`]) has applied every revocation and
    /// flushed its spill buffers — it holds no state a removal would
    /// lose. The dispatcher will not report a drain complete before this.
    pub drain_ready: bool,
}
wire_struct!(WorkerHeartbeatReq {
    worker_id,
    active_tasks,
    cpu_util_milli,
    spill_manifests,
    revoke_acks,
    drain_ready
});

/// One round-lease revocation (or its acknowledgment, same shape both
/// directions): the residues of one coordinated job being taken *from* a
/// worker. Phase one of the two-phase revoke-ack-grant handoff: the
/// dispatcher sends the revocation while the lease table still points at
/// the loser, the loser stops serving and acks on its next heartbeat, and
/// only then is the gainer's [`RoundAssignment`] granted — so, unlike the
/// old direct-flip path, no residue is ever co-held by two live owners.
#[derive(Debug, Clone, PartialEq)]
pub struct LeaseRevoke {
    pub job_id: u64,
    /// Residues being revoked (acked), a subset of the worker's owned set.
    pub residues: Vec<u32>,
}
wire_struct!(LeaseRevoke { job_id, residues });

/// One consumer joining or leaving a job's shared stream, pushed to
/// workers on their next heartbeat so the multi-consumer cache registers
/// (or drops) the matching cursor.
#[derive(Debug, Clone, PartialEq)]
pub struct ConsumerUpdate {
    pub job_id: u64,
    pub client_id: u64,
}
wire_struct!(ConsumerUpdate { job_id, client_id });

/// A round-lease update for one coordinated job (§3.6 fault tolerance):
/// the complete set of round residues (`round % num_workers`) this worker
/// now owns, delivered on its heartbeat after the dispatcher reassigned a
/// failed owner's lease. Round ownership is leased, not fixed: a worker's
/// heartbeat renews its lease implicitly, and a worker silent past the
/// dispatcher's `worker_timeout` forfeits its residues to the survivors.
#[derive(Debug, Clone, PartialEq)]
pub struct RoundAssignment {
    pub job_id: u64,
    /// All residues this worker now owns (replaces its previous set).
    pub owned_residues: Vec<u32>,
    /// Materialization floor for newly-adopted residues: the new owner
    /// starts labeling adopted rounds at the smallest round `>= this`
    /// in the residue class (the minimum round any consumer still
    /// needs), re-materializing from its own pipeline under the relaxed
    /// visitation guarantee.
    pub start_round: u64,
}
wire_struct!(RoundAssignment { job_id, owned_residues, start_round });

/// One step of a coordinated job's membership-epoch history: from
/// `barrier_round` (inclusive) onward, rounds are keyed for
/// `num_consumers` slots. Epoch 0 is the width the job was created with
/// (`barrier_round` 0); each [`dispatcher_methods::SET_JOB_CONSUMERS`]
/// call appends one entry with a barrier the dispatcher picks as the
/// first round no consumer slot has fetched yet, so a width change is a
/// round barrier and never re-shapes a round already in flight.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WidthEpoch {
    pub epoch: u32,
    pub barrier_round: u64,
    pub num_consumers: u32,
}
wire_struct!(WidthEpoch { epoch, barrier_round, num_consumers });

/// The full membership-epoch schedule of one coordinated job, pushed to
/// workers on their heartbeat after a width change. Carrying the whole
/// schedule (not a delta) makes application idempotent: a worker applies
/// only epochs newer than the last one it re-keyed at, so a re-push
/// after a missed heartbeat or a dispatcher restart is harmless.
#[derive(Debug, Clone, PartialEq)]
pub struct ConsumerSetUpdate {
    pub job_id: u64,
    pub width_epochs: Vec<WidthEpoch>,
}
wire_struct!(ConsumerSetUpdate { job_id, width_epochs });

#[derive(Debug, Clone, PartialEq)]
pub struct SetJobConsumersReq {
    pub job_id: u64,
    /// New consumer width (must be >= 1).
    pub num_consumers: u32,
}
wire_struct!(SetJobConsumersReq { job_id, num_consumers });

#[derive(Debug, Clone, PartialEq)]
pub struct SetJobConsumersResp {
    /// Membership epoch the change created (or the current epoch when
    /// the requested width already matched — idempotent no-op).
    pub epoch: u32,
    /// First round served at the new width.
    pub barrier_round: u64,
}
wire_struct!(SetJobConsumersResp { epoch, barrier_round });

#[derive(Debug, Clone, PartialEq)]
pub struct WorkerHeartbeatResp {
    /// Newly-assigned tasks.
    pub new_tasks: Vec<TaskDef>,
    /// Jobs that finished / were GC'd: the worker drops their state.
    pub removed_tasks: Vec<u64>,
    /// Clients that attached to an existing job since the last heartbeat
    /// (ephemeral sharing): register their cache cursors.
    pub attached_clients: Vec<ConsumerUpdate>,
    /// Clients that released since the last heartbeat: drop their cursors
    /// so a departed consumer cannot pin the sliding window.
    pub released_clients: Vec<ConsumerUpdate>,
    /// Round-lease reassignments for this worker's coordinated tasks.
    pub round_assignments: Vec<RoundAssignment>,
    /// Membership-epoch schedules for coordinated jobs whose consumer
    /// width changed (elastic membership): the worker re-keys buffered
    /// rounds at each new epoch's barrier. Re-pushed until acknowledged
    /// by a heartbeat from a confirmed-alive worker; application is
    /// idempotent (see [`ConsumerSetUpdate`]).
    pub width_updates: Vec<ConsumerSetUpdate>,
    /// Job ids whose reported spill manifests the dispatcher has durably
    /// recorded (journaled into a snapshot, or discarded for a job it no
    /// longer tracks): the worker stops re-reporting them.
    pub manifest_acks: Vec<u64>,
    /// Round-lease revocations (phase one of a drain or live-to-live
    /// re-balance handoff): residues this worker must stop serving. The
    /// worker drops the matching buffered rounds, flushes pending spill,
    /// and echoes each entry back in
    /// [`WorkerHeartbeatReq::revoke_acks`]; the gainer's grant activates
    /// only after that ack. Re-pushed until acked (idempotent: revoking
    /// an already-released residue is a no-op that still acks).
    pub round_revocations: Vec<LeaseRevoke>,
    /// True while the dispatcher holds this worker in the `Draining`
    /// state: it should flush spill buffers eagerly and report
    /// [`WorkerHeartbeatReq::drain_ready`] once it holds nothing a
    /// removal would lose. New consumers are no longer routed to it.
    pub drain: bool,
}
wire_struct!(WorkerHeartbeatResp {
    new_tasks,
    removed_tasks,
    attached_clients,
    released_clients,
    round_assignments,
    width_updates,
    manifest_acks,
    round_revocations,
    drain
});

/// A data-processing task: one job's pipeline on one worker.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskDef {
    pub job_id: u64,
    pub dataset_id: u64,
    pub graph: GraphDef,
    pub sharding: ShardingPolicy,
    pub mode: ProcessingMode,
    pub num_consumers: u32,
    /// For Static sharding: this worker's pre-assigned shard indices.
    pub static_shards: Vec<u64>,
    /// This worker's index among the job's workers at task-creation time
    /// (drives the coordinated-reads round-robin).
    pub worker_index: u32,
    /// Total workers the job had at task-creation time.
    pub num_workers: u32,
    /// Client ids attached to the job at task-creation time (the initial
    /// cursor set of the multi-consumer cache; later joins/leaves arrive
    /// via [`WorkerHeartbeatResp`] consumer updates).
    pub consumers: Vec<u64>,
    /// Coordinated mode: round residues this worker currently holds the
    /// lease for (normally `[worker_index]`; empty for a late joiner or
    /// a revived worker whose residues were reassigned while it was
    /// dead). Lease changes after delivery arrive as
    /// [`RoundAssignment`]s on heartbeats.
    pub owned_residues: Vec<u32>,
    /// Coordinated mode: materialization floor — the minimum round any
    /// consumer still needs (0 for a fresh job). A restarted worker
    /// re-receiving its task mid-epoch starts labeling here instead of
    /// crawling from round 0 through thousands of rounds every consumer
    /// has already moved past.
    pub start_round: u64,
    /// Coordinated mode: true when `owned_residues` is the dispatcher's
    /// authoritative lease view — an *empty* set then really means
    /// "leaseless" (a revived worker whose residues moved to survivors
    /// must not self-assign its home residue and materialize split-brain
    /// rounds). False only from pre-lease dispatchers, where the worker
    /// falls back to the fixed `worker_index` assignment.
    pub has_lease_view: bool,
    /// Coordinated mode: the job's membership-epoch schedule at
    /// task-creation time (always at least the epoch-0 entry). A worker
    /// (re)starting mid-job keys every round at the width its epoch
    /// dictates; later width changes arrive as
    /// [`ConsumerSetUpdate`]s on heartbeats.
    pub width_epochs: Vec<WidthEpoch>,
    /// Snapshot-serve mode (spill tier): this worker's slice of a
    /// committed fingerprint-keyed snapshot. When present, the worker
    /// streams the listed segments from storage instead of running
    /// `graph` (falling back to live production only on a missing or
    /// corrupt segment); `None` = normal production.
    pub snapshot_manifest: Option<SpillManifest>,
}
wire_struct!(TaskDef {
    job_id,
    dataset_id,
    graph,
    sharding,
    mode,
    num_consumers,
    static_shards,
    worker_index,
    num_workers,
    consumers,
    owned_residues,
    start_round,
    has_lease_view,
    width_epochs,
    snapshot_manifest
});

#[derive(Debug, Clone, PartialEq)]
pub struct GetSplitReq {
    pub job_id: u64,
    pub worker_id: u64,
}
wire_struct!(GetSplitReq { job_id, worker_id });

#[derive(Debug, Clone, PartialEq)]
pub struct GetSplitResp {
    /// Next shard index to process; `None` = end of splits this epoch.
    pub split: Option<u64>,
}
wire_struct!(GetSplitResp { split });

#[derive(Debug, Clone, PartialEq)]
pub struct GetElementReq {
    pub job_id: u64,
    pub client_id: u64,
    /// Coordinated mode: which consumer slot this client occupies.
    pub consumer_index: Option<u32>,
    /// Coordinated mode: the training round being fetched.
    pub round: Option<u64>,
    pub compression: CompressionMode,
}
wire_struct!(GetElementReq { job_id, client_id, consumer_index, round, compression });

#[derive(Debug, Clone, PartialEq)]
pub struct GetElementResp {
    /// Wire-encoded [`crate::data::Element`], possibly deflate-compressed.
    pub element: Option<Vec<u8>>,
    pub compressed: bool,
    /// True when the task has produced everything it ever will.
    pub end_of_sequence: bool,
    /// Coordinated mode: this round is not served by this worker — the
    /// client should ask the worker whose turn it is.
    pub wrong_worker_for_round: bool,
}
wire_struct!(GetElementResp { element, compressed, end_of_sequence, wrong_worker_for_round });

/// Batched streaming fetch (independent mode only): one RPC drains up to
/// `max_elements` / `max_bytes` of the task's ready queue, amortizing
/// per-element RPC overhead. Coordinated-reads rounds keep using
/// [`GetElementReq`] (one round slot per call is the §3.6 contract).
#[derive(Debug, Clone, PartialEq)]
pub struct GetElementsReq {
    pub job_id: u64,
    pub client_id: u64,
    /// Max elements per response; 0 = worker default.
    pub max_elements: u32,
    /// Soft response byte budget (pre-compression); 0 = worker default.
    /// At least one element is returned even if it alone exceeds this.
    pub max_bytes: u64,
    /// How long the worker may hold the request open waiting for data
    /// before returning an empty frame (long-poll); 0 = worker default.
    pub poll_ms: u32,
    pub compression: CompressionMode,
}
wire_struct!(GetElementsReq { job_id, client_id, max_elements, max_bytes, poll_ms, compression });

#[derive(Debug, Clone, PartialEq)]
pub struct GetElementsResp {
    /// Element count inside `frame` (sanity check for the decoder).
    pub num_elements: u32,
    pub compressed: bool,
    /// True when the task has produced everything it ever will *and*
    /// this client has consumed it all; may accompany a non-empty frame.
    pub end_of_sequence: bool,
    /// Response frame: a wire-encoded `Vec<Vec<u8>>` of element payloads
    /// (`u32` count, then length-prefixed entries). When `compressed`,
    /// the whole frame is compressed as one unit so codec overhead
    /// amortizes across the batch.
    ///
    /// Declared *last* so the worker can emit the fixed-size head and the
    /// multi-megabyte frame as separate slices of one scatter-gather RPC
    /// write ([`crate::rpc::frame::Frame::write_parts_to`]) instead of
    /// copying the frame into a contiguous response buffer.
    pub frame: Vec<u8>,
}
wire_struct!(GetElementsResp { num_elements, compressed, end_of_sequence, frame });

/// Encode a [`GetElementsResp`] as `(head, frame)` write slices for the
/// scatter-gather RPC path: `head ++ frame` is byte-identical to
/// `GetElementsResp::to_bytes`, but the (possibly multi-megabyte) frame
/// buffer is moved, never copied. Keep in lockstep with the
/// `wire_struct!` field order above.
pub fn encode_get_elements_resp_parts(
    num_elements: u32,
    compressed: bool,
    end_of_sequence: bool,
    frame: Vec<u8>,
) -> (Vec<u8>, Vec<u8>) {
    let mut head = Writer::with_capacity(4 + 1 + 1 + 4);
    head.put_u32(num_elements);
    compressed.encode(&mut head);
    end_of_sequence.encode(&mut head);
    head.put_u32(frame.len() as u32); // Vec<u8> length prefix
    (head.into_bytes(), frame)
}

/// Stream-session handshake (client -> worker). The client declares the
/// highest protocol version it speaks, its capability set, and the
/// largest response frame it will accept; the worker answers with the
/// negotiated (min / intersection) values and a session id that scopes
/// every subsequent [`FetchReq`].
#[derive(Debug, Clone, PartialEq)]
pub struct OpenStreamReq {
    pub job_id: u64,
    pub client_id: u64,
    /// Highest protocol version the client speaks (>= 1).
    pub protocol_version: u32,
    /// [`stream_caps`] bitmask the client supports.
    pub capabilities: u64,
    /// Largest response frame the client will accept; 0 = transport cap
    /// ([`crate::rpc::MAX_FRAME_LEN`]). Elements whose encoding exceeds
    /// the negotiated value stream as continuation frames when
    /// [`stream_caps::CHUNKED_TRANSFER`] is negotiated.
    pub max_frame_len: u64,
    /// Coordinated mode: which consumer slot this session reads for.
    pub consumer_index: Option<u32>,
}
wire_struct!(OpenStreamReq {
    job_id,
    client_id,
    protocol_version,
    capabilities,
    max_frame_len,
    consumer_index
});

#[derive(Debug, Clone, PartialEq)]
pub struct OpenStreamResp {
    /// Scope for all [`FetchReq`]s on this stream. Sessions die with the
    /// task, with the consumer's release, or via [`CloseStreamReq`];
    /// a fetch on a dead session errors and the client re-handshakes.
    pub session_id: u64,
    /// Negotiated version: `min(client, worker)`.
    pub protocol_version: u32,
    /// Negotiated capabilities: the intersection of both sides' sets.
    pub capabilities: u64,
    /// Negotiated response-frame budget: `min(client, worker)` bytes.
    pub max_frame_len: u64,
    /// The job's mode, so the client picks the right fetch discipline
    /// (batch drain vs one-slot round reads).
    pub mode: ProcessingMode,
}
wire_struct!(OpenStreamResp { session_id, protocol_version, capabilities, max_frame_len, mode });

/// Session-scoped fetch: the canonical data-plane request. Independent
/// mode drains a batch; coordinated mode reads one round slot
/// (`round = Some(..)`); a pending oversized element resumes from
/// `chunk_offset`.
#[derive(Debug, Clone, PartialEq)]
pub struct FetchReq {
    pub session_id: u64,
    /// Max elements per response; 0 = worker default.
    pub max_elements: u32,
    /// Soft response byte budget; 0 = worker default. Clamped to the
    /// negotiated frame budget.
    pub max_bytes: u64,
    /// Long-poll window when no data is ready; 0 = worker default.
    pub poll_ms: u32,
    pub compression: CompressionMode,
    /// Coordinated mode: the training round being fetched.
    pub round: Option<u64>,
    /// Chunked transfer: the [`FetchResp::chunk_seq`] of the oversized
    /// element `chunk_offset` refers to (0 = none). The worker ignores
    /// offsets tagged with a different seq than its parked element, so a
    /// retried ack from an already-released element can never release or
    /// corrupt the next one.
    pub chunk_seq: u64,
    /// Chunked transfer: bytes of the pending oversized element already
    /// received. The worker serves the next continuation frame from this
    /// offset (making chunk delivery idempotent under RPC retries) and
    /// releases the element only once the client's offset — tagged with
    /// the matching `chunk_seq` — reaches its total length, so a lost
    /// response can never skip data.
    pub chunk_offset: u64,
}
wire_struct!(FetchReq {
    session_id,
    max_elements,
    max_bytes,
    poll_ms,
    compression,
    round,
    chunk_seq,
    chunk_offset
});

#[derive(Debug, Clone, PartialEq)]
pub struct FetchResp {
    /// Element count inside `frame` (0 for continuation frames and empty
    /// long-poll expiries).
    pub num_elements: u32,
    pub compressed: bool,
    /// True when the stream has produced everything it ever will *and*
    /// this session's cursor has consumed it all.
    pub end_of_sequence: bool,
    /// Coordinated mode: this round belongs to another worker.
    pub wrong_worker_for_round: bool,
    /// Chunked transfer: when `chunk_total_len > 0`, `frame` is the raw
    /// byte range `[chunk_offset, chunk_offset + frame.len())` of one
    /// oversized element's encoding; the client reassembles and decodes
    /// once its buffer reaches `chunk_total_len`. `chunk_seq` identifies
    /// the element within the session (monotonically increasing from 1):
    /// continuation frames of one element all carry the same seq, and the
    /// client echoes it back with its offsets.
    pub chunk_seq: u64,
    pub chunk_offset: u64,
    pub chunk_total_len: u64,
    /// Backpressure hints for adaptive batching: elements immediately
    /// available to this cursor (producer backlog + unread window).
    pub ready_elements: u32,
    /// Sliding-window occupancy at serve time.
    pub window_elements: u32,
    pub window_bytes: u64,
    /// Response frame: a wire-encoded `Vec<Vec<u8>>` of element payloads
    /// (possibly whole-frame compressed), or a raw element byte range in
    /// chunk mode. Declared last for the scatter-gather write path, like
    /// [`GetElementsResp::frame`].
    pub frame: Vec<u8>,
}
wire_struct!(FetchResp {
    num_elements,
    compressed,
    end_of_sequence,
    wrong_worker_for_round,
    chunk_seq,
    chunk_offset,
    chunk_total_len,
    ready_elements,
    window_elements,
    window_bytes,
    frame
});

/// Encode a [`FetchResp`] as `(head, frame)` write slices for the
/// scatter-gather RPC path: `head ++ frame` is byte-identical to
/// `FetchResp::to_bytes`, but the (possibly multi-megabyte) frame buffer
/// is moved, never copied (see [`encode_get_elements_resp_parts`]). Keep
/// in lockstep with the `wire_struct!` field order above.
pub fn encode_fetch_resp_parts(resp: FetchResp) -> (Vec<u8>, Vec<u8>) {
    let mut head = Writer::with_capacity(4 + 1 + 1 + 1 + 8 + 8 + 8 + 4 + 4 + 8 + 4);
    head.put_u32(resp.num_elements);
    resp.compressed.encode(&mut head);
    resp.end_of_sequence.encode(&mut head);
    resp.wrong_worker_for_round.encode(&mut head);
    head.put_u64(resp.chunk_seq);
    head.put_u64(resp.chunk_offset);
    head.put_u64(resp.chunk_total_len);
    head.put_u32(resp.ready_elements);
    head.put_u32(resp.window_elements);
    head.put_u64(resp.window_bytes);
    head.put_u32(resp.frame.len() as u32); // Vec<u8> length prefix
    (head.into_bytes(), resp.frame)
}

#[derive(Debug, Clone, PartialEq)]
pub struct CloseStreamReq {
    pub session_id: u64,
}
wire_struct!(CloseStreamReq { session_id });

#[derive(Debug, Clone, PartialEq)]
pub struct CloseStreamResp {
    /// False when the session was already gone (idempotent close).
    pub closed: bool,
}
wire_struct!(CloseStreamResp { closed });

/// Dispatcher -> worker push of consumer churn (attaches and releases),
/// sent best-effort the moment a client joins or leaves a shared job.
/// The heartbeat consumer updates remain the reliable fallback: applying
/// an update twice is idempotent (registration re-anchors nothing,
/// releases tombstone). The push is what makes **eager window eviction**
/// safe — without it, a new consumer's cursor could register a heartbeat
/// interval late and miss elements the existing cursors already consumed
/// (and eagerly evicted).
#[derive(Debug, Clone, PartialEq)]
pub struct UpdateConsumersReq {
    pub attached: Vec<ConsumerUpdate>,
    pub released: Vec<ConsumerUpdate>,
}
wire_struct!(UpdateConsumersReq { attached, released });

#[derive(Debug, Clone, PartialEq)]
pub struct UpdateConsumersResp {
    /// Number of updates that landed on a live task (informational).
    pub applied: u32,
}
wire_struct!(UpdateConsumersResp { applied });

#[derive(Debug, Clone, PartialEq)]
pub struct WorkerStatusReq {}
wire_struct!(WorkerStatusReq {});

/// Per-job sliding-window occupancy (ROADMAP window-sizing follow-up):
/// how much of the shared stream each task currently retains.
#[derive(Debug, Clone, PartialEq)]
pub struct JobWindowStat {
    pub job_id: u64,
    pub elements: u64,
    pub bytes: u64,
}
wire_struct!(JobWindowStat { job_id, elements, bytes });

#[derive(Debug, Clone, PartialEq)]
pub struct WorkerStatusResp {
    pub active_tasks: Vec<u64>,
    pub buffered_elements: u64,
    pub elements_produced: u64,
    pub cache_hits: u64,
    pub cache_evictions: u64,
    /// Elements produced once into a stream that had ≥ 2 registered
    /// consumers at production time (the §3.5 "1× production" half of the
    /// sharing ledger; the k× half is `client/elements_fetched`).
    pub shared_elements_served: u64,
    /// Elements a lagging consumer skipped because they were evicted
    /// before it arrived (the relaxed-visitation escape hatch).
    pub relaxed_skips: u64,
    /// Per-job sliding-window occupancy (elements + bytes) for the
    /// currently-live independent-mode tasks.
    pub window_stats: Vec<JobWindowStat>,
    /// Spill tier: segments flushed to the store by this worker.
    pub spill_segments_written: u64,
    /// Spill tier: elements served to a consumer from spilled segments
    /// (the RAM → spill fallback) instead of being skipped.
    pub spill_elements_served: u64,
    /// Snapshot-serve tasks started (re-submitted pipelines streamed
    /// from a committed snapshot instead of re-produced).
    pub snapshot_serves: u64,
}
wire_struct!(WorkerStatusResp {
    active_tasks,
    buffered_elements,
    elements_produced,
    cache_hits,
    cache_evictions,
    shared_elements_served,
    relaxed_skips,
    window_stats,
    spill_segments_written,
    spill_elements_served,
    snapshot_serves
});

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::graph::PipelineBuilder;
    use crate::wire::{Decode, Encode};

    fn rt<T: Encode + Decode + PartialEq + std::fmt::Debug>(v: T) {
        assert_eq!(T::from_bytes(&v.to_bytes()).unwrap(), v);
    }

    #[test]
    fn enums_roundtrip() {
        rt(ShardingPolicy::Off);
        rt(ShardingPolicy::Dynamic);
        rt(ShardingPolicy::Static);
        rt(ProcessingMode::Independent);
        rt(ProcessingMode::Coordinated);
        rt(CompressionMode::Deflate);
        rt(SharingMode::Auto);
        rt(SharingMode::Off);
    }

    #[test]
    fn messages_roundtrip() {
        let graph = PipelineBuilder::source_range(10).batch(2).build();
        rt(RegisterDatasetReq {
            graph: graph.clone(),
            udf_digests: vec![UdfDigest { name: "vision.augment".into(), digest: 0xfeed }],
        });
        rt(RegisterDatasetResp { dataset_id: 9, fingerprint: vec![7u8; 32] });
        rt(GetOrCreateJobReq {
            dataset_id: 9,
            job_name: "hp-tuning".into(),
            sharding: ShardingPolicy::Dynamic,
            mode: ProcessingMode::Coordinated,
            num_consumers: 4,
            sharing: SharingMode::Auto,
        });
        rt(GetOrCreateJobResp { job_id: 3, client_id: 8, attached: true, snapshot: false });
        rt(GetOrCreateJobResp { job_id: 4, client_id: 9, attached: false, snapshot: true });
        rt(ClientHeartbeatReq {
            job_id: 3,
            client_id: 8,
            next_round: 42,
            consumer_index: 1,
            stall_fraction_milli: 125,
        });
        rt(ClientHeartbeatResp {
            worker_addrs: vec!["127.0.0.1:1234".into()],
            job_finished: false,
            round_owner_addrs: vec!["127.0.0.1:1234".into(), "127.0.0.1:1234".into()],
            round_floor: 17,
            membership_epoch: 2,
            num_consumers: 3,
            width_barrier_round: 12,
        });
        rt(RegisterWorkerReq { addr: "127.0.0.1:9".into() });
        rt(RegisterWorkerResp {
            worker_id: 2,
            tasks: vec![TaskDef {
                job_id: 3,
                dataset_id: 9,
                graph,
                sharding: ShardingPolicy::Static,
                mode: ProcessingMode::Independent,
                num_consumers: 0,
                static_shards: vec![0, 2],
                worker_index: 1,
                num_workers: 4,
                consumers: vec![8, 9],
                owned_residues: vec![1, 3],
                start_round: 21,
                has_lease_view: true,
                width_epochs: vec![WidthEpoch { epoch: 0, barrier_round: 0, num_consumers: 2 }],
                snapshot_manifest: Some(SpillManifest {
                    fingerprint: 9,
                    job_id: 3,
                    epoch: 1,
                    total_elements: 6,
                    complete: true,
                    segments: vec![crate::service::spill::SegmentMeta {
                        key: "spill/job-3/data".into(),
                        offset: 64,
                        len: 48,
                        start_seq: 0,
                        num_elements: 6,
                        crc32: 0x0102_0304,
                    }],
                }),
            }],
        });
        rt(WorkerHeartbeatReq {
            worker_id: 2,
            active_tasks: vec![3],
            cpu_util_milli: 700,
            spill_manifests: vec![SpillManifest {
                fingerprint: 9,
                job_id: 3,
                epoch: 0,
                total_elements: 0,
                complete: true,
                segments: vec![],
            }],
            revoke_acks: vec![LeaseRevoke { job_id: 3, residues: vec![1] }],
            drain_ready: true,
        });
        rt(WorkerHeartbeatResp {
            new_tasks: vec![],
            removed_tasks: vec![3],
            attached_clients: vec![ConsumerUpdate { job_id: 3, client_id: 11 }],
            released_clients: vec![ConsumerUpdate { job_id: 3, client_id: 8 }],
            round_assignments: vec![RoundAssignment {
                job_id: 3,
                owned_residues: vec![0, 2],
                start_round: 17,
            }],
            width_updates: vec![ConsumerSetUpdate {
                job_id: 3,
                width_epochs: vec![
                    WidthEpoch { epoch: 0, barrier_round: 0, num_consumers: 2 },
                    WidthEpoch { epoch: 1, barrier_round: 9, num_consumers: 3 },
                ],
            }],
            manifest_acks: vec![3],
            round_revocations: vec![LeaseRevoke { job_id: 3, residues: vec![0, 2] }],
            drain: true,
        });
        rt(SetJobConsumersReq { job_id: 3, num_consumers: 3 });
        rt(SetJobConsumersResp { epoch: 1, barrier_round: 9 });
        rt(UpdateConsumersReq {
            attached: vec![ConsumerUpdate { job_id: 3, client_id: 11 }],
            released: vec![],
        });
        rt(UpdateConsumersResp { applied: 1 });
        rt(GetSplitReq { job_id: 3, worker_id: 2 });
        rt(GetSplitResp { split: Some(7) });
        rt(GetSplitResp { split: None });
        rt(GetElementReq {
            job_id: 3,
            client_id: 8,
            consumer_index: Some(1),
            round: Some(42),
            compression: CompressionMode::None,
        });
        rt(GetElementResp {
            element: Some(vec![1, 2, 3]),
            compressed: false,
            end_of_sequence: false,
            wrong_worker_for_round: true,
        });
        rt(ReleaseJobReq { job_id: 3, client_id: 8 });
        rt(ReleaseJobResp { released: true });
        rt(GetElementsReq {
            job_id: 3,
            client_id: 8,
            max_elements: 64,
            max_bytes: 1 << 20,
            poll_ms: 50,
            compression: CompressionMode::Deflate,
        });
        rt(WorkerStatusResp {
            active_tasks: vec![1],
            buffered_elements: 5,
            elements_produced: 100,
            cache_hits: 7,
            cache_evictions: 2,
            shared_elements_served: 60,
            relaxed_skips: 3,
            window_stats: vec![JobWindowStat { job_id: 1, elements: 5, bytes: 4096 }],
            spill_segments_written: 4,
            spill_elements_served: 9,
            snapshot_serves: 1,
        });
    }

    #[test]
    fn stream_session_messages_roundtrip() {
        rt(OpenStreamReq {
            job_id: 3,
            client_id: 8,
            protocol_version: STREAM_PROTOCOL_VERSION,
            capabilities: stream_caps::ALL,
            max_frame_len: 4 << 20,
            consumer_index: None,
        });
        rt(OpenStreamReq {
            job_id: 3,
            client_id: 8,
            protocol_version: 99,
            capabilities: 0,
            max_frame_len: 0,
            consumer_index: Some(1),
        });
        rt(OpenStreamResp {
            session_id: 17,
            protocol_version: 1,
            capabilities: stream_caps::DEFLATE | stream_caps::CHUNKED_TRANSFER,
            max_frame_len: 1 << 20,
            mode: ProcessingMode::Coordinated,
        });
        rt(FetchReq {
            session_id: 17,
            max_elements: 64,
            max_bytes: 1 << 20,
            poll_ms: 50,
            compression: CompressionMode::Deflate,
            round: Some(7),
            chunk_seq: 0,
            chunk_offset: 0,
        });
        rt(FetchReq {
            session_id: 17,
            max_elements: 0,
            max_bytes: 0,
            poll_ms: 0,
            compression: CompressionMode::None,
            round: None,
            chunk_seq: 3,
            chunk_offset: 9 << 20,
        });
        rt(CloseStreamReq { session_id: 17 });
        rt(CloseStreamResp { closed: true });
    }

    #[test]
    fn fetch_resp_roundtrip_variants() {
        // Plain batch frame.
        let frame = vec![vec![1u8, 2, 3], vec![4u8, 5]].to_bytes();
        rt(FetchResp {
            num_elements: 2,
            compressed: false,
            end_of_sequence: false,
            wrong_worker_for_round: false,
            chunk_seq: 0,
            chunk_offset: 0,
            chunk_total_len: 0,
            ready_elements: 12,
            window_elements: 7,
            window_bytes: 9000,
            frame,
        });
        // Continuation frame: raw byte range of an oversized element.
        rt(FetchResp {
            num_elements: 0,
            compressed: false,
            end_of_sequence: false,
            wrong_worker_for_round: false,
            chunk_seq: 2,
            chunk_offset: 1 << 20,
            chunk_total_len: 80 << 20,
            ready_elements: 0,
            window_elements: 1,
            window_bytes: 80 << 20,
            frame: vec![0xab; 64],
        });
        // Bare end-of-sequence.
        rt(FetchResp {
            num_elements: 0,
            compressed: false,
            end_of_sequence: true,
            wrong_worker_for_round: false,
            chunk_seq: 0,
            chunk_offset: 0,
            chunk_total_len: 0,
            ready_elements: 0,
            window_elements: 0,
            window_bytes: 0,
            frame: Vec::<Vec<u8>>::new().to_bytes(),
        });
    }

    /// The worker's scatter-gather path hand-encodes the fetch-response
    /// head; the concatenation must stay byte-identical to the
    /// `wire_struct!` layout clients decode.
    #[test]
    fn fetch_resp_parts_match_struct_encoding() {
        let frame = vec![vec![9u8, 8, 7], vec![6u8]].to_bytes();
        let resp = FetchResp {
            num_elements: 2,
            compressed: true,
            end_of_sequence: true,
            wrong_worker_for_round: false,
            chunk_seq: 4,
            chunk_offset: 5,
            chunk_total_len: 6,
            ready_elements: 3,
            window_elements: 2,
            window_bytes: 1 << 30,
            frame,
        };
        let (head, tail) = encode_fetch_resp_parts(resp.clone());
        let mut joined = head;
        joined.extend_from_slice(&tail);
        assert_eq!(joined, resp.to_bytes());
        assert_eq!(FetchResp::from_bytes(&joined).unwrap(), resp);
    }

    #[test]
    fn get_elements_resp_roundtrip_variants() {
        // Plain frame carrying two elements.
        let frame = vec![vec![1u8, 2, 3], vec![4u8, 5]].to_bytes();
        rt(GetElementsResp {
            frame: frame.clone(),
            num_elements: 2,
            compressed: false,
            end_of_sequence: false,
        });
        // Compressed variant: the frame bytes are a compressed blob.
        let z = crate::wire::compress(&frame);
        rt(GetElementsResp { frame: z, num_elements: 2, compressed: true, end_of_sequence: false });
        // End-of-sequence variant: empty frame (count 0), eos set.
        let empty = Vec::<Vec<u8>>::new().to_bytes();
        rt(GetElementsResp { frame: empty, num_elements: 0, compressed: false, end_of_sequence: true });
    }

    /// The worker's scatter-gather path hand-encodes the response head and
    /// appends the frame as a separate write slice; the concatenation must
    /// stay byte-identical to the `wire_struct!` layout clients decode.
    #[test]
    fn get_elements_resp_parts_match_struct_encoding() {
        let frame = vec![vec![9u8, 8, 7], vec![6u8]].to_bytes();
        let resp = GetElementsResp {
            num_elements: 2,
            compressed: false,
            end_of_sequence: true,
            frame: frame.clone(),
        };
        let (head, tail) = encode_get_elements_resp_parts(2, false, true, frame);
        let mut joined = head;
        joined.extend_from_slice(&tail);
        assert_eq!(joined, resp.to_bytes());
        assert_eq!(GetElementsResp::from_bytes(&joined).unwrap(), resp);
    }

    #[test]
    fn get_elements_frame_decodes_through_compression() {
        use crate::data::element::Tensor;
        use crate::data::Element;
        // Worker-side assembly: encode each element, frame them, compress
        // the whole frame; client-side: decompress, split, decode.
        let elems: Vec<Element> = (0..4)
            .map(|i| Element::with_ids(vec![Tensor::scalar_i32(i)], vec![i as u64]))
            .collect();
        let payloads: Vec<Vec<u8>> = elems.iter().map(|e| e.to_bytes()).collect();
        let frame = payloads.to_bytes();
        let resp = GetElementsResp {
            frame: crate::wire::compress(&frame),
            num_elements: 4,
            compressed: true,
            end_of_sequence: true,
        };
        let wire = resp.to_bytes();
        let back = GetElementsResp::from_bytes(&wire).unwrap();
        assert!(back.compressed && back.end_of_sequence);
        let plain = crate::wire::decompress(&back.frame).unwrap();
        let parts = Vec::<Vec<u8>>::from_bytes(&plain).unwrap();
        assert_eq!(parts.len(), back.num_elements as usize);
        for (i, p) in parts.iter().enumerate() {
            let e = Element::from_bytes(p).unwrap();
            assert_eq!(e.tensors[0].as_i32(), vec![i as i32]);
            assert_eq!(e.ids, vec![i as u64]);
        }
    }
}
