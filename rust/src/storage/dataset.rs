//! Synthetic source datasets (the COCO / ImageNet / NLP-corpus stand-ins).
//!
//! The paper's experiments read real corpora we do not have; these
//! generators produce sharded record files with the same *structural*
//! properties the system exercises: many shard files per dataset (§3.3),
//! multi-KB image samples, and NLP token sequences whose lengths follow a
//! heavy-tailed distribution (the source of the Fig-11 straggler problem).
//!
//! Every sample is deterministic given `(seed, shard, index)`, so tests
//! can assert visitation guarantees by sample identity.

use super::record::{RecordReader, RecordWriter};
use super::{ObjectStore, StorageResult};
use crate::util::rng::Rng;
use crate::wire::{Decode, Encode};
use crate::wire_struct;

/// A raw vision sample: encoded image bytes + label.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct VisionSample {
    /// Unique global id (asserting visitation guarantees keys on this).
    pub id: u64,
    pub height: u32,
    pub width: u32,
    pub channels: u32,
    /// H*W*C interleaved u8 pixels.
    pub pixels: Vec<u8>,
    pub label: u32,
}

wire_struct!(VisionSample { id, height, width, channels, pixels, label });

/// A raw NLP sample: token ids (variable length) + label.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TextSample {
    pub id: u64,
    pub tokens: Vec<u32>,
    pub label: u32,
}

wire_struct!(TextSample { id, tokens, label });

/// Description of a generated dataset: where its shards live.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DatasetSpec {
    /// Key prefix in the object store, e.g. `datasets/coco-mini`.
    pub prefix: String,
    /// Shard keys in order.
    pub shards: Vec<String>,
    pub samples_per_shard: usize,
    pub total_samples: usize,
}

wire_struct!(DatasetSpec { prefix, shards, samples_per_shard, total_samples });

impl DatasetSpec {
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }
}

/// Parameters for the synthetic vision corpus.
#[derive(Debug, Clone)]
pub struct VisionGenConfig {
    pub num_shards: usize,
    pub samples_per_shard: usize,
    pub height: u32,
    pub width: u32,
    pub channels: u32,
    pub num_classes: u32,
    pub seed: u64,
}

impl Default for VisionGenConfig {
    fn default() -> Self {
        VisionGenConfig {
            num_shards: 8,
            samples_per_shard: 64,
            height: 32,
            width: 32,
            channels: 3,
            num_classes: 10,
            seed: 0x5eed_0001,
        }
    }
}

/// Generate and store a sharded vision dataset. Returns its spec.
pub fn generate_vision(store: &ObjectStore, prefix: &str, cfg: &VisionGenConfig) -> DatasetSpec {
    let mut shards = Vec::with_capacity(cfg.num_shards);
    for shard in 0..cfg.num_shards {
        let mut w = RecordWriter::new();
        for i in 0..cfg.samples_per_shard {
            let id = (shard * cfg.samples_per_shard + i) as u64;
            let mut rng = Rng::new(cfg.seed ^ id.wrapping_mul(0x9e37_79b9_7f4a_7c15));
            let n = (cfg.height * cfg.width * cfg.channels) as usize;
            let mut pixels = vec![0u8; n];
            for p in pixels.iter_mut() {
                *p = (rng.next_u32() & 0xff) as u8;
            }
            let sample = VisionSample {
                id,
                height: cfg.height,
                width: cfg.width,
                channels: cfg.channels,
                pixels,
                label: (rng.next_u32() % cfg.num_classes),
            };
            w.push(&sample.to_bytes());
        }
        let key = format!("{prefix}/shard-{shard:05}");
        store.put(&key, w.finish());
        shards.push(key);
    }
    DatasetSpec {
        prefix: prefix.to_string(),
        shards,
        samples_per_shard: cfg.samples_per_shard,
        total_samples: cfg.num_shards * cfg.samples_per_shard,
    }
}

/// Parameters for the synthetic NLP corpus. Sequence lengths are drawn
/// from a lognormal clipped to `[min_len, max_len]`, which matches the
/// long-tail the paper's coordinated-reads feature targets.
#[derive(Debug, Clone)]
pub struct TextGenConfig {
    pub num_shards: usize,
    pub samples_per_shard: usize,
    pub vocab: u32,
    pub min_len: usize,
    pub max_len: usize,
    /// lognormal(mu, sigma) of the raw length before clipping.
    pub len_mu: f64,
    pub len_sigma: f64,
    pub num_classes: u32,
    pub seed: u64,
}

impl Default for TextGenConfig {
    fn default() -> Self {
        TextGenConfig {
            num_shards: 8,
            samples_per_shard: 128,
            vocab: 30_000,
            min_len: 4,
            max_len: 512,
            len_mu: 4.0,  // median ~55 tokens
            len_sigma: 0.9,
            num_classes: 2,
            seed: 0x5eed_0002,
        }
    }
}

/// Generate and store a sharded NLP dataset. Returns its spec.
pub fn generate_text(store: &ObjectStore, prefix: &str, cfg: &TextGenConfig) -> DatasetSpec {
    let mut shards = Vec::with_capacity(cfg.num_shards);
    for shard in 0..cfg.num_shards {
        let mut w = RecordWriter::new();
        for i in 0..cfg.samples_per_shard {
            let id = (shard * cfg.samples_per_shard + i) as u64;
            let mut rng = Rng::new(cfg.seed ^ id.wrapping_mul(0xd134_2543_de82_ef95));
            let raw = rng.lognormal(cfg.len_mu, cfg.len_sigma);
            let len = (raw as usize).clamp(cfg.min_len, cfg.max_len);
            let tokens = (0..len).map(|_| rng.next_u32() % cfg.vocab).collect();
            let sample = TextSample { id, tokens, label: rng.next_u32() % cfg.num_classes };
            w.push(&sample.to_bytes());
        }
        let key = format!("{prefix}/shard-{shard:05}");
        store.put(&key, w.finish());
        shards.push(key);
    }
    DatasetSpec {
        prefix: prefix.to_string(),
        shards,
        samples_per_shard: cfg.samples_per_shard,
        total_samples: cfg.num_shards * cfg.samples_per_shard,
    }
}

/// Generate a *learnable* text corpus: each sample is a periodic token
/// sequence (a random base motif of length 2–8 repeated, with 5% noise).
/// A byte-level LM trained on this should drive its loss well below the
/// uniform-entropy floor — used by `examples/e2e_train.rs` to show a real
/// loss curve through the full stack.
pub fn generate_text_patterned(store: &ObjectStore, prefix: &str, cfg: &TextGenConfig) -> DatasetSpec {
    let mut shards = Vec::with_capacity(cfg.num_shards);
    for shard in 0..cfg.num_shards {
        let mut w = RecordWriter::new();
        for i in 0..cfg.samples_per_shard {
            let id = (shard * cfg.samples_per_shard + i) as u64;
            let mut rng = Rng::new(cfg.seed ^ id.wrapping_mul(0xa076_1d64_78bd_642f));
            let len = cfg.max_len.max(cfg.min_len);
            let period = 2 + (rng.next_u32() % 7) as usize;
            let motif: Vec<u32> =
                (0..period).map(|_| 1 + rng.next_u32() % (cfg.vocab - 1).max(1)).collect();
            let tokens: Vec<u32> = (0..len)
                .map(|j| {
                    if rng.chance(0.05) {
                        1 + rng.next_u32() % (cfg.vocab - 1).max(1)
                    } else {
                        motif[j % period]
                    }
                })
                .collect();
            let sample = TextSample { id, tokens, label: (period % cfg.num_classes as usize) as u32 };
            w.push(&sample.to_bytes());
        }
        let key = format!("{prefix}/shard-{shard:05}");
        store.put(&key, w.finish());
        shards.push(key);
    }
    DatasetSpec {
        prefix: prefix.to_string(),
        shards,
        samples_per_shard: cfg.samples_per_shard,
        total_samples: cfg.num_shards * cfg.samples_per_shard,
    }
}

/// Read every sample of a vision shard.
pub fn read_vision_shard(store: &ObjectStore, key: &str) -> StorageResult<Vec<VisionSample>> {
    let body = store.get(key)?;
    let mut out = Vec::new();
    let mut r = RecordReader::new(&body);
    while let Some(rec) = r.next_record()? {
        out.push(VisionSample::from_bytes(rec).map_err(|e| super::StorageError::Corrupt(e.to_string()))?);
    }
    Ok(out)
}

/// Read every sample of a text shard.
pub fn read_text_shard(store: &ObjectStore, key: &str) -> StorageResult<Vec<TextSample>> {
    let body = store.get(key)?;
    let mut out = Vec::new();
    let mut r = RecordReader::new(&body);
    while let Some(rec) = r.next_record()? {
        out.push(TextSample::from_bytes(rec).map_err(|e| super::StorageError::Corrupt(e.to_string()))?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vision_dataset_roundtrip() {
        let store = ObjectStore::in_memory();
        let cfg = VisionGenConfig { num_shards: 3, samples_per_shard: 5, ..Default::default() };
        let spec = generate_vision(&store, "ds/vis", &cfg);
        assert_eq!(spec.num_shards(), 3);
        assert_eq!(spec.total_samples, 15);
        let samples = read_vision_shard(&store, &spec.shards[1]).unwrap();
        assert_eq!(samples.len(), 5);
        assert_eq!(samples[0].id, 5);
        assert_eq!(samples[0].pixels.len(), 32 * 32 * 3);
        assert!(samples.iter().all(|s| s.label < cfg.num_classes));
    }

    #[test]
    fn vision_is_deterministic() {
        let s1 = ObjectStore::in_memory();
        let s2 = ObjectStore::in_memory();
        let cfg = VisionGenConfig { num_shards: 2, samples_per_shard: 4, ..Default::default() };
        let a = generate_vision(&s1, "d", &cfg);
        let b = generate_vision(&s2, "d", &cfg);
        assert_eq!(a, b);
        assert_eq!(
            read_vision_shard(&s1, &a.shards[0]).unwrap(),
            read_vision_shard(&s2, &b.shards[0]).unwrap()
        );
    }

    #[test]
    fn text_lengths_are_heavy_tailed_and_clipped() {
        let store = ObjectStore::in_memory();
        let cfg = TextGenConfig { num_shards: 2, samples_per_shard: 500, ..Default::default() };
        let spec = generate_text(&store, "ds/txt", &cfg);
        let mut lens = Vec::new();
        for sh in &spec.shards {
            for s in read_text_shard(&store, sh).unwrap() {
                assert!(s.tokens.len() >= cfg.min_len && s.tokens.len() <= cfg.max_len);
                assert!(s.tokens.iter().all(|&t| t < cfg.vocab));
                lens.push(s.tokens.len() as f64);
            }
        }
        let mut samples = crate::util::hist::Samples::from_vec(lens);
        // Heavy tail: p95 well above median.
        assert!(samples.percentile(95.0) > 2.0 * samples.median());
    }

    #[test]
    fn ids_are_globally_unique() {
        let store = ObjectStore::in_memory();
        let cfg = TextGenConfig { num_shards: 4, samples_per_shard: 16, ..Default::default() };
        let spec = generate_text(&store, "d", &cfg);
        let mut ids = std::collections::HashSet::new();
        for sh in &spec.shards {
            for s in read_text_shard(&store, sh).unwrap() {
                assert!(ids.insert(s.id), "duplicate id {}", s.id);
            }
        }
        assert_eq!(ids.len(), spec.total_samples);
    }

    #[test]
    fn spec_wire_roundtrip() {
        let store = ObjectStore::in_memory();
        let spec = generate_vision(&store, "d", &VisionGenConfig { num_shards: 2, samples_per_shard: 2, ..Default::default() });
        let back = DatasetSpec::from_bytes(&spec.to_bytes()).unwrap();
        assert_eq!(spec, back);
    }
}
