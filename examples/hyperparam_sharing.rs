//! Ephemeral data sharing (§3.5 / Fig. 10): k hyperparameter-tuning
//! clients attach to ONE shared job and consume the same preprocessed
//! stream from the workers' sliding-window caches.
//!
//! Demonstrates the §4.3 claim live: worker CPU (elements produced) stays
//! constant as client count grows, while total elements *served* scales
//! with k — each batch is produced once and served k times.
//!
//! Run: `cargo run --release --example hyperparam_sharing -- --clients 4`

use std::sync::Arc;
use tfdatasvc::data::exec::ElemIter;
use tfdatasvc::data::graph::PipelineBuilder;
use tfdatasvc::data::udf::UdfRegistry;
use tfdatasvc::orchestrator::Cell;
use tfdatasvc::service::dispatcher::DispatcherConfig;
use tfdatasvc::service::proto::ShardingPolicy;
use tfdatasvc::service::{ServiceClient, ServiceClientConfig};
use tfdatasvc::storage::dataset::{generate_vision, VisionGenConfig};
use tfdatasvc::storage::ObjectStore;
use tfdatasvc::util::cli::Args;

fn run_tuning_trial(
    dispatcher: &str,
    graph: &tfdatasvc::data::GraphDef,
    trial: usize,
) -> (usize, usize) {
    // Each trial is one "hyperparameter setting": same input pipeline,
    // same job name => attaches to the shared job.
    let client = ServiceClient::new(dispatcher);
    let mut it = client
        .distribute(
            graph,
            ServiceClientConfig {
                sharding: ShardingPolicy::Dynamic,
                job_name: "hp-sweep".into(),
                ..Default::default()
            },
        )
        .expect("distribute");
    let mut batches = 0;
    let mut samples = 0;
    while let Some(e) = it.next().expect("next") {
        batches += 1;
        samples += e.ids.len();
        // "Train" on the batch: different trials would use different
        // learning rates here; data handling is identical.
        std::hint::black_box(&e);
    }
    println!("  trial {trial}: {batches} batches, {samples} samples");
    (batches, samples)
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args = Args::from_env();
    let k = args.usize_or("clients", 4);

    let store = ObjectStore::in_memory();
    let spec = generate_vision(
        &store,
        "datasets/hp",
        &VisionGenConfig { num_shards: 8, samples_per_shard: 32, ..Default::default() },
    );
    let total = spec.total_samples;

    let cell = Arc::new(Cell::new(store, UdfRegistry::with_builtins(), DispatcherConfig::default())?);
    // Large cache window so concurrent trials never miss a batch.
    cell.set_worker_config_mutator(|c| c.cache_window = 4096);
    cell.scale_to(2)?;

    let graph = PipelineBuilder::source_vision(spec)
        .map_parallel("vision.normalize+vision.augment", 4)
        .batch(16)
        .build();

    println!("running {k} concurrent tuning trials on one shared deployment:");
    let dispatcher = cell.dispatcher_addr();
    let handles: Vec<_> = (0..k)
        .map(|trial| {
            let d = dispatcher.clone();
            let g = graph.clone();
            std::thread::spawn(move || run_tuning_trial(&d, &g, trial))
        })
        .collect();
    let results: Vec<(usize, usize)> = handles.into_iter().map(|h| h.join().unwrap()).collect();

    // Every trial saw the full stream.
    for (i, (_, samples)) in results.iter().enumerate() {
        assert_eq!(*samples, total, "trial {i} saw the full dataset");
    }

    // Production happened once, service happened k times: query workers.
    let pool = tfdatasvc::rpc::Pool::with_defaults();
    let mut produced = 0u64;
    let mut served = 0u64;
    for addr in cell.worker_addrs() {
        let status: tfdatasvc::service::proto::WorkerStatusResp = tfdatasvc::rpc::call_typed(
            &pool,
            &addr,
            tfdatasvc::service::proto::worker_methods::WORKER_STATUS,
            &tfdatasvc::service::proto::WorkerStatusReq {},
            std::time::Duration::from_secs(5),
        )?;
        produced += status.elements_produced;
        served += status.cache_hits;
    }
    println!("workers produced {produced} elements, served {served} cache reads");
    println!(
        "sharing factor: {:.2}x (paper: k trials share 1x preprocessing)",
        served as f64 / produced.max(1) as f64
    );
    assert_eq!(served as usize, k * (total / 16), "each trial served from the shared cache");
    assert_eq!(produced as usize, total / 16, "preprocessing ran exactly once");
    println!("hyperparam_sharing OK");
    Ok(())
}
