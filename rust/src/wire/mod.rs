//! Binary wire format: `Encode`/`Decode` for every protocol and pipeline
//! graph type.
//!
//! The repo builds fully offline (no serde), so we define a small,
//! deterministic, little-endian, length-prefixed format:
//!
//! * fixed-width integers and floats are little-endian,
//! * `String` / `Vec<u8>` are `u32` length + bytes,
//! * `Vec<T>` is `u32` count + elements,
//! * `Option<T>` is a `u8` tag (0/1) + payload,
//! * enums encode a `u8` discriminant + per-variant payload (implemented
//!   by hand in the types that need it).
//!
//! All protocol messages in [`crate::service::proto`], the dataset graph in
//! [`crate::data::graph`], and the journal records in
//! [`crate::service::journal`] ride on these traits.

mod buf;
pub mod compress;

pub use buf::{BufPool, Reader, Writer};
pub use compress::{compress, decompress, AdaptiveCodec, CodecAction, CODEC_MIN_LEN};

use std::io;

/// Errors surfaced while decoding a wire buffer.
#[derive(Debug)]
pub enum WireError {
    Eof { wanted: usize, remaining: usize },
    Utf8,
    BadTag { tag: u8, ty: &'static str },
    TooLong { len: usize, limit: usize },
    Checksum { stored: u32, computed: u32 },
    Io(io::Error),
    Other(String),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Eof { wanted, remaining } => {
                write!(f, "unexpected end of buffer: wanted {wanted} more bytes, had {remaining}")
            }
            WireError::Utf8 => write!(f, "invalid utf-8 in string field"),
            WireError::BadTag { tag, ty } => write!(f, "invalid enum tag {tag} for {ty}"),
            WireError::TooLong { len, limit } => write!(f, "length {len} exceeds limit {limit}"),
            WireError::Checksum { stored, computed } => {
                write!(f, "checksum mismatch: stored {stored:#010x}, computed {computed:#010x}")
            }
            WireError::Io(e) => write!(f, "io: {e}"),
            WireError::Other(msg) => write!(f, "{msg}"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<io::Error> for WireError {
    fn from(e: io::Error) -> WireError {
        WireError::Io(e)
    }
}

pub type WireResult<T> = Result<T, WireError>;

/// Serialize `self` into the writer. Infallible by construction: encoding
/// only appends to a growable buffer.
pub trait Encode {
    fn encode(&self, w: &mut Writer);

    /// Convenience: encode into a fresh buffer.
    fn to_bytes(&self) -> Vec<u8> {
        let mut w = Writer::new();
        self.encode(&mut w);
        w.into_bytes()
    }
}

/// Deserialize a value from the reader.
pub trait Decode: Sized {
    fn decode(r: &mut Reader) -> WireResult<Self>;

    /// Convenience: decode from a complete buffer, requiring full consumption.
    fn from_bytes(bytes: &[u8]) -> WireResult<Self> {
        let mut r = Reader::new(bytes);
        let v = Self::decode(&mut r)?;
        if r.remaining() != 0 {
            return Err(WireError::Other(format!(
                "{} trailing bytes after decode",
                r.remaining()
            )));
        }
        Ok(v)
    }
}

macro_rules! impl_prim {
    ($ty:ty, $put:ident, $get:ident) => {
        impl Encode for $ty {
            fn encode(&self, w: &mut Writer) {
                w.$put(*self);
            }
        }
        impl Decode for $ty {
            fn decode(r: &mut Reader) -> WireResult<Self> {
                r.$get()
            }
        }
    };
}

impl_prim!(u8, put_u8, get_u8);
impl_prim!(u16, put_u16, get_u16);
impl_prim!(u32, put_u32, get_u32);
impl_prim!(u64, put_u64, get_u64);
impl_prim!(i32, put_i32, get_i32);
impl_prim!(i64, put_i64, get_i64);
impl_prim!(f32, put_f32, get_f32);
impl_prim!(f64, put_f64, get_f64);

impl Encode for bool {
    fn encode(&self, w: &mut Writer) {
        w.put_u8(*self as u8);
    }
}

impl Decode for bool {
    fn decode(r: &mut Reader) -> WireResult<Self> {
        match r.get_u8()? {
            0 => Ok(false),
            1 => Ok(true),
            tag => Err(WireError::BadTag { tag, ty: "bool" }),
        }
    }
}

impl Encode for usize {
    fn encode(&self, w: &mut Writer) {
        w.put_u64(*self as u64);
    }
}

impl Decode for usize {
    fn decode(r: &mut Reader) -> WireResult<Self> {
        Ok(r.get_u64()? as usize)
    }
}

impl Encode for String {
    fn encode(&self, w: &mut Writer) {
        w.put_bytes(self.as_bytes());
    }
}

impl Decode for String {
    fn decode(r: &mut Reader) -> WireResult<Self> {
        let b = r.get_bytes()?;
        String::from_utf8(b).map_err(|_| WireError::Utf8)
    }
}

/// `Vec<T>`: count-prefixed elements. Note for `Vec<u8>` this layout is
/// byte-identical to [`Writer::put_bytes`] (u32 length + raw bytes), so
/// bulk byte fields may use either form; hot paths (e.g. tensor data)
/// call `put_bytes`/`get_bytes` directly for the memcpy fast path.
impl<T: Encode> Encode for Vec<T> {
    fn encode(&self, w: &mut Writer) {
        w.put_u32(self.len() as u32);
        for x in self {
            x.encode(w);
        }
    }
}

impl<T: Decode> Decode for Vec<T> {
    fn decode(r: &mut Reader) -> WireResult<Self> {
        let n = r.get_u32()? as usize;
        r.check_count(n, 1)?;
        let mut v = Vec::with_capacity(n.min(1 << 16));
        for _ in 0..n {
            v.push(T::decode(r)?);
        }
        Ok(v)
    }
}

/// Helper used by derived-by-hand composite types to encode a `Vec<T>` of
/// any `Encode` type (use when the macro list above doesn't cover `T`).
pub fn encode_vec<T: Encode>(v: &[T], w: &mut Writer) {
    w.put_u32(v.len() as u32);
    for x in v {
        x.encode(w);
    }
}

/// Counterpart of [`encode_vec`].
pub fn decode_vec<T: Decode>(r: &mut Reader) -> WireResult<Vec<T>> {
    let n = r.get_u32()? as usize;
    r.check_count(n, 1)?;
    let mut v = Vec::with_capacity(n.min(1 << 16));
    for _ in 0..n {
        v.push(T::decode(r)?);
    }
    Ok(v)
}

impl<T: Encode> Encode for Option<T> {
    fn encode(&self, w: &mut Writer) {
        match self {
            None => w.put_u8(0),
            Some(v) => {
                w.put_u8(1);
                v.encode(w);
            }
        }
    }
}

impl<T: Decode> Decode for Option<T> {
    fn decode(r: &mut Reader) -> WireResult<Self> {
        match r.get_u8()? {
            0 => Ok(None),
            1 => Ok(Some(T::decode(r)?)),
            tag => Err(WireError::BadTag { tag, ty: "Option" }),
        }
    }
}

impl<A: Encode, B: Encode> Encode for (A, B) {
    fn encode(&self, w: &mut Writer) {
        self.0.encode(w);
        self.1.encode(w);
    }
}

impl<A: Decode, B: Decode> Decode for (A, B) {
    fn decode(r: &mut Reader) -> WireResult<Self> {
        Ok((A::decode(r)?, B::decode(r)?))
    }
}

/// Derive-style macro for plain structs: `wire_struct!(Foo { a, b, c });`
/// encodes fields in declaration order.
#[macro_export]
macro_rules! wire_struct {
    ($name:ident { $($field:ident),* $(,)? }) => {
        impl $crate::wire::Encode for $name {
            fn encode(&self, #[allow(unused_variables)] w: &mut $crate::wire::Writer) {
                $( $crate::wire::Encode::encode(&self.$field, w); )*
            }
        }
        impl $crate::wire::Decode for $name {
            fn decode(#[allow(unused_variables)] r: &mut $crate::wire::Reader) -> $crate::wire::WireResult<Self> {
                Ok($name {
                    $( $field: $crate::wire::Decode::decode(r)?, )*
                })
            }
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip<T: Encode + Decode + PartialEq + std::fmt::Debug>(v: T) {
        let b = v.to_bytes();
        let back = T::from_bytes(&b).expect("decode");
        assert_eq!(v, back);
    }

    #[test]
    fn primitives_roundtrip() {
        roundtrip(0u8);
        roundtrip(255u8);
        roundtrip(0xdeadu16);
        roundtrip(0xdead_beefu32);
        roundtrip(u64::MAX);
        roundtrip(-42i32);
        roundtrip(i64::MIN);
        roundtrip(3.5f32);
        roundtrip(-2.75f64);
        roundtrip(true);
        roundtrip(false);
    }

    #[test]
    fn strings_and_bytes() {
        roundtrip(String::from("héllo wörld"));
        roundtrip(String::new());
        roundtrip(vec![1u8, 2, 3]);
        roundtrip(Vec::<u8>::new());
    }

    #[test]
    fn vecs_and_options() {
        roundtrip(vec![1u64, 2, 3]);
        roundtrip(vec![String::from("a"), String::from("b")]);
        roundtrip(Option::<u32>::None);
        roundtrip(Some(77u32));
        roundtrip(vec![vec![1u8, 2], vec![3u8]]);
        roundtrip((42u32, String::from("x")));
    }

    #[test]
    fn trailing_bytes_rejected() {
        let mut b = 5u32.to_bytes();
        b.push(0);
        assert!(u32::from_bytes(&b).is_err());
    }

    #[test]
    fn truncated_rejected() {
        let b = 5u64.to_bytes();
        assert!(u64::from_bytes(&b[..7]).is_err());
        assert!(String::from_bytes(&[3, 0, 0, 0, b'a']).is_err());
    }

    #[test]
    fn bad_bool_tag() {
        assert!(matches!(
            bool::from_bytes(&[2]),
            Err(WireError::BadTag { tag: 2, ty: "bool" })
        ));
    }

    #[test]
    fn invalid_utf8_rejected() {
        let mut w = Writer::new();
        w.put_bytes(&[0xff, 0xfe]);
        assert!(matches!(
            String::from_bytes(&w.into_bytes()),
            Err(WireError::Utf8)
        ));
    }

    #[test]
    fn wire_struct_macro() {
        #[derive(Debug, PartialEq)]
        struct Demo {
            a: u32,
            b: String,
            c: Vec<u64>,
        }
        wire_struct!(Demo { a, b, c });
        roundtrip(Demo { a: 7, b: "x".into(), c: vec![1, 2] });
    }

    #[test]
    fn hostile_count_rejected() {
        // A 4-billion-element vec header on a 6-byte buffer must error,
        // not attempt allocation.
        let bytes = [0xff, 0xff, 0xff, 0xff, 0, 0];
        assert!(Vec::<u64>::from_bytes(&bytes).is_err());
    }
}
