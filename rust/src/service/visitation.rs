//! Data-visitation-guarantee verification (§2, §3.3).
//!
//! The paper's central relaxation is trading exactly-once visitation for
//! at-most-once (dynamic sharding under failures) or zero-once-or-more
//! (no sharding). Tests and benches feed every consumed element's source
//! ids into a [`VisitationTracker`] and then assert the guarantee the
//! active sharding policy promises.

use std::collections::HashMap;

/// Which guarantee to check.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Guarantee {
    /// Every sample seen exactly once.
    ExactlyOnce,
    /// No sample seen more than once; misses allowed.
    AtMostOnce,
    /// Anything goes (OFF sharding).
    ZeroOnceOrMore,
}

/// Accumulates observed sample ids for one epoch.
#[derive(Debug, Default)]
pub struct VisitationTracker {
    counts: HashMap<u64, u64>,
    total_observations: u64,
}

/// Verification outcome with enough detail to debug a violation.
#[derive(Debug, Clone, PartialEq)]
pub struct VisitationReport {
    pub guarantee: Guarantee,
    pub ok: bool,
    pub unique_seen: usize,
    pub duplicates: Vec<u64>,
    pub missing: Vec<u64>,
    pub total_observations: u64,
}

impl VisitationTracker {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one element's contributing sample ids.
    pub fn observe(&mut self, ids: &[u64]) {
        for &id in ids {
            *self.counts.entry(id).or_insert(0) += 1;
            self.total_observations += 1;
        }
    }

    pub fn seen(&self, id: u64) -> u64 {
        self.counts.get(&id).copied().unwrap_or(0)
    }

    pub fn unique_seen(&self) -> usize {
        self.counts.len()
    }

    /// Verify `guarantee` against the universe `0..total_samples`.
    pub fn verify(&self, guarantee: Guarantee, total_samples: u64) -> VisitationReport {
        let mut duplicates: Vec<u64> =
            self.counts.iter().filter(|&(_, &c)| c > 1).map(|(&id, _)| id).collect();
        duplicates.sort_unstable();
        let mut missing: Vec<u64> =
            (0..total_samples).filter(|id| !self.counts.contains_key(id)).collect();
        missing.sort_unstable();
        let extraneous = self.counts.keys().any(|&id| id >= total_samples);

        let ok = match guarantee {
            Guarantee::ExactlyOnce => duplicates.is_empty() && missing.is_empty() && !extraneous,
            Guarantee::AtMostOnce => duplicates.is_empty() && !extraneous,
            Guarantee::ZeroOnceOrMore => !extraneous,
        };
        VisitationReport {
            guarantee,
            ok,
            unique_seen: self.counts.len(),
            duplicates,
            missing,
            total_observations: self.total_observations,
        }
    }
}

/// §3.6 round-contract verification: per training round, every consumer
/// must see a batch from the same group (same sequence-length bucket —
/// the "signature"), and each `(consumer, round)` slot is delivered at
/// most once. Tests feed every consumed round here and assert the
/// contract, with an explicit allowance for rounds interrupted by a
/// lease change (the relaxed guarantee: a round materialized twice —
/// once by the previous owner, once by the lease inheritor, whether the
/// change came from an owner crash or a revival re-balance — may hand
/// different groups to consumers that fetched on opposite sides of the
/// change; the window is bounded by one heartbeat interval).
#[derive(Debug, Default)]
pub struct RoundTracker {
    /// round -> (first-seen signature, mismatch flag, consumers seen).
    rounds: HashMap<u64, (u64, bool, Vec<usize>)>,
    duplicate_deliveries: u64,
    /// Highest recovery floor recorded so far (see
    /// [`RoundTracker::set_floor`]).
    floor: u64,
    below_floor_deliveries: u64,
}

/// Verification outcome of [`RoundTracker::report`].
#[derive(Debug, Clone, PartialEq)]
pub struct RoundReport {
    pub rounds_seen: usize,
    /// Rounds where consumers observed different signatures (0 in
    /// failure-free runs; bounded by the in-flight window across an
    /// owner crash).
    pub mismatched_rounds: usize,
    /// (consumer, round) slots delivered more than once (always a
    /// violation — the §3.6 exactly-once-per-slot half).
    pub duplicate_deliveries: u64,
    /// Deliveries observed for rounds below a recorded recovery floor
    /// (always a violation — a consumed round was re-labeled and
    /// re-served after a restart or lease move).
    pub below_floor_deliveries: u64,
}

impl RoundTracker {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a recovery floor (dispatcher restart, lease re-balance):
    /// every consumer had consumed all rounds `< floor` when the event
    /// happened, so a *later* delivery labeled below it means a consumed
    /// round was re-served — the across-restart half of the §3.6
    /// exactly-once-per-slot contract. Monotonic (the highest floor
    /// recorded wins).
    pub fn set_floor(&mut self, floor: u64) {
        self.floor = self.floor.max(floor);
    }

    /// Record that `consumer` received a batch with `signature` (e.g.
    /// its bucket id) for `round`.
    pub fn observe(&mut self, round: u64, consumer: usize, signature: u64) {
        if round < self.floor {
            self.below_floor_deliveries += 1;
        }
        let entry = self.rounds.entry(round).or_insert((signature, false, Vec::new()));
        if entry.0 != signature {
            entry.1 = true;
        }
        if entry.2.contains(&consumer) {
            self.duplicate_deliveries += 1;
        } else {
            entry.2.push(consumer);
        }
    }

    pub fn report(&self) -> RoundReport {
        RoundReport {
            rounds_seen: self.rounds.len(),
            mismatched_rounds: self.rounds.values().filter(|(_, m, _)| *m).count(),
            duplicate_deliveries: self.duplicate_deliveries,
            below_floor_deliveries: self.below_floor_deliveries,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_tracker_checks_same_signature_and_single_delivery() {
        let mut t = RoundTracker::new();
        t.observe(0, 0, 64);
        t.observe(0, 1, 64);
        t.observe(1, 0, 128);
        t.observe(1, 1, 256); // bucket mismatch
        t.observe(1, 1, 256); // duplicate slot delivery
        let r = t.report();
        assert_eq!(r.rounds_seen, 2);
        assert_eq!(r.mismatched_rounds, 1);
        assert_eq!(r.duplicate_deliveries, 1);
        assert_eq!(r.below_floor_deliveries, 0);
    }

    #[test]
    fn round_tracker_floor_flags_resurrected_rounds() {
        let mut t = RoundTracker::new();
        t.observe(0, 0, 1);
        t.observe(1, 0, 1);
        // Restart: everyone had consumed rounds < 2.
        t.set_floor(2);
        t.observe(2, 0, 1); // resumes at the floor: fine
        assert_eq!(t.report().below_floor_deliveries, 0);
        t.observe(1, 0, 1); // a consumed round re-served: violation
        let r = t.report();
        assert_eq!(r.below_floor_deliveries, 1);
        // The floor is monotonic: a lower later floor cannot relax it.
        t.set_floor(1);
        t.observe(1, 1, 1);
        assert_eq!(t.report().below_floor_deliveries, 2);
    }

    #[test]
    fn exactly_once_happy_path() {
        let mut t = VisitationTracker::new();
        t.observe(&[0, 1, 2]);
        t.observe(&[3, 4]);
        let r = t.verify(Guarantee::ExactlyOnce, 5);
        assert!(r.ok, "{r:?}");
        assert_eq!(r.unique_seen, 5);
        assert_eq!(r.total_observations, 5);
    }

    #[test]
    fn exactly_once_detects_miss_and_dup() {
        let mut t = VisitationTracker::new();
        t.observe(&[0, 1, 1, 3]);
        let r = t.verify(Guarantee::ExactlyOnce, 4);
        assert!(!r.ok);
        assert_eq!(r.duplicates, vec![1]);
        assert_eq!(r.missing, vec![2]);
    }

    #[test]
    fn at_most_once_allows_misses_only() {
        let mut t = VisitationTracker::new();
        t.observe(&[0, 2]);
        assert!(t.verify(Guarantee::AtMostOnce, 4).ok);
        t.observe(&[2]);
        let r = t.verify(Guarantee::AtMostOnce, 4);
        assert!(!r.ok);
        assert_eq!(r.duplicates, vec![2]);
    }

    #[test]
    fn zero_once_or_more_allows_everything_in_range() {
        let mut t = VisitationTracker::new();
        t.observe(&[0, 0, 0, 1]);
        assert!(t.verify(Guarantee::ZeroOnceOrMore, 2).ok);
    }

    #[test]
    fn out_of_universe_ids_always_fail() {
        let mut t = VisitationTracker::new();
        t.observe(&[99]);
        assert!(!t.verify(Guarantee::ZeroOnceOrMore, 5).ok);
        assert!(!t.verify(Guarantee::AtMostOnce, 5).ok);
    }

    #[test]
    fn seen_counts() {
        let mut t = VisitationTracker::new();
        t.observe(&[7, 7]);
        assert_eq!(t.seen(7), 2);
        assert_eq!(t.seen(8), 0);
    }
}
