//! Fault tolerance (§3.4): train through sustained worker preemptions and
//! a dispatcher restart, and verify at-most-once visitation end to end.
//!
//! A failure injector kills a worker every ~100 ms and restarts a
//! replacement; the job keeps making progress and never sees a sample
//! twice (dynamic sharding's at-most-once guarantee).
//!
//! Run: `cargo run --release --example fault_tolerance`

use std::sync::Arc;
use std::time::Duration;
use tfdatasvc::data::exec::ElemIter;
use tfdatasvc::data::graph::PipelineBuilder;
use tfdatasvc::data::udf::UdfRegistry;
use tfdatasvc::orchestrator::failure::{FailureConfig, FailureInjector};
use tfdatasvc::orchestrator::Cell;
use tfdatasvc::service::dispatcher::DispatcherConfig;
use tfdatasvc::service::proto::ShardingPolicy;
use tfdatasvc::service::visitation::{Guarantee, VisitationTracker};
use tfdatasvc::service::{ServiceClient, ServiceClientConfig};
use tfdatasvc::storage::dataset::{generate_vision, VisionGenConfig};
use tfdatasvc::storage::ObjectStore;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let store = ObjectStore::in_memory();
    let spec = generate_vision(
        &store,
        "datasets/ft",
        &VisionGenConfig { num_shards: 24, samples_per_shard: 8, ..Default::default() },
    );
    let total = spec.total_samples as u64;

    let cell = Arc::new(Cell::new(
        store,
        UdfRegistry::with_builtins(),
        DispatcherConfig { worker_timeout: Duration::from_millis(500), ..Default::default() },
    )?);
    cell.scale_to(4)?;

    // Kill a worker roughly every other tick; restart replacements.
    let injector = FailureInjector::start(
        cell.clone(),
        FailureConfig {
            kill_probability: 0.5,
            tick: Duration::from_millis(100),
            restart_after: Some(Duration::from_millis(150)),
            seed: 0xf417,
        },
    );

    // Slow preprocessing so failures land mid-stream.
    let graph = PipelineBuilder::source_vision(spec)
        .map("synthetic.burn:2000")
        .batch(4)
        .build();
    let client = ServiceClient::new(&cell.dispatcher_addr());
    let mut it = client.distribute(
        &graph,
        ServiceClientConfig { sharding: ShardingPolicy::Dynamic, ..Default::default() },
    )?;

    let mut tracker = VisitationTracker::new();
    let mut batches = 0;
    while let Some(e) = it.next()? {
        tracker.observe(&e.ids);
        batches += 1;
    }
    injector.stop();
    let kills = injector.kills.load(std::sync::atomic::Ordering::SeqCst);
    let restarts = injector.restarts.load(std::sync::atomic::Ordering::SeqCst);
    println!(
        "consumed {batches} batches under {kills} preemptions / {restarts} restarts"
    );

    let report = tracker.verify(Guarantee::AtMostOnce, total);
    println!(
        "visitation: {} unique of {total} samples seen; duplicates: {}; lost to failures: {}",
        report.unique_seen,
        report.duplicates.len(),
        total as usize - report.unique_seen
    );
    assert!(report.ok, "at-most-once violated: {report:?}");
    assert!(batches > 0, "job made progress despite failures");
    println!("fault_tolerance OK");
    Ok(())
}
