//! Framed TCP RPC: the gRPC stand-in.
//!
//! The paper's deployment uses gRPC over HTTP/2, multiplexing many logical
//! calls on a single TCP connection. We reproduce the architectural
//! properties that matter to the system — one connection per peer pair,
//! call-id multiplexing, deadlines, retries with backoff — on a compact
//! length-prefixed binary framing (see [`frame`]).
//!
//! * [`server::Server`] — accept loop + per-connection reader threads,
//!   handler dispatch by method id, concurrent responses on one socket.
//! * [`client::Client`] — one background reader per connection, blocking
//!   `call()` with deadline, out-of-order response matching by call id.
//! * [`client::Pool`] — connection pool keyed by address with automatic
//!   reconnect and call retries.

pub mod client;
pub mod frame;
pub mod server;

pub use client::{call_typed, Client, Pool};
pub use frame::{Frame, FrameKind, MAX_FRAME_LEN};
pub use server::{Handler, RespBody, Server};

use std::io;
use std::time::Duration;

/// RPC-layer errors. `Remote` carries an application error string returned
/// by the peer handler; everything else is transport-level.
#[derive(Debug)]
pub enum RpcError {
    Connect { addr: String, err: io::Error },
    Io(io::Error),
    Wire(crate::wire::WireError),
    DeadlineExceeded(Duration),
    ConnectionClosed,
    Remote(String),
    FrameTooLarge(usize),
    RetriesExhausted(String),
}

impl std::fmt::Display for RpcError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RpcError::Connect { addr, err } => write!(f, "connect to {addr} failed: {err}"),
            RpcError::Io(e) => write!(f, "io: {e}"),
            RpcError::Wire(e) => write!(f, "wire: {e}"),
            RpcError::DeadlineExceeded(d) => write!(f, "deadline exceeded after {d:?}"),
            RpcError::ConnectionClosed => write!(f, "connection closed"),
            RpcError::Remote(msg) => write!(f, "remote error: {msg}"),
            RpcError::FrameTooLarge(n) => write!(f, "frame too large: {n} bytes"),
            RpcError::RetriesExhausted(msg) => write!(f, "retries exhausted: {msg}"),
        }
    }
}

impl std::error::Error for RpcError {}

impl From<io::Error> for RpcError {
    fn from(e: io::Error) -> RpcError {
        RpcError::Io(e)
    }
}

impl From<crate::wire::WireError> for RpcError {
    fn from(e: crate::wire::WireError) -> RpcError {
        RpcError::Wire(e)
    }
}

pub type RpcResult<T> = Result<T, RpcError>;

impl RpcError {
    /// Transport errors are retryable (the peer may have restarted);
    /// application (`Remote`) errors and deadline expiries are not.
    pub fn is_retryable(&self) -> bool {
        matches!(
            self,
            RpcError::Connect { .. }
                | RpcError::Io(_)
                | RpcError::ConnectionClosed
                | RpcError::FrameTooLarge(_)
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::wire::{Decode, Encode};
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    /// Echo handler: method 1 echoes, method 2 errors, method 3 sleeps.
    fn spawn_echo() -> (Server, String) {
        let srv = Server::bind("127.0.0.1:0", move |method, payload: &[u8]| match method {
            1 => Ok(payload.to_vec().into()),
            2 => Err("boom".to_string()),
            3 => {
                std::thread::sleep(Duration::from_millis(200));
                Ok(RespBody::default())
            }
            m => Err(format!("no such method {m}")),
        })
        .unwrap();
        let addr = srv.local_addr().to_string();
        (srv, addr)
    }

    #[test]
    fn echo_roundtrip() {
        let (_srv, addr) = spawn_echo();
        let client = Client::connect(&addr, Duration::from_secs(2)).unwrap();
        let out = client.call(1, b"hello", Duration::from_secs(2)).unwrap();
        assert_eq!(out, b"hello");
    }

    #[test]
    fn remote_error_propagates() {
        let (_srv, addr) = spawn_echo();
        let client = Client::connect(&addr, Duration::from_secs(2)).unwrap();
        match client.call(2, b"", Duration::from_secs(2)) {
            Err(RpcError::Remote(msg)) => assert_eq!(msg, "boom"),
            other => panic!("expected remote error, got {other:?}"),
        }
    }

    #[test]
    fn deadline_enforced() {
        let (_srv, addr) = spawn_echo();
        let client = Client::connect(&addr, Duration::from_secs(2)).unwrap();
        match client.call(3, b"", Duration::from_millis(30)) {
            Err(RpcError::DeadlineExceeded(_)) => {}
            other => panic!("expected deadline, got {other:?}"),
        }
    }

    #[test]
    fn multiplexed_concurrent_calls() {
        let (_srv, addr) = spawn_echo();
        let client = Arc::new(Client::connect(&addr, Duration::from_secs(2)).unwrap());
        let mut handles = vec![];
        for i in 0..32u32 {
            let c = client.clone();
            handles.push(std::thread::spawn(move || {
                let msg = i.to_le_bytes();
                let out = c.call(1, &msg, Duration::from_secs(5)).unwrap();
                assert_eq!(out, msg);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn slow_call_does_not_block_fast_call() {
        let (_srv, addr) = spawn_echo();
        let client = Arc::new(Client::connect(&addr, Duration::from_secs(2)).unwrap());
        let slow = {
            let c = client.clone();
            std::thread::spawn(move || c.call(3, b"", Duration::from_secs(5)))
        };
        // The fast echo must complete while the slow call is in flight.
        let t0 = std::time::Instant::now();
        client.call(1, b"fast", Duration::from_secs(5)).unwrap();
        assert!(t0.elapsed() < Duration::from_millis(150), "fast call was serialized behind slow one");
        slow.join().unwrap().unwrap();
    }

    #[test]
    fn pool_reconnects_after_server_restart() {
        let (srv, addr) = spawn_echo();
        let pool = Pool::new(Duration::from_millis(500), 5);
        assert_eq!(pool.call(&addr, 1, b"a", Duration::from_secs(2)).unwrap(), b"a");
        let port_addr = addr.clone();
        drop(srv);
        // Restart a fresh server on the same port. Retry binds briefly: the
        // OS may hold the port for a moment.
        let srv2 = loop {
            match Server::bind(&port_addr, |_, p: &[u8]| Ok(p.to_vec().into())) {
                Ok(s) => break s,
                Err(_) => std::thread::sleep(Duration::from_millis(20)),
            }
        };
        let out = pool.call(&addr, 1, b"b", Duration::from_secs(2)).unwrap();
        assert_eq!(out, b"b");
        drop(srv2);
    }

    #[test]
    fn pool_call_counts_connections() {
        let (_srv, addr) = spawn_echo();
        let pool = Pool::new(Duration::from_millis(500), 3);
        for _ in 0..10 {
            pool.call(&addr, 1, b"x", Duration::from_secs(2)).unwrap();
        }
        assert_eq!(pool.connection_count(), 1, "pool must reuse one connection per addr");
    }

    #[test]
    fn typed_rpc_call_helper() {
        #[derive(Debug, PartialEq)]
        struct Ping {
            n: u64,
        }
        crate::wire_struct!(Ping { n });
        let (_srv, addr) = {
            let srv = Server::bind("127.0.0.1:0", |_m, p: &[u8]| {
                let ping = Ping::from_bytes(p).map_err(|e| e.to_string())?;
                Ok(Ping { n: ping.n + 1 }.to_bytes().into())
            })
            .unwrap();
            let a = srv.local_addr().to_string();
            (srv, a)
        };
        let pool = Pool::new(Duration::from_millis(500), 3);
        let out: Ping = call_typed(&pool, &addr, 9, &Ping { n: 41 }, Duration::from_secs(2)).unwrap();
        assert_eq!(out, Ping { n: 42 });
    }

    #[test]
    fn handler_panics_are_contained() {
        let calls = Arc::new(AtomicUsize::new(0));
        let c2 = calls.clone();
        let srv = Server::bind("127.0.0.1:0", move |m, p: &[u8]| {
            c2.fetch_add(1, Ordering::SeqCst);
            if m == 7 {
                panic!("handler bug");
            }
            Ok(p.to_vec().into())
        })
        .unwrap();
        let addr = srv.local_addr().to_string();
        let client = Client::connect(&addr, Duration::from_secs(2)).unwrap();
        // Panic in handler => Remote error, connection survives.
        assert!(matches!(client.call(7, b"", Duration::from_secs(2)), Err(RpcError::Remote(_))));
        assert_eq!(client.call(1, b"ok", Duration::from_secs(2)).unwrap(), b"ok");
        assert_eq!(calls.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn large_payload_roundtrip() {
        let (_srv, addr) = spawn_echo();
        let client = Client::connect(&addr, Duration::from_secs(2)).unwrap();
        let big = vec![0xabu8; 4 << 20]; // 4 MiB batch-sized payload
        let out = client.call(1, &big, Duration::from_secs(10)).unwrap();
        assert_eq!(out.len(), big.len());
        assert_eq!(out, big);
    }
}
