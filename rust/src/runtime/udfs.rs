//! XLA-artifact UDFs: pipeline map functions backed by the AOT-compiled
//! L1/L2 preprocessing graphs.
//!
//! Workers call these via the normal UDF mechanism; the heavy math (fused
//! augmentation Pallas kernel, NLP featurization) runs inside PJRT on the
//! lowered HLO, proving the three-layer composition on the request path.
//!
//! Both UDFs operate on *batched* elements (apply them after `batch`/
//! `padded_batch` with the artifact's batch size):
//!
//! * `xla.preprocess_vision`: `(u8[B,H,W,C] pixels, u32[B] labels)` →
//!   `(f32[B,H,W,C] augmented, u32[B] labels)`. Per-sample augmentation
//!   parameters (flip/brightness/contrast) derive deterministically from
//!   sample ids, so results are reproducible across workers.
//! * `xla.preprocess_nlp`: `(u32[B,L] tokens, u32[B] labels)` →
//!   `(i32[B,S] tokens, f32[B,S] mask, i32[B] lengths, u32[B] labels)`,
//!   padding or cropping `L` to the artifact's fixed `S`.

use super::Engine;
use crate::data::element::{DType, Element, Tensor};
use crate::data::udf::UdfRegistry;
use crate::util::rng::Rng;

/// Register the XLA UDFs against `registry`. Call once per worker after
/// loading the engine.
pub fn register_xla_udfs(registry: &UdfRegistry, engine: &Engine) {
    let m = engine.manifest();
    let (vb, vh, vc) = (m.vision_batch, m.vision_hw, m.vision_c);
    let (nb, ns) = (m.nlp_batch, m.nlp_seq);

    let e = engine.clone();
    registry.register_fn("xla.preprocess_vision", move |elem: Element| {
        let pixels = elem.tensors.first().ok_or("vision: missing pixels tensor")?;
        if pixels.dtype != DType::U8 || pixels.shape != vec![vb, vh, vh, vc] {
            return Err(format!(
                "xla.preprocess_vision wants u8[{vb},{vh},{vh},{vc}], got {}{:?} (batch to {vb} first)",
                pixels.dtype.name(),
                pixels.shape
            ));
        }
        // Deterministic per-sample augmentation params from sample ids.
        let mut flip = Vec::with_capacity(vb);
        let mut brightness = Vec::with_capacity(vb);
        let mut contrast = Vec::with_capacity(vb);
        for i in 0..vb {
            let id = elem.ids.get(i).copied().unwrap_or(i as u64);
            let mut rng = Rng::new(id ^ 0x00c0_ffee);
            flip.push(if rng.chance(0.5) { 1.0 } else { 0.0 });
            brightness.push(rng.uniform(0.8, 1.2) as f32);
            contrast.push(rng.uniform(0.9, 1.1) as f32);
        }
        let out = e
            .execute(
                "preprocess_vision",
                vec![
                    pixels.clone(),
                    Tensor::from_f32(vec![vb], &flip),
                    Tensor::from_f32(vec![vb], &brightness),
                    Tensor::from_f32(vec![vb], &contrast),
                ],
            )
            .map_err(|err| err.to_string())?;
        let mut tensors = out;
        tensors.extend(elem.tensors.into_iter().skip(1)); // carry labels etc.
        Ok(Element { tensors, ids: elem.ids, bucket: elem.bucket })
    });

    let e = engine.clone();
    registry.register_fn("xla.preprocess_nlp", move |elem: Element| {
        let toks = elem.tensors.first().ok_or("nlp: missing tokens tensor")?;
        if toks.dtype != DType::U32 || toks.rank() != 2 || toks.shape[0] != nb {
            return Err(format!(
                "xla.preprocess_nlp wants u32[{nb},*], got {}{:?} (padded_batch to {nb} first)",
                toks.dtype.name(),
                toks.shape
            ));
        }
        // Pad/crop the variable batch length L to the fixed artifact S.
        let l = toks.shape[1];
        let vals = toks.as_u32();
        let mut fixed = vec![0u32; nb * ns];
        for r in 0..nb {
            let n = l.min(ns);
            fixed[r * ns..r * ns + n].copy_from_slice(&vals[r * l..r * l + n]);
        }
        let out = e
            .execute("preprocess_nlp", vec![Tensor::from_u32(vec![nb, ns], &fixed)])
            .map_err(|err| err.to_string())?;
        let mut tensors = out;
        tensors.extend(elem.tensors.into_iter().skip(1));
        Ok(Element { tensors, ids: elem.ids, bucket: elem.bucket })
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::exec::{Executor, ExecutorConfig};
    use crate::data::graph::PipelineBuilder;
    use crate::storage::dataset::{generate_text, generate_vision, TextGenConfig, VisionGenConfig};
    use crate::storage::ObjectStore;

    fn engine() -> Option<Engine> {
        let dir = super::super::default_artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return None;
        }
        Some(Engine::load(dir).unwrap())
    }

    #[test]
    fn vision_pipeline_through_xla() {
        let Some(e) = engine() else { return };
        let m = e.manifest();
        let store = ObjectStore::in_memory();
        let spec = generate_vision(
            &store,
            "v",
            &VisionGenConfig {
                num_shards: 2,
                samples_per_shard: m.vision_batch,
                height: m.vision_hw as u32,
                width: m.vision_hw as u32,
                channels: m.vision_c as u32,
                ..Default::default()
            },
        );
        let udfs = UdfRegistry::with_builtins();
        register_xla_udfs(&udfs, &e);
        let n = spec.num_shards();
        let ex = Executor::new(ExecutorConfig::local(store, udfs, n));
        let g = PipelineBuilder::source_vision(spec)
            .batch(m.vision_batch as u32)
            .map("xla.preprocess_vision")
            .build();
        let out = ex.collect(&g).unwrap();
        assert_eq!(out.len(), 2);
        let b = &out[0];
        assert_eq!(b.tensors[0].dtype, DType::F32);
        assert_eq!(b.tensors[0].shape, vec![m.vision_batch, m.vision_hw, m.vision_hw, m.vision_c]);
        // labels preserved as the trailing tensor
        assert_eq!(b.tensors.last().unwrap().shape, vec![m.vision_batch]);
        assert_eq!(b.ids.len(), m.vision_batch);
    }

    #[test]
    fn vision_xla_is_deterministic() {
        let Some(e) = engine() else { return };
        let m = e.manifest();
        let udfs = UdfRegistry::with_builtins();
        register_xla_udfs(&udfs, &e);
        let f = udfs.resolve("xla.preprocess_vision").unwrap();
        let (b, h, c) = (m.vision_batch, m.vision_hw, m.vision_c);
        let elem = Element::with_ids(
            vec![Tensor::from_u8(vec![b, h, h, c], vec![100; b * h * h * c])],
            (0..b as u64).collect(),
        );
        let a = f.call(elem.clone()).unwrap();
        let bb = f.call(elem).unwrap();
        assert_eq!(a, bb);
    }

    #[test]
    fn nlp_pipeline_through_xla() {
        let Some(e) = engine() else { return };
        let m = e.manifest();
        let store = ObjectStore::in_memory();
        let spec = generate_text(
            &store,
            "t",
            &TextGenConfig {
                num_shards: 1,
                samples_per_shard: m.nlp_batch * 2,
                max_len: 200,
                ..Default::default()
            },
        );
        let udfs = UdfRegistry::with_builtins();
        register_xla_udfs(&udfs, &e);
        let ex = Executor::new(ExecutorConfig::local(store, udfs, 1));
        let g = PipelineBuilder::source_text(spec)
            .padded_batch(m.nlp_batch as u32)
            .map("xla.preprocess_nlp")
            .build();
        let out = ex.collect(&g).unwrap();
        assert_eq!(out.len(), 2);
        let b = &out[0];
        assert_eq!(b.tensors[0].shape, vec![m.nlp_batch, m.nlp_seq]);
        assert_eq!(b.tensors[0].dtype, DType::I32);
        assert_eq!(b.tensors[1].shape, vec![m.nlp_batch, m.nlp_seq]); // mask
        assert_eq!(b.tensors[2].shape, vec![m.nlp_batch]); // lengths
    }

    #[test]
    fn xla_udf_rejects_unbatched_input() {
        let Some(e) = engine() else { return };
        let udfs = UdfRegistry::with_builtins();
        register_xla_udfs(&udfs, &e);
        let f = udfs.resolve("xla.preprocess_vision").unwrap();
        let elem = Element::new(vec![Tensor::from_u8(vec![2, 2, 1], vec![0; 4])]);
        assert!(f.call(elem).is_err());
    }
}
