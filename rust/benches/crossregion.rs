//! §4.2 "Cross-region Scenario": M3 with source data stored on another
//! continent. Paper: colocated preprocessing becomes 13.3x slower than
//! ideal (vs 2.9x in-region); the service reaches ideal anyway by using
//! extra workers to hide fetch latency.
//!
//! Runs both the calibrated DES and a *live* measurement on the real
//! storage layer's region model.

use std::sync::Arc;
use tfdatasvc::data::exec::{AllSplits, ElemIter, Executor, ExecutorConfig};
use tfdatasvc::data::graph::PipelineBuilder;
use tfdatasvc::data::udf::UdfRegistry;
use tfdatasvc::sim::des::{simulate_job, JobSimConfig};
use tfdatasvc::sim::models::model;
use tfdatasvc::storage::dataset::{generate_vision, VisionGenConfig};
use tfdatasvc::storage::{NetModel, ObjectStore, Region};

fn main() {
    // ---- DES: the paper's numbers ----
    let m = model("M3");
    let io = 13.3 / m.ideal_bps; // calibrated per-batch cross-region I/O
    let in_region = simulate_job(m, &JobSimConfig::default());
    let out_region_colo = simulate_job(m, &JobSimConfig { io_time_per_batch: io, ..Default::default() });
    let out_region_dis = simulate_job(
        m,
        &JobSimConfig { n_workers: 1024, io_time_per_batch: io, ..Default::default() },
    );
    println!("=== Cross-region scenario (M3, ideal {:.1} b/s) ===", m.ideal_bps);
    println!("colocated in-region:   {:>7.2} b/s ({:.1}x below ideal; paper 2.9x)", in_region.throughput_bps, m.ideal_bps / in_region.throughput_bps);
    println!("colocated out-region:  {:>7.2} b/s ({:.1}x below ideal; paper 13.3x)", out_region_colo.throughput_bps, m.ideal_bps / out_region_colo.throughput_bps);
    println!("service out-region:    {:>7.2} b/s ({:.0}% of ideal; paper: reaches ideal)", out_region_dis.throughput_bps, 100.0 * out_region_dis.throughput_bps / m.ideal_bps);
    assert!(m.ideal_bps / out_region_colo.throughput_bps > 8.0);
    assert!(out_region_dis.throughput_bps > 0.9 * m.ideal_bps);

    // ---- Live: real pipeline over the region-modeled object store ----
    let us = Region::new("us-central1");
    let eu = Region::new("europe-west4");
    let net = NetModel {
        cross_region_latency: std::time::Duration::from_millis(25), // scaled-down RTT so the bench stays fast
        inject_delays: true,
        ..Default::default()
    };
    let store = ObjectStore::new(us.clone(), net);
    let spec = generate_vision(
        &store,
        "ds",
        &VisionGenConfig { num_shards: 16, samples_per_shard: 8, ..Default::default() },
    );
    let graph = PipelineBuilder::source_vision(spec.clone()).batch(8).build();

    let mut time_from = |reader: Region, shards: usize| {
        let cfg = ExecutorConfig {
            store: store.clone(),
            udfs: UdfRegistry::with_builtins(),
            region: reader,
            splits: AllSplits::new(shards),
            autotune: Arc::new(tfdatasvc::data::autotune::AutotuneState::default()),
        };
        let ex = Executor::new(cfg);
        let t0 = std::time::Instant::now();
        let mut it = ex.iterate(&graph).unwrap();
        let mut n = 0;
        while let Ok(Some(_)) = it.next() {
            n += 1;
        }
        (t0.elapsed(), n)
    };
    let (t_near, n1) = time_from(us, spec.num_shards());
    let (t_far, n2) = time_from(eu, spec.num_shards());
    assert_eq!(n1, n2);
    println!(
        "\nlive storage model: in-region read {:?}, cross-region {:?} ({:.1}x slower per reader)",
        t_near,
        t_far,
        t_far.as_secs_f64() / t_near.as_secs_f64()
    );
    assert!(t_far > t_near * 3, "cross-region reads must be much slower per reader");
    println!("crossregion OK");
}
