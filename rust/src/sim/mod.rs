//! Fleet-scale evaluation substrate.
//!
//! The paper's evaluation runs production models (M1–M8) on TPU-v4 pods
//! against a multi-tenant worker fleet — hardware we do not have. Per the
//! substitution rule (DESIGN.md §2) we rebuild the evaluation as a
//! calibrated simulation:
//!
//! * [`models`] — the model zoo: per-model resource profiles pinned to
//!   every observable the paper reports (baseline/ideal batches/s, worker
//!   counts, speedups).
//! * [`des`] — a discrete-event simulator of one training job: workers
//!   produce batches (CPU + storage I/O + RPC overhead), clients consume
//!   at accelerator speed through a bounded buffer; reports throughput,
//!   stall fractions, and utilization.
//! * [`coord`] — the coordinated-reads straggler model (§4.4): padded-
//!   batch step times with and without same-bucket rounds.
//! * [`sharing`] — the ephemeral-sharing cost model (§4.3, Fig. 10).
//! * [`fleet`] — heavy-tailed fleet generators for Fig. 1 and Fig. 12.
//! * [`cost`] — Equation (1) verbatim, with the paper's public prices.
//!
//! The claim reproduced is the *shape* — who wins and by roughly what
//! factor — not the authors' absolute numbers.

pub mod coord;
pub mod cost;
pub mod des;
pub mod fleet;
pub mod models;
pub mod sharing;

pub use cost::{CostModel, JobCost};
pub use des::{simulate_job, JobSimConfig, JobSimResult};
pub use models::{Domain, ModelSpec, MODEL_ZOO};
