//! Data-plane throughput: legacy single-element `GetElement`, legacy
//! batched `GetElements`, and the stream-session `Fetch` plane with
//! static vs AIMD-adaptive batch sizing, on the shapes that bracket the
//! design space:
//!
//! * small elements (~100 B on the wire): per-RPC overhead dominates,
//!   which is exactly what batching (and adaptive growth) amortizes;
//! * large elements (~196 KiB): byte throughput dominates, batching
//!   should at least not hurt and adaptive should widen the per-RPC
//!   byte budget;
//! * chunked shape: elements larger than a deliberately small negotiated
//!   frame budget stream as continuation frames — the oversized-element
//!   path must be lossless and serviceable, not fast.
//!
//! Acceptance targets (full mode): legacy batched >= 2x single-element
//! throughput and >= 8x fewer RPCs per element on the small shape;
//! adaptive >= static throughput (with a small noise allowance) on both
//! shapes. `--smoke` shrinks the datasets and relaxes thresholds for CI.

use std::sync::Arc;
use std::time::Instant;
use tfdatasvc::data::exec::ElemIter;
use tfdatasvc::data::graph::{GraphDef, PipelineBuilder};
use tfdatasvc::data::udf::UdfRegistry;
use tfdatasvc::metrics::write_json_file;
use tfdatasvc::orchestrator::Cell;
use tfdatasvc::service::dispatcher::DispatcherConfig;
use tfdatasvc::service::proto::{CompressionMode, ShardingPolicy};
use tfdatasvc::service::{ServiceClient, ServiceClientConfig};
use tfdatasvc::storage::dataset::{generate_vision, VisionGenConfig};
use tfdatasvc::storage::ObjectStore;
use tfdatasvc::util::json::{obj, Json};

#[derive(Clone, Copy, PartialEq)]
enum Path {
    /// Legacy one-element-per-RPC plane (no handshake).
    Single,
    /// Legacy batched GetElements plane (no handshake).
    Batched,
    /// Stream sessions with the static config budgets.
    SessionStatic,
    /// Stream sessions with the AIMD loop on.
    SessionAdaptive,
}

impl Path {
    fn name(self) -> &'static str {
        match self {
            Path::Single => "single",
            Path::Batched => "batched",
            Path::SessionStatic => "static",
            Path::SessionAdaptive => "adaptive",
        }
    }

    fn cfg(self) -> ServiceClientConfig {
        let base = ServiceClientConfig { sharding: ShardingPolicy::Off, ..Default::default() };
        match self {
            Path::Single => ServiceClientConfig {
                batching: false,
                stream_sessions: false,
                adaptive_batching: false,
                ..base
            },
            Path::Batched => ServiceClientConfig {
                batching: true,
                stream_sessions: false,
                adaptive_batching: false,
                ..base
            },
            Path::SessionStatic => {
                ServiceClientConfig { stream_sessions: true, adaptive_batching: false, ..base }
            }
            Path::SessionAdaptive => {
                ServiceClientConfig { stream_sessions: true, adaptive_batching: true, ..base }
            }
        }
    }
}

struct RunStats {
    elements: u64,
    secs: f64,
    rpcs: u64,
    bytes: u64,
}

fn run(cell: &Cell, graph: &GraphDef, path: Path) -> RunStats {
    let client = ServiceClient::new(&cell.dispatcher_addr());
    let mut it = client.distribute(graph, path.cfg()).unwrap();
    let t0 = Instant::now();
    let mut elements = 0u64;
    while let Ok(Some(_)) = it.next() {
        elements += 1;
    }
    let secs = t0.elapsed().as_secs_f64();
    it.release();
    RunStats {
        elements,
        secs,
        rpcs: client.metrics().counter("client/rpcs").get(),
        bytes: client.metrics().counter("client/bytes_fetched").get(),
    }
}

/// Best of `n` runs (throughput benchmarks on shared CI boxes are noisy;
/// the best run is the least-perturbed measurement of the same code).
fn run_best(cell: &Cell, graph: &GraphDef, path: Path, n: usize) -> RunStats {
    let mut best: Option<RunStats> = None;
    for _ in 0..n {
        let s = run(cell, graph, path);
        if best.as_ref().map(|b| s.secs < b.secs).unwrap_or(true) {
            best = Some(s);
        }
    }
    best.unwrap()
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let store = ObjectStore::in_memory();
    let cell = Arc::new(
        Cell::new(store.clone(), UdfRegistry::with_builtins(), DispatcherConfig::default())
            .unwrap(),
    );
    // Deep worker buffers so the data plane, not production, is measured.
    cell.set_worker_config_mutator(|c| {
        c.buffer_size = 256;
        c.cache_window = 8192;
        c.cache_window_bytes = 256 << 20;
    });
    cell.scale_to(1).unwrap();

    // Small shape: 8 range rows per element, ~100 B on the wire.
    let small_rows = if smoke { 4096 } else { 32768 };
    let small = PipelineBuilder::source_range(small_rows).batch(8).build();
    // Large shape: 16-image vision batches, ~196 KiB on the wire.
    let (shards, samples) = if smoke { (2, 128) } else { (4, 256) };
    let spec = generate_vision(
        &store,
        "bench",
        &VisionGenConfig { num_shards: shards, samples_per_shard: samples, ..Default::default() },
    );
    let large = PipelineBuilder::source_vision(spec).batch(16).build();
    let reps = if smoke { 1 } else { 2 };

    println!("=== getelements_throughput (1 worker, loopback{}) ===", if smoke { ", smoke" } else { "" });
    println!(
        "{:<18} {:>10} {:>12} {:>8} {:>12}",
        "shape/path", "elements", "elements/s", "rpcs", "rpcs/element"
    );
    // Machine-readable results (out/bench_getelements_throughput.json):
    // per shape/path throughput + RPC amortization, for cross-PR
    // trajectory tracking.
    let mut json_shapes: Vec<(String, Json)> = Vec::new();
    for (name, graph) in [("small", &small), ("large", &large)] {
        let mut stats = Vec::new();
        for path in [Path::Single, Path::Batched, Path::SessionStatic, Path::SessionAdaptive] {
            let s = run_best(&cell, graph, path, reps);
            println!(
                "{:<18} {:>10} {:>12.0} {:>8} {:>12.3}",
                format!("{name}/{}", path.name()),
                s.elements,
                s.elements as f64 / s.secs,
                s.rpcs,
                s.rpcs as f64 / s.elements as f64
            );
            stats.push((path, s));
        }
        let get = |p: Path| stats.iter().find(|(q, _)| *q == p).map(|(_, s)| s).unwrap();
        let (single, batched) = (get(Path::Single), get(Path::Batched));
        let (stat, adap) = (get(Path::SessionStatic), get(Path::SessionAdaptive));
        assert!(
            stats.iter().all(|(_, s)| s.elements == single.elements),
            "all paths must deliver the same stream"
        );

        let speedup = single.secs / batched.secs;
        let rpc_drop = (single.rpcs as f64 / single.elements as f64)
            / (batched.rpcs as f64 / batched.elements as f64);
        let adaptive_ratio = stat.secs / adap.secs;
        // Sustained bytes/sec gate: the best amortizing path (batched or
        // either session flavor) against the one-element-per-RPC
        // pre-change baseline.
        let single_bps = single.bytes as f64 / single.secs;
        let best_bps = [batched, stat, adap]
            .iter()
            .map(|s| s.bytes as f64 / s.secs)
            .fold(0.0f64, f64::max);
        let bytes_speedup = best_bps / single_bps;
        json_shapes.push((
            name.to_string(),
            Json::Obj(
                stats
                    .iter()
                    .map(|(p, s)| {
                        (
                            p.name().to_string(),
                            obj([
                                ("elements_per_sec", (s.elements as f64 / s.secs).into()),
                                ("rpcs", s.rpcs.into()),
                                ("rpcs_per_element", (s.rpcs as f64 / s.elements as f64).into()),
                                ("bytes", s.bytes.into()),
                            ]),
                        )
                    })
                    .chain([
                        ("batched_speedup".to_string(), speedup.into()),
                        ("rpc_drop".to_string(), rpc_drop.into()),
                        ("adaptive_ratio".to_string(), adaptive_ratio.into()),
                        ("bytes_speedup".to_string(), bytes_speedup.into()),
                        ("best_bytes_per_sec".to_string(), best_bps.into()),
                    ])
                    .collect(),
            ),
        ));
        println!(
            "{name}: batched speedup {speedup:.2}x, rpc drop {rpc_drop:.1}x, adaptive/static \
             throughput {adaptive_ratio:.2}x (rpcs {} -> {}), bytes {} -> {}",
            stat.rpcs, adap.rpcs, single.bytes, batched.bytes
        );
        if name == "small" {
            // Acceptance (raw-speed data plane): sustained bytes/sec on
            // small elements must be >= 2x the single-element baseline,
            // in smoke mode too — this is the per-worker serve-rate
            // denominator of the paper's §5 cost claims, so it gets a
            // hard gate rather than a relaxed smoke floor.
            assert!(
                bytes_speedup >= 2.0,
                "acceptance: best data-plane path must sustain >= 2x single-element bytes/sec \
                 on small elements (got {bytes_speedup:.2}x, {best_bps:.0} vs {single_bps:.0} B/s)"
            );
            let (min_speedup, min_drop) = if smoke { (1.5, 4.0) } else { (2.0, 8.0) };
            assert!(
                speedup >= min_speedup,
                "acceptance: batched must sustain >= {min_speedup}x element throughput on \
                 small elements (got {speedup:.2}x)"
            );
            assert!(
                rpc_drop >= min_drop,
                "acceptance: client/rpcs per element must drop >= {min_drop}x (got {rpc_drop:.1}x)"
            );
            // Adaptive growth is structural on the small shape: the AIMD
            // loop must issue measurably fewer RPCs than static config.
            // (Full mode only: the smoke epoch is short enough that the
            // ramp never amortizes a full 2x.)
            if !smoke {
                assert!(
                    adap.rpcs * 2 <= stat.rpcs,
                    "adaptive batching must amortize RPCs beyond static config ({} vs {})",
                    adap.rpcs,
                    stat.rpcs
                );
            } else {
                assert!(
                    adap.rpcs < stat.rpcs,
                    "adaptive batching must issue fewer RPCs than static config ({} vs {})",
                    adap.rpcs,
                    stat.rpcs
                );
            }
        }
        // Acceptance: adaptive >= static throughput on both shapes. The
        // allowance absorbs run-to-run noise on shared machines; the
        // RPC-count assertion above pins the mechanism itself.
        let min_ratio = if smoke { 0.85 } else { 0.95 };
        assert!(
            adaptive_ratio >= min_ratio,
            "acceptance: adaptive batching must not lose to static config on the {name} \
             shape (got {adaptive_ratio:.2}x)"
        );
    }

    // Chunked-transfer shape: ~1.5 MiB elements against a 128 KiB
    // negotiated frame budget stream as continuation frames. Lossless
    // delivery is the acceptance bar; throughput is printed for tracking.
    let chunk_samples = if smoke { 128usize } else { 256 };
    let spec = generate_vision(
        &store,
        "bench-chunk",
        &VisionGenConfig {
            num_shards: 2,
            samples_per_shard: chunk_samples / 2,
            ..Default::default()
        },
    );
    let chunky = PipelineBuilder::source_vision(spec).batch(128).build();
    let expected = (chunk_samples / 128) as u64;
    let client = ServiceClient::new(&cell.dispatcher_addr());
    let mut it = client
        .distribute(
            &chunky,
            ServiceClientConfig {
                sharding: ShardingPolicy::Off,
                max_frame_len: 128 << 10,
                ..Default::default()
            },
        )
        .unwrap();
    let t0 = Instant::now();
    let mut n = 0u64;
    while let Ok(Some(e)) = it.next() {
        assert_eq!(e.ids.len(), 128);
        n += 1;
    }
    let secs = t0.elapsed().as_secs_f64();
    it.release();
    let frames = client.metrics().counter("client/chunk_frames").get();
    let chunked = client.metrics().counter("client/chunked_elements_fetched").get();
    println!(
        "chunked: {n} oversized elements in {secs:.2}s ({:.1} MiB/s), {frames} continuation \
         frames, {chunked} reassembled",
        (client.metrics().counter("client/bytes_fetched").get() as f64 / (1 << 20) as f64) / secs
    );
    assert_eq!(n, expected, "every oversized element delivered");
    assert_eq!(chunked, n, "all elements travelled chunked");
    assert!(frames >= n * 2, "each element needed several continuation frames");

    json_shapes.push((
        "chunked".to_string(),
        obj([
            ("elements", n.into()),
            ("mib_per_sec", {
                let mib =
                    client.metrics().counter("client/bytes_fetched").get() as f64 / (1 << 20) as f64;
                (mib / secs).into()
            }),
            ("continuation_frames", frames.into()),
        ]),
    ));

    // Mixed-class codec shape: compressible small frames (range rows are
    // zero-heavy little-endian integers) and incompressible large frames
    // (random vision pixels) through the same worker with compression
    // requested. The worker's observed-ratio chooser must settle per
    // size class — LZ for the range frames (`compression_bytes_saved`
    // grows) and Skip for the vision frames (`codec_skips` grows) —
    // while delivery stays lossless on both.
    let mix_rows = if smoke { 2048u64 } else { 8192 };
    let mix_range = PipelineBuilder::source_range(mix_rows).batch(8).build();
    let (mix_shards, mix_samples) = if smoke { (2usize, 256usize) } else { (2, 512) };
    let mix_spec = generate_vision(
        &store,
        "bench-mixed",
        &VisionGenConfig {
            num_shards: mix_shards,
            samples_per_shard: mix_samples,
            ..Default::default()
        },
    );
    let mix_vision = PipelineBuilder::source_vision(mix_spec).batch(4).build();
    let skips0 = cell.worker_counter_sum("worker/codec_skips");
    let saved0 = cell.worker_counter_sum("worker/compression_bytes_saved");
    let mut delivered = 0u64;
    let t0 = Instant::now();
    for graph in [&mix_range, &mix_vision] {
        let client = ServiceClient::new(&cell.dispatcher_addr());
        let mut it = client
            .distribute(
                graph,
                ServiceClientConfig {
                    sharding: ShardingPolicy::Off,
                    compression: CompressionMode::Deflate,
                    adaptive_batching: false,
                    batch_max_elements: 4,
                    ..Default::default()
                },
            )
            .unwrap();
        while let Ok(Some(_)) = it.next() {
            delivered += 1;
        }
        it.release();
    }
    let mix_secs = t0.elapsed().as_secs_f64();
    let expected_mix = mix_rows / 8 + (mix_shards * mix_samples / 4) as u64;
    assert_eq!(
        delivered, expected_mix,
        "mixed-class shape must deliver losslessly under the adaptive codec"
    );
    let codec_skips = cell.worker_counter_sum("worker/codec_skips") - skips0;
    let lz_saved = cell.worker_counter_sum("worker/compression_bytes_saved") - saved0;
    println!(
        "mixed: {delivered} elements in {mix_secs:.2}s, codec skip plans {codec_skips}, \
         LZ bytes saved {lz_saved}"
    );
    assert!(
        lz_saved > 0,
        "compressible range frames must settle on LZ (no compression savings observed)"
    );
    assert!(
        codec_skips > 0,
        "incompressible vision frames must settle on Skip (no skip plans observed)"
    );
    json_shapes.push((
        "mixed".to_string(),
        obj([
            ("elements", delivered.into()),
            ("elements_per_sec", (delivered as f64 / mix_secs).into()),
            ("codec_skips", codec_skips.into()),
            ("lz_bytes_saved", lz_saved.into()),
        ]),
    ));
    let bench_json = obj([
        ("bench", "getelements_throughput".into()),
        ("smoke", smoke.into()),
        ("shapes", Json::Obj(json_shapes.into_iter().collect())),
    ]);
    write_json_file("out/bench_getelements_throughput.json", &bench_json).unwrap();
    // Repo-root mirror under the stable name the roadmap tracks (CI
    // regenerates it every run; the checked-in copy is the latest
    // accepted baseline).
    write_json_file("BENCH_getelements.json", &bench_json).unwrap();
    println!("getelements_throughput OK -> out/bench_getelements_throughput.json + BENCH_getelements.json");
}
