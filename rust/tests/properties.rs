//! Property-based tests (hand-rolled: proptest is not vendored).
//!
//! Each property runs many randomized trials from a seeded RNG, so
//! failures are reproducible. Invariants covered: wire-format roundtrips
//! for arbitrary values, pipeline semantics against a reference
//! interpreter, split-tracker disjointness/at-most-once under random
//! worker churn, coordinated-round ownership, and optimizer semantic
//! equivalence.

use tfdatasvc::data::element::{DType, Element, Tensor};
use tfdatasvc::data::exec::{ElemIter, Executor, ExecutorConfig};
use tfdatasvc::data::graph::{GraphDef, Node, PipelineBuilder};
use tfdatasvc::data::optimize::{optimize, OptimizeOptions};
use tfdatasvc::data::udf::UdfRegistry;
use tfdatasvc::service::sharding::{static_assignment, SplitTracker};
use tfdatasvc::storage::ObjectStore;
use tfdatasvc::util::rng::Rng;
use tfdatasvc::wire::{Decode, Encode};

const TRIALS: usize = 200;

fn rand_tensor(rng: &mut Rng) -> Tensor {
    let rank = rng.below(3) as usize;
    let shape: Vec<usize> = (0..rank).map(|_| rng.below(5) as usize + 1).collect();
    let n: usize = shape.iter().product();
    match rng.below(4) {
        0 => Tensor::from_f32(shape, &(0..n).map(|i| i as f32 * 0.5).collect::<Vec<_>>()),
        1 => Tensor::from_i32(shape, &(0..n).map(|i| i as i32 - 3).collect::<Vec<_>>()),
        2 => Tensor::from_u32(shape, &(0..n).map(|i| i as u32).collect::<Vec<_>>()),
        _ => Tensor::from_u8(shape, (0..n).map(|i| i as u8).collect()),
    }
}

fn rand_element(rng: &mut Rng) -> Element {
    let arity = rng.below(3) as usize + 1;
    let tensors = (0..arity).map(|_| rand_tensor(rng)).collect();
    let ids = (0..rng.below(4)).map(|_| rng.next_u64() % 1000).collect();
    let mut e = Element::with_ids(tensors, ids);
    if rng.chance(0.3) {
        e.bucket = Some(rng.next_u32() % 8);
    }
    e
}

#[test]
fn prop_element_wire_roundtrip() {
    let mut rng = Rng::new(0x9_0001);
    for _ in 0..TRIALS {
        let e = rand_element(&mut rng);
        let back = Element::from_bytes(&e.to_bytes()).expect("decode");
        assert_eq!(e, back);
    }
}

fn rand_graph(rng: &mut Rng) -> GraphDef {
    let n = rng.below(200) + 1;
    let mut b = PipelineBuilder::source_range(n);
    // At most one (terminal-ish) batch node: re-batching a ragged partial
    // batch is a shape error in tf.data too.
    let mut batched = false;
    for _ in 0..rng.below(5) {
        b = match rng.below(6) {
            0 if !batched => b.take(rng.below(2 * n) + 1),
            1 if !batched => b.skip(rng.below(n)),
            2 if !batched => b.shuffle(rng.next_u32() % 32 + 2, rng.next_u64()),
            3 if !batched => {
                batched = true;
                b.batch_partial(rng.next_u32() % 7 + 1)
            }
            4 if !batched => b.repeat(rng.next_u32() % 3 + 1),
            _ => b.map("identity"),
        };
    }
    b.build()
}

/// Reference interpreter over plain vectors for the operator subset used
/// by `rand_graph`.
fn reference_eval(graph: &GraphDef) -> Vec<Vec<i32>> {
    // Element stream as Vec<i32> values; batches become multi-value rows.
    let mut stream: Vec<Vec<i32>> = Vec::new();
    fn eval(nodes: &[Node], rng_seed_stack: &mut Vec<u64>) -> Vec<Vec<i32>> {
        let mut cur: Vec<Vec<i32>> = Vec::new();
        for node in nodes {
            match node {
                Node::SourceRange { n } => {
                    cur = (0..*n as i32).map(|v| vec![v]).collect();
                }
                Node::Take { n } => cur.truncate(*n as usize),
                Node::Skip { n } => {
                    cur.drain(..(*n as usize).min(cur.len()));
                }
                Node::Shuffle { buffer, seed } => {
                    // Mirror the executor's sliding-buffer shuffle.
                    cur = shuffle_ref(&cur, *buffer as usize, *seed);
                    rng_seed_stack.push(*seed);
                }
                Node::Batch { size, .. } => {
                    let mut out = Vec::new();
                    for chunk in cur.chunks(*size as usize) {
                        out.push(chunk.iter().flatten().copied().collect());
                    }
                    cur = out;
                }
                Node::Repeat { n } => {
                    let prefix_out = cur.clone();
                    let mut all = Vec::new();
                    for _ in 0..*n {
                        all.extend(prefix_out.clone());
                    }
                    cur = all;
                }
                Node::Map { .. } => {} // identity only
                _ => unreachable!("rand_graph subset"),
            }
        }
        cur
    }
    fn shuffle_ref(items: &[Vec<i32>], cap: usize, seed: u64) -> Vec<Vec<i32>> {
        let cap = cap.max(1);
        let mut rng = Rng::new(seed);
        let mut buf: Vec<Vec<i32>> = Vec::new();
        let mut out = Vec::new();
        let mut it = items.iter().cloned();
        for _ in 0..cap {
            match it.next() {
                Some(v) => buf.push(v),
                None => break,
            }
        }
        if buf.is_empty() {
            return out;
        }
        loop {
            if buf.is_empty() {
                break;
            }
            let idx = rng.below_usize(buf.len());
            match it.next() {
                Some(mut v) => {
                    std::mem::swap(&mut buf[idx], &mut v);
                    out.push(v);
                }
                None => out.push(buf.swap_remove(idx)),
            }
        }
        out
    }
    let mut stack = Vec::new();
    stream.extend(eval(&graph.nodes, &mut stack));
    stream
}

#[test]
fn prop_pipeline_matches_reference_interpreter() {
    let mut rng = Rng::new(0x9_0002);
    let ex = Executor::new(ExecutorConfig::local(
        ObjectStore::in_memory(),
        UdfRegistry::with_builtins(),
        0,
    ));
    for trial in 0..TRIALS {
        let g = rand_graph(&mut rng);
        let got: Vec<Vec<i32>> = ex
            .collect(&g)
            .unwrap_or_else(|e| panic!("trial {trial}: exec failed on {g:?}: {e}"))
            .iter()
            .map(|e| {
                e.tensors[0]
                    .as_i32()
            })
            .collect();
        let want = reference_eval(&g);
        assert_eq!(got, want, "trial {trial}: graph {g:?}");
    }
}

#[test]
fn prop_split_tracker_disjoint_under_churn() {
    let mut rng = Rng::new(0x9_0003);
    for trial in 0..TRIALS {
        let num_shards = rng.below(64) as usize + 1;
        let num_workers = rng.below(8) + 1;
        let t = SplitTracker::new(num_shards, rng.next_u64());
        let mut seen = std::collections::HashSet::new();
        let mut lost_total = 0usize;
        let mut alive: Vec<u64> = (0..num_workers).collect();
        loop {
            if alive.is_empty() {
                break;
            }
            // Random worker pulls; occasionally a worker dies.
            let w = *rng.choice(&alive);
            match t.next_split(w) {
                Some(s) => {
                    assert!(seen.insert(s), "trial {trial}: split {s} handed out twice");
                }
                None => break,
            }
            if rng.chance(0.05) && alive.len() > 1 {
                let dead = alive.swap_remove(rng.below_usize(alive.len()));
                lost_total += t.worker_failed(dead).len();
            }
        }
        // at-most-once accounting: everything handed out is either
        // completed, lost, or still assigned to a live worker.
        let completed = t.completed().len();
        let lost = t.lost().len();
        assert_eq!(lost, lost_total);
        assert!(completed + lost <= num_shards);
        assert!(seen.len() <= num_shards);
    }
}

#[test]
fn prop_static_assignment_partitions_and_balances() {
    let mut rng = Rng::new(0x9_0004);
    for _ in 0..TRIALS {
        let shards = rng.below(100) as usize;
        let workers = rng.below(10) as usize + 1;
        let a = static_assignment(shards, workers);
        assert_eq!(a.len(), workers);
        let mut all: Vec<u64> = a.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..shards as u64).collect::<Vec<_>>(), "partition exact");
        let lens: Vec<usize> = a.iter().map(|v| v.len()).collect();
        assert!(lens.iter().max().unwrap() - lens.iter().min().unwrap() <= 1, "balanced");
    }
}

#[test]
fn prop_round_ownership_is_a_partition() {
    // Every round is owned by exactly one worker index.
    let mut rng = Rng::new(0x9_0005);
    for _ in 0..TRIALS {
        let num_workers = rng.below(12) + 1;
        for round in 0..64u64 {
            let owners: Vec<u64> =
                (0..num_workers).filter(|w| round % num_workers == *w).collect();
            assert_eq!(owners.len(), 1, "round {round} owners {owners:?}");
        }
    }
}

#[test]
fn prop_optimizer_preserves_semantics() {
    let mut rng = Rng::new(0x9_0006);
    let ex = Executor::new(ExecutorConfig::local(
        ObjectStore::in_memory(),
        UdfRegistry::with_builtins(),
        0,
    ));
    for trial in 0..TRIALS {
        let g = rand_graph(&mut rng);
        let o = optimize(&g, &OptimizeOptions::default());
        let a: Vec<Vec<i32>> = ex.collect(&g).unwrap().iter().map(|e| e.tensors[0].as_i32()).collect();
        let b: Vec<Vec<i32>> = ex.collect(&o).unwrap().iter().map(|e| e.tensors[0].as_i32()).collect();
        assert_eq!(a, b, "trial {trial}: optimize changed semantics of {g:?}");
    }
}

#[test]
fn prop_graph_wire_roundtrip_random() {
    let mut rng = Rng::new(0x9_0007);
    for _ in 0..TRIALS {
        let g = rand_graph(&mut rng);
        assert_eq!(GraphDef::from_bytes(&g.to_bytes()).unwrap(), g);
        // Fingerprint is stable under re-encode.
        assert_eq!(g.fingerprint(), GraphDef::from_bytes(&g.to_bytes()).unwrap().fingerprint());
    }
}

#[test]
fn prop_padded_batch_never_loses_tokens() {
    let mut rng = Rng::new(0x9_0008);
    for _ in 0..50 {
        let n = rng.below(30) as usize + 2;
        let tensors: Vec<Tensor> = (0..n)
            .map(|_| {
                let len = rng.below(20) as usize + 1;
                Tensor::from_u32(vec![len], &(1..=len as u32).collect::<Vec<_>>())
            })
            .collect();
        let padded = Tensor::stack_padded(&tensors, &0u32.to_le_bytes()).unwrap();
        assert_eq!(padded.dtype, DType::U32);
        let max_len = tensors.iter().map(|t| t.shape[0]).max().unwrap();
        assert_eq!(padded.shape, vec![n, max_len]);
        let vals = padded.as_u32();
        for (i, t) in tensors.iter().enumerate() {
            let row = &vals[i * max_len..(i + 1) * max_len];
            assert_eq!(&row[..t.shape[0]], t.as_u32().as_slice(), "payload preserved");
            assert!(row[t.shape[0]..].iter().all(|&v| v == 0), "padding is zero");
        }
    }
}
