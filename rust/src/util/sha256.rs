//! SHA-256 (FIPS 180-4), in-tree replacement for the `sha2` dependency.
//!
//! Used by the runtime to verify AOT-artifact integrity against the
//! manifest. One-shot only — artifacts are read fully into memory before
//! hashing, so no streaming state is needed.

const K: [u32; 64] = [
    0x428A2F98, 0x71374491, 0xB5C0FBCF, 0xE9B5DBA5, 0x3956C25B, 0x59F111F1, 0x923F82A4, 0xAB1C5ED5,
    0xD807AA98, 0x12835B01, 0x243185BE, 0x550C7DC3, 0x72BE5D74, 0x80DEB1FE, 0x9BDC06A7, 0xC19BF174,
    0xE49B69C1, 0xEFBE4786, 0x0FC19DC6, 0x240CA1CC, 0x2DE92C6F, 0x4A7484AA, 0x5CB0A9DC, 0x76F988DA,
    0x983E5152, 0xA831C66D, 0xB00327C8, 0xBF597FC7, 0xC6E00BF3, 0xD5A79147, 0x06CA6351, 0x14292967,
    0x27B70A85, 0x2E1B2138, 0x4D2C6DFC, 0x53380D13, 0x650A7354, 0x766A0ABB, 0x81C2C92E, 0x92722C85,
    0xA2BFE8A1, 0xA81A664B, 0xC24B8B70, 0xC76C51A3, 0xD192E819, 0xD6990624, 0xF40E3585, 0x106AA070,
    0x19A4C116, 0x1E376C08, 0x2748774C, 0x34B0BCB5, 0x391C0CB3, 0x4ED8AA4A, 0x5B9CCA4F, 0x682E6FF3,
    0x748F82EE, 0x78A5636F, 0x84C87814, 0x8CC70208, 0x90BEFFFA, 0xA4506CEB, 0xBEF9A3F7, 0xC67178F2,
];

const H0: [u32; 8] = [
    0x6A09E667, 0xBB67AE85, 0x3C6EF372, 0xA54FF53A, 0x510E527F, 0x9B05688C, 0x1F83D9AB, 0x5BE0CD19,
];

fn compress(state: &mut [u32; 8], block: &[u8]) {
    debug_assert_eq!(block.len(), 64);
    let mut w = [0u32; 64];
    for (i, chunk) in block.chunks_exact(4).enumerate() {
        w[i] = u32::from_be_bytes(chunk.try_into().unwrap());
    }
    for i in 16..64 {
        let s0 = w[i - 15].rotate_right(7) ^ w[i - 15].rotate_right(18) ^ (w[i - 15] >> 3);
        let s1 = w[i - 2].rotate_right(17) ^ w[i - 2].rotate_right(19) ^ (w[i - 2] >> 10);
        w[i] = w[i - 16]
            .wrapping_add(s0)
            .wrapping_add(w[i - 7])
            .wrapping_add(s1);
    }
    let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut h] = *state;
    for i in 0..64 {
        let s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
        let ch = (e & f) ^ (!e & g);
        let t1 = h
            .wrapping_add(s1)
            .wrapping_add(ch)
            .wrapping_add(K[i])
            .wrapping_add(w[i]);
        let s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
        let maj = (a & b) ^ (a & c) ^ (b & c);
        let t2 = s0.wrapping_add(maj);
        h = g;
        g = f;
        f = e;
        e = d.wrapping_add(t1);
        d = c;
        c = b;
        b = a;
        a = t1.wrapping_add(t2);
    }
    state[0] = state[0].wrapping_add(a);
    state[1] = state[1].wrapping_add(b);
    state[2] = state[2].wrapping_add(c);
    state[3] = state[3].wrapping_add(d);
    state[4] = state[4].wrapping_add(e);
    state[5] = state[5].wrapping_add(f);
    state[6] = state[6].wrapping_add(g);
    state[7] = state[7].wrapping_add(h);
}

/// SHA-256 digest of `bytes`.
pub fn sha256(bytes: &[u8]) -> [u8; 32] {
    let mut state = H0;
    let mut chunks = bytes.chunks_exact(64);
    for block in &mut chunks {
        compress(&mut state, block);
    }
    // Padding: 0x80, zeros, then the 64-bit big-endian bit length.
    let rem = chunks.remainder();
    let bit_len = (bytes.len() as u64).wrapping_mul(8);
    let mut tail = [0u8; 128];
    tail[..rem.len()].copy_from_slice(rem);
    tail[rem.len()] = 0x80;
    let tail_len = if rem.len() < 56 { 64 } else { 128 };
    tail[tail_len - 8..tail_len].copy_from_slice(&bit_len.to_be_bytes());
    for block in tail[..tail_len].chunks_exact(64) {
        compress(&mut state, block);
    }
    let mut out = [0u8; 32];
    for (i, word) in state.iter().enumerate() {
        out[i * 4..i * 4 + 4].copy_from_slice(&word.to_be_bytes());
    }
    out
}

/// Lowercase hex digest, the format artifact manifests store.
pub fn sha256_hex(bytes: &[u8]) -> String {
    sha256(bytes).iter().map(|b| format!("{b:02x}")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // hashlib.sha256 reference values.
        assert_eq!(
            sha256_hex(b"abc"),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
        assert_eq!(
            sha256_hex(b""),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
        assert_eq!(
            sha256_hex(&vec![b'a'; 1000]),
            "41edece42d63e8d9bf515a9ba6932e1c20cbc9f5a5d134645adb5db1b9737ea3"
        );
        let data: Vec<u8> = (0..100u32).map(|i| (i % 251) as u8).collect();
        assert_eq!(
            sha256_hex(&data),
            "bce0aff19cf5aa6a7469a30d61d04e4376e4bbf6381052ee9e7f33925c954d52"
        );
    }

    #[test]
    fn padding_boundaries() {
        // Lengths straddling the 55/56/64-byte padding cases must not panic
        // and must be distinct.
        let digests: Vec<String> =
            (53..=66).map(|n| sha256_hex(&vec![0u8; n])).collect();
        for w in digests.windows(2) {
            assert_ne!(w[0], w[1]);
        }
    }
}
