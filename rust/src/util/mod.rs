pub mod chan;
pub mod cli;
pub mod crc32;
pub mod hist;
pub mod json;
pub mod rng;
pub mod sha256;
