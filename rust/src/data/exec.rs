//! Pull-based pipeline executor.
//!
//! [`Executor::iterate`] turns a [`GraphDef`] into an iterator tree. Each
//! node becomes an [`ElemIter`]; `map` with parallelism > 1 fans work out
//! to a thread pool with order-preserving reassembly, and `prefetch` runs
//! the upstream on a background thread feeding a bounded channel — the two
//! concurrency primitives tf.data's runtime is built around.
//!
//! Source nodes pull *splits* (shard indices) from a [`SplitProvider`],
//! which is how the service's sharding policies (§3.3) plug in: OFF gives
//! every worker a provider over all shards, DYNAMIC gives a provider that
//! asks the dispatcher for the next split.

use super::element::{Element, Tensor};
use super::graph::{GraphDef, Node};
use super::udf::{predicate_verdict, Udf, UdfRegistry};
use super::{DataError, DataResult};
use crate::storage::dataset::DatasetSpec;
use crate::storage::{ObjectStore, Region};
use crate::wire::Decode;
use crate::util::chan;
use crate::util::rng::Rng;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Provides source splits (shard indices) to a pipeline instance.
pub trait SplitProvider: Send + Sync {
    /// The next shard index to process, or `None` when the epoch's splits
    /// are exhausted.
    fn next_split(&self) -> Option<usize>;
    /// Restart for a new epoch (no-op for dispatcher-driven providers:
    /// the dispatcher owns epoch boundaries).
    fn reset(&self);
}

/// Sequential provider over all `n` shards — colocated / OFF-sharding mode.
pub struct AllSplits {
    n: usize,
    next: AtomicUsize,
}

impl AllSplits {
    pub fn new(n: usize) -> Arc<AllSplits> {
        Arc::new(AllSplits { n, next: AtomicUsize::new(0) })
    }
}

impl SplitProvider for AllSplits {
    fn next_split(&self) -> Option<usize> {
        let i = self.next.fetch_add(1, Ordering::SeqCst);
        (i < self.n).then_some(i)
    }

    fn reset(&self) {
        self.next.store(0, Ordering::SeqCst);
    }
}

/// Fixed subset of shards (static sharding).
pub struct FixedSplits {
    shards: Vec<usize>,
    next: AtomicUsize,
}

impl FixedSplits {
    pub fn new(shards: Vec<usize>) -> Arc<FixedSplits> {
        Arc::new(FixedSplits { shards, next: AtomicUsize::new(0) })
    }
}

impl SplitProvider for FixedSplits {
    fn next_split(&self) -> Option<usize> {
        let i = self.next.fetch_add(1, Ordering::SeqCst);
        self.shards.get(i).copied()
    }

    fn reset(&self) {
        self.next.store(0, Ordering::SeqCst);
    }
}

/// The iterator interface all pipeline stages implement.
pub trait ElemIter: Send {
    fn next(&mut self) -> DataResult<Option<Element>>;
}

/// Executor configuration.
#[derive(Clone)]
pub struct ExecutorConfig {
    pub store: Arc<ObjectStore>,
    pub udfs: UdfRegistry,
    /// Region this pipeline executes in (drives storage read costs).
    pub region: Region,
    /// Provider for source splits.
    pub splits: Arc<dyn SplitProvider>,
    /// Shared autotune state (parallelism targets per map stage).
    pub autotune: Arc<super::autotune::AutotuneState>,
}

impl ExecutorConfig {
    pub fn local(store: Arc<ObjectStore>, udfs: UdfRegistry, num_shards: usize) -> ExecutorConfig {
        let region = store.region().clone();
        ExecutorConfig {
            store,
            udfs,
            region,
            splits: AllSplits::new(num_shards),
            autotune: Arc::new(super::autotune::AutotuneState::default()),
        }
    }
}

/// Builds iterators from graphs.
pub struct Executor {
    cfg: ExecutorConfig,
}

impl Executor {
    pub fn new(cfg: ExecutorConfig) -> Executor {
        Executor { cfg }
    }

    /// Validate + build the iterator tree for `graph`.
    pub fn iterate(&self, graph: &GraphDef) -> DataResult<Box<dyn ElemIter>> {
        graph.validate().map_err(DataError::InvalidGraph)?;
        build(&self.cfg, &graph.nodes)
    }

    /// Drain the pipeline into a vector (tests / small workloads).
    pub fn collect(&self, graph: &GraphDef) -> DataResult<Vec<Element>> {
        let mut it = self.iterate(graph)?;
        let mut out = Vec::new();
        while let Some(e) = it.next()? {
            out.push(e);
        }
        Ok(out)
    }
}

fn build(cfg: &ExecutorConfig, nodes: &[Node]) -> DataResult<Box<dyn ElemIter>> {
    let (head, rest) = nodes.split_first().ok_or_else(|| DataError::InvalidGraph("empty".into()))?;
    let mut it: Box<dyn ElemIter> = match head {
        Node::SourceVision { spec } => Box::new(SourceIter::new(cfg, spec.clone(), SourceKind::Vision, 1)),
        Node::SourceText { spec } => Box::new(SourceIter::new(cfg, spec.clone(), SourceKind::Text, 1)),
        Node::SourceRange { n } => Box::new(RangeIter { n: *n, i: 0 }),
        other => return Err(DataError::InvalidGraph(format!("graph must start with a source, got {}", other.op_name()))),
    };
    for (idx, node) in rest.iter().enumerate() {
        // `idx + 1` is the node's absolute position in `nodes`.
        it = apply(cfg, it, node, idx + 1, nodes)?;
    }
    Ok(it)
}

fn apply(
    cfg: &ExecutorConfig,
    upstream: Box<dyn ElemIter>,
    node: &Node,
    node_idx: usize,
    all_nodes: &[Node],
) -> DataResult<Box<dyn ElemIter>> {
    Ok(match node {
        Node::SourceVision { .. } | Node::SourceText { .. } | Node::SourceRange { .. } => {
            return Err(DataError::InvalidGraph("source in tail position".into()))
        }
        Node::Map { udf, parallelism } => {
            let f = cfg.udfs.resolve(udf).ok_or_else(|| DataError::UnknownUdf(udf.clone()))?;
            if *parallelism <= 1 && *parallelism != 0 {
                Box::new(MapIter { upstream, f, name: udf.clone() })
            } else {
                let elastic = *parallelism == 0;
                let workers = if elastic {
                    // AUTOTUNE: start from the shared target, default 4.
                    cfg.autotune.target_parallelism(node_idx).max(1)
                } else {
                    *parallelism as usize
                };
                Box::new(ParallelMapIter::new(
                    upstream,
                    f,
                    udf.clone(),
                    workers,
                    elastic,
                    cfg.autotune.clone(),
                    node_idx,
                ))
            }
        }
        Node::Filter { udf } => {
            let f = cfg.udfs.resolve(udf).ok_or_else(|| DataError::UnknownUdf(udf.clone()))?;
            Box::new(FilterIter { upstream, f, name: udf.clone() })
        }
        Node::Shuffle { buffer, seed } => Box::new(ShuffleIter {
            upstream,
            buf: Vec::with_capacity(*buffer as usize),
            cap: (*buffer as usize).max(1),
            rng: Rng::new(*seed),
            filled: false,
        }),
        Node::Batch { size, drop_remainder } => Box::new(BatchIter {
            upstream,
            size: *size as usize,
            drop_remainder: *drop_remainder,
            padded: false,
            done: false,
        }),
        Node::PaddedBatch { size, drop_remainder } => Box::new(BatchIter {
            upstream,
            size: *size as usize,
            drop_remainder: *drop_remainder,
            padded: true,
            done: false,
        }),
        Node::Prefetch { n } => Box::new(PrefetchIter::new(upstream, (*n as usize).max(1))),
        Node::Repeat { n } => {
            // Rebuild the upstream chain per epoch: capture the prefix.
            let prefix: Vec<Node> = all_nodes[..=node_idx].to_vec(); // includes Repeat itself; strip below
            let prefix = prefix[..prefix.len() - 1].to_vec();
            Box::new(RepeatIter {
                cfg: cfg.clone(),
                prefix,
                current: Some(upstream),
                remaining: if *n == 0 { None } else { Some(*n) },
            })
        }
        Node::Take { n } => Box::new(TakeIter { upstream, left: *n }),
        Node::Skip { n } => Box::new(SkipIter { upstream, to_skip: *n }),
        Node::Cache => Box::new(CacheIter { upstream: Some(upstream), cache: Vec::new(), pos: 0, filled: false }),
        Node::Interleave { .. } => upstream, // file-level interleave handled at source; identity here
        Node::BucketBySequenceLength { boundaries, batch_size } => Box::new(BucketIter {
            upstream,
            boundaries: boundaries.clone(),
            batch_size: *batch_size as usize,
            pending: vec![VecDeque::new(); boundaries.len() + 1],
            done: false,
        }),
        Node::GroupByWindow { window_size } => Box::new(GroupByWindowIter {
            upstream,
            window: *window_size as usize,
            pending: std::collections::HashMap::new(),
            ready: VecDeque::new(),
            done: false,
        }),
        Node::FlatMap => upstream, // windows are already emitted flattened
    })
}

// ---------------------------------------------------------------- sources

enum SourceKind {
    Vision,
    Text,
}

struct SourceIter {
    store: Arc<ObjectStore>,
    region: Region,
    spec: DatasetSpec,
    kind: SourceKind,
    splits: Arc<dyn SplitProvider>,
    /// Parsed samples of the shard currently being drained.
    current: VecDeque<Element>,
}

impl SourceIter {
    fn new(cfg: &ExecutorConfig, spec: DatasetSpec, kind: SourceKind, _cycle: usize) -> SourceIter {
        SourceIter {
            store: cfg.store.clone(),
            region: cfg.region.clone(),
            spec,
            kind,
            splits: cfg.splits.clone(),
            current: VecDeque::new(),
        }
    }

    fn load_shard(&mut self, idx: usize) -> DataResult<()> {
        let key = self
            .spec
            .shards
            .get(idx)
            .ok_or_else(|| DataError::Other(format!("split {idx} out of range ({} shards)", self.spec.shards.len())))?;
        let body = self.store.get_from(&self.region, key)?;
        let mut reader = crate::storage::record::RecordReader::new(&body);
        while let Some(rec) = reader.next_record()? {
            let elem = match self.kind {
                SourceKind::Vision => {
                    let s = crate::storage::dataset::VisionSample::from_bytes(rec)?;
                    Element::with_ids(
                        vec![
                            Tensor::from_u8(
                                vec![s.height as usize, s.width as usize, s.channels as usize],
                                s.pixels,
                            ),
                            Tensor::scalar_u32(s.label),
                        ],
                        vec![s.id],
                    )
                }
                SourceKind::Text => {
                    let s = crate::storage::dataset::TextSample::from_bytes(rec)?;
                    let n = s.tokens.len();
                    Element::with_ids(
                        vec![Tensor::from_u32(vec![n], &s.tokens), Tensor::scalar_u32(s.label)],
                        vec![s.id],
                    )
                }
            };
            self.current.push_back(elem);
        }
        Ok(())
    }
}

impl ElemIter for SourceIter {
    fn next(&mut self) -> DataResult<Option<Element>> {
        loop {
            if let Some(e) = self.current.pop_front() {
                return Ok(Some(e));
            }
            match self.splits.next_split() {
                Some(idx) => self.load_shard(idx)?,
                None => return Ok(None),
            }
        }
    }
}

struct RangeIter {
    n: u64,
    i: u64,
}

impl ElemIter for RangeIter {
    fn next(&mut self) -> DataResult<Option<Element>> {
        if self.i >= self.n {
            return Ok(None);
        }
        let v = self.i as i64;
        self.i += 1;
        Ok(Some(Element::with_ids(vec![Tensor::scalar_i32(v as i32)], vec![v as u64])))
    }
}

// ----------------------------------------------------------- transformers

struct MapIter {
    upstream: Box<dyn ElemIter>,
    f: Arc<dyn Udf>,
    name: String,
}

impl ElemIter for MapIter {
    fn next(&mut self) -> DataResult<Option<Element>> {
        match self.upstream.next()? {
            Some(e) => {
                let out = self
                    .f
                    .call(e)
                    .map_err(|msg| DataError::UdfFailed { name: self.name.clone(), msg })?;
                Ok(Some(out))
            }
            None => Ok(None),
        }
    }
}

struct FilterIter {
    upstream: Box<dyn ElemIter>,
    f: Arc<dyn Udf>,
    name: String,
}

impl ElemIter for FilterIter {
    fn next(&mut self) -> DataResult<Option<Element>> {
        loop {
            match self.upstream.next()? {
                Some(e) => {
                    let saved_bucket = e.bucket;
                    let verdicted = self
                        .f
                        .call(e)
                        .map_err(|msg| DataError::UdfFailed { name: self.name.clone(), msg })?;
                    if predicate_verdict(&verdicted) {
                        let mut kept = verdicted;
                        kept.bucket = saved_bucket;
                        return Ok(Some(kept));
                    }
                }
                None => return Ok(None),
            }
        }
    }
}

/// Elements moved per channel operation in the parallel map. Chunking
/// amortizes the Mutex+Condvar cost of the bounded channel over several
/// elements: with ~10 µs channel overhead and ~20 µs UDFs, per-element
/// handoff made pmap(8) *slower* than a serial map (§Perf before/after in
/// EXPERIMENTS.md).
const PMAP_CHUNK: usize = 8;

/// Order-preserving parallel map: a feeder thread pulls upstream elements
/// into chunks tagged with sequence numbers; `workers` threads apply the
/// UDF to every element of a chunk; the consumer reassembles chunks in
/// sequence order and streams out their elements.
struct ParallelMapIter {
    out_rx: chan::Receiver<(u64, Vec<DataResult<Element>>)>,
    reorder: std::collections::BTreeMap<u64, Vec<DataResult<Element>>>,
    /// Elements of the chunk currently being drained (reversed: pop()).
    current: Vec<DataResult<Element>>,
    next_seq: u64,
    /// Number of chunks the feeder announced (set when upstream ends).
    total: Arc<AtomicUsize>,
    finished_feeding: Arc<std::sync::atomic::AtomicBool>,
}

impl ParallelMapIter {
    fn new(
        upstream: Box<dyn ElemIter>,
        f: Arc<dyn Udf>,
        name: String,
        workers: usize,
        elastic: bool,
        autotune: Arc<super::autotune::AutotuneState>,
        node_idx: usize,
    ) -> ParallelMapIter {
        // Elastic (AUTOTUNE) stages spawn threads up to the CPU budget so
        // a later replan can scale *up* past the build-time target;
        // surplus threads park on the plan-generation condvar and cost
        // nothing but stack. Explicit-parallelism stages keep the fixed
        // pool the pipeline author asked for.
        let pool_size =
            if elastic { workers.max(autotune.budget().min(16)) } else { workers };
        let (work_tx, work_rx) = chan::bounded::<(u64, Vec<Element>)>(workers * 2);
        let (out_tx, out_rx) = chan::bounded::<(u64, Vec<DataResult<Element>>)>(workers * 2);
        let total = Arc::new(AtomicUsize::new(usize::MAX));
        let finished = Arc::new(std::sync::atomic::AtomicBool::new(false));

        // Feeder.
        {
            let total = total.clone();
            let finished = finished.clone();
            let out_tx_err = out_tx.clone();
            let mut upstream = upstream;
            std::thread::Builder::new()
                .name("pmap-feeder".into())
                .spawn(move || {
                    let mut seq = 0u64;
                    let mut chunk: Vec<Element> = Vec::with_capacity(PMAP_CHUNK);
                    loop {
                        match upstream.next() {
                            Ok(Some(e)) => {
                                chunk.push(e);
                                if chunk.len() == PMAP_CHUNK {
                                    if work_tx.send((seq, std::mem::take(&mut chunk))).is_err() {
                                        break;
                                    }
                                    seq += 1;
                                    chunk.reserve(PMAP_CHUNK);
                                }
                            }
                            Ok(None) => {
                                if !chunk.is_empty()
                                    && work_tx.send((seq, std::mem::take(&mut chunk))).is_ok()
                                {
                                    seq += 1;
                                }
                                break;
                            }
                            Err(err) => {
                                // Flush the partial chunk, then the error.
                                if !chunk.is_empty()
                                    && work_tx.send((seq, std::mem::take(&mut chunk))).is_ok()
                                {
                                    seq += 1;
                                }
                                let _ = out_tx_err.send((seq, vec![Err(err)]));
                                seq += 1;
                                break;
                            }
                        }
                    }
                    total.store(seq as usize, Ordering::SeqCst);
                    finished.store(true, Ordering::SeqCst);
                    work_tx.close();
                })
                .ok();
        }

        // Workers.
        for w in 0..pool_size {
            let rx = work_rx.clone();
            let tx = out_tx.clone();
            let f = f.clone();
            let name = name.clone();
            let autotune = autotune.clone();
            std::thread::Builder::new()
                .name(format!("pmap-{w}"))
                .spawn(move || {
                    loop {
                        if elastic && w >= autotune.target_parallelism(node_idx).max(1) {
                            // Above the current plan's target: park until
                            // the next replan (or bounded re-check, which
                            // also notices upstream shutdown) instead of
                            // competing for work the plan says we should
                            // not take.
                            if rx.is_closed() {
                                break;
                            }
                            let gen = autotune.plan_generation();
                            autotune.wait_replan(gen, std::time::Duration::from_millis(50));
                            continue;
                        }
                        let Ok((seq, chunk)) = rx.recv() else { break };
                        let t0 = std::time::Instant::now();
                        let n = chunk.len() as u32;
                        let results: Vec<DataResult<Element>> = chunk
                            .into_iter()
                            .map(|e| {
                                f.call(e).map_err(|msg| DataError::UdfFailed {
                                    name: name.clone(),
                                    msg,
                                })
                            })
                            .collect();
                        autotune.record_work(node_idx, t0.elapsed() / n.max(1));
                        if tx.send((seq, results)).is_err() {
                            break;
                        }
                    }
                })
                .ok();
        }
        drop(out_tx);

        ParallelMapIter {
            out_rx,
            reorder: Default::default(),
            current: Vec::new(),
            next_seq: 0,
            total,
            finished_feeding: finished,
        }
    }
}

impl ElemIter for ParallelMapIter {
    fn next(&mut self) -> DataResult<Option<Element>> {
        loop {
            if let Some(r) = self.current.pop() {
                return r.map(Some);
            }
            if let Some(chunk) = self.reorder.remove(&self.next_seq) {
                self.next_seq += 1;
                self.current = chunk;
                self.current.reverse(); // drain front-first via pop()
                continue;
            }
            // All produced and consumed?
            if self.finished_feeding.load(Ordering::SeqCst)
                && self.next_seq as usize >= self.total.load(Ordering::SeqCst)
            {
                return Ok(None);
            }
            match self.out_rx.recv() {
                Ok((seq, chunk)) => {
                    self.reorder.insert(seq, chunk);
                }
                Err(_) => {
                    // Channel closed: drain whatever is reordered, else end.
                    if let Some(chunk) = self.reorder.remove(&self.next_seq) {
                        self.next_seq += 1;
                        self.current = chunk;
                        self.current.reverse();
                        continue;
                    }
                    return Ok(None);
                }
            }
        }
    }
}

struct ShuffleIter {
    upstream: Box<dyn ElemIter>,
    buf: Vec<Element>,
    cap: usize,
    rng: Rng,
    filled: bool,
}

impl ElemIter for ShuffleIter {
    fn next(&mut self) -> DataResult<Option<Element>> {
        if !self.filled {
            while self.buf.len() < self.cap {
                match self.upstream.next()? {
                    Some(e) => self.buf.push(e),
                    None => break,
                }
            }
            self.filled = true;
        }
        if self.buf.is_empty() {
            return Ok(None);
        }
        let idx = self.rng.below_usize(self.buf.len());
        // Swap-replace with the next upstream element, if any.
        match self.upstream.next()? {
            Some(mut e) => {
                std::mem::swap(&mut self.buf[idx], &mut e);
                Ok(Some(e))
            }
            None => Ok(Some(self.buf.swap_remove(idx))),
        }
    }
}

struct BatchIter {
    upstream: Box<dyn ElemIter>,
    size: usize,
    drop_remainder: bool,
    padded: bool,
    done: bool,
}

impl ElemIter for BatchIter {
    fn next(&mut self) -> DataResult<Option<Element>> {
        if self.done {
            return Ok(None);
        }
        let mut batch = Vec::with_capacity(self.size);
        while batch.len() < self.size {
            match self.upstream.next()? {
                Some(e) => batch.push(e),
                None => {
                    self.done = true;
                    break;
                }
            }
        }
        if batch.is_empty() || (batch.len() < self.size && self.drop_remainder) {
            return Ok(None);
        }
        Ok(Some(combine_batch(&batch, self.padded)?))
    }
}

/// Stack `n` elements into one batched element; `padded` pads rank-1
/// tensors to the longest sample (zeros).
pub(crate) fn combine_batch(batch: &[Element], padded: bool) -> DataResult<Element> {
    let arity = batch[0].tensors.len();
    let mut tensors = Vec::with_capacity(arity);
    for i in 0..arity {
        let column: Vec<Tensor> = batch.iter().map(|e| e.tensors[i].clone()).collect();
        let stacked = if padded && column[0].rank() == 1 {
            let pad = vec![0u8; column[0].dtype.size_of()];
            Tensor::stack_padded(&column, &pad).map_err(DataError::Shape)?
        } else {
            Tensor::stack(&column).map_err(DataError::Shape)?
        };
        tensors.push(stacked);
    }
    let ids = batch.iter().flat_map(|e| e.ids.iter().copied()).collect();
    let bucket = batch[0].bucket.filter(|b| batch.iter().all(|e| e.bucket == Some(*b)));
    Ok(Element { tensors, ids, bucket })
}

/// Background prefetch: upstream runs on its own thread feeding a bounded
/// channel of depth `n`.
struct PrefetchIter {
    rx: chan::Receiver<DataResult<Element>>,
}

impl PrefetchIter {
    fn new(upstream: Box<dyn ElemIter>, n: usize) -> PrefetchIter {
        let (tx, rx) = chan::bounded::<DataResult<Element>>(n);
        let mut upstream = upstream;
        std::thread::Builder::new()
            .name("prefetch".into())
            .spawn(move || loop {
                match upstream.next() {
                    Ok(Some(e)) => {
                        if tx.send(Ok(e)).is_err() {
                            break;
                        }
                    }
                    Ok(None) => {
                        tx.close();
                        break;
                    }
                    Err(err) => {
                        let _ = tx.send(Err(err));
                        tx.close();
                        break;
                    }
                }
            })
            .ok();
        PrefetchIter { rx }
    }
}

impl ElemIter for PrefetchIter {
    fn next(&mut self) -> DataResult<Option<Element>> {
        match self.rx.recv() {
            Ok(r) => r.map(Some),
            Err(_) => Ok(None),
        }
    }
}

struct RepeatIter {
    cfg: ExecutorConfig,
    prefix: Vec<Node>,
    current: Option<Box<dyn ElemIter>>,
    /// None = infinite.
    remaining: Option<u32>,
}

impl ElemIter for RepeatIter {
    fn next(&mut self) -> DataResult<Option<Element>> {
        loop {
            if let Some(cur) = self.current.as_mut() {
                if let Some(e) = cur.next()? {
                    return Ok(Some(e));
                }
            }
            // Epoch done.
            if let Some(r) = self.remaining.as_mut() {
                *r = r.saturating_sub(1);
                if *r == 0 {
                    return Ok(None);
                }
            }
            self.cfg.splits.reset();
            self.current = Some(build(&self.cfg, &self.prefix)?);
        }
    }
}

struct TakeIter {
    upstream: Box<dyn ElemIter>,
    left: u64,
}

impl ElemIter for TakeIter {
    fn next(&mut self) -> DataResult<Option<Element>> {
        if self.left == 0 {
            return Ok(None);
        }
        self.left -= 1;
        self.upstream.next()
    }
}

struct SkipIter {
    upstream: Box<dyn ElemIter>,
    to_skip: u64,
}

impl ElemIter for SkipIter {
    fn next(&mut self) -> DataResult<Option<Element>> {
        while self.to_skip > 0 {
            self.to_skip -= 1;
            if self.upstream.next()?.is_none() {
                return Ok(None);
            }
        }
        self.upstream.next()
    }
}

struct CacheIter {
    upstream: Option<Box<dyn ElemIter>>,
    cache: Vec<Element>,
    pos: usize,
    filled: bool,
}

impl ElemIter for CacheIter {
    fn next(&mut self) -> DataResult<Option<Element>> {
        if !self.filled {
            if let Some(up) = self.upstream.as_mut() {
                match up.next()? {
                    Some(e) => {
                        self.cache.push(e.clone());
                        return Ok(Some(e));
                    }
                    None => {
                        self.filled = true;
                        self.upstream = None;
                        self.pos = self.cache.len(); // first pass already consumed
                    }
                }
            }
        }
        if self.pos >= self.cache.len() {
            self.pos = 0;
            return Ok(None);
        }
        let e = self.cache[self.pos].clone();
        self.pos += 1;
        Ok(Some(e))
    }
}

/// `bucket_by_sequence_length`: route each sample to a length bucket; emit
/// a (padded) batch whenever a bucket fills. Tags elements with their
/// bucket id for downstream `group_by_window` / coordinated reads.
struct BucketIter {
    upstream: Box<dyn ElemIter>,
    boundaries: Vec<u32>,
    batch_size: usize,
    pending: Vec<VecDeque<Element>>,
    done: bool,
}

impl BucketIter {
    fn bucket_of(&self, len: u32) -> usize {
        self.boundaries.iter().position(|&b| len <= b).unwrap_or(self.boundaries.len())
    }

    fn pop_ready(&mut self, min: usize) -> Option<DataResult<Element>> {
        for (b, q) in self.pending.iter_mut().enumerate() {
            if q.len() >= min && !q.is_empty() {
                let take = q.len().min(self.batch_size);
                let batch: Vec<Element> = q.drain(..take).collect();
                let r = combine_batch(&batch, true).map(|mut e| {
                    e.bucket = Some(b as u32);
                    e
                });
                return Some(r);
            }
        }
        None
    }
}

impl ElemIter for BucketIter {
    fn next(&mut self) -> DataResult<Option<Element>> {
        loop {
            if let Some(r) = self.pop_ready(self.batch_size) {
                return r.map(Some);
            }
            if self.done {
                // Flush partial buckets at end of input.
                if let Some(r) = self.pop_ready(1) {
                    return r.map(Some);
                }
                return Ok(None);
            }
            match self.upstream.next()? {
                Some(e) => {
                    let len = e.tensors.first().and_then(|t| t.shape.first().copied()).unwrap_or(0) as u32;
                    let b = self.bucket_of(len);
                    self.pending[b].push_back(e);
                }
                None => self.done = true,
            }
        }
    }
}

/// `group_by_window(window_size).flat_map(identity)`: reorder upstream
/// elements into runs of `window_size` consecutive elements sharing a
/// bucket key.
struct GroupByWindowIter {
    upstream: Box<dyn ElemIter>,
    window: usize,
    pending: std::collections::HashMap<u32, Vec<Element>>,
    ready: VecDeque<Element>,
    done: bool,
}

impl ElemIter for GroupByWindowIter {
    fn next(&mut self) -> DataResult<Option<Element>> {
        loop {
            if let Some(e) = self.ready.pop_front() {
                return Ok(Some(e));
            }
            if self.done {
                // Flush residual partial windows deterministically by key.
                let mut keys: Vec<u32> = self.pending.keys().copied().collect();
                keys.sort_unstable();
                for k in keys {
                    let v = self.pending.remove(&k).unwrap();
                    self.ready.extend(v);
                }
                return Ok(self.ready.pop_front());
            }
            match self.upstream.next()? {
                Some(e) => {
                    let key = e.bucket.unwrap_or(0);
                    let entry = self.pending.entry(key).or_default();
                    entry.push(e);
                    if entry.len() >= self.window {
                        let v = self.pending.remove(&key).unwrap();
                        self.ready.extend(v);
                    }
                }
                None => self.done = true,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::graph::PipelineBuilder;
    use crate::storage::dataset::{generate_text, generate_vision, TextGenConfig, VisionGenConfig};

    fn exec_with_range() -> Executor {
        Executor::new(ExecutorConfig::local(ObjectStore::in_memory(), UdfRegistry::with_builtins(), 0))
    }

    fn vals(elems: &[Element]) -> Vec<i32> {
        elems.iter().map(|e| e.tensors[0].as_i32()[0]).collect()
    }

    #[test]
    fn range_take_skip() {
        let ex = exec_with_range();
        let g = PipelineBuilder::source_range(10).skip(2).take(3).build();
        let out = ex.collect(&g).unwrap();
        assert_eq!(vals(&out), vec![2, 3, 4]);
    }

    #[test]
    fn batch_stacks_and_carries_ids() {
        let ex = exec_with_range();
        let g = PipelineBuilder::source_range(7).batch(3).build();
        let out = ex.collect(&g).unwrap();
        assert_eq!(out.len(), 2, "drop_remainder drops the partial batch");
        assert_eq!(out[0].tensors[0].shape, vec![3]);
        assert_eq!(out[0].ids, vec![0, 1, 2]);
        let g2 = PipelineBuilder::source_range(7).batch_partial(3).build();
        let out2 = ex.collect(&g2).unwrap();
        assert_eq!(out2.len(), 3);
        assert_eq!(out2[2].tensors[0].shape, vec![1]);
    }

    #[test]
    fn repeat_replays_source() {
        let ex = exec_with_range();
        let g = PipelineBuilder::source_range(3).repeat(3).build();
        let out = ex.collect(&g).unwrap();
        assert_eq!(vals(&out), vec![0, 1, 2, 0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn repeat_infinite_with_take() {
        let ex = exec_with_range();
        let g = PipelineBuilder::source_range(2).repeat(0).take(7).build();
        let out = ex.collect(&g).unwrap();
        assert_eq!(vals(&out), vec![0, 1, 0, 1, 0, 1, 0]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let ex = exec_with_range();
        let g = PipelineBuilder::source_range(50).shuffle(16, 42).build();
        let out = ex.collect(&g).unwrap();
        let mut v = vals(&out);
        assert_ne!(v, (0..50).collect::<Vec<_>>(), "should not be identity order");
        v.sort_unstable();
        assert_eq!(v, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn shuffle_is_seed_deterministic() {
        let ex = exec_with_range();
        let g = PipelineBuilder::source_range(20).shuffle(8, 9).build();
        let a = vals(&ex.collect(&g).unwrap());
        let b = vals(&ex.collect(&g).unwrap());
        assert_eq!(a, b);
    }

    #[test]
    fn cache_replays_after_first_pass() {
        let ex = exec_with_range();
        let g = PipelineBuilder::source_range(4).cache().repeat(2).build();
        let out = ex.collect(&g).unwrap();
        assert_eq!(vals(&out), vec![0, 1, 2, 3, 0, 1, 2, 3]);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let store = ObjectStore::in_memory();
        let udfs = UdfRegistry::with_builtins();
        udfs.register_fn("inc", |mut e: Element| {
            let v = e.tensors[0].as_i32()[0] + 1;
            e.tensors[0] = Tensor::scalar_i32(v);
            Ok(e)
        });
        let ex = Executor::new(ExecutorConfig::local(store, udfs, 0));
        let g = PipelineBuilder::source_range(100).map_parallel("inc", 8).build();
        let out = ex.collect(&g).unwrap();
        assert_eq!(vals(&out), (1..=100).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_propagates_udf_error() {
        let store = ObjectStore::in_memory();
        let udfs = UdfRegistry::with_builtins();
        udfs.register_fn("fail_on_5", |e: Element| {
            if e.tensors[0].as_i32()[0] == 5 {
                Err("boom".into())
            } else {
                Ok(e)
            }
        });
        let ex = Executor::new(ExecutorConfig::local(store, udfs, 0));
        let g = PipelineBuilder::source_range(10).map_parallel("fail_on_5", 4).build();
        let mut it = ex.iterate(&g).unwrap();
        let mut seen_err = false;
        loop {
            match it.next() {
                Ok(Some(_)) => {}
                Ok(None) => break,
                Err(DataError::UdfFailed { name, msg }) => {
                    assert_eq!(name, "fail_on_5");
                    assert_eq!(msg, "boom");
                    seen_err = true;
                    break;
                }
                Err(e) => panic!("unexpected {e:?}"),
            }
        }
        assert!(seen_err);
    }

    #[test]
    fn filter_keeps_matching() {
        let store = ObjectStore::in_memory();
        let udfs = UdfRegistry::with_builtins();
        udfs.register_fn("even", |e: Element| {
            let keep = e.tensors[0].as_i32()[0] % 2 == 0;
            crate::data::udf::predicate_result(e, keep)
        });
        let ex = Executor::new(ExecutorConfig::local(store, udfs, 0));
        let g = PipelineBuilder::source_range(10).filter("even").build();
        let out = ex.collect(&g).unwrap();
        assert_eq!(vals(&out), vec![0, 2, 4, 6, 8]);
    }

    #[test]
    fn prefetch_is_transparent() {
        let ex = exec_with_range();
        let g = PipelineBuilder::source_range(10).prefetch(3).build();
        assert_eq!(vals(&ex.collect(&g).unwrap()), (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn vision_source_end_to_end() {
        let store = ObjectStore::in_memory();
        let spec = generate_vision(&store, "v", &VisionGenConfig { num_shards: 2, samples_per_shard: 6, ..Default::default() });
        let n_shards = spec.num_shards();
        let udfs = UdfRegistry::with_builtins();
        let ex = Executor::new(ExecutorConfig::local(store, udfs, n_shards));
        let g = PipelineBuilder::source_vision(spec)
            .map_parallel("vision.normalize+vision.augment", 4)
            .batch(4)
            .prefetch(2)
            .build();
        let out = ex.collect(&g).unwrap();
        assert_eq!(out.len(), 3);
        for b in &out {
            assert_eq!(b.tensors[0].shape, vec![4, 32, 32, 3]);
            assert_eq!(b.tensors[0].dtype, crate::data::element::DType::F32);
            assert_eq!(b.ids.len(), 4);
        }
    }

    #[test]
    fn text_bucketing_groups_by_length() {
        let store = ObjectStore::in_memory();
        let spec = generate_text(&store, "t", &TextGenConfig { num_shards: 2, samples_per_shard: 100, ..Default::default() });
        let n_shards = spec.num_shards();
        let ex = Executor::new(ExecutorConfig::local(store, UdfRegistry::with_builtins(), n_shards));
        let g = PipelineBuilder::source_text(spec)
            .bucket_by_sequence_length(vec![64, 128, 256], 8)
            .build();
        let out = ex.collect(&g).unwrap();
        assert!(!out.is_empty());
        let bounds = [64u32, 128, 256, u32::MAX];
        for b in &out {
            let bucket = b.bucket.expect("batch must carry bucket id") as usize;
            let max_len = b.tensors[0].shape[1] as u32;
            assert!(max_len <= bounds[bucket], "bucket {bucket} padded len {max_len}");
            if bucket > 0 {
                assert!(max_len > bounds[bucket - 1], "bucket {bucket} should exceed lower bound");
            }
        }
        // All samples accounted for (padding batches never drop samples).
        let total: usize = out.iter().map(|b| b.ids.len()).sum();
        assert_eq!(total, 200);
    }

    #[test]
    fn group_by_window_emits_same_bucket_runs() {
        let store = ObjectStore::in_memory();
        let spec = generate_text(&store, "t", &TextGenConfig { num_shards: 1, samples_per_shard: 200, ..Default::default() });
        let ex = Executor::new(ExecutorConfig::local(store, UdfRegistry::with_builtins(), 1));
        let g = PipelineBuilder::source_text(spec)
            .bucket_by_sequence_length(vec![64, 128], 4)
            .group_by_window(2)
            .flat_map()
            .build();
        let out = ex.collect(&g).unwrap();
        // Full windows come in same-bucket pairs.
        let mut i = 0;
        let mut full_pairs = 0;
        while i + 1 < out.len() {
            if out[i].bucket == out[i + 1].bucket {
                full_pairs += 1;
                i += 2;
            } else {
                i += 1; // residual partial window
            }
        }
        assert!(full_pairs > 0, "expected at least one same-bucket window");
    }

    #[test]
    fn fixed_splits_limits_shards() {
        let store = ObjectStore::in_memory();
        let spec = generate_vision(&store, "v", &VisionGenConfig { num_shards: 4, samples_per_shard: 3, ..Default::default() });
        let udfs = UdfRegistry::with_builtins();
        let cfg = ExecutorConfig {
            store: store.clone(),
            udfs,
            region: store.region().clone(),
            splits: FixedSplits::new(vec![1, 3]),
            autotune: Arc::new(crate::data::autotune::AutotuneState::default()),
        };
        let ex = Executor::new(cfg);
        let g = PipelineBuilder::source_vision(spec).build();
        let out = ex.collect(&g).unwrap();
        let ids: Vec<u64> = out.iter().flat_map(|e| e.ids.iter().copied()).collect();
        assert_eq!(ids, vec![3, 4, 5, 9, 10, 11]);
    }

    #[test]
    fn unknown_udf_is_error() {
        let ex = exec_with_range();
        let g = PipelineBuilder::source_range(3).map("missing").build();
        assert!(matches!(ex.collect(&g), Err(DataError::UnknownUdf(_))));
    }
}
