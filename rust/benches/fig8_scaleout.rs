//! Fig. 8: end-to-end speedups (a) and cost reductions (b) from
//! horizontal scale-out, for M1, M2, M3, and ResNet50.
//!
//! Paper rows: speedup 11.7x / 110.3x / 2.9x / 2.57x (avg 31.7x), cost
//! saving 10.8x / 89.3x / 2.8x / 1.97x (avg 26.2x); M2 lands 8% short of
//! ideal; ResNet50 $80.2 -> $40.6.

use tfdatasvc::metrics::write_csv_rows;
use tfdatasvc::sim::cost::{resnet50_vm_cost, CostModel};
use tfdatasvc::sim::des::{simulate_job, JobSimConfig};
use tfdatasvc::sim::models::model;

fn main() {
    println!("=== Fig 8a: training throughput speedup over colocated ===");
    println!("{:<10} {:>10} {:>12} {:>10} {:>10} {:>8} {:>8}", "model", "colo b/s", "service b/s", "ideal b/s", "workers", "speedup", "paper");
    let mut rows = Vec::new();
    let mut speedups = Vec::new();
    let mut savings = Vec::new();
    for name in ["M1", "M2", "M3", "ResNet50"] {
        let m = model(name);
        let colo = simulate_job(m, &JobSimConfig::default());
        let dis = simulate_job(m, &JobSimConfig { n_workers: m.paper_workers, ..Default::default() });
        let speedup = dis.throughput_bps / colo.throughput_bps;
        speedups.push(speedup);
        println!(
            "{:<10} {:>10.2} {:>12.2} {:>10.2} {:>10} {:>7.1}x {:>7.1}x",
            name, colo.throughput_bps, dis.throughput_bps, m.ideal_bps, m.paper_workers, speedup, m.paper_speedup
        );

        // Fig 8b: cost via Eq. (1): job time shrinks by the speedup; pay
        // for workers' utilized CPU/RAM meanwhile.
        let cm = CostModel::production_like();
        let t_colo = 10.0; // reference colocated job length (hours)
        let t_dis = t_colo / speedup;
        let clients = (m.accelerators as f64 / 8.0).max(1.0);
        let colo_cost = cm.job_cost(t_colo, 0.0, 0.0, 0.0, clients, 96.0, 335.0, 8.0);
        let dis_cost = cm.job_cost(
            t_dis,
            m.paper_workers as f64,
            m.worker_cpu_cores * dis.worker_utilization,
            8.0,
            clients,
            96.0,
            335.0,
            8.0,
        );
        let saving = colo_cost.total / dis_cost.total;
        savings.push(saving);
        rows.push(vec![
            name.to_string(),
            format!("{speedup:.2}"),
            format!("{:.2}", m.paper_speedup),
            format!("{saving:.2}"),
            format!("{:.2}", m.paper_cost_saving),
        ]);
    }
    println!("\n=== Fig 8b: cost reduction (Eq. 1, production-like prices) ===");
    println!("{:<10} {:>10} {:>12}", "model", "saving", "paper saving");
    for r in &rows {
        println!("{:<10} {:>9}x {:>11}x", r[0], r[3], r[4]);
    }
    let avg_speedup = speedups.iter().sum::<f64>() / speedups.len() as f64;
    let avg_saving = savings.iter().sum::<f64>() / savings.len() as f64;
    println!("\naverages: speedup {avg_speedup:.1}x (paper 31.7x), cost saving {avg_saving:.1}x (paper 26.2x)");

    // M2's 8% shortfall from client-side ingest pressure.
    let m2 = model("M2");
    let r = simulate_job(m2, &JobSimConfig { n_workers: m2.paper_workers, ..Default::default() });
    println!(
        "M2 ideal-gap: service {:.0} vs ideal {:.0} b/s ({:.0}% short; paper: 8%)",
        r.throughput_bps,
        m2.ideal_bps,
        (1.0 - r.throughput_bps / m2.ideal_bps) * 100.0
    );

    // ResNet50 open-source dollars.
    let colo_hours = 80.2 / 4.50;
    let (rn_colo, _, _) = resnet50_vm_cost(colo_hours, 0.0);
    let (rn_dis, tpu, svc) = resnet50_vm_cost(colo_hours / speedups[3], 17.0);
    println!(
        "ResNet50 dollars: colocated ${rn_colo:.1} -> disaggregated ${rn_dis:.1} (TPU ${tpu:.1} + service ${svc:.1}; paper: $80.2 -> $40.6)"
    );

    write_csv_rows("out/fig8.csv", "model,speedup,paper_speedup,cost_saving,paper_cost_saving", &rows).unwrap();
    println!("fig8 OK -> out/fig8.csv");
}
