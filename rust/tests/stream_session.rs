//! Protocol-level tests for the versioned stream-session data plane:
//! handshake negotiation (version / capabilities / frame budget),
//! session-scoped Fetch, continuation-frame chunking with idempotent
//! resume, and the per-job window-occupancy stats in WorkerStatus.
//!
//! These drive the wire surface directly through a raw RPC pool — no
//! `ServiceClient` fetcher machinery — so they pin the contract an
//! independently-written client would code against.

mod common;

use std::time::{Duration, Instant};

use common::{raw_independent_job as setup_job, T};
use tfdatasvc::data::element::{DType, Tensor};
use tfdatasvc::data::graph::PipelineBuilder;
use tfdatasvc::data::udf::UdfRegistry;
use tfdatasvc::data::Element;
use tfdatasvc::rpc::{call_typed, Pool, RpcError, MAX_FRAME_LEN};
use tfdatasvc::service::proto::*;
use tfdatasvc::service::worker::MIN_STREAM_FRAME_LEN;
use tfdatasvc::wire::Decode;

fn open(
    pool: &Pool,
    addr: &str,
    job_id: u64,
    client_id: u64,
    version: u32,
    caps: u64,
    max_frame: u64,
) -> Result<OpenStreamResp, RpcError> {
    call_typed(
        pool,
        addr,
        worker_methods::OPEN_STREAM,
        &OpenStreamReq {
            job_id,
            client_id,
            protocol_version: version,
            capabilities: caps,
            max_frame_len: max_frame,
            consumer_index: None,
        },
        T,
    )
}

fn fetch(
    pool: &Pool,
    addr: &str,
    session_id: u64,
    chunk_seq: u64,
    chunk_offset: u64,
) -> Result<FetchResp, RpcError> {
    call_typed(
        pool,
        addr,
        worker_methods::FETCH,
        &FetchReq {
            session_id,
            max_elements: 0,
            max_bytes: 0,
            poll_ms: 0,
            compression: CompressionMode::None,
            round: None,
            chunk_seq,
            chunk_offset,
        },
        T,
    )
}

#[test]
fn handshake_negotiates_version_caps_and_frame_budget() {
    let graph = PipelineBuilder::source_range(8).batch(4).build();
    let (_d, w, pool, job_id, client_id) = setup_job(&graph, UdfRegistry::with_builtins());

    // A far-future client downgrades to the worker's version; the
    // capability set is the intersection; the frame budget is the min.
    let r = open(&pool, &w.addr(), job_id, client_id, 99, stream_caps::DEFLATE, 1 << 20).unwrap();
    assert_eq!(r.protocol_version, STREAM_PROTOCOL_VERSION);
    assert_eq!(r.capabilities, stream_caps::DEFLATE, "intersection drops unoffered caps");
    assert_eq!(r.max_frame_len, 1 << 20);
    assert_eq!(r.mode, ProcessingMode::Independent);
    assert!(r.session_id > 0);

    // Unknown capability bits are dropped, not echoed.
    let r2 =
        open(&pool, &w.addr(), job_id, client_id, 1, stream_caps::ALL | (1 << 63), 0).unwrap();
    assert_eq!(r2.capabilities, stream_caps::ALL);
    assert_eq!(r2.max_frame_len as usize, MAX_FRAME_LEN, "0 means the transport cap");
    assert_ne!(r2.session_id, r.session_id, "sessions are distinct");

    // A degenerate frame budget is floored so chunking stays sane.
    let r3 = open(&pool, &w.addr(), job_id, client_id, 1, 0, 1).unwrap();
    assert_eq!(r3.max_frame_len as usize, MIN_STREAM_FRAME_LEN);

    // Version 0 is a protocol error, not a downgrade.
    match open(&pool, &w.addr(), job_id, client_id, 0, 0, 0) {
        Err(RpcError::Remote(msg)) => {
            assert!(msg.contains("unsupported stream protocol version"), "{msg}")
        }
        other => panic!("expected version error, got {other:?}"),
    }

    // Unknown jobs are rejected at handshake time.
    match open(&pool, &w.addr(), 777, client_id, 1, 0, 0) {
        Err(RpcError::Remote(msg)) => assert!(msg.contains("unknown job"), "{msg}"),
        other => panic!("expected unknown-job error, got {other:?}"),
    }
}

#[test]
fn fetch_requires_a_live_session() {
    let graph = PipelineBuilder::source_range(8).batch(4).build();
    let (_d, w, pool, job_id, client_id) = setup_job(&graph, UdfRegistry::with_builtins());
    match fetch(&pool, &w.addr(), 424242, 0, 0) {
        Err(RpcError::Remote(msg)) => assert!(msg.contains("unknown stream session"), "{msg}"),
        other => panic!("expected unknown-session error, got {other:?}"),
    }
    // Close is idempotent; closing a never-opened session reports false.
    let r: CloseStreamResp = call_typed(
        &pool,
        &w.addr(),
        worker_methods::CLOSE_STREAM,
        &CloseStreamReq { session_id: 424242 },
        T,
    )
    .unwrap();
    assert!(!r.closed);
    // A closed session no longer serves.
    let s = open(&pool, &w.addr(), job_id, client_id, 1, stream_caps::ALL, 0).unwrap();
    let r: CloseStreamResp = call_typed(
        &pool,
        &w.addr(),
        worker_methods::CLOSE_STREAM,
        &CloseStreamReq { session_id: s.session_id },
        T,
    )
    .unwrap();
    assert!(r.closed);
    assert!(matches!(fetch(&pool, &w.addr(), s.session_id, 0, 0), Err(RpcError::Remote(_))));
}

#[test]
fn session_fetch_drains_epoch_with_hints_and_window_stats() {
    let graph = PipelineBuilder::source_range(64).batch(4).build();
    let (_d, w, pool, job_id, client_id) = setup_job(&graph, UdfRegistry::with_builtins());
    let s = open(&pool, &w.addr(), job_id, client_id, 1, stream_caps::ALL, 0).unwrap();

    let mut elements = 0u32;
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let r = fetch(&pool, &w.addr(), s.session_id, 0, 0).unwrap();
        assert_eq!(r.chunk_total_len, 0, "small elements never chunk");
        let payloads = {
            let plain =
                if r.compressed { tfdatasvc::wire::decompress(&r.frame).unwrap() } else { r.frame };
            Vec::<Vec<u8>>::from_bytes(&plain).unwrap()
        };
        assert_eq!(payloads.len(), r.num_elements as usize);
        for p in &payloads {
            let e = Element::from_bytes(p).unwrap();
            assert_eq!(e.ids.len(), 4);
        }
        elements += r.num_elements;
        // Backpressure hints stay coherent with the advertised window.
        assert!(r.window_elements as u64 <= 64);
        if r.window_elements > 0 {
            assert!(r.window_bytes > 0);
        }
        if r.end_of_sequence {
            break;
        }
        assert!(Instant::now() < deadline, "drain never finished");
    }
    assert_eq!(elements, 16, "64 rows batched by 4");

    // Satellite: per-job window occupancy is exposed in WorkerStatus and
    // as registry gauges. With eager consumed-by-all eviction (the
    // default), a fully-drained single-consumer window is *empty* —
    // steady-state window RAM tracks the consumer spread, not the
    // configured capacity.
    let st: WorkerStatusResp =
        call_typed(&pool, &w.addr(), worker_methods::WORKER_STATUS, &WorkerStatusReq {}, T)
            .unwrap();
    let ws = st.window_stats.iter().find(|s| s.job_id == job_id).expect("job window stat");
    assert_eq!(ws.elements, 0, "eager eviction empties a fully-consumed window");
    assert_eq!(ws.bytes, 0);
    assert_eq!(
        w.metrics().gauge(&format!("worker/job/{job_id}/window_elements")).get(),
        ws.elements as i64,
        "registry gauge matches status"
    );
    assert!(w.metrics().counter("worker/stream_sessions_opened").get() >= 1);
}

#[test]
fn chunked_transfer_reassembles_and_resumes_idempotently() {
    // Elements (~600 KiB) far exceed a deliberately tiny negotiated frame
    // budget, forcing many continuation frames per element. The client
    // echoes its received offset each call, so a retried RPC (here: an
    // explicitly repeated offset, as after a lost response) returns the
    // identical frame instead of skipping data.
    let udfs = UdfRegistry::with_builtins();
    let big_len: usize = 600 << 10;
    udfs.register_fn("test.inflate", move |e| {
        let fill = (e.ids[0] % 251) as u8;
        Ok(Element::with_ids(
            vec![Tensor::new(DType::U8, vec![big_len], vec![fill; big_len])],
            e.ids.clone(),
        ))
    });
    let graph = PipelineBuilder::source_range(3).map("test.inflate").build();
    let (_d, w, pool, job_id, client_id) = setup_job(&graph, udfs);

    let s = open(
        &pool,
        &w.addr(),
        job_id,
        client_id,
        1,
        stream_caps::ALL,
        MIN_STREAM_FRAME_LEN as u64,
    )
    .unwrap();
    let budget = s.max_frame_len as usize;
    assert_eq!(budget, MIN_STREAM_FRAME_LEN);

    let mut got = Vec::new();
    let mut resumed = false;
    let mut stale_ack_checked = false;
    // After finishing an element, the next request echoes (its seq, its
    // total length): that is the release ack. A plain (0, 0) while the
    // element is parked would mean "resend from scratch" — which the
    // retry-resume assertions below rely on.
    let mut ack = (0u64, 0u64);
    let deadline = Instant::now() + Duration::from_secs(20);
    'epoch: loop {
        // Ask for the next thing; a chunked element announces itself via
        // chunk_total_len on the first continuation frame.
        let first = fetch(&pool, &w.addr(), s.session_id, ack.0, ack.1).unwrap();
        if first.end_of_sequence && first.num_elements == 0 {
            break 'epoch;
        }
        assert!(Instant::now() < deadline, "chunk drain never finished");
        if first.chunk_total_len == 0 {
            // Nothing ready yet (long-poll expiry while producing).
            assert_eq!(first.num_elements, 0, "small elements are impossible in this pipeline");
            ack = (0, 0); // the worker handled the request: ack consumed
            continue;
        }
        assert_eq!(first.num_elements, 0, "continuation frames carry no element count");
        let seq = first.chunk_seq;
        assert!(seq > 0, "chunk frames are seq-tagged");
        if ack.0 != 0 && !stale_ack_checked {
            // Regression: the ack we just sent released the *previous*
            // element and the worker parked this new one. Re-sending the
            // now-stale ack (a retried RPC after a lost response) must
            // NOT release the new element — the worker sees a foreign
            // seq and restarts this element's delivery from offset 0.
            assert_ne!(seq, ack.0, "a fresh element gets a fresh seq");
            let retry = fetch(&pool, &w.addr(), s.session_id, ack.0, ack.1).unwrap();
            assert_eq!(retry.chunk_seq, seq, "stale ack does not release the new element");
            assert_eq!(retry.chunk_offset, 0);
            assert_eq!(retry.frame, first.frame, "delivery restarts from scratch");
            stale_ack_checked = true;
        }
        ack = (0, 0);
        let total = first.chunk_total_len as usize;
        let mut buf = Vec::with_capacity(total);
        assert_eq!(first.chunk_offset, 0);
        assert!(first.frame.len() < total, "must take several frames");
        buf.extend_from_slice(&first.frame);
        while buf.len() < total {
            if !resumed {
                // Simulate a lost response: re-request the offset we are
                // at, twice — both must return byte-identical frames.
                let a = fetch(&pool, &w.addr(), s.session_id, seq, buf.len() as u64).unwrap();
                let b = fetch(&pool, &w.addr(), s.session_id, seq, buf.len() as u64).unwrap();
                assert_eq!(a.frame, b.frame, "idempotent resume");
                assert_eq!(a.chunk_offset as usize, buf.len());
                assert_eq!(a.chunk_seq, seq);
                buf.extend_from_slice(&a.frame);
                resumed = true;
            } else {
                let r = fetch(&pool, &w.addr(), s.session_id, seq, buf.len() as u64).unwrap();
                assert_eq!(r.chunk_offset as usize, buf.len(), "serves from the echoed offset");
                assert_eq!(r.chunk_total_len as usize, total);
                buf.extend_from_slice(&r.frame);
            }
        }
        // The worker still holds the element (unacked): a retry of the
        // final frame's offset must replay it, not skip data.
        let replay = fetch(&pool, &w.addr(), s.session_id, seq, (total - 1) as u64).unwrap();
        assert_eq!(replay.chunk_offset as usize, total - 1);
        assert_eq!(replay.frame, buf[total - 1..], "final frame replays until acked");
        let e = Element::from_bytes(&buf).expect("lossless reassembly");
        let fill = (e.ids[0] % 251) as u8;
        assert_eq!(e.tensors[0].data, vec![fill; big_len]);
        got.push(e.ids[0]);
        ack = (seq, total as u64);
    }
    got.sort_unstable();
    assert_eq!(got, vec![0, 1, 2], "every oversized element delivered exactly once");
    assert!(stale_ack_checked, "the stale-ack regression path was exercised");
    assert_eq!(w.metrics().counter("worker/chunked_elements_served").get(), 3);
}
