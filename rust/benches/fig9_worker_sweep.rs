//! Fig. 9: M1 across worker pool sizes {8,16,32,64,128,256,512,640}:
//! (a) job-time speedup vs colocated with the ideal line, (b) cost
//! savings. Paper anchors: 8 workers -> 0.55x (slower than colocated!),
//! 16 -> 1.14x, 64 -> 4.1x, 128 -> 8.6x, 512 -> 12.3x (ideal), 640 ->
//! same time, slightly higher cost.
//!
//! A live section walks the same worker-count axis on a real cell:
//! pool resizes go through `Cell::request_scale_to`, so every shrink
//! runs the two-phase graceful drain (revoke -> flush -> ack -> grant)
//! while a coordinated consumer keeps stepping. `--smoke` shortens the
//! sweep for CI; the live results land in
//! `out/bench_worker_sweep_live.json`.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use tfdatasvc::data::exec::ElemIter;
use tfdatasvc::data::graph::PipelineBuilder;
use tfdatasvc::data::udf::UdfRegistry;
use tfdatasvc::metrics::{write_csv_rows, write_json_file};
use tfdatasvc::orchestrator::Cell;
use tfdatasvc::service::dispatcher::DispatcherConfig;
use tfdatasvc::service::proto::{ProcessingMode, ShardingPolicy};
use tfdatasvc::service::{ServiceClient, ServiceClientConfig};
use tfdatasvc::sim::cost::CostModel;
use tfdatasvc::sim::des::{simulate_job, JobSimConfig};
use tfdatasvc::sim::models::model;
use tfdatasvc::storage::ObjectStore;
use tfdatasvc::util::json::{obj, Json};

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let m = model("M1");
    let colo = simulate_job(m, &JobSimConfig::default());
    let ideal_speedup = m.ideal_bps / colo.throughput_bps;
    let cm = CostModel::production_like();
    let clients = m.accelerators as f64 / 8.0;
    let t_colo = 10.0;
    let colo_cost = cm.job_cost(t_colo, 0.0, 0.0, 0.0, clients, 96.0, 335.0, 8.0).total;

    println!("=== Fig 9: M1 worker-count sweep (colocated: {:.2} b/s; ideal {ideal_speedup:.1}x) ===", colo.throughput_bps);
    println!("{:>8} {:>10} {:>9} {:>11} {:>10} {:>10}", "workers", "b/s", "speedup", "worker util", "cost", "saving");
    let mut rows = Vec::new();
    let mut prev_bps = 0.0;
    for n in [8usize, 16, 32, 64, 128, 256, 512, 640] {
        let r = simulate_job(m, &JobSimConfig { n_workers: n, ..Default::default() });
        let speedup = r.throughput_bps / colo.throughput_bps;
        let t_dis = t_colo / speedup;
        let cost = cm
            .job_cost(
                t_dis,
                n as f64,
                m.worker_cpu_cores * r.worker_utilization,
                8.0,
                clients,
                96.0,
                335.0,
                8.0,
            )
            .total;
        let saving = colo_cost / cost;
        println!(
            "{:>8} {:>10.2} {:>8.2}x {:>10.0}% {:>10.1} {:>9.2}x",
            n,
            r.throughput_bps,
            speedup,
            r.worker_utilization * 100.0,
            cost,
            saving
        );
        rows.push(vec![
            n.to_string(),
            format!("{:.3}", r.throughput_bps),
            format!("{speedup:.3}"),
            format!("{saving:.3}"),
        ]);
        assert!(r.throughput_bps >= prev_bps - 1e-6, "throughput must be monotone");
        prev_bps = r.throughput_bps;
    }
    // Shape assertions from the paper.
    let at = |n: usize| {
        simulate_job(m, &JobSimConfig { n_workers: n, ..Default::default() }).throughput_bps
            / colo.throughput_bps
    };
    assert!(at(8) < 1.0, "8 workers slower than colocated");
    assert!(at(16) > 1.0, "16 workers faster than colocated");
    assert!(at(512) > 0.95 * ideal_speedup, "512 workers reach ideal");
    let (s512, s640) = (at(512), at(640));
    assert!((s640 - s512).abs() / s512 < 0.02, "over-provisioning does not change job time");
    write_csv_rows("out/fig9.csv", "workers,bps,speedup,cost_saving", &rows).unwrap();

    // --- Live pool-size sweep (§3.1): the worker-count axis walked on a
    // real cell. Growth adds workers mid-job; every shrink picks the
    // least-loaded worker and runs the two-phase graceful drain while a
    // coordinated consumer keeps stepping — no step may stall longer
    // than ~one worker heartbeat, and no round may be skipped.
    let sizes: &[usize] = if smoke { &[1, 2, 1] } else { &[1, 2, 4, 2, 1] };
    let cell = Arc::new(
        Cell::new(
            ObjectStore::in_memory(),
            UdfRegistry::with_builtins(),
            DispatcherConfig::default(),
        )
        .unwrap(),
    );
    cell.scale_to(1).unwrap();
    // Drive the drain state machine the way the scaling controller does:
    // tick plans lease handoffs, reap removes workers whose drain
    // completed.
    let stop_tick = Arc::new(AtomicBool::new(false));
    let ticker = {
        let (c, s) = (cell.clone(), stop_tick.clone());
        std::thread::spawn(move || {
            while !s.load(Ordering::SeqCst) {
                c.tick();
                c.reap_drained();
                std::thread::sleep(Duration::from_millis(50));
            }
        })
    };
    let live_graph = PipelineBuilder::source_range(1_000_000).build();
    let client = ServiceClient::new(&cell.dispatcher_addr());
    let mut it = client
        .distribute(
            &live_graph,
            ServiceClientConfig {
                sharding: ShardingPolicy::Off,
                mode: ProcessingMode::Coordinated,
                job_name: "fig9-live".into(),
                num_consumers: 1,
                consumer_index: 0,
                ..Default::default()
            },
        )
        .unwrap();
    let mut max_step = Duration::ZERO;
    let mut step = |max_step: &mut Duration, timed: bool| {
        let f0 = Instant::now();
        let e = it.next().expect("round fetch failed").expect("stream ended early");
        std::hint::black_box(&e);
        if timed {
            *max_step = (*max_step).max(f0.elapsed());
        }
    };
    // Warm up untimed: job registration and the first task attach cost a
    // couple of heartbeats and are not a resize stall.
    for _ in 0..5 {
        step(&mut max_step, false);
    }

    println!(
        "\n=== Fig 9 live sweep: pool {:?} via graceful drains{} ===",
        sizes,
        if smoke { ", smoke" } else { "" }
    );
    let mut resizes: Vec<Json> = Vec::new();
    let mut expect_drains = 0u64;
    let mut prev = 1usize;
    for &n in sizes {
        if n < prev {
            expect_drains += (prev - n) as u64;
        }
        prev = n;
        let t0 = Instant::now();
        cell.request_scale_to(n).unwrap();
        let deadline = Instant::now() + Duration::from_secs(20);
        while cell.worker_count() != n {
            assert!(Instant::now() < deadline, "resize to {n} workers never converged");
            step(&mut max_step, true);
            std::thread::sleep(Duration::from_millis(10));
        }
        let converge_ms = t0.elapsed().as_secs_f64() * 1e3;
        // A few steady rounds at the new size: the plane must flow.
        for _ in 0..5 {
            step(&mut max_step, true);
        }
        println!("live resize -> {n:>2} workers in {converge_ms:>6.0} ms");
        resizes.push(obj([("target", (n as u64).into()), ("converge_ms", converge_ms.into())]));
    }

    let dm = cell.dispatcher().metrics();
    let drains_started = dm.counter("dispatcher/worker_drains_started").get();
    let drained = dm.counter("dispatcher/workers_drained").get();
    let skipped = client.metrics().counter("client/rounds_skipped_forward").get();
    println!(
        "live sweep: {drains_started} drains started / {drained} drained, max step {:.1} ms",
        max_step.as_secs_f64() * 1e3
    );
    assert_eq!(drained, expect_drains, "every shrink must go through a graceful drain");
    assert!(
        drains_started >= expect_drains,
        "drains started ({drains_started}) below drains completed"
    );
    // One worker heartbeat (100 ms) is the protocol stall bound for a
    // lease handoff; 5x covers CI scheduler noise.
    assert!(
        max_step < Duration::from_millis(500),
        "a step stalled {max_step:?} during a live resize"
    );
    assert_eq!(skipped, 0, "a graceful resize must never trigger skip-forward");
    it.release();
    stop_tick.store(true, Ordering::SeqCst);
    let _ = ticker.join();

    write_json_file(
        "out/bench_worker_sweep_live.json",
        &obj([
            ("bench", "fig9_worker_sweep_live".into()),
            ("smoke", smoke.into()),
            ("resizes", Json::Arr(resizes)),
            ("worker_drains_started", drains_started.into()),
            ("workers_drained", drained.into()),
            ("max_step_ms", (max_step.as_secs_f64() * 1e3).into()),
            ("rounds_skipped_forward", skipped.into()),
        ]),
    )
    .unwrap();
    println!("fig9 OK -> out/fig9.csv + out/bench_worker_sweep_live.json");
}
