//! The calibrated model zoo.
//!
//! The paper reports, per model: accelerator count, baseline (colocated)
//! and ideal training throughput, the worker count the service scaled to,
//! and the resulting speedup. Those observables pin each model's resource
//! profile:
//!
//! * accelerator step time  = accelerators / ideal_bps (sync data-parallel:
//!   one step produces one batch per accelerator),
//! * preprocessing cost per batch = client CPU cores / colocated_bps
//!   (input-bound baselines saturate the client host's CPU),
//! * per-batch worker-side overhead (serialization + RPC) explains why 8
//!   remote workers underperform colocated processing (§4.2 sweep).
//!
//! Paper numbers (Fig. 8, §4.2): M1 0.55→6.47 b/s @442 workers (11.7×),
//! M2 4.7→518.4 @421 (110.3×, 8% short of ideal), M3 22.2→63.8 @128
//! (2.9×), ResNet50 1.75→4.5 @16 (2.57×). Fig. 11: M5 1.62×, M6 1.53×,
//! M7 3.5×, M8 2.15×.

/// Workload domain.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Domain {
    Vision,
    Nlp,
}

/// One evaluated model's calibrated profile.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub name: &'static str,
    pub domain: Domain,
    /// Accelerators used in the paper's experiment.
    pub accelerators: usize,
    /// Colocated-baseline training throughput (batches/s, aggregate).
    pub colocated_bps: f64,
    /// Ideal throughput with an infinitely fast input pipeline.
    pub ideal_bps: f64,
    /// Worker count the service scaled to in the paper.
    pub paper_workers: usize,
    /// Throughput the service actually delivered (batches/s): equals
    /// `ideal_bps` except M2, which fell 8% short of ideal due to
    /// client-side deserialization/copy pressure (§4.2).
    pub service_bps: f64,
    /// Per-remote-worker production rate (batches/s/worker). For M1 this
    /// comes straight from the Fig. 9 sweep (0.3 b/s at 8 workers, 2.3
    /// at 64, 4.77 at 128 => ~0.0375 b/s/worker); for the others it is
    /// service_bps / paper_workers x 1.18 headroom (deployed pools run
    /// below full utilization; an exactly-balanced queue cannot sustain
    /// the measured throughput).
    pub per_worker_bps: f64,
    /// Paper-reported speedup (Fig. 8a / Fig. 11) — the target our sim
    /// must land near.
    pub paper_speedup: f64,
    /// Paper-reported cost saving (Fig. 8b), 0.0 if not reported.
    pub paper_cost_saving: f64,
    /// CPU cores available on the client host(s) for colocated
    /// preprocessing (aggregate across hosts).
    pub client_cpu_cores: f64,
    /// Cores per remote worker (fleet VMs are n2-standard-8-like).
    pub worker_cpu_cores: f64,
    /// Preprocessed batch size on the wire, bytes.
    pub batch_bytes: usize,
    /// NLP only: lognormal(mu, sigma) sequence-length distribution and
    /// the max (padded) sequence length.
    pub seq_len_dist: Option<(f64, f64, u32)>,
    /// NLP only: coordinated-reads bucket width (64 for M5/M7, 128 for
    /// M6/M8; §4.4).
    pub bucket_width: u32,
    /// Fraction of a training step's compute that does NOT scale with the
    /// padded token count (optimizer, collectives, fixed kernels). Low
    /// values mean step time tracks padding closely — M7's 3.5x gain
    /// implies an almost fully token-proportional step.
    pub fixed_compute_fraction: f64,
}

impl ModelSpec {
    /// Accelerator step time (seconds): one sync step produces
    /// `accelerators` batches.
    pub fn accel_step_time(&self) -> f64 {
        self.accelerators as f64 / self.ideal_bps
    }

    /// CPU-seconds of preprocessing per batch, derived from the
    /// input-bound colocated baseline saturating the client host CPU.
    pub fn preprocess_cpu_per_batch(&self) -> f64 {
        self.client_cpu_cores / self.colocated_bps
    }

    /// Whether the job is input-bound with colocated preprocessing.
    pub fn input_bound(&self) -> bool {
        self.colocated_bps < 0.99 * self.ideal_bps
    }
}

/// Per-batch worker-side CPU overhead (serialization, RPC framing, data
/// copies) as a fraction of each worker's cores — the §4.2 explanation
/// for why 8 remote workers lose to colocated processing. Calibrated
/// from the Fig. 9 sweep: 8 workers produce 0.3 b/s for M1 while the
/// colocated host's larger CPU reaches 0.55 b/s.
pub const WORKER_OVERHEAD_FRACTION: f64 = 0.18;

/// The model zoo. M1–M3 + ResNet50 drive the horizontal-scale-out
/// experiments; M4 drives ephemeral sharing; M5–M8 drive coordinated
/// reads (not input-bound: colocated == ideal).
pub const MODEL_ZOO: &[ModelSpec] = &[
    ModelSpec {
        name: "M1",
        domain: Domain::Vision,
        accelerators: 32,
        colocated_bps: 0.55,
        ideal_bps: 6.47,
        paper_workers: 442,
        service_bps: 6.47,
        per_worker_bps: 0.0375,
        paper_speedup: 11.7,
        paper_cost_saving: 10.8,
        client_cpu_cores: 480.0,
        worker_cpu_cores: 8.0,
        batch_bytes: 64 << 20,
        seq_len_dist: None,
        bucket_width: 0,
        fixed_compute_fraction: 0.0,
    },
    ModelSpec {
        name: "M2",
        domain: Domain::Vision,
        accelerators: 8,
        colocated_bps: 4.7,
        ideal_bps: 563.0, // ideal; service reached 518.4 (8% short)
        paper_workers: 421,
        service_bps: 518.4,
        per_worker_bps: 1.453,
        paper_speedup: 110.3,
        paper_cost_saving: 89.3,
        client_cpu_cores: 480.0,
        worker_cpu_cores: 8.0,
        batch_bytes: 2 << 20,
        seq_len_dist: None,
        bucket_width: 0,
        fixed_compute_fraction: 0.0,
    },
    ModelSpec {
        name: "M3",
        domain: Domain::Vision,
        accelerators: 16,
        colocated_bps: 22.2,
        ideal_bps: 63.8,
        paper_workers: 128,
        service_bps: 63.8,
        per_worker_bps: 0.588,
        paper_speedup: 2.9,
        paper_cost_saving: 2.8,
        client_cpu_cores: 480.0,
        worker_cpu_cores: 8.0,
        batch_bytes: 8 << 20,
        seq_len_dist: None,
        bucket_width: 0,
        fixed_compute_fraction: 0.0,
    },
    ModelSpec {
        name: "ResNet50",
        domain: Domain::Vision,
        accelerators: 1,
        colocated_bps: 1.75,
        ideal_bps: 4.5,
        paper_workers: 16,
        service_bps: 4.5,
        per_worker_bps: 0.332,
        paper_speedup: 2.57,
        paper_cost_saving: 1.97,
        client_cpu_cores: 96.0, // TPU v2-8 VM
        worker_cpu_cores: 8.0,  // n2-standard-8
        batch_bytes: 1024 * 224 * 224 * 3 / 2,
        seq_len_dist: None,
        bucket_width: 0,
        fixed_compute_fraction: 0.0,
    },
    ModelSpec {
        name: "M4", // ephemeral-sharing model: not input-bound at >=128 workers
        domain: Domain::Vision,
        accelerators: 16,
        colocated_bps: 1.92,
        ideal_bps: 1.92,
        paper_workers: 128,
        service_bps: 1.92,
        per_worker_bps: 0.0177,
        paper_speedup: 1.0,
        paper_cost_saving: 0.0,
        client_cpu_cores: 480.0,
        worker_cpu_cores: 8.0,
        batch_bytes: 16 << 20,
        seq_len_dist: None,
        bucket_width: 0,
        fixed_compute_fraction: 0.0,
    },
    // NLP models: colocated == ideal (not input-bound); the §4.4 gains
    // come from straggler removal. seq dists calibrated to land near the
    // paper's speedups: more skew + finer buckets => larger gains.
    ModelSpec {
        name: "M5",
        domain: Domain::Nlp,
        accelerators: 64,
        colocated_bps: 3.18,
        ideal_bps: 3.18,
        paper_workers: 4,
        service_bps: 5.15,
        per_worker_bps: 1.2875,
        paper_speedup: 1.62,
        paper_cost_saving: 1.62,
        client_cpu_cores: 480.0,
        worker_cpu_cores: 8.0,
        batch_bytes: 4 << 20,
        seq_len_dist: Some((4.3, 0.35, 512)),
        bucket_width: 64,
        fixed_compute_fraction: 0.15,
    },
    ModelSpec {
        name: "M6",
        domain: Domain::Nlp,
        accelerators: 8,
        colocated_bps: 11.9,
        ideal_bps: 11.9,
        paper_workers: 1,
        service_bps: 18.3,
        per_worker_bps: 18.3,
        paper_speedup: 1.53,
        paper_cost_saving: 1.53,
        client_cpu_cores: 480.0,
        worker_cpu_cores: 8.0,
        batch_bytes: 2 << 20,
        seq_len_dist: Some((4.4, 0.45, 512)),
        bucket_width: 128,
        fixed_compute_fraction: 0.15,
    },
    ModelSpec {
        name: "M7",
        domain: Domain::Nlp,
        accelerators: 64,
        colocated_bps: 2.0,
        ideal_bps: 2.0,
        paper_workers: 4,
        service_bps: 7.0,
        per_worker_bps: 1.75,
        paper_speedup: 3.5,
        paper_cost_saving: 3.5,
        client_cpu_cores: 480.0,
        worker_cpu_cores: 8.0,
        batch_bytes: 4 << 20,
        seq_len_dist: Some((3.5, 1.2, 512)),
        bucket_width: 64,
        fixed_compute_fraction: 0.05,
    },
    ModelSpec {
        name: "M8",
        domain: Domain::Nlp,
        accelerators: 4,
        colocated_bps: 5.9,
        ideal_bps: 5.9,
        paper_workers: 1,
        service_bps: 12.7,
        per_worker_bps: 12.7,
        paper_speedup: 2.15,
        paper_cost_saving: 2.15,
        client_cpu_cores: 480.0,
        worker_cpu_cores: 8.0,
        batch_bytes: 2 << 20,
        seq_len_dist: Some((3.8, 1.0, 512)),
        bucket_width: 128,
        fixed_compute_fraction: 0.15,
    },
];

/// Look up a model by name.
pub fn model(name: &str) -> &'static ModelSpec {
    MODEL_ZOO.iter().find(|m| m.name == name).unwrap_or_else(|| panic!("no model {name}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_contains_all_paper_models() {
        let names: Vec<&str> = MODEL_ZOO.iter().map(|m| m.name).collect();
        for n in ["M1", "M2", "M3", "M4", "M5", "M6", "M7", "M8", "ResNet50"] {
            assert!(names.contains(&n), "missing {n}");
        }
    }

    #[test]
    fn derived_quantities_are_consistent() {
        let m1 = model("M1");
        assert!(m1.input_bound());
        // Paper: M1 ideal is 11.7x colocated.
        assert!((m1.ideal_bps / m1.colocated_bps - 11.76).abs() < 0.1);
        // Step time positive and sane.
        assert!(m1.accel_step_time() > 0.0);
        assert!(m1.preprocess_cpu_per_batch() > 100.0, "M1 is very preprocessing-heavy");
    }

    #[test]
    fn nlp_models_are_not_input_bound() {
        for n in ["M5", "M6", "M7", "M8"] {
            assert!(!model(n).input_bound(), "{n} must be model-bound");
            assert!(model(n).seq_len_dist.is_some());
        }
    }

    #[test]
    fn speedups_match_paper_table() {
        assert_eq!(model("M2").paper_speedup, 110.3);
        assert_eq!(model("ResNet50").paper_cost_saving, 1.97);
        let avg: f64 = ["M1", "M2", "M3", "ResNet50"]
            .iter()
            .map(|n| model(n).paper_speedup)
            .sum::<f64>()
            / 4.0;
        assert!((avg - 31.7).abs() < 0.3, "paper: 31.7x average, got {avg}");
    }
}
