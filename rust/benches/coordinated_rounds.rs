//! Pipelined coordinated reads (§3.6): round-lease prefetch on vs off
//! under skewed element sizes — the paper's straggler scenario.
//!
//! The trainer spends ~T per step on compute; every round costs F on the
//! wire (materialize is already overlapped by the worker's multi-round
//! buffer; F is transfer + decode, with periodic stragglers several
//! times larger than the median, travelling as continuation frames
//! against a small negotiated frame budget). Lock-step pays `T + F` per
//! step; the prefetching client pays `max(T, F)` — the §3.6 software
//! pipeline applied across the wire.
//!
//! Acceptance (full mode): prefetch-on >= 1.5x steps/sec and a lower
//! p99 round latency than prefetch-off. A second section compares the
//! single-thread pipelined engine against **multi-owner concurrent
//! fetch** on a 3-worker topology (one in-flight round per distinct
//! owner): >= 1.2x steps/sec required, smoke included. A third section
//! resizes a live job 1 -> 2 -> 1 (§3.6 elastic membership) and records
//! join/drain latencies plus the surviving slot's round-gap tail.
//! `--smoke` shrinks the epochs and relaxes the prefetch ratio for
//! shared CI boxes. Results are emitted machine-readable to
//! `out/bench_coordinated_rounds.json` and mirrored to the repo-root
//! baseline `BENCH_coordinated_rounds.json`.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use tfdatasvc::data::element::{DType, Tensor};
use tfdatasvc::data::exec::ElemIter;
use tfdatasvc::data::graph::PipelineBuilder;
use tfdatasvc::data::udf::UdfRegistry;
use tfdatasvc::data::Element;
use tfdatasvc::metrics::write_json_file;
use tfdatasvc::service::dispatcher::{Dispatcher, DispatcherConfig};
use tfdatasvc::service::proto::{ProcessingMode, ShardingPolicy};
use tfdatasvc::service::worker::{Worker, WorkerConfig, MIN_STREAM_FRAME_LEN};
use tfdatasvc::service::{ServiceClient, ServiceClientConfig};
use tfdatasvc::storage::ObjectStore;
use tfdatasvc::util::hist::Samples;
use tfdatasvc::util::json::obj;

/// Median element ~512 KiB; every 4th a ~4 MiB straggler. Against a
/// 128 KiB negotiated frame budget both travel as continuation frames,
/// so the fetch cost F is dominated by chunk RPC round-trips and skews
/// hard at p99.
const SMALL_BYTES: usize = 512 << 10;
const BIG_BYTES: usize = 4 << 20;

struct RunStats {
    steps: u64,
    secs: f64,
    mean_ms: f64,
    p50_ms: f64,
    p95_ms: f64,
    p99_ms: f64,
    prefetched: u64,
}

fn run(
    dispatcher_addr: &str,
    graph: &tfdatasvc::data::GraphDef,
    depth: u32,
    concurrent: bool,
    train_step: Duration,
) -> RunStats {
    let client = ServiceClient::new(dispatcher_addr);
    let mut it = client
        .distribute(
            graph,
            ServiceClientConfig {
                sharding: ShardingPolicy::Off,
                mode: ProcessingMode::Coordinated,
                num_consumers: 1,
                consumer_index: 0,
                max_frame_len: MIN_STREAM_FRAME_LEN as u64,
                round_prefetch_depth: depth,
                concurrent_round_fetch: concurrent,
                ..Default::default()
            },
        )
        .unwrap();
    let mut lat = Samples::new();
    let t0 = Instant::now();
    let mut steps = 0u64;
    loop {
        let f0 = Instant::now();
        match it.next() {
            Ok(Some(e)) => {
                lat.push(f0.elapsed().as_secs_f64() * 1e3);
                std::hint::black_box(&e);
                steps += 1;
                // "Train" on the round: spin for the step budget (spin,
                // not sleep — immune to timer quantization on CI boxes).
                let s0 = Instant::now();
                while s0.elapsed() < train_step {
                    std::hint::black_box(steps);
                }
            }
            Ok(None) => break,
            Err(e) => panic!("round fetch failed: {e}"),
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    let prefetched = client.metrics().counter("client/rounds_prefetched").get();
    it.release();
    RunStats {
        steps,
        secs,
        mean_ms: lat.mean(),
        p50_ms: lat.percentile(50.0),
        p95_ms: lat.percentile(95.0),
        p99_ms: lat.percentile(99.0),
        prefetched,
    }
}

/// Skewed element sizes: the straggler scenario coordinated reads exist
/// for (§3.6) — every 4th element ~8x the median.
fn skewed_udfs() -> UdfRegistry {
    let udfs = UdfRegistry::with_builtins();
    udfs.register_fn("bench.skew", move |e| {
        let n = if e.ids[0] % 4 == 3 { BIG_BYTES } else { SMALL_BYTES };
        Ok(Element::with_ids(
            vec![Tensor::new(DType::U8, vec![n], vec![(e.ids[0] % 251) as u8; n])],
            e.ids.clone(),
        ))
    });
    udfs
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let rounds: u64 = if smoke { 96 } else { 384 };

    let store = ObjectStore::in_memory();
    let d = Dispatcher::start("127.0.0.1:0", DispatcherConfig::default()).unwrap();
    let _w =
        Worker::start("127.0.0.1:0", &d.addr(), WorkerConfig::new(store, skewed_udfs())).unwrap();
    let graph = Arc::new(PipelineBuilder::source_range(rounds).map("bench.skew").build());
    let calib_graph = PipelineBuilder::source_range(32).map("bench.skew").build();

    // Self-calibrate the trainer's step budget to the *measured* mean
    // fetch cost on this machine: the software pipeline's win is largest
    // (2x ideal) when compute and fetch are balanced, and calibrating
    // keeps the acceptance ratio meaningful on fast and slow boxes
    // alike.
    let probe = run(&d.addr(), &calib_graph, 0, false, Duration::ZERO);
    let train_step = Duration::from_secs_f64(
        (probe.mean_ms / 1e3).clamp(0.000_3, 0.02),
    );
    println!(
        "=== coordinated_rounds: round-lease prefetch on vs off ({} rounds{}, fetch ~{:.2} ms, \
         train step {:.2} ms) ===",
        rounds,
        if smoke { ", smoke" } else { "" },
        probe.mean_ms,
        train_step.as_secs_f64() * 1e3
    );
    println!(
        "{:<14} {:>8} {:>10} {:>9} {:>9} {:>9} {:>11}",
        "mode", "steps", "steps/s", "p50 ms", "p95 ms", "p99 ms", "prefetched"
    );
    let report = |name: &str, s: &RunStats| {
        println!(
            "{:<14} {:>8} {:>10.1} {:>9.2} {:>9.2} {:>9.2} {:>11}",
            name,
            s.steps,
            s.steps as f64 / s.secs,
            s.p50_ms,
            s.p95_ms,
            s.p99_ms,
            s.prefetched
        );
    };
    // Off first (cold caches penalize the baseline, not the candidate —
    // conservative for the assertion below). Each mode drains one full
    // epoch of the same pipeline. Both prefetch modes here use the
    // single-thread engine: the multi-owner comparison below isolates
    // concurrency on a 3-worker topology.
    let off = run(&d.addr(), &graph, 0, false, train_step);
    report("prefetch-off", &off);
    let on = run(&d.addr(), &graph, 2, false, train_step);
    report("prefetch-on", &on);

    assert_eq!(on.steps, off.steps, "both modes must deliver the same round count");
    assert_eq!(off.prefetched, 0, "depth 0 is lock-step");
    assert!(on.prefetched > 0, "depth 2 really prefetched");

    let speedup = (on.steps as f64 / on.secs) / (off.steps as f64 / off.secs);
    println!(
        "prefetch speedup: {speedup:.2}x steps/sec, p99 round latency {:.2} ms -> {:.2} ms",
        off.p99_ms, on.p99_ms
    );

    // --- Multi-owner concurrent fetch on a 3-worker topology (§3.6
    // across owners). The single-thread pipelined engine serializes wire
    // transfers even with rounds prefetched; the multi-owner engine
    // keeps one round in flight per distinct owner, so the round cadence
    // approaches fetch/3. Both engines run depth 3 over the same
    // cluster; the trainer step is calibrated to a third of the measured
    // fetch cost (the fetch-dominated regime the concurrency targets).
    let rounds3: u64 = if smoke { 40 } else { 128 };
    let d3 = Dispatcher::start("127.0.0.1:0", DispatcherConfig::default()).unwrap();
    let store3 = ObjectStore::in_memory();
    let _workers3: Vec<Worker> = (0..3)
        .map(|_| {
            Worker::start(
                "127.0.0.1:0",
                &d3.addr(),
                WorkerConfig::new(store3.clone(), skewed_udfs()),
            )
            .unwrap()
        })
        .collect();
    let graph3 = PipelineBuilder::source_range(rounds3).map("bench.skew").build();
    let calib3 = PipelineBuilder::source_range(12).map("bench.skew").build();
    let probe3 = run(&d3.addr(), &calib3, 0, false, Duration::ZERO);
    let train_step3 =
        Duration::from_secs_f64((probe3.mean_ms / 1e3 / 3.0).clamp(0.000_1, 0.01));
    println!(
        "=== multi-owner concurrent fetch: 3 workers, depth 3 (fetch ~{:.2} ms, train step \
         {:.2} ms) ===",
        probe3.mean_ms,
        train_step3.as_secs_f64() * 1e3
    );
    let single = run(&d3.addr(), &graph3, 3, false, train_step3);
    report("single-thread", &single);
    let multi = run(&d3.addr(), &graph3, 3, true, train_step3);
    report("multi-owner", &multi);
    assert_eq!(
        multi.steps, single.steps,
        "both engines must deliver the same round count"
    );
    let mo_speedup =
        (multi.steps as f64 / multi.secs) / (single.steps as f64 / single.secs);
    println!("multi-owner speedup: {mo_speedup:.2}x steps/sec over the single-thread engine");

    // --- Elastic consumer membership (§3.6 elasticity): resize a live
    // 2-worker coordinated job 1 -> 2 -> 1 and measure what a trainer
    // fleet actually feels — the time from the resize RPC to the grown
    // slot's first delivered round, the time for the shrunk slot to
    // drain to a clean end-of-stream at the barrier, and the round-gap
    // distribution the surviving slot sees across both barriers (the
    // round plane must keep flowing while membership changes underneath
    // it; skip-forward must never fire on a resize).
    let de = Dispatcher::start("127.0.0.1:0", DispatcherConfig::default()).unwrap();
    let store_e = ObjectStore::in_memory();
    let workers_e: Vec<Worker> = (0..2)
        .map(|_| {
            Worker::start(
                "127.0.0.1:0",
                &de.addr(),
                WorkerConfig::new(store_e.clone(), UdfRegistry::with_builtins()),
            )
            .unwrap()
        })
        .collect();
    let graph_e = PipelineBuilder::source_range(1_000_000).build();
    let elastic_cfg = |ci: u32, n: u32| ServiceClientConfig {
        sharding: ShardingPolicy::Off,
        mode: ProcessingMode::Coordinated,
        job_name: "bench-elastic".into(),
        num_consumers: n,
        consumer_index: ci,
        ..Default::default()
    };
    let client0 = ServiceClient::new(&de.addr());
    let mut it0 = client0.distribute(&graph_e, elastic_cfg(0, 1)).unwrap();
    let elastic_job = it0.job_id();
    // The surviving slot drains continuously (unpaced) on its own thread;
    // it must ride out both barriers without an error or a skip.
    let stop0 = Arc::new(AtomicBool::new(false));
    let survivor = {
        let stop0 = stop0.clone();
        std::thread::spawn(move || {
            let mut gaps = Samples::new();
            let mut n = 0u64;
            let mut last = Instant::now();
            while !stop0.load(Ordering::SeqCst) {
                match it0.next() {
                    Ok(Some(e)) => {
                        std::hint::black_box(&e);
                        gaps.push(last.elapsed().as_secs_f64() * 1e3);
                        last = Instant::now();
                        n += 1;
                    }
                    Ok(None) => break,
                    Err(e) => panic!("surviving slot errored during resize: {e}"),
                }
            }
            it0.release();
            (gaps, n)
        })
    };
    // Let progress heartbeats land so the grow barrier sits at the live
    // frontier, then grow and join a second consumer slot.
    std::thread::sleep(Duration::from_millis(150));
    let t_grow = Instant::now();
    let (_, grow_barrier) = de.set_job_consumers(elastic_job, 2).unwrap();
    let client1 = ServiceClient::new(&de.addr());
    let mut it1 = client1.distribute(&graph_e, elastic_cfg(1, 2)).unwrap();
    let first = it1.next().unwrap().expect("grown slot got no round");
    std::hint::black_box(&first);
    let join_ms = t_grow.elapsed().as_secs_f64() * 1e3;
    let mut grown_rounds = 1u64;
    while grown_rounds < 25 {
        let e = it1.next().unwrap().expect("grown slot ended early");
        std::hint::black_box(&e);
        grown_rounds += 1;
    }
    // Shrink back: the grown slot drains up to the barrier and ends
    // cleanly (no terminal error, no skip), while slot 0 keeps flowing.
    std::thread::sleep(Duration::from_millis(150));
    let t_shrink = Instant::now();
    let (_, shrink_barrier) = de.set_job_consumers(elastic_job, 1).unwrap();
    while let Some(e) = it1.next().expect("shrunk slot must end cleanly, not error") {
        std::hint::black_box(&e);
        grown_rounds += 1;
    }
    let drain_ms = t_shrink.elapsed().as_secs_f64() * 1e3;
    it1.release();
    stop0.store(true, Ordering::SeqCst);
    let (gaps0, survivor_rounds) = survivor.join().unwrap();
    println!(
        "=== elastic resize 1 -> 2 -> 1: join-to-first-round {join_ms:.1} ms, shrink drain \
         {drain_ms:.1} ms, survivor {survivor_rounds} rounds (p99 gap {:.2} ms) ===",
        gaps0.percentile(99.0)
    );
    assert!(shrink_barrier > grow_barrier, "resize barriers must advance monotonically");
    assert!(grown_rounds >= 25, "grown slot delivered only {grown_rounds} rounds");
    for c in [&client0, &client1] {
        assert_eq!(
            c.metrics().counter("client/rounds_skipped_forward").get(),
            0,
            "a resize must never trigger skip-forward"
        );
    }
    for w in &workers_e {
        assert!(
            w.metrics().counter("worker/width_updates_applied").get() >= 1,
            "every worker must apply the membership-epoch schedule"
        );
    }

    let bench_json = obj([
            ("bench", "coordinated_rounds".into()),
            ("smoke", smoke.into()),
            ("rounds", rounds.into()),
            ("fetch_mean_ms", probe.mean_ms.into()),
            ("train_step_ms", (train_step.as_secs_f64() * 1e3).into()),
            (
                "prefetch_off",
                obj([
                    ("steps_per_sec", (off.steps as f64 / off.secs).into()),
                    ("p50_ms", off.p50_ms.into()),
                    ("p95_ms", off.p95_ms.into()),
                    ("p99_ms", off.p99_ms.into()),
                ]),
            ),
            (
                "prefetch_on",
                obj([
                    ("steps_per_sec", (on.steps as f64 / on.secs).into()),
                    ("p50_ms", on.p50_ms.into()),
                    ("p95_ms", on.p95_ms.into()),
                    ("p99_ms", on.p99_ms.into()),
                    ("rounds_prefetched", on.prefetched.into()),
                ]),
            ),
            ("speedup", speedup.into()),
            (
                "multi_owner",
                obj([
                    ("workers", 3.0.into()),
                    ("depth", 3.0.into()),
                    ("single_steps_per_sec", (single.steps as f64 / single.secs).into()),
                    ("multi_steps_per_sec", (multi.steps as f64 / multi.secs).into()),
                    ("single_p99_ms", single.p99_ms.into()),
                    ("multi_p99_ms", multi.p99_ms.into()),
                    ("speedup", mo_speedup.into()),
                ]),
            ),
            (
                "elastic_resize",
                obj([
                    ("workers", 2.0.into()),
                    ("grow_barrier", grow_barrier.into()),
                    ("shrink_barrier", shrink_barrier.into()),
                    ("join_first_round_ms", join_ms.into()),
                    ("shrink_drain_ms", drain_ms.into()),
                    ("grown_slot_rounds", grown_rounds.into()),
                    ("surviving_slot_rounds", survivor_rounds.into()),
                    ("surviving_slot_p50_gap_ms", gaps0.percentile(50.0).into()),
                    ("surviving_slot_p99_gap_ms", gaps0.percentile(99.0).into()),
                    ("rounds_skipped_forward", 0.0.into()),
                ]),
            ),
        ]);
    write_json_file("out/bench_coordinated_rounds.json", &bench_json).unwrap();
    // Also publish at the repo root under the stable name the roadmap
    // tracks (CI regenerates it every run; the checked-in copy is the
    // latest accepted baseline).
    write_json_file("BENCH_coordinated_rounds.json", &bench_json).unwrap();

    // Acceptance: the pipeline must beat lock-step decisively under skew
    // in full mode; smoke (CI) only guards against gross regressions —
    // shared runners are too noisy for the full bar.
    let min_speedup = if smoke { 1.1 } else { 1.5 };
    assert!(
        speedup >= min_speedup,
        "acceptance: prefetch-on must sustain >= {min_speedup}x steps/sec vs lock-step \
         (got {speedup:.2}x)"
    );
    if !smoke {
        assert!(
            on.p99_ms < off.p99_ms,
            "acceptance: prefetch must cut p99 round latency ({:.2} ms vs {:.2} ms)",
            on.p99_ms,
            off.p99_ms
        );
    }
    // Acceptance (smoke included): multi-owner concurrent fetch must
    // sustain >= 1.2x steps/sec over the single-thread engine on the
    // 3-worker topology (theoretical ceiling ~3x in this fetch-bound
    // regime, so 1.2x leaves headroom for noisy CI boxes).
    assert!(
        mo_speedup >= 1.2,
        "acceptance: multi-owner engine must sustain >= 1.2x steps/sec vs single-thread \
         (got {mo_speedup:.2}x)"
    );
    println!(
        "coordinated_rounds OK -> out/bench_coordinated_rounds.json + BENCH_coordinated_rounds.json"
    );
}
