//! Fig. 10: normalized preprocessing cost of hyperparameter-tuning jobs
//! under deployment modes A (shared + sharing), B (shared, no sharing),
//! C (dedicated per job), for k in {1,2,4,8,16}.
//!
//! Paper: A flat at 1x (tested to 64 jobs); B fine to 4 jobs then job
//! time grows 1.75x @ 8 and 3x @ 16; C cost grows linearly.
//!
//! Two halves:
//! 1. the `sim::sharing` cost model reproducing the figure, and
//! 2. a **real-service cross-check**: k in-process jobs against a live
//!    dispatcher/worker, once with `sharing: auto` (mode A — all k attach
//!    to one fingerprint-matched job) and once with `sharing: off`
//!    (mode B — k dedicated productions on the same pool), printing
//!    measured production cost next to the sim prediction so the model
//!    and the implementation keep each other honest.
//!
//! A third section exercises the **spill tier** (§3.5 window backed by
//! the §4.2 store): a late client attaches mid-epoch and replays the
//! full epoch from spilled segments with zero relaxed-visitation
//! skips, and a re-submitted identical pipeline is served from the
//! committed fingerprint-keyed snapshot with no new production.
//!
//! `--smoke` shrinks the dataset and k for CI.

use std::sync::Arc;
use std::time::{Duration, Instant};
use tfdatasvc::data::exec::ElemIter;
use tfdatasvc::data::graph::PipelineBuilder;
use tfdatasvc::data::udf::UdfRegistry;
use tfdatasvc::metrics::write_csv_rows;
use tfdatasvc::orchestrator::Cell;
use tfdatasvc::rpc::{call_typed, Pool};
use tfdatasvc::service::dispatcher::DispatcherConfig;
use tfdatasvc::service::proto::{
    worker_methods, SharingMode, ShardingPolicy, WorkerStatusReq, WorkerStatusResp,
};
use tfdatasvc::service::spill::{SpillConfig, SpillPolicy};
use tfdatasvc::service::{ServiceClient, ServiceClientConfig};
use tfdatasvc::sim::models::model;
use tfdatasvc::sim::sharing::{mode_a, mode_b, mode_c, sequential_sharing_cost, SharingConfig};
use tfdatasvc::storage::dataset::{generate_vision, VisionGenConfig};
use tfdatasvc::storage::ObjectStore;

struct RealRun {
    /// Elements the worker pool produced, total.
    produced: u64,
    /// Elements all clients consumed, total.
    consumed: u64,
    /// How many clients attached to an existing job.
    attaches: usize,
    distinct_jobs: usize,
}

/// Run k concurrent anonymous clients over one identical pipeline on a
/// fresh single-worker cell, with the given sharing policy.
fn run_real(k: usize, sharing: SharingMode, shards: usize, samples_per_shard: usize) -> RealRun {
    let store = ObjectStore::in_memory();
    let spec = generate_vision(
        &store,
        "ds",
        &VisionGenConfig { num_shards: shards, samples_per_shard, ..Default::default() },
    );
    let cell =
        Arc::new(Cell::new(store, UdfRegistry::with_builtins(), DispatcherConfig::default()).unwrap());
    cell.set_worker_config_mutator(|c| c.cache_window = 4096);
    cell.scale_to(1).unwrap();
    let graph = PipelineBuilder::source_vision(spec).batch(8).build();

    // Join all k clients first (so every attach targets a live job), then
    // drain concurrently.
    let iters: Vec<_> = (0..k)
        .map(|_| {
            let c = ServiceClient::new(&cell.dispatcher_addr());
            c.distribute(
                &graph,
                ServiceClientConfig {
                    sharding: ShardingPolicy::Dynamic,
                    sharing,
                    ..Default::default()
                },
            )
            .unwrap()
        })
        .collect();
    let handles: Vec<_> = iters
        .into_iter()
        .map(|mut it| {
            std::thread::spawn(move || {
                let mut n = 0u64;
                while let Ok(Some(_)) = it.next() {
                    n += 1;
                }
                (n, it.job_id(), it.attached())
            })
        })
        .collect();
    let results: Vec<(u64, u64, bool)> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let consumed = results.iter().map(|r| r.0).sum();
    let attaches = results.iter().filter(|r| r.2).count();
    let mut jobs: Vec<u64> = results.iter().map(|r| r.1).collect();
    jobs.sort_unstable();
    jobs.dedup();

    let status = worker_status(&cell.worker_addrs()[0]);
    RealRun { produced: status.elements_produced, consumed, attaches, distinct_jobs: jobs.len() }
}

fn worker_status(addr: &str) -> WorkerStatusResp {
    let pool = Pool::with_defaults();
    call_typed(&pool, addr, worker_methods::WORKER_STATUS, &WorkerStatusReq {}, Duration::from_secs(5))
        .unwrap()
}

struct SpillRun {
    epoch: u64,
    late_consumed: u64,
    late_attached: bool,
    snapshot_consumed: u64,
    produced_live: u64,
    produced_after_snapshot: u64,
    spill_segments: u64,
    spill_served: u64,
    snapshot_serves: u64,
    relaxed_skips: u64,
}

/// Late attach + snapshot resubmission on a spill-All worker. Client 1
/// drains half the epoch first (eager eviction archives the consumed
/// prefix to the store), so the late attacher's replay of sequence 0
/// onward can only come from the spill tier; after the epoch commits as
/// a fingerprint-keyed snapshot, a re-submitted identical pipeline is
/// streamed from the store with no new production.
fn run_spill_real(shards: usize, samples_per_shard: usize) -> SpillRun {
    let store = ObjectStore::in_memory();
    let spec = generate_vision(
        &store,
        "ds",
        &VisionGenConfig { num_shards: shards, samples_per_shard, ..Default::default() },
    );
    let cell =
        Arc::new(Cell::new(store, UdfRegistry::with_builtins(), DispatcherConfig::default()).unwrap());
    cell.set_worker_config_mutator(|c| {
        c.spill = SpillConfig { policy: SpillPolicy::All, segment_bytes: 32 << 10 };
    });
    cell.scale_to(1).unwrap();
    let graph = PipelineBuilder::source_vision(spec).batch(8).build();
    let epoch = (shards * samples_per_shard / 8) as u64;
    let cfg = || ServiceClientConfig {
        sharding: ShardingPolicy::Off,
        sharing: SharingMode::Auto,
        ..Default::default()
    };

    let c1 = ServiceClient::new(&cell.dispatcher_addr());
    let mut it1 = c1.distribute(&graph, cfg()).unwrap();
    let mut n1 = 0u64;
    while n1 < epoch / 2 {
        it1.next().unwrap().expect("producer ended before half the epoch");
        n1 += 1;
    }

    let late = {
        let addr = cell.dispatcher_addr();
        let graph = graph.clone();
        let cfg = cfg();
        std::thread::spawn(move || {
            let c2 = ServiceClient::new(&addr);
            let mut it2 = c2.distribute(&graph, cfg).unwrap();
            let attached = it2.attached();
            let mut n = 0u64;
            while let Ok(Some(_)) = it2.next() {
                n += 1;
            }
            (n, attached)
        })
    };
    while let Ok(Some(_)) = it1.next() {
        n1 += 1;
    }
    assert_eq!(n1, epoch, "client 1 drains the epoch");
    let (late_consumed, late_attached) = late.join().unwrap();
    drop(it1);
    let live = worker_status(&cell.worker_addrs()[0]);

    // Epoch drained on every consumer -> the worker finalizes its spill
    // manifest and the dispatcher commits the fingerprint snapshot on
    // the next heartbeat.
    let deadline = Instant::now() + Duration::from_secs(10);
    while cell.dispatcher().metrics().counter("dispatcher/snapshots_committed").get() == 0 {
        assert!(Instant::now() < deadline, "snapshot never committed");
        std::thread::sleep(Duration::from_millis(20));
    }

    let c3 = ServiceClient::new(&cell.dispatcher_addr());
    let mut it3 = c3.distribute(&graph, cfg()).unwrap();
    assert!(it3.snapshot(), "resubmission must attach to the committed snapshot");
    let mut n3 = 0u64;
    while let Ok(Some(_)) = it3.next() {
        n3 += 1;
    }
    drop(it3);
    let after = worker_status(&cell.worker_addrs()[0]);

    SpillRun {
        epoch,
        late_consumed,
        late_attached,
        snapshot_consumed: n3,
        produced_live: live.elements_produced,
        produced_after_snapshot: after.elements_produced,
        spill_segments: after.spill_segments_written,
        spill_served: after.spill_elements_served,
        snapshot_serves: after.snapshot_serves,
        relaxed_skips: after.relaxed_skips,
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let m = model("M4");
    let cfg = SharingConfig::default();
    println!("=== Fig 10: preprocessing cost by deployment mode (sim) ===");
    println!("{:>4} {:>12} {:>12} {:>12} {:>14}", "k", "A(shared)", "B(no share)", "C(dedicated)", "B slowdown");
    let mut rows = Vec::new();
    for k in [1usize, 2, 4, 8, 16] {
        let a = mode_a(m, &cfg, k);
        let b = mode_b(m, &cfg, k);
        let c = mode_c(m, &cfg, k);
        println!(
            "{:>4} {:>12.2} {:>12.2} {:>12.2} {:>13.2}x",
            k,
            a.preprocessing_cost,
            b.preprocessing_cost,
            c.preprocessing_cost,
            1.0 / b.per_job_throughput_frac
        );
        rows.push(vec![
            k.to_string(),
            format!("{:.3}", a.preprocessing_cost),
            format!("{:.3}", b.preprocessing_cost),
            format!("{:.3}", c.preprocessing_cost),
        ]);
    }
    // Paper anchor points.
    let b8 = mode_b(m, &cfg, 8);
    let b16 = mode_b(m, &cfg, 16);
    assert!((1.0 / b8.per_job_throughput_frac - 1.75).abs() < 0.3);
    assert!((1.0 / b16.per_job_throughput_frac - 3.0).abs() < 0.35);
    assert_eq!(mode_a(m, &cfg, 64).preprocessing_cost, 1.0, "A flat to 64 jobs");
    println!(
        "worst-case sequential sharing (cache 1% of dataset, k=16): {:.2}x of one job's cost (vs 16x unshared)",
        sequential_sharing_cost(16, 0.01, 1.0)
    );
    write_csv_rows("out/fig10.csv", "k,mode_a_cost,mode_b_cost,mode_c_cost", &rows).unwrap();

    // ---- Real-service cross-check: fingerprint sharing vs dedicated ----
    let (shards, samples, k) = if smoke { (2, 16, 2) } else { (4, 32, 4) };
    let epoch = (shards * samples / 8) as u64; // batches per epoch

    let shared = run_real(k, SharingMode::Auto, shards, samples);
    assert_eq!(shared.distinct_jobs, 1, "auto sharing converged on one job");
    assert_eq!(shared.attaches, k - 1, "k-1 clients attached");
    assert_eq!(shared.consumed, k as u64 * epoch, "every client drained the epoch");
    assert!(
        shared.produced as f64 <= 1.1 * epoch as f64,
        "mode A single production: produced {} vs epoch {epoch}",
        shared.produced
    );

    let dedicated = run_real(k, SharingMode::Off, shards, samples);
    assert_eq!(dedicated.distinct_jobs, k, "opt-out keeps k dedicated jobs");
    assert_eq!(dedicated.attaches, 0);
    assert_eq!(dedicated.consumed, k as u64 * epoch);
    assert!(
        dedicated.produced as f64 >= 0.9 * (k as u64 * epoch) as f64,
        "mode B k productions: produced {} vs k*epoch {}",
        dedicated.produced,
        k as u64 * epoch
    );

    let measured_a = shared.produced as f64 / epoch as f64;
    let measured_b = dedicated.produced as f64 / epoch as f64;
    let sim_a = mode_a(m, &cfg, k).preprocessing_cost;
    let sim_b_reads = mode_b(m, &cfg, k).storage_reads_rel;
    println!("=== Fig 10: real-service cross-check (k={k}, epoch={epoch} batches) ===");
    println!(
        "mode A (sharing auto): measured production cost {measured_a:.2}x, sim predicts {sim_a:.2}x"
    );
    println!(
        "mode B (sharing off):  measured production cost {measured_b:.2}x, sim predicts {sim_b_reads:.0}x productions"
    );
    write_csv_rows(
        "out/fig10_real.csv",
        "k,measured_a_cost,sim_a_cost,measured_b_cost,sim_b_productions",
        &[vec![
            k.to_string(),
            format!("{measured_a:.3}"),
            format!("{sim_a:.3}"),
            format!("{measured_b:.3}"),
            format!("{sim_b_reads:.3}"),
        ]],
    )
    .unwrap();
    assert!((measured_a - sim_a).abs() <= 0.1, "sim and implementation agree on mode A");
    assert!(
        (measured_b - sim_b_reads).abs() <= 0.1 * sim_b_reads,
        "sim and implementation agree on mode B production count"
    );

    // ---- Spill tier: late attach + snapshot resubmission ----
    let (sshards, ssamples) = if smoke { (4, 16) } else { (8, 32) };
    let sr = run_spill_real(sshards, ssamples);
    println!("=== Fig 10 addendum: spill tier (epoch = {} batches) ===", sr.epoch);
    println!(
        "late attach: consumed {}/{} from spill ({} segments, {} elements served), {} skips",
        sr.late_consumed, sr.epoch, sr.spill_segments, sr.spill_served, sr.relaxed_skips
    );
    println!(
        "snapshot resubmission: consumed {}/{}, production {} -> {} ({} snapshot serves)",
        sr.snapshot_consumed,
        sr.epoch,
        sr.produced_live,
        sr.produced_after_snapshot,
        sr.snapshot_serves
    );
    assert!(sr.late_attached, "late client must attach to the live fingerprint-matched job");
    assert_eq!(sr.late_consumed, sr.epoch, "late attacher replays the full epoch from spill");
    assert_eq!(sr.relaxed_skips, 0, "the spill tier leaves nothing to skip");
    assert!(sr.spill_segments >= 1, "the window must have spilled segments");
    assert!(sr.spill_served >= 1, "the late attacher must be served from spill");
    assert_eq!(sr.snapshot_consumed, sr.epoch, "snapshot serve streams the full epoch");
    assert_eq!(
        sr.produced_after_snapshot, sr.produced_live,
        "a snapshot-served resubmission must produce nothing new"
    );
    assert!(sr.snapshot_serves >= 1, "the worker must record a snapshot-serve task");
    write_csv_rows(
        "out/fig10_spill.csv",
        "epoch,late_consumed,relaxed_skips,spill_segments,spill_elements_served,\
         snapshot_consumed,produced_live,produced_after_snapshot,snapshot_serves",
        &[vec![
            sr.epoch.to_string(),
            sr.late_consumed.to_string(),
            sr.relaxed_skips.to_string(),
            sr.spill_segments.to_string(),
            sr.spill_served.to_string(),
            sr.snapshot_consumed.to_string(),
            sr.produced_live.to_string(),
            sr.produced_after_snapshot.to_string(),
            sr.snapshot_serves.to_string(),
        ]],
    )
    .unwrap();
    println!("fig10 OK -> out/fig10.csv, out/fig10_real.csv, out/fig10_spill.csv");
}
