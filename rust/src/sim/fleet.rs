//! Heavy-tailed fleet generators (Fig. 1, Fig. 2, Fig. 12).
//!
//! Fig. 1's takeaway is that per-job CPU/RAM needs for input processing
//! are wildly heterogeneous (heavy-tailed CDFs over 73k jobs). Fig. 12a
//! shows deployment sizes from 2 to >5000 workers; Fig. 12b shows the top
//! jobs using up to 25× the client hosts' CPU. We regenerate all of these
//! from documented distributions, plus the Fig. 2 bursty colocated
//! CPU-usage timeline.

use crate::util::rng::Rng;

/// One fleet job's normalized resource demands.
#[derive(Debug, Clone, Copy)]
pub struct FleetJob {
    /// CPU demand normalized to fleet peak (0, 1].
    pub cpu: f64,
    /// RAM demand normalized to fleet peak (0, 1].
    pub ram: f64,
}

/// Generate `n` jobs with lognormal, positively-correlated CPU/RAM
/// demands, normalized to the observed peak (Fig. 1's axes).
pub fn generate_fleet(n: usize, seed: u64) -> Vec<FleetJob> {
    let mut rng = Rng::new(seed);
    let mut raw: Vec<(f64, f64)> = Vec::with_capacity(n);
    for _ in 0..n {
        // Shared factor induces CPU/RAM correlation; idiosyncratic noise
        // keeps the ratio heterogeneous (the paper's core observation).
        let shared = rng.normal();
        let cpu = (0.8 * shared + 0.6 * rng.normal()) * 1.6 - 1.0;
        let ram = (0.8 * shared + 0.6 * rng.normal()) * 1.4 - 1.2;
        raw.push((cpu.exp(), ram.exp()));
    }
    let cpu_peak = raw.iter().map(|r| r.0).fold(f64::MIN, f64::max);
    let ram_peak = raw.iter().map(|r| r.1).fold(f64::MIN, f64::max);
    raw.into_iter().map(|(c, m)| FleetJob { cpu: c / cpu_peak, ram: m / ram_peak }).collect()
}

/// Fig. 12a: per-job tf.data service worker counts. Most jobs use 2–32
/// workers; the tail reaches past 5000.
pub fn generate_worker_counts(n: usize, seed: u64) -> Vec<u64> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            // Mixture: the bulk is log2(workers) ~ N(3, 1.7) (median 8,
            // most mass in 2..32); a 0.05% sliver of giant jobs reaches
            // past 5000 workers (Fig. 12a: "the largest model uses more
            // than 5K workers").
            if rng.chance(0.0005) {
                rng.range_u64(4000, 8000)
            } else {
                let log2 = rng.normal_ms(3.0, 1.7);
                (log2.exp2().round() as u64).clamp(1, 2048)
            }
        })
        .collect()
}

/// Fig. 12b: for the top-`k` most CPU-intensive jobs, the ratio of
/// tf.data-worker CPU usage to the client hosts' CPU limit (up to ~25×).
pub fn generate_top_job_cpu_ratios(k: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    let mut ratios: Vec<f64> = (0..k.max(1) * 40)
        .map(|_| rng.lognormal(0.5, 1.1))
        .collect();
    ratios.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let mut top: Vec<f64> = ratios.into_iter().take(k).collect();
    // Normalize the very top toward the paper's ~25x.
    if let Some(&max) = top.first() {
        if max > 0.0 {
            for r in &mut top {
                *r = (*r / max) * 25.0;
            }
        }
    }
    top
}

/// Fig. 2: colocated-training CPU-utilization timeline. Preprocessing
/// bursts to near-full utilization while preparing the next batches, then
/// drops while the accelerator computes; memory climbs slowly (buffered
/// batches) and plateaus.
#[derive(Debug, Clone, Copy)]
pub struct UsagePoint {
    pub t: f64,
    pub cpu: f64,
    pub mem: f64,
}

pub fn burstiness_timeline(
    duration_s: f64,
    step_time_s: f64,
    preprocess_fraction: f64,
    seed: u64,
) -> Vec<UsagePoint> {
    let mut rng = Rng::new(seed);
    let dt = step_time_s / 20.0;
    let mut out = Vec::new();
    let mut t = 0.0f64;
    let mut mem = 0.25f64;
    while t < duration_s {
        let phase = (t % step_time_s) / step_time_s;
        let burst = phase < preprocess_fraction;
        let cpu = if burst {
            0.75 + 0.2 * rng.f64()
        } else {
            0.08 + 0.07 * rng.f64()
        };
        mem = (mem + 0.002 * (1.0 - mem)).min(0.62) + 0.01 * (rng.f64() - 0.5);
        out.push(UsagePoint { t, cpu, mem: mem.clamp(0.0, 1.0) });
        t += dt;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::hist::Samples;

    #[test]
    fn fleet_is_heavy_tailed_and_normalized() {
        let jobs = generate_fleet(20_000, 42);
        assert_eq!(jobs.len(), 20_000);
        let mut cpu = Samples::from_vec(jobs.iter().map(|j| j.cpu).collect());
        // Normalized to peak.
        assert!(cpu.max() <= 1.0 + 1e-12);
        assert!(cpu.min() > 0.0);
        // Heavy tail: median tiny relative to peak (paper Fig. 1 shape:
        // most jobs need a small fraction of the max).
        assert!(cpu.median() < 0.05, "median {}", cpu.median());
        assert!(cpu.percentile(99.0) > 10.0 * cpu.median());
    }

    #[test]
    fn fleet_cpu_ram_ratios_vary() {
        // The figure's takeaway: no single CPU:RAM ratio fits. Check the
        // ratio spread spans >10x between p10 and p90.
        let jobs = generate_fleet(20_000, 7);
        let mut ratios = Samples::from_vec(jobs.iter().map(|j| j.cpu / j.ram).collect());
        assert!(ratios.percentile(90.0) / ratios.percentile(10.0) > 10.0);
    }

    #[test]
    fn worker_counts_match_fig12a_shape() {
        let counts = generate_worker_counts(50_000, 3);
        let mut s = Samples::from_vec(counts.iter().map(|&c| c as f64).collect());
        // Most deployments between 2 and 32 workers.
        let frac_2_32 = s.cdf_at(32.0) - s.cdf_at(1.9);
        assert!(frac_2_32 > 0.5, "2..32 fraction {frac_2_32}");
        // Tail exceeds 5000.
        assert!(s.max() > 5000.0, "max {}", s.max());
    }

    #[test]
    fn top_job_ratios_reach_25x() {
        let top = generate_top_job_cpu_ratios(10, 5);
        assert_eq!(top.len(), 10);
        assert!((top[0] - 25.0).abs() < 1e-9);
        assert!(top.windows(2).all(|w| w[0] >= w[1]), "sorted descending");
        assert!(top.iter().all(|&r| r > 1.0), "top jobs all exceed local CPU");
    }

    #[test]
    fn burstiness_alternates() {
        let tl = burstiness_timeline(60.0, 2.0, 0.4, 1);
        assert!(!tl.is_empty());
        let high = tl.iter().filter(|p| p.cpu > 0.7).count() as f64 / tl.len() as f64;
        let low = tl.iter().filter(|p| p.cpu < 0.2).count() as f64 / tl.len() as f64;
        // Bimodal: both phases well represented.
        assert!(high > 0.25 && low > 0.4, "high {high} low {low}");
        // Memory bounded.
        assert!(tl.iter().all(|p| (0.0..=1.0).contains(&p.mem)));
    }
}
