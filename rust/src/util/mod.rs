pub mod chan;
pub mod cli;
pub mod hist;
pub mod json;
pub mod rng;
