//! Autopilot-like horizontal autoscaler (§3.1).
//!
//! Borg's Autopilot scales worker pools from "user hints and CPU
//! utilization"; Cachew-style policies additionally watch client batch
//! times. This controller combines both signals:
//!
//! * scale **up** when mean worker CPU utilization exceeds `hi_util` *or*
//!   clients report input stalls (starvation fraction above threshold);
//! * scale **down** when utilization falls below `lo_util` and no client
//!   is starved;
//! * hysteresis via a cooldown between actions, bounded by min/max.
//!
//! The controller is deployment-agnostic: callers feed it [`Signals`] and
//! apply the returned [`Decision`] (the [`super::Cell`] does this in its
//! control loop; the DES applies it analytically).

use std::time::{Duration, Instant};

/// Autoscaler policy knobs.
#[derive(Debug, Clone)]
pub struct AutoscalerConfig {
    pub min_workers: usize,
    pub max_workers: usize,
    /// Scale up above this mean CPU utilization (0..1).
    pub hi_util: f64,
    /// Scale down below this mean CPU utilization (0..1).
    pub lo_util: f64,
    /// Scale up when the fraction of client fetches that stalled exceeds
    /// this.
    pub starvation_threshold: f64,
    /// Workers added per scale-up action (multiplicative growth: the
    /// worker-sweep experiment shows diminishing marginal gains, so we
    /// grow geometrically then settle).
    pub growth_factor: f64,
    pub cooldown: Duration,
}

impl Default for AutoscalerConfig {
    fn default() -> Self {
        AutoscalerConfig {
            min_workers: 1,
            max_workers: 1024,
            hi_util: 0.8,
            lo_util: 0.3,
            starvation_threshold: 0.05,
            growth_factor: 2.0,
            cooldown: Duration::from_secs(1),
        }
    }
}

/// Inputs sampled from the running deployment.
#[derive(Debug, Clone, Copy)]
pub struct Signals {
    pub current_workers: usize,
    /// Mean worker CPU utilization in [0, 1].
    pub mean_worker_util: f64,
    /// Fraction of client GetElement calls that found no data ready.
    pub client_starvation: f64,
}

/// What to do now.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Decision {
    Hold,
    ScaleTo(usize),
}

/// Stateful controller (owns the cooldown clock).
pub struct Autoscaler {
    cfg: AutoscalerConfig,
    last_action: Option<Instant>,
    /// History for tests/inspection.
    pub decisions: Vec<(f64, usize)>,
}

impl Autoscaler {
    pub fn new(cfg: AutoscalerConfig) -> Autoscaler {
        Autoscaler { cfg, last_action: None, decisions: Vec::new() }
    }

    pub fn config(&self) -> &AutoscalerConfig {
        &self.cfg
    }

    /// Pure policy: desired size given signals (no cooldown).
    pub fn desired(&self, s: Signals) -> usize {
        let n = s.current_workers.max(1);
        let starved = s.client_starvation > self.cfg.starvation_threshold;
        if starved || s.mean_worker_util > self.cfg.hi_util {
            let grown = ((n as f64) * self.cfg.growth_factor).ceil() as usize;
            grown.clamp(self.cfg.min_workers, self.cfg.max_workers)
        } else if s.mean_worker_util < self.cfg.lo_util && !starved {
            // Shrink proportionally to spare capacity, one notch at a time.
            let shrunk = ((n as f64) * 0.75).floor() as usize;
            shrunk.clamp(self.cfg.min_workers, self.cfg.max_workers)
        } else {
            n.clamp(self.cfg.min_workers, self.cfg.max_workers)
        }
    }

    /// Policy + cooldown: `Hold` while within the cooldown window or when
    /// the desired size equals the current size.
    pub fn evaluate(&mut self, s: Signals) -> Decision {
        if let Some(t) = self.last_action {
            if t.elapsed() < self.cfg.cooldown {
                return Decision::Hold;
            }
        }
        let want = self.desired(s);
        if want == s.current_workers {
            return Decision::Hold;
        }
        self.last_action = Some(Instant::now());
        self.decisions.push((s.mean_worker_util, want));
        Decision::ScaleTo(want)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> AutoscalerConfig {
        AutoscalerConfig { cooldown: Duration::ZERO, ..Default::default() }
    }

    fn sig(workers: usize, util: f64, starve: f64) -> Signals {
        Signals { current_workers: workers, mean_worker_util: util, client_starvation: starve }
    }

    #[test]
    fn scales_up_on_high_util() {
        let a = Autoscaler::new(cfg());
        assert_eq!(a.desired(sig(4, 0.95, 0.0)), 8);
    }

    #[test]
    fn scales_up_on_starvation_even_at_low_util() {
        let a = Autoscaler::new(cfg());
        // Workers idle but clients starve (e.g. network-bound): still grow.
        assert_eq!(a.desired(sig(4, 0.2, 0.5)), 8);
    }

    #[test]
    fn scales_down_when_idle() {
        let a = Autoscaler::new(cfg());
        assert_eq!(a.desired(sig(8, 0.1, 0.0)), 6);
    }

    #[test]
    fn holds_in_band() {
        let mut a = Autoscaler::new(cfg());
        assert_eq!(a.evaluate(sig(4, 0.5, 0.0)), Decision::Hold);
    }

    #[test]
    fn respects_bounds() {
        let a = Autoscaler::new(AutoscalerConfig {
            min_workers: 2,
            max_workers: 6,
            cooldown: Duration::ZERO,
            ..Default::default()
        });
        assert_eq!(a.desired(sig(6, 0.99, 0.0)), 6, "capped at max");
        assert_eq!(a.desired(sig(2, 0.0, 0.0)), 2, "floored at min");
    }

    #[test]
    fn cooldown_throttles_actions() {
        let mut a = Autoscaler::new(AutoscalerConfig {
            cooldown: Duration::from_secs(60),
            ..Default::default()
        });
        assert_eq!(a.evaluate(sig(4, 0.95, 0.0)), Decision::ScaleTo(8));
        // Immediately after: held despite pressure.
        assert_eq!(a.evaluate(sig(8, 0.95, 0.0)), Decision::Hold);
    }

    #[test]
    fn converges_to_fixed_point_under_constant_load() {
        // With util inversely proportional to workers, repeated evaluation
        // settles inside the [lo, hi] band.
        let mut a = Autoscaler::new(cfg());
        let mut workers = 1usize;
        let demand = 10.0; // total CPU-seconds per second of demand
        for _ in 0..32 {
            let util = (demand / workers as f64).min(1.0);
            match a.evaluate(sig(workers, util, 0.0)) {
                Decision::ScaleTo(n) => workers = n,
                Decision::Hold => break,
            }
        }
        let final_util = demand / workers as f64;
        assert!(
            (0.3..=0.8).contains(&final_util),
            "settled at {workers} workers, util {final_util}"
        );
    }
}
