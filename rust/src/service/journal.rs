//! Dispatcher write-ahead journal (§3.4).
//!
//! Every dispatcher state change — dataset registration, job creation,
//! worker registration, client joins/releases — appends a CRC-framed
//! record before the change is acknowledged. On restart the dispatcher
//! replays the journal to restore its metadata. Split-assignment progress
//! is deliberately *not* journaled: the paper relaxes visitation to
//! at-most-once, so an epoch's in-flight splits may be lost on recovery.

use crate::data::graph::GraphDef;
use crate::service::proto::{ProcessingMode, SharingMode, ShardingPolicy};
use crate::service::spill::SpillManifest;
use crate::wire::{Decode, Encode, Reader, WireError, WireResult, Writer};
use crate::util::crc32::Hasher;
use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Read, Write};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// One replayable state change.
#[derive(Debug, Clone, PartialEq)]
pub enum JournalRecord {
    RegisterDataset { dataset_id: u64, graph: GraphDef },
    CreateJob {
        job_id: u64,
        dataset_id: u64,
        job_name: String,
        sharding: ShardingPolicy,
        mode: ProcessingMode,
        num_consumers: u32,
        /// Ephemeral-sharing policy: replayed so fingerprint-matched
        /// attach keeps working across a dispatcher restart (§3.4 + §3.5).
        sharing: SharingMode,
        /// Worker ordering fixed at job creation (the coordinated-reads
        /// round-robin). Replayed so a restarted dispatcher rebuilds the
        /// round-lease table instead of resetting coordinated jobs to an
        /// unroutable state (§3.6 fault tolerance).
        worker_order: Vec<u64>,
        /// True when the job was created in snapshot-serve mode (its
        /// workers stream a committed snapshot instead of producing);
        /// replayed so a restarted dispatcher keeps handing snapshot
        /// tasks to re-registering workers.
        snapshot: bool,
    },
    RegisterWorker { worker_id: u64, addr: String },
    ClientJoined { job_id: u64, client_id: u64 },
    ClientReleased { job_id: u64, client_id: u64 },
    JobFinished { job_id: u64 },
    /// Round-lease table change for one coordinated job: the complete
    /// residue -> owner map after a failure reassignment or a revival
    /// re-balance. Replayed last-writer-wins over the `CreateJob`
    /// baseline, so dispatcher restart resumes the *current* lease
    /// layout; the materialization floor is deliberately not journaled —
    /// it is rebuilt from the first post-restart client heartbeats.
    RoundLeaseChanged { job_id: u64, residue_owners: Vec<u64> },
    /// Consumer-width change for one coordinated job (elastic
    /// membership): from `barrier_round` onward, rounds are keyed for
    /// `num_consumers` slots. Journaled *before* the change is published
    /// to workers or acknowledged to the caller, so a restarted
    /// dispatcher replays the full membership-epoch history and a
    /// heartbeating worker re-receives the schedule it may have missed.
    ConsumerSetChanged { job_id: u64, epoch: u32, barrier_round: u64, num_consumers: u32 },
    /// A fingerprint's epoch output was fully spilled and the per-worker
    /// manifests merged: from here on, an identical re-submitted
    /// pipeline (`sharing: auto`) may be served from storage instead of
    /// re-produced. Journaled *before* the snapshot is offered to any
    /// client; replayed last-writer-wins per fingerprint (`epoch` is
    /// monotone), so a restarted dispatcher keeps serving snapshots.
    SnapshotCommitted { fingerprint: u64, epoch: u64, manifest: SpillManifest },
    /// A worker entered (`draining: true`) or left (`false`) the
    /// two-phase graceful-drain state. Journaled *before* the state is
    /// acted on, so a restarted dispatcher resumes the drain — keeps the
    /// worker out of new-consumer routing and re-initiates pending lease
    /// handoffs — instead of silently re-admitting a half-drained worker.
    WorkerDrainChanged { worker_id: u64, draining: bool },
}

impl Encode for JournalRecord {
    fn encode(&self, w: &mut Writer) {
        match self {
            JournalRecord::RegisterDataset { dataset_id, graph } => {
                w.put_u8(0);
                w.put_u64(*dataset_id);
                graph.encode(w);
            }
            JournalRecord::CreateJob {
                job_id,
                dataset_id,
                job_name,
                sharding,
                mode,
                num_consumers,
                sharing,
                worker_order,
                snapshot,
            } => {
                w.put_u8(1);
                w.put_u64(*job_id);
                w.put_u64(*dataset_id);
                job_name.encode(w);
                sharding.encode(w);
                mode.encode(w);
                w.put_u32(*num_consumers);
                sharing.encode(w);
                worker_order.encode(w);
                snapshot.encode(w);
            }
            JournalRecord::RegisterWorker { worker_id, addr } => {
                w.put_u8(2);
                w.put_u64(*worker_id);
                addr.encode(w);
            }
            JournalRecord::ClientJoined { job_id, client_id } => {
                w.put_u8(3);
                w.put_u64(*job_id);
                w.put_u64(*client_id);
            }
            JournalRecord::ClientReleased { job_id, client_id } => {
                w.put_u8(4);
                w.put_u64(*job_id);
                w.put_u64(*client_id);
            }
            JournalRecord::JobFinished { job_id } => {
                w.put_u8(5);
                w.put_u64(*job_id);
            }
            JournalRecord::RoundLeaseChanged { job_id, residue_owners } => {
                w.put_u8(6);
                w.put_u64(*job_id);
                residue_owners.encode(w);
            }
            JournalRecord::ConsumerSetChanged { job_id, epoch, barrier_round, num_consumers } => {
                w.put_u8(7);
                w.put_u64(*job_id);
                w.put_u32(*epoch);
                w.put_u64(*barrier_round);
                w.put_u32(*num_consumers);
            }
            JournalRecord::SnapshotCommitted { fingerprint, epoch, manifest } => {
                w.put_u8(8);
                w.put_u64(*fingerprint);
                w.put_u64(*epoch);
                manifest.encode(w);
            }
            JournalRecord::WorkerDrainChanged { worker_id, draining } => {
                w.put_u8(9);
                w.put_u64(*worker_id);
                draining.encode(w);
            }
        }
    }
}

impl Decode for JournalRecord {
    fn decode(r: &mut Reader) -> WireResult<Self> {
        Ok(match r.get_u8()? {
            0 => JournalRecord::RegisterDataset { dataset_id: r.get_u64()?, graph: GraphDef::decode(r)? },
            1 => JournalRecord::CreateJob {
                job_id: r.get_u64()?,
                dataset_id: r.get_u64()?,
                job_name: String::decode(r)?,
                sharding: ShardingPolicy::decode(r)?,
                mode: ProcessingMode::decode(r)?,
                num_consumers: r.get_u32()?,
                sharing: SharingMode::decode(r)?,
                worker_order: Vec::<u64>::decode(r)?,
                snapshot: bool::decode(r)?,
            },
            2 => JournalRecord::RegisterWorker { worker_id: r.get_u64()?, addr: String::decode(r)? },
            3 => JournalRecord::ClientJoined { job_id: r.get_u64()?, client_id: r.get_u64()? },
            4 => JournalRecord::ClientReleased { job_id: r.get_u64()?, client_id: r.get_u64()? },
            5 => JournalRecord::JobFinished { job_id: r.get_u64()? },
            6 => JournalRecord::RoundLeaseChanged {
                job_id: r.get_u64()?,
                residue_owners: Vec::<u64>::decode(r)?,
            },
            7 => JournalRecord::ConsumerSetChanged {
                job_id: r.get_u64()?,
                epoch: r.get_u32()?,
                barrier_round: r.get_u64()?,
                num_consumers: r.get_u32()?,
            },
            8 => JournalRecord::SnapshotCommitted {
                fingerprint: r.get_u64()?,
                epoch: r.get_u64()?,
                manifest: SpillManifest::decode(r)?,
            },
            9 => JournalRecord::WorkerDrainChanged {
                worker_id: r.get_u64()?,
                draining: bool::decode(r)?,
            },
            tag => return Err(WireError::BadTag { tag, ty: "JournalRecord" }),
        })
    }
}

/// Append-only journal file. Thread-safe; every append is flushed before
/// returning (write-ahead semantics).
pub struct Journal {
    path: PathBuf,
    writer: Mutex<BufWriter<File>>,
}

impl Journal {
    /// Open (creating if missing) the journal at `path`.
    pub fn open(path: impl AsRef<Path>) -> std::io::Result<Journal> {
        let path = path.as_ref().to_path_buf();
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let file = OpenOptions::new().create(true).append(true).open(&path)?;
        Ok(Journal { path, writer: Mutex::new(BufWriter::new(file)) })
    }

    /// Append one record (length + crc framed) and flush.
    pub fn append(&self, rec: &JournalRecord) -> std::io::Result<()> {
        let body = rec.to_bytes();
        let mut h = Hasher::new();
        h.update(&body);
        let crc = h.finalize();
        let mut w = self.writer.lock().unwrap();
        w.write_all(&(body.len() as u32).to_le_bytes())?;
        w.write_all(&crc.to_le_bytes())?;
        w.write_all(&body)?;
        w.flush()
    }

    /// Replay all intact records. A torn tail (partial final record, e.g.
    /// crash mid-append) is tolerated and ignored; corruption in the
    /// middle is an error.
    pub fn replay(path: impl AsRef<Path>) -> std::io::Result<Vec<JournalRecord>> {
        let mut bytes = Vec::new();
        match File::open(path.as_ref()) {
            Ok(mut f) => {
                f.read_to_end(&mut bytes)?;
            }
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(vec![]),
            Err(e) => return Err(e),
        }
        let mut out = Vec::new();
        let mut pos = 0usize;
        while pos < bytes.len() {
            if bytes.len() - pos < 8 {
                break; // torn header at tail
            }
            let len = u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap()) as usize;
            let crc = u32::from_le_bytes(bytes[pos + 4..pos + 8].try_into().unwrap());
            if bytes.len() - pos - 8 < len {
                break; // torn body at tail
            }
            let body = &bytes[pos + 8..pos + 8 + len];
            let mut h = Hasher::new();
            h.update(body);
            if h.finalize() != crc {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("journal crc mismatch at byte {pos}"),
                ));
            }
            let rec = JournalRecord::from_bytes(body).map_err(|e| {
                std::io::Error::new(std::io::ErrorKind::InvalidData, format!("journal decode: {e}"))
            })?;
            out.push(rec);
            pos += 8 + len;
        }
        Ok(out)
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::graph::PipelineBuilder;

    fn tmpfile(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("tfdatasvc-journal-tests");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(format!("{name}-{}", std::process::id()));
        let _ = std::fs::remove_file(&p);
        p
    }

    fn sample_records() -> Vec<JournalRecord> {
        vec![
            JournalRecord::RegisterDataset {
                dataset_id: 11,
                graph: PipelineBuilder::source_range(5).batch(2).build(),
            },
            JournalRecord::CreateJob {
                job_id: 1,
                dataset_id: 11,
                job_name: "shared".into(),
                sharding: ShardingPolicy::Dynamic,
                mode: ProcessingMode::Independent,
                num_consumers: 0,
                sharing: SharingMode::Auto,
                worker_order: vec![5, 9],
                snapshot: false,
            },
            JournalRecord::RegisterWorker { worker_id: 5, addr: "127.0.0.1:4000".into() },
            JournalRecord::ClientJoined { job_id: 1, client_id: 2 },
            JournalRecord::ClientReleased { job_id: 1, client_id: 2 },
            JournalRecord::RoundLeaseChanged { job_id: 1, residue_owners: vec![5, 5] },
            JournalRecord::ConsumerSetChanged {
                job_id: 1,
                epoch: 1,
                barrier_round: 12,
                num_consumers: 3,
            },
            JournalRecord::SnapshotCommitted {
                fingerprint: 11,
                epoch: 0,
                manifest: crate::service::spill::SpillManifest {
                    fingerprint: 11,
                    job_id: 1,
                    epoch: 0,
                    total_elements: 4,
                    complete: true,
                    segments: vec![crate::service::spill::SegmentMeta {
                        key: "spill/job-1/data".into(),
                        offset: 0,
                        len: 32,
                        start_seq: 0,
                        num_elements: 4,
                        crc32: 0xdead_beef,
                    }],
                },
            },
            JournalRecord::WorkerDrainChanged { worker_id: 5, draining: true },
            JournalRecord::WorkerDrainChanged { worker_id: 5, draining: false },
            JournalRecord::JobFinished { job_id: 1 },
        ]
    }

    #[test]
    fn append_replay_roundtrip() {
        let p = tmpfile("roundtrip");
        let j = Journal::open(&p).unwrap();
        let recs = sample_records();
        for r in &recs {
            j.append(r).unwrap();
        }
        drop(j);
        assert_eq!(Journal::replay(&p).unwrap(), recs);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn replay_missing_file_is_empty() {
        assert!(Journal::replay("/nonexistent/journal").unwrap().is_empty());
    }

    #[test]
    fn torn_tail_tolerated() {
        let p = tmpfile("torn");
        let j = Journal::open(&p).unwrap();
        let recs = sample_records();
        for r in &recs {
            j.append(r).unwrap();
        }
        drop(j);
        // Truncate mid-record to simulate a crash during append.
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 3]).unwrap();
        let replayed = Journal::replay(&p).unwrap();
        assert_eq!(replayed, recs[..recs.len() - 1]);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn mid_file_corruption_is_error() {
        let p = tmpfile("corrupt");
        let j = Journal::open(&p).unwrap();
        for r in sample_records() {
            j.append(&r).unwrap();
        }
        drop(j);
        let mut bytes = std::fs::read(&p).unwrap();
        bytes[10] ^= 0xff; // flip a byte in the first record's body
        std::fs::write(&p, &bytes).unwrap();
        assert!(Journal::replay(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn reopen_appends_not_truncates() {
        let p = tmpfile("reopen");
        {
            let j = Journal::open(&p).unwrap();
            j.append(&JournalRecord::JobFinished { job_id: 1 }).unwrap();
        }
        {
            let j = Journal::open(&p).unwrap();
            j.append(&JournalRecord::JobFinished { job_id: 2 }).unwrap();
        }
        let recs = Journal::replay(&p).unwrap();
        assert_eq!(
            recs,
            vec![JournalRecord::JobFinished { job_id: 1 }, JournalRecord::JobFinished { job_id: 2 }]
        );
        std::fs::remove_file(&p).ok();
    }
}
