//! Orchestrator: the Borg/Kubernetes stand-in.
//!
//! The paper deploys dispatcher, workers, and clients as containers
//! managed by Borg, horizontally scaled by Autopilot from CPU-utilization
//! signals (§3.1 "Orchestrator"). This module reproduces the control
//! surface in-process:
//!
//! * [`Cell`] — a "cell" that deploys the dispatcher and a dynamic pool of
//!   workers as managed threads, with add/remove/kill operations.
//! * [`autoscaler`] — an Autopilot-like horizontal autoscaler driven by
//!   worker CPU utilization and client-starvation signals, with hysteresis
//!   and cooldown.
//! * [`failure`] — a failure injector that preempts and later restarts
//!   workers, driving the §3.4 fault-tolerance paths.

pub mod autoscaler;
pub mod failure;

pub use autoscaler::{Autoscaler, AutoscalerConfig};

use crate::data::udf::UdfRegistry;
use crate::service::dispatcher::{Dispatcher, DispatcherConfig};
use crate::service::worker::{Worker, WorkerConfig};
use crate::service::ServiceResult;
use crate::storage::ObjectStore;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// An in-process cell hosting one tf.data service deployment.
pub struct Cell {
    store: Arc<ObjectStore>,
    udfs: UdfRegistry,
    dispatcher: Dispatcher,
    workers: Mutex<HashMap<u64, Worker>>,
    next_handle: Mutex<u64>,
    worker_cfg_mutator: Mutex<Option<Box<dyn Fn(&mut WorkerConfig) + Send>>>,
}

impl Cell {
    /// Deploy a dispatcher and return the cell.
    pub fn new(store: Arc<ObjectStore>, udfs: UdfRegistry, cfg: DispatcherConfig) -> ServiceResult<Cell> {
        let dispatcher = Dispatcher::start("127.0.0.1:0", cfg)?;
        Ok(Cell {
            store,
            udfs,
            dispatcher,
            workers: Mutex::new(HashMap::new()),
            next_handle: Mutex::new(1),
            worker_cfg_mutator: Mutex::new(None),
        })
    }

    /// Customize future workers' configs (cache window, buffer sizes…).
    pub fn set_worker_config_mutator(&self, f: impl Fn(&mut WorkerConfig) + Send + 'static) {
        *self.worker_cfg_mutator.lock().unwrap() = Some(Box::new(f));
    }

    pub fn dispatcher(&self) -> &Dispatcher {
        &self.dispatcher
    }

    pub fn dispatcher_addr(&self) -> String {
        self.dispatcher.addr()
    }

    /// Deploy one more worker ("container"); returns its cell handle.
    pub fn add_worker(&self) -> ServiceResult<u64> {
        let mut cfg = WorkerConfig::new(self.store.clone(), self.udfs.clone());
        if let Some(f) = self.worker_cfg_mutator.lock().unwrap().as_ref() {
            f(&mut cfg);
        }
        let w = Worker::start("127.0.0.1:0", &self.dispatcher.addr(), cfg)?;
        let mut handles = self.next_handle.lock().unwrap();
        let handle = *handles;
        *handles += 1;
        self.workers.lock().unwrap().insert(handle, w);
        Ok(handle)
    }

    /// Deploy `n` workers.
    pub fn scale_to(&self, n: usize) -> ServiceResult<()> {
        loop {
            let count = self.worker_count();
            if count == n {
                return Ok(());
            }
            if count < n {
                self.add_worker()?;
            } else {
                self.remove_any_worker();
            }
        }
    }

    /// Gracefully remove one worker (scale-down), if any.
    pub fn remove_any_worker(&self) -> bool {
        let mut ws = self.workers.lock().unwrap();
        if let Some(&h) = ws.keys().next() {
            ws.remove(&h); // Drop shuts the worker down
            return true;
        }
        false
    }

    /// Preempt a specific worker (abrupt kill, no draining).
    pub fn kill_worker(&self, handle: u64) -> bool {
        self.workers.lock().unwrap().remove(&handle).is_some()
    }

    pub fn worker_handles(&self) -> Vec<u64> {
        self.workers.lock().unwrap().keys().copied().collect()
    }

    pub fn worker_count(&self) -> usize {
        self.workers.lock().unwrap().len()
    }

    /// Aggregate worker status (buffered elements, cache stats) by RPC.
    pub fn worker_addrs(&self) -> Vec<String> {
        self.workers.lock().unwrap().values().map(|w| w.addr()).collect()
    }

    /// Drive dispatcher liveness checks.
    pub fn tick(&self) -> Vec<u64> {
        self.dispatcher.tick()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::exec::ElemIter;
    use crate::data::graph::PipelineBuilder;
    use crate::service::proto::ShardingPolicy;
    use crate::service::{ServiceClient, ServiceClientConfig};
    use crate::storage::dataset::{generate_vision, VisionGenConfig};

    fn mk_cell() -> (Cell, crate::storage::dataset::DatasetSpec) {
        let store = ObjectStore::in_memory();
        let spec = generate_vision(
            &store,
            "ds",
            &VisionGenConfig { num_shards: 4, samples_per_shard: 4, ..Default::default() },
        );
        let cell = Cell::new(store, UdfRegistry::with_builtins(), DispatcherConfig::default()).unwrap();
        (cell, spec)
    }

    #[test]
    fn scale_up_and_down() {
        let (cell, _) = mk_cell();
        cell.scale_to(3).unwrap();
        assert_eq!(cell.worker_count(), 3);
        cell.scale_to(1).unwrap();
        assert_eq!(cell.worker_count(), 1);
    }

    #[test]
    fn kill_specific_worker() {
        let (cell, _) = mk_cell();
        let h = cell.add_worker().unwrap();
        assert!(cell.kill_worker(h));
        assert!(!cell.kill_worker(h));
        assert_eq!(cell.worker_count(), 0);
    }

    #[test]
    fn job_runs_through_cell() {
        let (cell, spec) = mk_cell();
        cell.scale_to(2).unwrap();
        let graph = PipelineBuilder::source_vision(spec).batch(4).build();
        let client = ServiceClient::new(&cell.dispatcher_addr());
        let mut it = client
            .distribute(
                &graph,
                ServiceClientConfig { sharding: ShardingPolicy::Dynamic, ..Default::default() },
            )
            .unwrap();
        let mut n = 0;
        while let Some(_) = it.next().unwrap() {
            n += 1;
        }
        assert_eq!(n, 4);
    }
}
