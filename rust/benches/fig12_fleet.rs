//! Fig. 12: fleetwide usage. (a) CDF of per-job worker counts (most jobs
//! 2–32 workers, the largest >5k); (b) the top-10 most CPU-intensive
//! jobs use up to 25x the CPU available on their client hosts.

use tfdatasvc::metrics::{write_csv, write_csv_rows};
use tfdatasvc::sim::fleet::{generate_top_job_cpu_ratios, generate_worker_counts};
use tfdatasvc::util::hist::Samples;

fn main() {
    // ---- (a) worker-count CDF ----
    let counts = generate_worker_counts(50_000, 0xf16_12a);
    let mut s = Samples::from_vec(counts.iter().map(|&c| c as f64).collect());
    println!("=== Fig 12a: CDF of tf.data service deployment sizes ===");
    println!(
        "p25 {:.0}  p50 {:.0}  p75 {:.0}  p95 {:.0}  max {:.0}",
        s.percentile(25.0),
        s.percentile(50.0),
        s.percentile(75.0),
        s.percentile(95.0),
        s.max()
    );
    let in_2_32 = s.cdf_at(32.0) - s.cdf_at(1.9);
    println!("fraction of jobs with 2..32 workers: {:.0}% (paper: 'most')", in_2_32 * 100.0);
    assert!(in_2_32 > 0.5);
    assert!(s.max() > 5000.0, "largest deployment must exceed 5k workers");
    let pts = s.cdf_points(64);
    write_csv("out/fig12a.csv", "workers,cdf", &pts).unwrap();

    // ---- (b) top-10 job CPU ratios ----
    let top = generate_top_job_cpu_ratios(10, 0xf16_12b);
    println!("\n=== Fig 12b: top-10 jobs, worker CPU / client-host CPU limit ===");
    let rows: Vec<Vec<String>> = top
        .iter()
        .enumerate()
        .map(|(i, r)| {
            println!("job {:>2}: {:>5.1}x", i + 1, r);
            vec![(i + 1).to_string(), format!("{r:.2}")]
        })
        .collect();
    assert!((top[0] - 25.0).abs() < 1e-9, "peak ratio 25x");
    assert!(top.iter().all(|&r| r > 1.0), "all top jobs exceed local CPU");
    write_csv_rows("out/fig12b.csv", "rank,cpu_ratio", &rows).unwrap();
    println!("fig12 OK -> out/fig12a.csv, out/fig12b.csv");
}
