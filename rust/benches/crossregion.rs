//! §4.2 "Cross-region Scenario": M3 with source data stored on another
//! continent. Paper: colocated preprocessing becomes 13.3x slower than
//! ideal (vs 2.9x in-region); the service reaches ideal anyway by using
//! extra workers to hide fetch latency.
//!
//! Three sections:
//! 1. the calibrated DES reproducing the paper's numbers,
//! 2. a *live* measurement on the real storage layer's region model, and
//! 3. the **spill tier as a cross-region read path**: an epoch spilled
//!    to the store in the producing region is replayed through
//!    [`tfdatasvc::service::spill::read_segment`] by a same-region and a
//!    cross-region reader. Segment replay does one store round-trip per
//!    segment instead of one per source shard, so a remote snapshot
//!    reader beats remotely re-running the pipeline.
//!
//! `--smoke` shrinks the dataset for CI. Results land in
//! `out/bench_crossregion.json` and the repo-root baseline
//! `BENCH_crossregion.json`.

use std::sync::Arc;
use std::time::Instant;
use tfdatasvc::data::exec::{AllSplits, ElemIter, Executor, ExecutorConfig};
use tfdatasvc::data::graph::PipelineBuilder;
use tfdatasvc::data::udf::UdfRegistry;
use tfdatasvc::metrics::{write_json_file, Registry};
use tfdatasvc::service::spill::{read_segment, JobSpill, SpillConfig, SpillPolicy};
use tfdatasvc::sim::des::{simulate_job, JobSimConfig};
use tfdatasvc::sim::models::model;
use tfdatasvc::storage::dataset::{generate_vision, VisionGenConfig};
use tfdatasvc::storage::{NetModel, ObjectStore, Region};
use tfdatasvc::util::json::obj;
use tfdatasvc::wire::Encode;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");

    // ---- DES: the paper's numbers ----
    let m = model("M3");
    let io = 13.3 / m.ideal_bps; // calibrated per-batch cross-region I/O
    let in_region = simulate_job(m, &JobSimConfig::default());
    let out_region_colo = simulate_job(m, &JobSimConfig { io_time_per_batch: io, ..Default::default() });
    let out_region_dis = simulate_job(
        m,
        &JobSimConfig { n_workers: 1024, io_time_per_batch: io, ..Default::default() },
    );
    println!("=== Cross-region scenario (M3, ideal {:.1} b/s) ===", m.ideal_bps);
    println!("colocated in-region:   {:>7.2} b/s ({:.1}x below ideal; paper 2.9x)", in_region.throughput_bps, m.ideal_bps / in_region.throughput_bps);
    println!("colocated out-region:  {:>7.2} b/s ({:.1}x below ideal; paper 13.3x)", out_region_colo.throughput_bps, m.ideal_bps / out_region_colo.throughput_bps);
    println!("service out-region:    {:>7.2} b/s ({:.0}% of ideal; paper: reaches ideal)", out_region_dis.throughput_bps, 100.0 * out_region_dis.throughput_bps / m.ideal_bps);
    assert!(m.ideal_bps / out_region_colo.throughput_bps > 8.0);
    assert!(out_region_dis.throughput_bps > 0.9 * m.ideal_bps);

    // ---- Live: real pipeline over the region-modeled object store ----
    let us = Region::new("us-central1");
    let eu = Region::new("europe-west4");
    let net = NetModel {
        cross_region_latency: std::time::Duration::from_millis(25), // scaled-down RTT so the bench stays fast
        inject_delays: true,
        ..Default::default()
    };
    let store = ObjectStore::new(us.clone(), net);
    let (shards, samples) = if smoke { (8, 8) } else { (16, 8) };
    let spec = generate_vision(
        &store,
        "ds",
        &VisionGenConfig { num_shards: shards, samples_per_shard: samples, ..Default::default() },
    );
    let graph = PipelineBuilder::source_vision(spec.clone()).batch(8).build();

    let mut time_from = |reader: Region, shards: usize| {
        let cfg = ExecutorConfig {
            store: store.clone(),
            udfs: UdfRegistry::with_builtins(),
            region: reader,
            splits: AllSplits::new(shards),
            autotune: Arc::new(tfdatasvc::data::autotune::AutotuneState::default()),
        };
        let ex = Executor::new(cfg);
        let t0 = std::time::Instant::now();
        let mut it = ex.iterate(&graph).unwrap();
        let mut n = 0;
        while let Ok(Some(_)) = it.next() {
            n += 1;
        }
        (t0.elapsed(), n)
    };
    let (t_near, n1) = time_from(us.clone(), spec.num_shards());
    let (t_far, n2) = time_from(eu.clone(), spec.num_shards());
    assert_eq!(n1, n2);
    println!(
        "\nlive storage model: in-region read {:?}, cross-region {:?} ({:.1}x slower per reader)",
        t_near,
        t_far,
        t_far.as_secs_f64() / t_near.as_secs_f64()
    );
    assert!(t_far > t_near * 3, "cross-region reads must be much slower per reader");

    // ---- Spill tier as a cross-region read path ----
    // Produce one epoch in-region, spill every element, then replay the
    // sealed segments from both regions. The far replay pays the
    // cross-region latency once per *segment*; remotely re-running the
    // pipeline pays it once per *shard object* (plus decode), so the
    // snapshot-style read path must come out ahead.
    let encoded: Vec<Arc<Vec<u8>>> = {
        let ex = Executor::new(ExecutorConfig {
            store: store.clone(),
            udfs: UdfRegistry::with_builtins(),
            region: us.clone(),
            splits: AllSplits::new(spec.num_shards()),
            autotune: Arc::new(tfdatasvc::data::autotune::AutotuneState::default()),
        });
        let mut it = ex.iterate(&graph).unwrap();
        let mut out = Vec::new();
        while let Ok(Some(e)) = it.next() {
            out.push(Arc::new(e.to_bytes()));
        }
        out
    };
    assert_eq!(encoded.len(), n1);
    let total_bytes: usize = encoded.iter().map(|e| e.len()).sum();
    // Aim for ~4 segments so the per-segment round-trip cost is visible
    // but still well below the per-shard cost of re-production.
    let reg = Registry::new();
    let sp = JobSpill::new(
        store.clone(),
        us.clone(),
        &SpillConfig { policy: SpillPolicy::All, segment_bytes: (total_bytes / 4).max(1) },
        9001,
        42,
        &reg,
    );
    for (seq, e) in encoded.iter().enumerate() {
        sp.offer(seq as u64, e.clone());
    }
    let man = sp.finalize();
    assert!(man.complete);
    assert_eq!(man.total_elements, encoded.len() as u64);
    assert!(man.segments.len() >= 2, "want multiple segments, got {}", man.segments.len());

    let replay = |reader: &Region| {
        let t0 = Instant::now();
        let mut n = 0usize;
        for seg in &man.segments {
            n += read_segment(&store, reader, seg).unwrap().len();
        }
        (t0.elapsed(), n)
    };
    let (t_near_replay, r1) = replay(&us);
    let (t_far_replay, r2) = replay(&eu);
    assert_eq!(r1, encoded.len(), "near replay must decode the full epoch");
    assert_eq!(r2, encoded.len(), "far replay must decode the full epoch");
    assert!(
        t_far_replay > t_near_replay,
        "cross-region segment reads must pay the region latency"
    );
    assert!(
        t_far_replay < t_far,
        "snapshot replay from spill ({t_far_replay:?}) must beat re-producing the pipeline \
         cross-region ({t_far:?})"
    );
    let speedup = t_far.as_secs_f64() / t_far_replay.as_secs_f64();
    println!(
        "spill read path: {} elements in {} segments ({} KiB); near replay {:?}, far replay {:?} \
         vs far re-produce {:?} ({:.1}x faster)",
        encoded.len(),
        man.segments.len(),
        total_bytes >> 10,
        t_near_replay,
        t_far_replay,
        t_far,
        speedup
    );

    let bench_json = obj([
        ("bench", "crossregion".into()),
        ("smoke", smoke.into()),
        (
            "des",
            obj([
                ("ideal_bps", m.ideal_bps.into()),
                ("in_region_bps", in_region.throughput_bps.into()),
                ("out_region_colocated_bps", out_region_colo.throughput_bps.into()),
                ("out_region_service_bps", out_region_dis.throughput_bps.into()),
                ("colocated_slowdown", (m.ideal_bps / out_region_colo.throughput_bps).into()),
            ]),
        ),
        (
            "live_read",
            obj([
                ("batches", (n1 as u64).into()),
                ("in_region_ms", (t_near.as_secs_f64() * 1e3).into()),
                ("cross_region_ms", (t_far.as_secs_f64() * 1e3).into()),
                ("slowdown", (t_far.as_secs_f64() / t_near.as_secs_f64()).into()),
            ]),
        ),
        (
            "spill_replay",
            obj([
                ("elements", (encoded.len() as u64).into()),
                ("segments", (man.segments.len() as u64).into()),
                ("bytes", (total_bytes as u64).into()),
                ("near_replay_ms", (t_near_replay.as_secs_f64() * 1e3).into()),
                ("far_replay_ms", (t_far_replay.as_secs_f64() * 1e3).into()),
                ("far_reproduce_ms", (t_far.as_secs_f64() * 1e3).into()),
                ("replay_vs_reproduce_speedup", speedup.into()),
            ]),
        ),
    ]);
    write_json_file("out/bench_crossregion.json", &bench_json).unwrap();
    // Repo-root mirror under the stable name the roadmap tracks (CI
    // regenerates it every run; the checked-in copy is the latest
    // accepted baseline).
    write_json_file("BENCH_crossregion.json", &bench_json).unwrap();
    println!("crossregion OK -> out/bench_crossregion.json + BENCH_crossregion.json");
}
