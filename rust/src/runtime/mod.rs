//! PJRT runtime: load and execute the AOT artifacts from the L3 hot path.
//!
//! `make artifacts` lowers the L2 JAX graphs (which call the L1 Pallas
//! kernels) to HLO *text* (see `python/compile/aot.py` for why text, not
//! serialized protos). This module loads those artifacts into a PJRT CPU
//! client, compiles each once, and exposes a thread-safe [`Engine`]
//! handle for executing them with [`crate::data::Tensor`] inputs.
//!
//! `xla::PjRtClient` is `Rc`-based (not `Send`), so the engine runs a
//! dedicated runtime thread owning the client; callers submit work over a
//! channel. Executions serialize on that thread — matching a single
//! accelerator executing one step at a time, and keeping worker CPU (L3)
//! clearly separated from "device" compute.

pub mod manifest;
pub mod udfs;

pub use manifest::{ArtifactInfo, InputSpec, Manifest};

#[cfg(any(feature = "xla", test))]
use crate::data::element::DType;
use crate::data::element::Tensor;
use crate::util::chan;
#[cfg(feature = "xla")]
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// Runtime errors.
#[derive(Debug)]
pub enum RuntimeError {
    Dir(String),
    Manifest(String),
    UnknownArtifact(String),
    InputMismatch { artifact: String, msg: String },
    Xla(String),
    Integrity(String),
    ThreadDead,
}

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RuntimeError::Dir(msg) => write!(f, "artifact dir error: {msg}"),
            RuntimeError::Manifest(msg) => write!(f, "manifest: {msg}"),
            RuntimeError::UnknownArtifact(name) => write!(f, "unknown artifact: {name}"),
            RuntimeError::InputMismatch { artifact, msg } => {
                write!(f, "input mismatch for {artifact}: {msg}")
            }
            RuntimeError::Xla(msg) => write!(f, "xla: {msg}"),
            RuntimeError::Integrity(name) => {
                write!(f, "integrity: artifact {name} does not match manifest sha256")
            }
            RuntimeError::ThreadDead => write!(f, "runtime thread died"),
        }
    }
}

impl std::error::Error for RuntimeError {}

pub type RuntimeResult<T> = Result<T, RuntimeError>;

enum Cmd {
    Execute {
        name: String,
        inputs: Vec<Tensor>,
        reply: chan::Sender<RuntimeResult<Vec<Tensor>>>,
    },
    /// Compile (warm) an artifact without running it.
    Warm { name: String, reply: chan::Sender<RuntimeResult<()>> },
}

/// Thread-safe handle to the PJRT runtime thread.
#[derive(Clone)]
pub struct Engine {
    tx: chan::Sender<Cmd>,
    manifest: Arc<Manifest>,
}

impl Engine {
    /// Load `artifacts/` (manifest + HLO text files), start the runtime
    /// thread, and verify artifact integrity against the manifest.
    pub fn load(artifacts_dir: impl AsRef<Path>) -> RuntimeResult<Engine> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .map_err(|e| RuntimeError::Dir(format!("{}: {e}", manifest_path.display())))?;
        let manifest = Arc::new(Manifest::parse(&text).map_err(RuntimeError::Manifest)?);

        // Integrity check before starting the thread.
        for (name, art) in &manifest.artifacts {
            let body = std::fs::read(dir.join(&art.file))
                .map_err(|e| RuntimeError::Dir(format!("{}: {e}", art.file)))?;
            let digest = sha256_hex(&body);
            if digest != art.sha256 {
                return Err(RuntimeError::Integrity(name.clone()));
            }
        }

        let (tx, rx) = chan::bounded::<Cmd>(64);
        let m2 = manifest.clone();
        std::thread::Builder::new()
            .name("pjrt-runtime".into())
            .spawn(move || runtime_thread(dir, m2, rx))
            .map_err(|e| RuntimeError::Dir(e.to_string()))?;

        Ok(Engine { tx, manifest })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Validate inputs against the manifest, then execute the artifact.
    /// Returns the flattened output tuple.
    pub fn execute(&self, name: &str, inputs: Vec<Tensor>) -> RuntimeResult<Vec<Tensor>> {
        let art = self
            .manifest
            .artifacts
            .get(name)
            .ok_or_else(|| RuntimeError::UnknownArtifact(name.to_string()))?;
        validate_inputs(name, art, &inputs)?;
        let (rtx, rrx) = chan::bounded(1);
        self.tx
            .send(Cmd::Execute { name: name.to_string(), inputs, reply: rtx })
            .map_err(|_| RuntimeError::ThreadDead)?;
        rrx.recv().map_err(|_| RuntimeError::ThreadDead)?
    }

    /// Pre-compile an artifact so first-use latency doesn't hit the hot
    /// path (workers warm their preprocess artifact at startup).
    pub fn warm(&self, name: &str) -> RuntimeResult<()> {
        if !self.manifest.artifacts.contains_key(name) {
            return Err(RuntimeError::UnknownArtifact(name.to_string()));
        }
        let (rtx, rrx) = chan::bounded(1);
        self.tx
            .send(Cmd::Warm { name: name.to_string(), reply: rtx })
            .map_err(|_| RuntimeError::ThreadDead)?;
        rrx.recv().map_err(|_| RuntimeError::ThreadDead)?
    }
}

fn validate_inputs(name: &str, art: &ArtifactInfo, inputs: &[Tensor]) -> RuntimeResult<()> {
    if inputs.len() != art.inputs.len() {
        return Err(RuntimeError::InputMismatch {
            artifact: name.to_string(),
            msg: format!("want {} inputs, got {}", art.inputs.len(), inputs.len()),
        });
    }
    for (i, (spec, t)) in art.inputs.iter().zip(inputs).enumerate() {
        if spec.dtype != t.dtype || spec.shape != t.shape {
            return Err(RuntimeError::InputMismatch {
                artifact: name.to_string(),
                msg: format!(
                    "input {i}: want {}{:?}, got {}{:?}",
                    spec.dtype.name(),
                    spec.shape,
                    t.dtype.name(),
                    t.shape
                ),
            });
        }
    }
    Ok(())
}

/// Without the `xla` feature there is no PJRT client to run against: fail
/// every request with a clear error. Engine loading, manifest parsing,
/// input validation, and artifact integrity checks all still work, so the
/// rest of the system (and its tests) is unaffected by the gate.
#[cfg(not(feature = "xla"))]
fn runtime_thread(_dir: PathBuf, _manifest: Arc<Manifest>, rx: chan::Receiver<Cmd>) {
    while let Ok(cmd) = rx.recv() {
        let msg = RuntimeError::Xla(
            "built without the `xla` feature: PJRT execution unavailable".into(),
        );
        match cmd {
            Cmd::Execute { reply, .. } => {
                let _ = reply.send(Err(msg));
            }
            Cmd::Warm { reply, .. } => {
                let _ = reply.send(Err(msg));
            }
        }
    }
}

#[cfg(feature = "xla")]
fn runtime_thread(dir: PathBuf, manifest: Arc<Manifest>, rx: chan::Receiver<Cmd>) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            // Fail every request with the same error.
            while let Ok(cmd) = rx.recv() {
                let msg = RuntimeError::Xla(format!("client init failed: {e}"));
                match cmd {
                    Cmd::Execute { reply, .. } => {
                        let _ = reply.send(Err(msg));
                    }
                    Cmd::Warm { reply, .. } => {
                        let _ = reply.send(Err(msg));
                    }
                }
            }
            return;
        }
    };
    let mut cache: HashMap<String, xla::PjRtLoadedExecutable> = HashMap::new();

    let compile = |cache: &mut HashMap<String, xla::PjRtLoadedExecutable>,
                   name: &str|
     -> RuntimeResult<()> {
        if cache.contains_key(name) {
            return Ok(());
        }
        let art = manifest
            .artifacts
            .get(name)
            .ok_or_else(|| RuntimeError::UnknownArtifact(name.to_string()))?;
        let path = dir.join(&art.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| RuntimeError::Dir("non-utf8 path".into()))?,
        )
        .map_err(|e| RuntimeError::Xla(format!("parse {name}: {e}")))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).map_err(|e| RuntimeError::Xla(format!("compile {name}: {e}")))?;
        cache.insert(name.to_string(), exe);
        Ok(())
    };

    while let Ok(cmd) = rx.recv() {
        match cmd {
            Cmd::Warm { name, reply } => {
                let _ = reply.send(compile(&mut cache, &name));
            }
            Cmd::Execute { name, inputs, reply } => {
                let result = (|| -> RuntimeResult<Vec<Tensor>> {
                    compile(&mut cache, &name)?;
                    let exe = cache.get(&name).unwrap();
                    let literals: Vec<xla::Literal> = inputs
                        .iter()
                        .map(tensor_to_literal)
                        .collect::<RuntimeResult<_>>()?;
                    let out = exe
                        .execute::<xla::Literal>(&literals)
                        .map_err(|e| RuntimeError::Xla(format!("execute {name}: {e}")))?;
                    let lit = out[0][0]
                        .to_literal_sync()
                        .map_err(|e| RuntimeError::Xla(format!("fetch {name}: {e}")))?;
                    // aot.py lowers with return_tuple=True: always a tuple.
                    let parts = lit
                        .to_tuple()
                        .map_err(|e| RuntimeError::Xla(format!("untuple {name}: {e}")))?;
                    parts.iter().map(literal_to_tensor).collect()
                })();
                let _ = reply.send(result);
            }
        }
    }
}

#[cfg(feature = "xla")]
fn dtype_to_element_type(d: DType) -> xla::ElementType {
    match d {
        DType::U8 => xla::ElementType::U8,
        DType::U32 => xla::ElementType::U32,
        DType::I32 => xla::ElementType::S32,
        DType::I64 => xla::ElementType::S64,
        DType::F32 => xla::ElementType::F32,
    }
}

#[cfg(feature = "xla")]
fn tensor_to_literal(t: &Tensor) -> RuntimeResult<xla::Literal> {
    xla::Literal::create_from_shape_and_untyped_data(dtype_to_element_type(t.dtype), &t.shape, &t.data)
        .map_err(|e| RuntimeError::Xla(format!("literal: {e}")))
}

#[cfg(feature = "xla")]
fn literal_to_tensor(lit: &xla::Literal) -> RuntimeResult<Tensor> {
    let shape = lit
        .array_shape()
        .map_err(|e| RuntimeError::Xla(format!("shape: {e}")))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let (dtype, data) = match shape.ty() {
        xla::ElementType::F32 => {
            let v: Vec<f32> = lit.to_vec().map_err(|e| RuntimeError::Xla(e.to_string()))?;
            (DType::F32, v.iter().flat_map(|x| x.to_le_bytes()).collect::<Vec<u8>>())
        }
        xla::ElementType::S32 => {
            let v: Vec<i32> = lit.to_vec().map_err(|e| RuntimeError::Xla(e.to_string()))?;
            (DType::I32, v.iter().flat_map(|x| x.to_le_bytes()).collect())
        }
        xla::ElementType::U32 => {
            let v: Vec<u32> = lit.to_vec().map_err(|e| RuntimeError::Xla(e.to_string()))?;
            (DType::U32, v.iter().flat_map(|x| x.to_le_bytes()).collect())
        }
        xla::ElementType::S64 => {
            let v: Vec<i64> = lit.to_vec().map_err(|e| RuntimeError::Xla(e.to_string()))?;
            (DType::I64, v.iter().flat_map(|x| x.to_le_bytes()).collect())
        }
        xla::ElementType::U8 => {
            let v: Vec<u8> = lit.to_vec().map_err(|e| RuntimeError::Xla(e.to_string()))?;
            (DType::U8, v)
        }
        other => return Err(RuntimeError::Xla(format!("unsupported output dtype {other:?}"))),
    };
    Ok(Tensor::new(dtype, dims, data))
}

use crate::util::sha256::sha256_hex;

/// Default artifacts directory: `$TFDATASVC_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var("TFDATASVC_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine() -> Option<Engine> {
        let dir = default_artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return None;
        }
        Some(Engine::load(dir).expect("engine load"))
    }

    #[test]
    fn loads_manifest_and_warms() {
        let Some(e) = engine() else { return };
        assert!(e.manifest().artifacts.contains_key("train_step"));
        e.warm("preprocess_nlp").unwrap();
        assert!(matches!(e.warm("nope"), Err(RuntimeError::UnknownArtifact(_))));
    }

    #[test]
    fn preprocess_nlp_executes() {
        let Some(e) = engine() else { return };
        let (b, s) = (e.manifest().nlp_batch, e.manifest().nlp_seq);
        let toks: Vec<u32> = (0..b * s).map(|i| (i % 300) as u32).collect();
        let out = e.execute("preprocess_nlp", vec![Tensor::from_u32(vec![b, s], &toks)]).unwrap();
        assert_eq!(out.len(), 3, "(tokens, mask, lengths)");
        assert_eq!(out[0].dtype, DType::I32);
        assert_eq!(out[0].shape, vec![b, s]);
        // Tokens clipped to [0, 255].
        assert!(out[0].as_i32().iter().all(|&t| (0..=255).contains(&t)));
        // Mask is 0/1 and lengths = row-sums of mask.
        assert_eq!(out[1].shape, vec![b, s]);
        let mask = out[1].as_f32();
        assert!(mask.iter().all(|&m| m == 0.0 || m == 1.0));
        let lens = out[2].as_i32();
        for r in 0..b {
            let sum: f32 = mask[r * s..(r + 1) * s].iter().sum();
            assert_eq!(lens[r], sum as i32);
        }
    }

    #[test]
    fn preprocess_vision_matches_reference_shape() {
        let Some(e) = engine() else { return };
        let m = e.manifest();
        let (b, h, w, c) = (m.vision_batch, m.vision_hw, m.vision_hw, m.vision_c);
        let pixels: Vec<u8> = (0..b * h * w * c).map(|i| (i % 251) as u8).collect();
        // Neutral augmentation: no flip, zero brightness shift, unit
        // contrast — the output must equal plain (x/255 - mean)/std.
        let flip = vec![0.0f32; b];
        let brightness = vec![0.0f32; b];
        let contrast = vec![1.0f32; b];
        let out = e
            .execute(
                "preprocess_vision",
                vec![
                    Tensor::from_u8(vec![b, h, w, c], pixels.clone()),
                    Tensor::from_f32(vec![b], &flip),
                    Tensor::from_f32(vec![b], &brightness),
                    Tensor::from_f32(vec![b], &contrast),
                ],
            )
            .unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].shape, vec![b, h, w, c]);
        assert_eq!(out[0].dtype, DType::F32);
        // Check a handful of pixels against the reference normalization.
        const MEAN: [f32; 3] = [0.485, 0.456, 0.406];
        const STD: [f32; 3] = [0.229, 0.224, 0.225];
        let vals = out[0].as_f32();
        for idx in [0usize, 17, 1000, b * h * w * c - 1] {
            let ch = idx % c;
            let expect = (pixels[idx] as f32 / 255.0 - MEAN[ch]) / STD[ch];
            assert!((vals[idx] - expect).abs() < 1e-4, "pixel {idx}: {} vs {expect}", vals[idx]);
        }
    }

    #[test]
    fn execute_validates_inputs() {
        let Some(e) = engine() else { return };
        let bad = e.execute("preprocess_nlp", vec![Tensor::from_u32(vec![1, 1], &[0])]);
        assert!(matches!(bad, Err(RuntimeError::InputMismatch { .. })));
        let missing = e.execute("does_not_exist", vec![]);
        assert!(matches!(missing, Err(RuntimeError::UnknownArtifact(_))));
    }

    #[test]
    fn params_init_then_train_step_reduces_loss() {
        let Some(e) = engine() else { return };
        let params = e.execute("params_init", vec![]).unwrap();
        let m = e.manifest();
        assert_eq!(params.len(), m.param_shapes.len());
        // Tokens: simple repeating pattern the model can learn.
        let (b, s) = (m.model_batch, m.model_seq + 1);
        let toks: Vec<i32> = (0..b * s).map(|i| ((i % 7) + 1) as i32).collect();
        let tok_t = Tensor::from_i32(vec![b, s], &toks);
        let lr = Tensor::scalar_f32(0.05);

        let mut inputs = params.clone();
        inputs.push(tok_t.clone());
        let loss0 = {
            let out = e.execute("eval_loss", inputs).unwrap();
            out[0].as_f32()[0]
        };
        // A few SGD steps.
        let mut p = params;
        for _ in 0..5 {
            let mut inputs = p.clone();
            inputs.push(tok_t.clone());
            inputs.push(lr.clone());
            let out = e.execute("train_step", inputs).unwrap();
            // train_step returns (params'..., loss)
            p = out[..out.len() - 1].to_vec();
        }
        let mut inputs = p;
        inputs.push(tok_t);
        let loss1 = e.execute("eval_loss", inputs).unwrap()[0].as_f32()[0];
        assert!(loss1 < loss0, "loss should drop: {loss0} -> {loss1}");
    }
}
