//! RPC client: multiplexed calls over one connection, plus a reconnecting
//! connection pool.

use super::frame::{Frame, FrameKind};
use super::{RpcError, RpcResult};
use std::collections::HashMap;
use std::io::BufReader;
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Slot for a pending call's response.
#[derive(Default)]
struct PendingSlot {
    done: bool,
    result: Option<RpcResult<Vec<u8>>>,
}

struct Inner {
    writer: Mutex<TcpStream>,
    pending: Mutex<HashMap<u64, Arc<(Mutex<PendingSlot>, Condvar)>>>,
    next_call_id: AtomicU64,
    closed: AtomicBool,
}

/// A single multiplexed RPC connection. Clone-free: wrap in `Arc` to share
/// across threads (all methods take `&self`).
pub struct Client {
    inner: Arc<Inner>,
    peer: String,
}

impl Drop for Client {
    fn drop(&mut self) {
        // Shut the socket down so the background reader (which holds its
        // own clone of the fd) unblocks and exits; otherwise the TCP
        // connection would linger until process exit.
        self.inner.closed.store(true, Ordering::SeqCst);
        if let Ok(w) = self.inner.writer.lock() {
            let _ = w.shutdown(std::net::Shutdown::Both);
        }
    }
}

impl Client {
    /// Connect with a timeout; spawns a background reader thread that
    /// matches responses to pending calls by call id.
    pub fn connect(addr: &str, timeout: Duration) -> RpcResult<Client> {
        let sock_addr = addr
            .parse()
            .map_err(|e| RpcError::Connect { addr: addr.into(), err: std::io::Error::new(std::io::ErrorKind::InvalidInput, format!("{e}")) })?;
        let stream = TcpStream::connect_timeout(&sock_addr, timeout)
            .map_err(|err| RpcError::Connect { addr: addr.into(), err })?;
        stream.set_nodelay(true).ok();
        let read_half = stream.try_clone().map_err(RpcError::Io)?;

        let inner = Arc::new(Inner {
            writer: Mutex::new(stream),
            pending: Mutex::new(HashMap::new()),
            next_call_id: AtomicU64::new(1),
            closed: AtomicBool::new(false),
        });

        let weak = Arc::downgrade(&inner);
        std::thread::Builder::new()
            .name(format!("rpc-client-read-{addr}"))
            .spawn(move || {
                let mut reader = BufReader::with_capacity(256 << 10, read_half);
                loop {
                    let frame = match Frame::read_from(&mut reader) {
                        Ok(f) => f,
                        Err(_) => break,
                    };
                    let Some(inner) = weak.upgrade() else { break };
                    let slot = inner.pending.lock().unwrap().remove(&frame.call_id);
                    if let Some(slot) = slot {
                        let result = match frame.kind {
                            FrameKind::Response => Ok(frame.payload),
                            FrameKind::Error => {
                                Err(RpcError::Remote(String::from_utf8_lossy(&frame.payload).into_owned()))
                            }
                            FrameKind::Request => continue, // clients never serve
                        };
                        let (m, cv) = &*slot;
                        let mut g = m.lock().unwrap();
                        g.done = true;
                        g.result = Some(result);
                        cv.notify_all();
                    }
                }
                // Connection died: fail everything still pending.
                if let Some(inner) = weak.upgrade() {
                    inner.closed.store(true, Ordering::SeqCst);
                    let mut pend = inner.pending.lock().unwrap();
                    for (_, slot) in pend.drain() {
                        let (m, cv) = &*slot;
                        let mut g = m.lock().unwrap();
                        g.done = true;
                        g.result = Some(Err(RpcError::ConnectionClosed));
                        cv.notify_all();
                    }
                }
            })
            .ok();

        Ok(Client { inner, peer: addr.to_string() })
    }

    pub fn peer(&self) -> &str {
        &self.peer
    }

    pub fn is_closed(&self) -> bool {
        self.inner.closed.load(Ordering::SeqCst)
    }

    /// Issue a call and block until the response arrives or the deadline
    /// passes. The call id is abandoned on deadline; a late response is
    /// dropped by the reader.
    pub fn call(&self, method: u16, payload: &[u8], deadline: Duration) -> RpcResult<Vec<u8>> {
        if self.is_closed() {
            return Err(RpcError::ConnectionClosed);
        }
        let call_id = self.inner.next_call_id.fetch_add(1, Ordering::Relaxed);
        let slot = Arc::new((Mutex::new(PendingSlot::default()), Condvar::new()));
        self.inner.pending.lock().unwrap().insert(call_id, slot.clone());

        let frame = Frame::request(call_id, method, payload.to_vec());
        {
            let mut w = self.inner.writer.lock().unwrap();
            if let Err(e) = frame.write_to(&mut *w) {
                self.inner.pending.lock().unwrap().remove(&call_id);
                return Err(RpcError::Io(e));
            }
        }

        let (m, cv) = &*slot;
        let start = Instant::now();
        let mut g = m.lock().unwrap();
        while !g.done {
            let elapsed = start.elapsed();
            if elapsed >= deadline {
                drop(g);
                self.inner.pending.lock().unwrap().remove(&call_id);
                return Err(RpcError::DeadlineExceeded(deadline));
            }
            let (next, timeout) = cv.wait_timeout(g, deadline - elapsed).unwrap();
            g = next;
            if timeout.timed_out() && !g.done {
                drop(g);
                self.inner.pending.lock().unwrap().remove(&call_id);
                return Err(RpcError::DeadlineExceeded(deadline));
            }
        }
        g.result.take().unwrap_or(Err(RpcError::ConnectionClosed))
    }
}

/// Reconnecting connection pool keyed by address, with retry/backoff.
///
/// One [`Client`] per address (gRPC-style channel sharing); transport
/// failures evict the connection and retry with exponential backoff up to
/// `max_retries` attempts.
pub struct Pool {
    conns: Mutex<HashMap<String, Arc<Client>>>,
    connect_timeout: Duration,
    max_retries: usize,
}

impl Pool {
    pub fn new(connect_timeout: Duration, max_retries: usize) -> Pool {
        Pool { conns: Mutex::new(HashMap::new()), connect_timeout, max_retries }
    }

    /// Pool with defaults suitable for tests and examples.
    pub fn with_defaults() -> Pool {
        Pool::new(Duration::from_secs(2), 5)
    }

    fn get_or_connect(&self, addr: &str) -> RpcResult<Arc<Client>> {
        let mut conns = self.conns.lock().unwrap();
        if let Some(c) = conns.get(addr) {
            if !c.is_closed() {
                return Ok(c.clone());
            }
            conns.remove(addr);
        }
        let c = Arc::new(Client::connect(addr, self.connect_timeout)?);
        conns.insert(addr.to_string(), c.clone());
        Ok(c)
    }

    /// Drop the cached connection for `addr` (e.g. after a worker is
    /// removed from a job).
    pub fn evict(&self, addr: &str) {
        self.conns.lock().unwrap().remove(addr);
    }

    pub fn connection_count(&self) -> usize {
        self.conns.lock().unwrap().len()
    }

    /// Call with retries on retryable (transport) errors. Remote errors and
    /// deadline expiries surface immediately.
    pub fn call(&self, addr: &str, method: u16, payload: &[u8], deadline: Duration) -> RpcResult<Vec<u8>> {
        let mut backoff = Duration::from_millis(10);
        let mut last: Option<RpcError> = None;
        for attempt in 0..self.max_retries.max(1) {
            if attempt > 0 {
                std::thread::sleep(backoff);
                backoff = (backoff * 2).min(Duration::from_secs(1));
            }
            match self.get_or_connect(addr) {
                Ok(client) => match client.call(method, payload, deadline) {
                    Ok(v) => return Ok(v),
                    Err(e) if e.is_retryable() => {
                        self.evict(addr);
                        last = Some(e);
                    }
                    Err(e) => return Err(e),
                },
                Err(e) if e.is_retryable() => last = Some(e),
                Err(e) => return Err(e),
            }
        }
        Err(RpcError::RetriesExhausted(
            last.map(|e| e.to_string()).unwrap_or_else(|| "unknown".into()),
        ))
    }
}

/// Typed call helper: encode the request, call through the pool, decode the
/// response. All service RPCs go through this.
pub fn call_typed<Req, Resp>(
    pool: &Pool,
    addr: &str,
    method: u16,
    req: &Req,
    deadline: Duration,
) -> RpcResult<Resp>
where
    Req: crate::wire::Encode,
    Resp: crate::wire::Decode,
{
    let bytes = pool.call(addr, method, &req.to_bytes(), deadline)?;
    Ok(Resp::from_bytes(&bytes)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn connect_refused_reports_connect_error() {
        // Port 1 is essentially never listening.
        match Client::connect("127.0.0.1:1", Duration::from_millis(200)) {
            Err(err) => assert!(matches!(err, RpcError::Connect { .. })),
            Ok(_) => panic!("connect to port 1 unexpectedly succeeded"),
        }
    }

    #[test]
    fn pool_retries_then_exhausts() {
        let pool = Pool::new(Duration::from_millis(50), 2);
        let err = pool
            .call("127.0.0.1:1", 1, b"", Duration::from_millis(100))
            .unwrap_err();
        assert!(matches!(err, RpcError::RetriesExhausted(_)), "{err:?}");
    }

    #[test]
    fn call_on_closed_client_fails_fast() {
        let srv = super::super::Server::bind("127.0.0.1:0", |_m, p: &[u8]| Ok(p.to_vec().into())).unwrap();
        let addr = srv.local_addr().to_string();
        let client = Client::connect(&addr, Duration::from_secs(1)).unwrap();
        client.call(1, b"x", Duration::from_secs(1)).unwrap();
        drop(srv);
        // Wait for the reader thread to observe the close.
        for _ in 0..100 {
            if client.is_closed() {
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(matches!(
            client.call(1, b"x", Duration::from_secs(1)),
            Err(RpcError::ConnectionClosed) | Err(RpcError::Io(_))
        ));
    }
}
