"""Pure-jnp oracles for the Pallas kernels (L1 correctness ground truth).

Every Pallas kernel in this package has a reference implementation here,
written with plain jax.numpy ops only. pytest (python/tests/test_kernels.py)
sweeps shapes/dtypes with hypothesis and asserts allclose between kernel and
oracle. The oracles are also what the L2 model *would* use if the L1 kernels
did not exist, so they double as the baseline for the §Perf L1 comparison.
"""

import jax.numpy as jnp


# ImageNet-style per-channel normalization constants, scaled to [0,1] input.
NORM_MEAN = jnp.array([0.485, 0.456, 0.406], dtype=jnp.float32)
NORM_STD = jnp.array([0.229, 0.224, 0.225], dtype=jnp.float32)


def augment_ref(images_u8, flip, brightness, contrast):
    """Fused image augmentation oracle.

    Args:
      images_u8: (B, H, W, C) uint8 raw pixels.
      flip:       (B,) float32 in {0, 1}; 1 => horizontal flip.
      brightness: (B,) float32 additive delta (post-normalization units).
      contrast:   (B,) float32 multiplicative scale around the per-image mean.

    Returns:
      (B, H, W, C) float32 augmented, normalized images.
    """
    x = images_u8.astype(jnp.float32) / 255.0
    c = images_u8.shape[-1]
    mean = NORM_MEAN[:c]
    std = NORM_STD[:c]
    x = (x - mean) / std
    # Horizontal flip (width axis), per sample.
    flipped = x[:, :, ::-1, :]
    f = flip[:, None, None, None]
    x = f * flipped + (1.0 - f) * x
    # Contrast around per-image mean, then brightness.
    img_mean = jnp.mean(x, axis=(1, 2, 3), keepdims=True)
    x = contrast[:, None, None, None] * (x - img_mean) + img_mean
    x = x + brightness[:, None, None, None]
    return x


def gelu_ref(x):
    """tanh-approximation GELU (matches the Pallas kernel exactly)."""
    return 0.5 * x * (1.0 + jnp.tanh(0.7978845608028654 * (x + 0.044715 * x * x * x)))


def ffn_ref(x, w1, b1, w2, b2):
    """Fused transformer FFN block oracle: gelu(x @ w1 + b1) @ w2 + b2.

    Args:
      x:  (N, D) float32 activations (N = batch*seq rows).
      w1: (D, F), b1: (F,), w2: (F, D), b2: (D,).

    Returns:
      (N, D) float32.
    """
    h = gelu_ref(x @ w1 + b1)
    return h @ w2 + b2
