//! Minimal CLI argument parser (no clap offline).
//!
//! Supports `--flag`, `--key value`, and `--key=value`; everything else is
//! a positional. Used by the launcher binary and the examples.

use std::collections::HashMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    opts: HashMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (testable) — pass
    /// `std::env::args().skip(1)` in binaries.
    pub fn parse<I: IntoIterator<Item = String>>(iter: I) -> Self {
        let mut out = Args::default();
        let toks: Vec<String> = iter.into_iter().collect();
        let mut i = 0;
        while i < toks.len() {
            let t = &toks[i];
            if let Some(body) = t.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if i + 1 < toks.len() && !toks[i + 1].starts_with("--") {
                    out.opts.insert(body.to_string(), toks[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(t.clone());
            }
            i += 1;
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|s| s.to_string()))
    }

    #[test]
    fn key_value_both_styles() {
        let a = parse(&["--workers", "8", "--mode=dynamic"]);
        assert_eq!(a.get("workers"), Some("8"));
        assert_eq!(a.get("mode"), Some("dynamic"));
        assert_eq!(a.u64_or("workers", 0), 8);
    }

    #[test]
    fn bare_flags() {
        let a = parse(&["--verbose", "--workers", "4"]);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.u64_or("workers", 0), 4);
    }

    #[test]
    fn trailing_flag_and_positionals() {
        let a = parse(&["run", "--fast", "input.txt"]);
        // "--fast input.txt" binds as kv by the grammar; positional is "run".
        assert_eq!(a.positional, vec!["run"]);
        assert_eq!(a.get("fast"), Some("input.txt"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[]);
        assert_eq!(a.u64_or("x", 7), 7);
        assert_eq!(a.f64_or("y", 1.5), 1.5);
        assert_eq!(a.str_or("z", "d"), "d");
    }

    #[test]
    fn bad_number_falls_back() {
        let a = parse(&["--n", "abc"]);
        assert_eq!(a.u64_or("n", 3), 3);
    }
}
