//! Wire framing for the RPC transport.
//!
//! One frame per logical message, in both directions:
//!
//! ```text
//! +----------+----------+---------+-----------+------------------+
//! | len: u32 | call: u64| kind: u8| method:u16| payload: len-11 B|
//! +----------+----------+---------+-----------+------------------+
//! ```
//!
//! `len` counts everything after itself. `kind` distinguishes requests,
//! successful responses, and error responses (whose payload is a UTF-8
//! message). `method` is only meaningful on requests; responses echo it.

use crate::wire::{Reader, Writer};
use std::io::{self, Read, Write};

/// Hard cap on a single frame: a 64 MiB batch is far beyond any payload the
/// service produces; anything larger indicates corruption.
pub const MAX_FRAME_LEN: usize = 64 << 20;

/// Bytes of header following the length word.
const HEADER_LEN: usize = 8 + 1 + 2;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameKind {
    Request = 0,
    Response = 1,
    Error = 2,
}

impl FrameKind {
    fn from_u8(v: u8) -> io::Result<Self> {
        match v {
            0 => Ok(FrameKind::Request),
            1 => Ok(FrameKind::Response),
            2 => Ok(FrameKind::Error),
            _ => Err(io::Error::new(io::ErrorKind::InvalidData, format!("bad frame kind {v}"))),
        }
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Frame {
    pub call_id: u64,
    pub kind: FrameKind,
    pub method: u16,
    pub payload: Vec<u8>,
}

impl Frame {
    pub fn request(call_id: u64, method: u16, payload: Vec<u8>) -> Self {
        Frame { call_id, kind: FrameKind::Request, method, payload }
    }

    pub fn response(call_id: u64, method: u16, payload: Vec<u8>) -> Self {
        Frame { call_id, kind: FrameKind::Response, method, payload }
    }

    pub fn error(call_id: u64, method: u16, msg: &str) -> Self {
        Frame { call_id, kind: FrameKind::Error, method, payload: msg.as_bytes().to_vec() }
    }

    /// Serialize and write the frame, then flush. A single `write_all` keeps
    /// the frame contiguous even when multiple threads share the socket via
    /// a mutex around the writer.
    pub fn write_to<W: Write>(&self, w: &mut W) -> io::Result<()> {
        let mut hdr = Writer::with_capacity(4 + HEADER_LEN);
        hdr.put_u32((HEADER_LEN + self.payload.len()) as u32);
        hdr.put_u64(self.call_id);
        hdr.put_u8(self.kind as u8);
        hdr.put_u16(self.method);
        // Two writes (header, payload) avoid copying multi-MiB payloads.
        w.write_all(hdr.as_slice())?;
        w.write_all(&self.payload)?;
        w.flush()
    }

    /// Scatter-gather frame write: serialize a frame whose payload is the
    /// concatenation of `parts`, without ever copying the parts into one
    /// contiguous buffer. This is the zero-copy half of the batched data
    /// plane: the worker passes `[response head, element frame]` and the
    /// multi-megabyte frame goes from its assembly buffer straight to the
    /// socket (one gathered write), instead of through an intermediate
    /// payload copy in `to_bytes` + `write_to`.
    pub fn write_parts_to<W: Write>(
        w: &mut W,
        call_id: u64,
        kind: FrameKind,
        method: u16,
        parts: &[&[u8]],
    ) -> io::Result<()> {
        let payload_len: usize = parts.iter().map(|p| p.len()).sum();
        // Refuse to emit a frame the peer's reader will reject: writing it
        // would not "fail fast", it would desynchronize nothing visible
        // here and kill the peer's whole connection (taking every other
        // in-flight call with it). Serving paths are expected to chunk or
        // error before this point; this is the transport backstop.
        if HEADER_LEN + payload_len > MAX_FRAME_LEN {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("frame payload {payload_len} B exceeds MAX_FRAME_LEN {MAX_FRAME_LEN} B"),
            ));
        }
        let mut hdr = Writer::with_capacity(4 + HEADER_LEN);
        hdr.put_u32((HEADER_LEN + payload_len) as u32);
        hdr.put_u64(call_id);
        hdr.put_u8(kind as u8);
        hdr.put_u16(method);
        let mut slices: Vec<&[u8]> = Vec::with_capacity(1 + parts.len());
        slices.push(hdr.as_slice());
        slices.extend_from_slice(parts);
        write_all_vectored(w, &slices)?;
        w.flush()
    }

    /// Blocking read of one complete frame.
    ///
    /// The length word and fixed header are gathered in a single
    /// `read_vectored` scatter read (the request-side mirror of the
    /// gathered [`Frame::write_parts_to`] response path), and the payload
    /// is then read straight into its final, exactly-sized buffer. The
    /// previous shape read `len` bytes into a scratch `body` buffer and
    /// copied the payload back out of it — one full extra copy of every
    /// multi-megabyte batch frame.
    pub fn read_from<R: Read>(r: &mut R) -> io::Result<Frame> {
        let mut len4 = [0u8; 4];
        let mut hdr = [0u8; HEADER_LEN];
        read_exact_vectored(r, &mut len4, &mut hdr)?;
        let len = u32::from_le_bytes(len4) as usize;
        if len < HEADER_LEN || len > MAX_FRAME_LEN {
            return Err(io::Error::new(io::ErrorKind::InvalidData, format!("bad frame length {len}")));
        }
        let mut rd = Reader::new(&hdr);
        let call_id = rd.get_u64().map_err(to_io)?;
        let kind = FrameKind::from_u8(rd.get_u8().map_err(to_io)?)?;
        let method = rd.get_u16().map_err(to_io)?;
        let mut payload = vec![0u8; len - HEADER_LEN];
        r.read_exact(&mut payload)?;
        Ok(Frame { call_id, kind, method, payload })
    }
}

fn to_io(e: crate::wire::WireError) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, e.to_string())
}

/// `write_all` for a list of slices via `write_vectored`, tracking partial
/// progress across slice boundaries. Falls back gracefully on writers
/// whose `write_vectored` only consumes the first buffer (the default
/// impl): the loop simply re-enters with the remainder.
fn write_all_vectored<W: Write>(w: &mut W, slices: &[&[u8]]) -> io::Result<()> {
    let mut idx = 0usize; // first slice with unwritten bytes
    let mut off = 0usize; // progress within that slice
    while idx < slices.len() {
        if off >= slices[idx].len() {
            idx += 1;
            off = 0;
            continue;
        }
        let bufs: Vec<io::IoSlice> = std::iter::once(io::IoSlice::new(&slices[idx][off..]))
            .chain(slices[idx + 1..].iter().map(|s| io::IoSlice::new(s)))
            .collect();
        let mut n = match w.write_vectored(&bufs) {
            Ok(0) => {
                return Err(io::Error::new(io::ErrorKind::WriteZero, "gathered frame write stalled"))
            }
            Ok(n) => n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        };
        while n > 0 && idx < slices.len() {
            let rem = slices[idx].len() - off;
            if n < rem {
                off += n;
                n = 0;
            } else {
                n -= rem;
                idx += 1;
                off = 0;
            }
        }
    }
    Ok(())
}

/// `read_exact` across two buffers via `read_vectored`, tracking partial
/// progress across the buffer boundary (the read-side dual of
/// [`write_all_vectored`]). Falls back gracefully on readers whose
/// `read_vectored` only fills the first buffer (the default impl): the
/// loop simply re-enters with the remainder.
fn read_exact_vectored<R: Read>(r: &mut R, a: &mut [u8], b: &mut [u8]) -> io::Result<()> {
    let mut done_a = 0usize;
    let mut done_b = 0usize;
    while done_a < a.len() || done_b < b.len() {
        let mut bufs =
            [io::IoSliceMut::new(&mut a[done_a..]), io::IoSliceMut::new(&mut b[done_b..])];
        let n = match r.read_vectored(&mut bufs) {
            Ok(0) => {
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "eof inside frame header",
                ))
            }
            Ok(n) => n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        };
        let take_a = n.min(a.len() - done_a);
        done_a += take_a;
        done_b += n - take_a;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_kinds() {
        for f in [
            Frame::request(7, 3, b"abc".to_vec()),
            Frame::response(8, 3, vec![]),
            Frame::error(9, 0, "oops"),
        ] {
            let mut buf = Vec::new();
            f.write_to(&mut buf).unwrap();
            let back = Frame::read_from(&mut buf.as_slice()).unwrap();
            assert_eq!(f, back);
        }
    }

    #[test]
    fn rejects_oversized_length() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&((MAX_FRAME_LEN as u32) + 1).to_le_bytes());
        assert!(Frame::read_from(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn rejects_undersized_length() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&3u32.to_le_bytes());
        buf.extend_from_slice(&[0, 0, 0]);
        assert!(Frame::read_from(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn rejects_bad_kind() {
        let f = Frame::request(1, 1, vec![]);
        let mut buf = Vec::new();
        f.write_to(&mut buf).unwrap();
        buf[4 + 8] = 9; // corrupt kind byte
        assert!(Frame::read_from(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn truncated_stream_is_eof() {
        let f = Frame::request(1, 1, b"payload".to_vec());
        let mut buf = Vec::new();
        f.write_to(&mut buf).unwrap();
        buf.truncate(buf.len() - 2);
        let err = Frame::read_from(&mut buf.as_slice()).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof);
    }

    #[test]
    fn write_parts_matches_contiguous_write() {
        let head = vec![1u8, 2, 3];
        let tail = vec![4u8, 5, 6, 7, 8];
        let mut joined = head.clone();
        joined.extend_from_slice(&tail);
        let expect = Frame::response(42, 9, joined);
        let mut contiguous = Vec::new();
        expect.write_to(&mut contiguous).unwrap();

        let mut gathered = Vec::new();
        Frame::write_parts_to(&mut gathered, 42, FrameKind::Response, 9, &[&head, &tail]).unwrap();
        assert_eq!(gathered, contiguous, "scatter-gather bytes identical");
        assert_eq!(Frame::read_from(&mut gathered.as_slice()).unwrap(), expect);
    }

    #[test]
    fn write_parts_handles_empty_and_many_slices() {
        let parts: Vec<Vec<u8>> = vec![vec![], vec![1], vec![], vec![2, 3], vec![]];
        let refs: Vec<&[u8]> = parts.iter().map(|p| p.as_slice()).collect();
        let mut buf = Vec::new();
        Frame::write_parts_to(&mut buf, 1, FrameKind::Response, 2, &refs).unwrap();
        let back = Frame::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(back.payload, vec![1u8, 2, 3]);
        // Zero parts = empty payload.
        let mut buf2 = Vec::new();
        Frame::write_parts_to(&mut buf2, 1, FrameKind::Response, 2, &[]).unwrap();
        assert!(Frame::read_from(&mut buf2.as_slice()).unwrap().payload.is_empty());
    }

    /// A writer that accepts at most 3 bytes per call and ignores all but
    /// the first buffer of a vectored write — the worst legal behavior —
    /// must still receive the complete frame.
    #[test]
    fn write_parts_survives_short_writes() {
        struct Dribble(Vec<u8>);
        impl Write for Dribble {
            fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
                let n = buf.len().min(3);
                self.0.extend_from_slice(&buf[..n]);
                Ok(n)
            }
            fn flush(&mut self) -> io::Result<()> {
                Ok(())
            }
        }
        let head = vec![9u8; 5];
        let tail: Vec<u8> = (0..23u8).collect();
        let mut d = Dribble(Vec::new());
        Frame::write_parts_to(&mut d, 7, FrameKind::Response, 1, &[&head, &tail]).unwrap();
        let back = Frame::read_from(&mut d.0.as_slice()).unwrap();
        let mut joined = head;
        joined.extend_from_slice(&tail);
        assert_eq!(back.payload, joined);
    }

    /// The writer must refuse a frame the peer's reader would reject
    /// (reader-side rejection kills the whole connection; writer-side is
    /// a per-call error).
    #[test]
    fn write_parts_rejects_over_cap_payload() {
        struct NullSink;
        impl Write for NullSink {
            fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
                Ok(buf.len())
            }
            fn flush(&mut self) -> io::Result<()> {
                Ok(())
            }
        }
        // Claim an over-cap total without allocating 64 MiB: many refs to
        // one 1 MiB slice.
        let chunk = vec![0u8; 1 << 20];
        let parts: Vec<&[u8]> = (0..65).map(|_| chunk.as_slice()).collect();
        let err =
            Frame::write_parts_to(&mut NullSink, 1, FrameKind::Response, 2, &parts).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        // Exactly at the cap minus header is fine.
        let ok_parts: Vec<&[u8]> = (0..63).map(|_| chunk.as_slice()).collect();
        Frame::write_parts_to(&mut NullSink, 1, FrameKind::Response, 2, &ok_parts).unwrap();
    }

    /// A reader that returns at most 3 bytes per call and only fills the
    /// first buffer of a vectored read — the worst legal behavior — must
    /// still produce the complete frame through the scatter-read path.
    #[test]
    fn read_survives_short_vectored_reads() {
        struct Dribble<'a>(&'a [u8]);
        impl Read for Dribble<'_> {
            fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
                let n = buf.len().min(3).min(self.0.len());
                buf[..n].copy_from_slice(&self.0[..n]);
                self.0 = &self.0[n..];
                Ok(n)
            }
        }
        let f = Frame::request(11, 4, (0..37u8).collect());
        let mut buf = Vec::new();
        f.write_to(&mut buf).unwrap();
        assert_eq!(Frame::read_from(&mut Dribble(&buf)).unwrap(), f);
    }

    /// Truncation inside the gathered length+header read is clean EOF,
    /// whether the cut lands in the length word or the header proper.
    #[test]
    fn eof_inside_header_is_eof() {
        let f = Frame::request(1, 1, b"xyz".to_vec());
        let mut buf = Vec::new();
        f.write_to(&mut buf).unwrap();
        for cut in [2usize, 9] {
            let err = Frame::read_from(&mut &buf[..cut]).unwrap_err();
            assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof, "cut {cut}");
        }
    }

    #[test]
    fn back_to_back_frames() {
        let a = Frame::request(1, 2, b"a".to_vec());
        let b = Frame::response(1, 2, b"bb".to_vec());
        let mut buf = Vec::new();
        a.write_to(&mut buf).unwrap();
        b.write_to(&mut buf).unwrap();
        let mut cur = buf.as_slice();
        assert_eq!(Frame::read_from(&mut cur).unwrap(), a);
        assert_eq!(Frame::read_from(&mut cur).unwrap(), b);
        assert!(cur.is_empty());
    }
}
