//! RPC schema: method ids and message types for dispatcher and worker.
//!
//! Mirrors the tf.data service proto surface: dataset registration,
//! job creation, worker registration + heartbeats, dynamic split
//! distribution, and the client-facing `GetElement`.

use crate::data::graph::GraphDef;
use crate::wire::{Decode, Encode, Reader, WireError, WireResult, Writer};
use crate::wire_struct;

// ------------------------------------------------------------- method ids

/// Dispatcher-served methods.
pub mod dispatcher_methods {
    pub const REGISTER_DATASET: u16 = 1;
    pub const GET_OR_CREATE_JOB: u16 = 2;
    pub const CLIENT_HEARTBEAT: u16 = 3;
    pub const REGISTER_WORKER: u16 = 4;
    pub const WORKER_HEARTBEAT: u16 = 5;
    pub const GET_SPLIT: u16 = 6;
    pub const RELEASE_JOB: u16 = 7;
}

/// Worker-served methods.
pub mod worker_methods {
    pub const GET_ELEMENT: u16 = 32;
    pub const WORKER_STATUS: u16 = 33;
    /// Batched streaming fetch (the default independent-mode data plane).
    pub const GET_ELEMENTS: u16 = 34;
}

// ------------------------------------------------------------ enum types

/// Source-data sharding policy (§3.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardingPolicy {
    /// No sharding: every worker processes the whole dataset in its own
    /// random order (zero-once-or-more visitation).
    Off,
    /// Disjoint first-come-first-served splits from the dispatcher
    /// (at-most-once under failures, exactly-once without).
    Dynamic,
    /// Splits pre-assigned round-robin at job start.
    Static,
}

impl Encode for ShardingPolicy {
    fn encode(&self, w: &mut Writer) {
        w.put_u8(match self {
            ShardingPolicy::Off => 0,
            ShardingPolicy::Dynamic => 1,
            ShardingPolicy::Static => 2,
        });
    }
}

impl Decode for ShardingPolicy {
    fn decode(r: &mut Reader) -> WireResult<Self> {
        Ok(match r.get_u8()? {
            0 => ShardingPolicy::Off,
            1 => ShardingPolicy::Dynamic,
            2 => ShardingPolicy::Static,
            tag => return Err(WireError::BadTag { tag, ty: "ShardingPolicy" }),
        })
    }
}

/// How clients consume the job's output.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ProcessingMode {
    /// Each client pulls batches from any worker as fast as it can.
    Independent,
    /// Coordinated reads (§3.6): per training round, one worker feeds all
    /// `num_consumers` clients same-bucket batches, round-robin across
    /// workers.
    Coordinated,
}

impl Encode for ProcessingMode {
    fn encode(&self, w: &mut Writer) {
        w.put_u8(match self {
            ProcessingMode::Independent => 0,
            ProcessingMode::Coordinated => 1,
        });
    }
}

impl Decode for ProcessingMode {
    fn decode(r: &mut Reader) -> WireResult<Self> {
        Ok(match r.get_u8()? {
            0 => ProcessingMode::Independent,
            1 => ProcessingMode::Coordinated,
            tag => return Err(WireError::BadTag { tag, ty: "ProcessingMode" }),
        })
    }
}

/// Element payload compression between worker and client (§3.1: useful in
/// bandwidth-constrained deployments, wasteful otherwise).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CompressionMode {
    None,
    Deflate,
}

impl Encode for CompressionMode {
    fn encode(&self, w: &mut Writer) {
        w.put_u8(match self {
            CompressionMode::None => 0,
            CompressionMode::Deflate => 1,
        });
    }
}

impl Decode for CompressionMode {
    fn decode(r: &mut Reader) -> WireResult<Self> {
        Ok(match r.get_u8()? {
            0 => CompressionMode::None,
            1 => CompressionMode::Deflate,
            tag => return Err(WireError::BadTag { tag, ty: "CompressionMode" }),
        })
    }
}

// -------------------------------------------------------------- messages

#[derive(Debug, Clone, PartialEq)]
pub struct RegisterDatasetReq {
    /// Serialized, already-optimized pipeline graph.
    pub graph: GraphDef,
}
wire_struct!(RegisterDatasetReq { graph });

#[derive(Debug, Clone, PartialEq)]
pub struct RegisterDatasetResp {
    /// Dataset id = graph fingerprint (identical pipelines share an id,
    /// which is what makes ephemeral sharing discoverable).
    pub dataset_id: u64,
}
wire_struct!(RegisterDatasetResp { dataset_id });

#[derive(Debug, Clone, PartialEq)]
pub struct GetOrCreateJobReq {
    pub dataset_id: u64,
    /// Jobs with the same non-empty name attach to one shared job
    /// (ephemeral data sharing); empty = anonymous dedicated job.
    pub job_name: String,
    pub sharding: ShardingPolicy,
    pub mode: ProcessingMode,
    /// Number of coordinated consumers (0 for independent mode).
    pub num_consumers: u32,
}
wire_struct!(GetOrCreateJobReq { dataset_id, job_name, sharding, mode, num_consumers });

#[derive(Debug, Clone, PartialEq)]
pub struct GetOrCreateJobResp {
    pub job_id: u64,
    /// Client handle within the job (used to GC per-client state).
    pub client_id: u64,
}
wire_struct!(GetOrCreateJobResp { job_id, client_id });

#[derive(Debug, Clone, PartialEq)]
pub struct ClientHeartbeatReq {
    pub job_id: u64,
    pub client_id: u64,
}
wire_struct!(ClientHeartbeatReq { job_id, client_id });

#[derive(Debug, Clone, PartialEq)]
pub struct ClientHeartbeatResp {
    /// Addresses of workers currently running this job's task.
    pub worker_addrs: Vec<String>,
    pub job_finished: bool,
}
wire_struct!(ClientHeartbeatResp { worker_addrs, job_finished });

#[derive(Debug, Clone, PartialEq)]
pub struct ReleaseJobReq {
    pub job_id: u64,
    pub client_id: u64,
}
wire_struct!(ReleaseJobReq { job_id, client_id });

#[derive(Debug, Clone, PartialEq)]
pub struct ReleaseJobResp {
    pub released: bool,
}
wire_struct!(ReleaseJobResp { released });

#[derive(Debug, Clone, PartialEq)]
pub struct RegisterWorkerReq {
    /// Address the worker's data server listens on.
    pub addr: String,
}
wire_struct!(RegisterWorkerReq { addr });

#[derive(Debug, Clone, PartialEq)]
pub struct RegisterWorkerResp {
    pub worker_id: u64,
    /// Tasks for all currently-active jobs.
    pub tasks: Vec<TaskDef>,
}
wire_struct!(RegisterWorkerResp { worker_id, tasks });

#[derive(Debug, Clone, PartialEq)]
pub struct WorkerHeartbeatReq {
    pub worker_id: u64,
    /// Task (= job) ids the worker is currently running.
    pub active_tasks: Vec<u64>,
    /// Mean CPU utilization since last heartbeat, [0, 1] (autoscaler input).
    pub cpu_util_milli: u32,
}
wire_struct!(WorkerHeartbeatReq { worker_id, active_tasks, cpu_util_milli });

#[derive(Debug, Clone, PartialEq)]
pub struct WorkerHeartbeatResp {
    /// Newly-assigned tasks.
    pub new_tasks: Vec<TaskDef>,
    /// Jobs that finished / were GC'd: the worker drops their state.
    pub removed_tasks: Vec<u64>,
}
wire_struct!(WorkerHeartbeatResp { new_tasks, removed_tasks });

/// A data-processing task: one job's pipeline on one worker.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskDef {
    pub job_id: u64,
    pub dataset_id: u64,
    pub graph: GraphDef,
    pub sharding: ShardingPolicy,
    pub mode: ProcessingMode,
    pub num_consumers: u32,
    /// For Static sharding: this worker's pre-assigned shard indices.
    pub static_shards: Vec<u64>,
    /// This worker's index among the job's workers at task-creation time
    /// (drives the coordinated-reads round-robin).
    pub worker_index: u32,
    /// Total workers the job had at task-creation time.
    pub num_workers: u32,
}
wire_struct!(TaskDef {
    job_id,
    dataset_id,
    graph,
    sharding,
    mode,
    num_consumers,
    static_shards,
    worker_index,
    num_workers
});

#[derive(Debug, Clone, PartialEq)]
pub struct GetSplitReq {
    pub job_id: u64,
    pub worker_id: u64,
}
wire_struct!(GetSplitReq { job_id, worker_id });

#[derive(Debug, Clone, PartialEq)]
pub struct GetSplitResp {
    /// Next shard index to process; `None` = end of splits this epoch.
    pub split: Option<u64>,
}
wire_struct!(GetSplitResp { split });

#[derive(Debug, Clone, PartialEq)]
pub struct GetElementReq {
    pub job_id: u64,
    pub client_id: u64,
    /// Coordinated mode: which consumer slot this client occupies.
    pub consumer_index: Option<u32>,
    /// Coordinated mode: the training round being fetched.
    pub round: Option<u64>,
    pub compression: CompressionMode,
}
wire_struct!(GetElementReq { job_id, client_id, consumer_index, round, compression });

#[derive(Debug, Clone, PartialEq)]
pub struct GetElementResp {
    /// Wire-encoded [`crate::data::Element`], possibly deflate-compressed.
    pub element: Option<Vec<u8>>,
    pub compressed: bool,
    /// True when the task has produced everything it ever will.
    pub end_of_sequence: bool,
    /// Coordinated mode: this round is not served by this worker — the
    /// client should ask the worker whose turn it is.
    pub wrong_worker_for_round: bool,
}
wire_struct!(GetElementResp { element, compressed, end_of_sequence, wrong_worker_for_round });

/// Batched streaming fetch (independent mode only): one RPC drains up to
/// `max_elements` / `max_bytes` of the task's ready queue, amortizing
/// per-element RPC overhead. Coordinated-reads rounds keep using
/// [`GetElementReq`] (one round slot per call is the §3.6 contract).
#[derive(Debug, Clone, PartialEq)]
pub struct GetElementsReq {
    pub job_id: u64,
    pub client_id: u64,
    /// Max elements per response; 0 = worker default.
    pub max_elements: u32,
    /// Soft response byte budget (pre-compression); 0 = worker default.
    /// At least one element is returned even if it alone exceeds this.
    pub max_bytes: u64,
    /// How long the worker may hold the request open waiting for data
    /// before returning an empty frame (long-poll); 0 = worker default.
    pub poll_ms: u32,
    pub compression: CompressionMode,
}
wire_struct!(GetElementsReq { job_id, client_id, max_elements, max_bytes, poll_ms, compression });

#[derive(Debug, Clone, PartialEq)]
pub struct GetElementsResp {
    /// Response frame: a wire-encoded `Vec<Vec<u8>>` of element payloads
    /// (`u32` count, then length-prefixed entries). When `compressed`,
    /// the whole frame is compressed as one unit so codec overhead
    /// amortizes across the batch.
    pub frame: Vec<u8>,
    /// Element count inside `frame` (sanity check for the decoder).
    pub num_elements: u32,
    pub compressed: bool,
    /// True when the task has produced everything it ever will *and*
    /// this client has consumed it all; may accompany a non-empty frame.
    pub end_of_sequence: bool,
}
wire_struct!(GetElementsResp { frame, num_elements, compressed, end_of_sequence });

#[derive(Debug, Clone, PartialEq)]
pub struct WorkerStatusReq {}
wire_struct!(WorkerStatusReq {});

#[derive(Debug, Clone, PartialEq)]
pub struct WorkerStatusResp {
    pub active_tasks: Vec<u64>,
    pub buffered_elements: u64,
    pub elements_produced: u64,
    pub cache_hits: u64,
    pub cache_evictions: u64,
}
wire_struct!(WorkerStatusResp {
    active_tasks,
    buffered_elements,
    elements_produced,
    cache_hits,
    cache_evictions
});

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::graph::PipelineBuilder;
    use crate::wire::{Decode, Encode};

    fn rt<T: Encode + Decode + PartialEq + std::fmt::Debug>(v: T) {
        assert_eq!(T::from_bytes(&v.to_bytes()).unwrap(), v);
    }

    #[test]
    fn enums_roundtrip() {
        rt(ShardingPolicy::Off);
        rt(ShardingPolicy::Dynamic);
        rt(ShardingPolicy::Static);
        rt(ProcessingMode::Independent);
        rt(ProcessingMode::Coordinated);
        rt(CompressionMode::Deflate);
    }

    #[test]
    fn messages_roundtrip() {
        let graph = PipelineBuilder::source_range(10).batch(2).build();
        rt(RegisterDatasetReq { graph: graph.clone() });
        rt(RegisterDatasetResp { dataset_id: 9 });
        rt(GetOrCreateJobReq {
            dataset_id: 9,
            job_name: "hp-tuning".into(),
            sharding: ShardingPolicy::Dynamic,
            mode: ProcessingMode::Coordinated,
            num_consumers: 4,
        });
        rt(GetOrCreateJobResp { job_id: 3, client_id: 8 });
        rt(ClientHeartbeatReq { job_id: 3, client_id: 8 });
        rt(ClientHeartbeatResp { worker_addrs: vec!["127.0.0.1:1234".into()], job_finished: false });
        rt(RegisterWorkerReq { addr: "127.0.0.1:9".into() });
        rt(RegisterWorkerResp {
            worker_id: 2,
            tasks: vec![TaskDef {
                job_id: 3,
                dataset_id: 9,
                graph,
                sharding: ShardingPolicy::Static,
                mode: ProcessingMode::Independent,
                num_consumers: 0,
                static_shards: vec![0, 2],
                worker_index: 1,
                num_workers: 4,
            }],
        });
        rt(WorkerHeartbeatReq { worker_id: 2, active_tasks: vec![3], cpu_util_milli: 700 });
        rt(WorkerHeartbeatResp { new_tasks: vec![], removed_tasks: vec![3] });
        rt(GetSplitReq { job_id: 3, worker_id: 2 });
        rt(GetSplitResp { split: Some(7) });
        rt(GetSplitResp { split: None });
        rt(GetElementReq {
            job_id: 3,
            client_id: 8,
            consumer_index: Some(1),
            round: Some(42),
            compression: CompressionMode::None,
        });
        rt(GetElementResp {
            element: Some(vec![1, 2, 3]),
            compressed: false,
            end_of_sequence: false,
            wrong_worker_for_round: true,
        });
        rt(ReleaseJobReq { job_id: 3, client_id: 8 });
        rt(ReleaseJobResp { released: true });
        rt(GetElementsReq {
            job_id: 3,
            client_id: 8,
            max_elements: 64,
            max_bytes: 1 << 20,
            poll_ms: 50,
            compression: CompressionMode::Deflate,
        });
        rt(WorkerStatusResp {
            active_tasks: vec![1],
            buffered_elements: 5,
            elements_produced: 100,
            cache_hits: 7,
            cache_evictions: 2,
        });
    }

    #[test]
    fn get_elements_resp_roundtrip_variants() {
        // Plain frame carrying two elements.
        let frame = vec![vec![1u8, 2, 3], vec![4u8, 5]].to_bytes();
        rt(GetElementsResp {
            frame: frame.clone(),
            num_elements: 2,
            compressed: false,
            end_of_sequence: false,
        });
        // Compressed variant: the frame bytes are a compressed blob.
        let z = crate::wire::compress(&frame);
        rt(GetElementsResp { frame: z, num_elements: 2, compressed: true, end_of_sequence: false });
        // End-of-sequence variant: empty frame (count 0), eos set.
        let empty = Vec::<Vec<u8>>::new().to_bytes();
        rt(GetElementsResp { frame: empty, num_elements: 0, compressed: false, end_of_sequence: true });
    }

    #[test]
    fn get_elements_frame_decodes_through_compression() {
        use crate::data::element::Tensor;
        use crate::data::Element;
        // Worker-side assembly: encode each element, frame them, compress
        // the whole frame; client-side: decompress, split, decode.
        let elems: Vec<Element> = (0..4)
            .map(|i| Element::with_ids(vec![Tensor::scalar_i32(i)], vec![i as u64]))
            .collect();
        let payloads: Vec<Vec<u8>> = elems.iter().map(|e| e.to_bytes()).collect();
        let frame = payloads.to_bytes();
        let resp = GetElementsResp {
            frame: crate::wire::compress(&frame),
            num_elements: 4,
            compressed: true,
            end_of_sequence: true,
        };
        let wire = resp.to_bytes();
        let back = GetElementsResp::from_bytes(&wire).unwrap();
        assert!(back.compressed && back.end_of_sequence);
        let plain = crate::wire::decompress(&back.frame).unwrap();
        let parts = Vec::<Vec<u8>>::from_bytes(&plain).unwrap();
        assert_eq!(parts.len(), back.num_elements as usize);
        for (i, p) in parts.iter().enumerate() {
            let e = Element::from_bytes(p).unwrap();
            assert_eq!(e.tensors[0].as_i32(), vec![i as i32]);
            assert_eq!(e.ids, vec![i as u64]);
        }
    }
}
