//! Coordinated-reads straggler model (§3.6, §4.4, Fig. 11).
//!
//! Synchronous distributed training: per step, each of `n` clients gets a
//! padded batch; the step's wall time is the *max* of per-client compute
//! times (everyone waits for the straggler) plus a sync overhead.
//! Per-client compute scales with the padded token count.
//!
//! * **Uncoordinated**: each client's batch is drawn independently; its
//!   padded length is the max sample length in the batch — long-tailed,
//!   so the per-step max across clients is badly skewed.
//! * **Coordinated**: per step all clients receive batches from the same
//!   length bucket; padded length ≈ the bucket's bound and is equal
//!   across clients, eliminating both excess padding and the straggler.
//!
//! Speedup = mean uncoordinated step time / mean coordinated step time,
//! compared at equal *useful* (unpadded) token throughput.

use super::models::ModelSpec;
use crate::util::rng::Rng;

/// Simulation knobs.
#[derive(Debug, Clone)]
pub struct CoordSimConfig {
    pub batch_size: usize,
    pub steps: u64,
    pub seed: u64,
    /// Fixed per-step synchronization overhead as a fraction of the
    /// compute time of a full-length batch.
    pub sync_overhead: f64,
    /// Override the model's `fixed_compute_fraction` (tests/ablations).
    pub fixed_compute_override: Option<f64>,
}

impl Default for CoordSimConfig {
    fn default() -> Self {
        CoordSimConfig {
            batch_size: 32,
            steps: 300,
            seed: 0xc0_0d,
            sync_overhead: 0.05,
            fixed_compute_override: None,
        }
    }
}

/// Outcome of one comparison.
#[derive(Debug, Clone)]
pub struct CoordSimResult {
    pub uncoordinated_step_time: f64,
    pub coordinated_step_time: f64,
    pub speedup: f64,
    pub uncoordinated_padding_fraction: f64,
    pub coordinated_padding_fraction: f64,
}

fn draw_len(rng: &mut Rng, mu: f64, sigma: f64, max: u32) -> u32 {
    (rng.lognormal(mu, sigma) as u32).clamp(1, max)
}

/// Compute time of one padded batch, normalized so a full-length batch
/// costs 1.0: `fixed + (1-fixed) * padded_len/max_len`.
fn batch_compute(padded_len: u32, max_len: u32, fixed_frac: f64) -> f64 {
    fixed_frac + (1.0 - fixed_frac) * padded_len as f64 / max_len as f64
}

/// Run the comparison for one NLP model.
pub fn simulate_coordinated_reads(model: &ModelSpec, cfg: &CoordSimConfig) -> CoordSimResult {
    let (mu, sigma, max_len) =
        model.seq_len_dist.expect("coordinated-reads sim needs an NLP model");
    let n_clients = model.accelerators.max(1);
    let bucket = model.bucket_width.max(1);
    let fixed_frac = cfg.fixed_compute_override.unwrap_or(model.fixed_compute_fraction);
    let mut rng = Rng::new(cfg.seed);

    // ---- Uncoordinated: independent batches, padded to batch max ----
    let mut un_time = 0.0;
    let mut un_tokens_padded = 0u64;
    let mut un_tokens_real = 0u64;
    for _ in 0..cfg.steps {
        let mut worst = 0.0f64;
        for _ in 0..n_clients {
            let mut batch_max = 0u32;
            let mut real = 0u64;
            for _ in 0..cfg.batch_size {
                let l = draw_len(&mut rng, mu, sigma, max_len);
                batch_max = batch_max.max(l);
                real += l as u64;
            }
            un_tokens_padded += (batch_max as u64) * cfg.batch_size as u64;
            un_tokens_real += real;
            worst = worst.max(batch_compute(batch_max, max_len, fixed_frac));
        }
        un_time += worst + cfg.sync_overhead;
    }

    // ---- Coordinated: per step, all clients serve the same bucket ----
    // Build a long sample stream, bucketize, then deal per-bucket batches
    // round-robin to rounds (the worker-side group_by_window effect).
    let mut co_time = 0.0;
    let mut co_tokens_padded = 0u64;
    let mut co_tokens_real = 0u64;
    let n_buckets = (max_len as usize).div_ceil(bucket as usize);
    let mut buckets: Vec<Vec<u32>> = vec![Vec::new(); n_buckets];
    // Enough samples for the same number of steps.
    for _ in 0..cfg.steps as usize * n_clients * cfg.batch_size {
        let l = draw_len(&mut rng, mu, sigma, max_len);
        buckets[((l - 1) / bucket) as usize].push(l);
    }
    let mut steps_done = 0u64;
    'outer: loop {
        for b in 0..n_buckets {
            // One round = n_clients batches from bucket b.
            while buckets[b].len() >= n_clients * cfg.batch_size {
                if steps_done >= cfg.steps {
                    break 'outer;
                }
                let mut worst = 0.0f64;
                for _ in 0..n_clients {
                    let drained: Vec<u32> =
                        buckets[b].drain(..cfg.batch_size).collect();
                    let batch_max = *drained.iter().max().unwrap();
                    let real: u64 = drained.iter().map(|&l| l as u64).sum();
                    co_tokens_padded += (batch_max as u64) * cfg.batch_size as u64;
                    co_tokens_real += real;
                    worst = worst.max(batch_compute(batch_max, max_len, fixed_frac));
                }
                co_time += worst + cfg.sync_overhead;
                steps_done += 1;
            }
        }
        if steps_done >= cfg.steps {
            break;
        }
        // Not enough stock left in any bucket: top up.
        for _ in 0..n_clients * cfg.batch_size * 4 {
            let l = draw_len(&mut rng, mu, sigma, max_len);
            buckets[((l - 1) / bucket) as usize].push(l);
        }
    }

    // Normalize per *useful token*: both modes must train on the same
    // data volume for the comparison to be fair.
    let un_per_token = un_time / un_tokens_real as f64;
    let co_per_token = co_time / co_tokens_real as f64;
    CoordSimResult {
        uncoordinated_step_time: un_time / cfg.steps as f64,
        coordinated_step_time: co_time / steps_done.max(1) as f64,
        speedup: un_per_token / co_per_token,
        uncoordinated_padding_fraction: 1.0 - un_tokens_real as f64 / un_tokens_padded.max(1) as f64,
        coordinated_padding_fraction: 1.0 - co_tokens_real as f64 / co_tokens_padded.max(1) as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::models::model;

    #[test]
    fn coordination_always_helps_nlp() {
        for name in ["M5", "M6", "M7", "M8"] {
            let r = simulate_coordinated_reads(model(name), &CoordSimConfig::default());
            assert!(r.speedup > 1.2, "{name}: speedup {:.2}", r.speedup);
            assert!(
                r.coordinated_padding_fraction < r.uncoordinated_padding_fraction,
                "{name}: padding must shrink"
            );
        }
    }

    #[test]
    fn speedups_land_near_paper() {
        // Fig. 11: M5 1.62x, M6 1.53x, M7 3.5x, M8 2.15x. Require the
        // calibrated sim within 30% of each and the ordering preserved
        // for the extremes.
        let mut got = std::collections::HashMap::new();
        for name in ["M5", "M6", "M7", "M8"] {
            let m = model(name);
            let r = simulate_coordinated_reads(m, &CoordSimConfig::default());
            let rel = (r.speedup - m.paper_speedup).abs() / m.paper_speedup;
            assert!(rel < 0.3, "{name}: got {:.2}, paper {:.2}", r.speedup, m.paper_speedup);
            got.insert(name, r.speedup);
        }
        assert!(got["M7"] > got["M5"], "M7 (3.5x) must beat M5 (1.62x)");
        assert!(got["M7"] > got["M6"], "M7 must beat M6");
    }

    #[test]
    fn deterministic_given_seed() {
        let m = model("M5");
        let a = simulate_coordinated_reads(m, &CoordSimConfig::default());
        let b = simulate_coordinated_reads(m, &CoordSimConfig::default());
        assert_eq!(a.speedup, b.speedup);
    }

    #[test]
    fn uniform_lengths_gain_little() {
        // With near-uniform lengths there are no stragglers to remove.
        let mut m = model("M5").clone();
        m.seq_len_dist = Some((5.5, 0.05, 512));
        let r = simulate_coordinated_reads(&m, &CoordSimConfig::default());
        assert!(r.speedup < 1.25, "no skew => little gain, got {:.2}", r.speedup);
    }
}
