"""L1 Pallas kernel: fused image augmentation (the vision preprocessing
hot-spot distributed by the service to its workers).

The paper's vision models (M1..M4, ResNet50+AutoAugment) are input-bound
because per-image augmentation is expensive. This kernel fuses the chain

    u8 -> f32 scale -> per-channel normalize -> conditional horizontal flip
       -> contrast (around per-image mean) -> brightness

into a single pass over each image so the data is read from HBM once and
written once.

TPU adaptation (DESIGN.md §Hardware-Adaptation): the grid iterates over the
batch dimension; each grid step stages exactly one (H, W, C) image plus its
scalar augmentation parameters into VMEM via BlockSpec. For a 224x224x3
image that is ~600 KB of f32 — comfortably inside the ~16 MB VMEM budget,
leaving room for double buffering of the HBM->VMEM pipeline. All arithmetic
is elementwise VPU work (there is no MXU component in this kernel), so the
roofline is HBM bandwidth; fusing the five stages is what moves us from 5x
to 1x bytes moved.

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret mode lowers to plain HLO that the Rust runtime
(xla crate / PJRT CPU) runs directly.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _augment_kernel(img_ref, flip_ref, bright_ref, contrast_ref, mean_ref, std_ref, out_ref):
    """One grid step = one image staged in VMEM."""
    x = img_ref[...].astype(jnp.float32) / 255.0
    x = (x - mean_ref[...]) / std_ref[...]
    # Conditional horizontal flip: reverse the W axis, select by flag.
    # jnp.where on the full block keeps this a single vectorized select.
    # Block shape is (1, H, W, C), so W is axis 2.
    f = flip_ref[0]
    x = jnp.where(f > 0.5, x[:, :, ::-1, :], x)
    # Contrast around the image mean, then brightness.
    img_mean = jnp.mean(x)
    x = contrast_ref[0] * (x - img_mean) + img_mean
    x = x + bright_ref[0]
    out_ref[...] = x


def augment(images_u8, flip, brightness, contrast):
    """Fused augmentation over a batch of images.

    Args:
      images_u8: (B, H, W, C) uint8.
      flip, brightness, contrast: (B,) float32 per-sample parameters.

    Returns:
      (B, H, W, C) float32 augmented batch. Matches ref.augment_ref, except
      contrast is applied around the *per-image* scalar mean (identical
      because the oracle also reduces over (H, W, C)).
    """
    b, h, w, c = images_u8.shape
    mean = ref.NORM_MEAN[:c]
    std = ref.NORM_STD[:c]
    return pl.pallas_call(
        _augment_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, h, w, c), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((c,), lambda i: (0,)),
            pl.BlockSpec((c,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, h, w, c), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, w, c), jnp.float32),
        interpret=True,
    )(images_u8, flip, brightness, contrast, mean, std)


def vmem_bytes(h: int, w: int, c: int) -> int:
    """Estimated VMEM working set per grid step (for DESIGN.md §Perf).

    One u8 input tile + one f32 compute/output tile (+ scalars).
    """
    return h * w * c * (1 + 4 + 4)  # u8 in, f32 working copy, f32 out
