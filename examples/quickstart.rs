//! Quickstart: the Fig. 4 flow, end to end, in one process.
//!
//! 1. Generate a small synthetic vision dataset into the shared store.
//! 2. Deploy a tf.data service cell (dispatcher + 2 workers).
//! 3. Build a pipeline, `distribute` it, and train-loop over batches.
//!
//! Run: `cargo run --release --example quickstart`

use std::sync::Arc;
use tfdatasvc::data::exec::ElemIter;
use tfdatasvc::data::graph::PipelineBuilder;
use tfdatasvc::data::udf::UdfRegistry;
use tfdatasvc::orchestrator::Cell;
use tfdatasvc::service::dispatcher::DispatcherConfig;
use tfdatasvc::service::proto::ShardingPolicy;
use tfdatasvc::service::{ServiceClient, ServiceClientConfig};
use tfdatasvc::storage::dataset::{generate_vision, VisionGenConfig};
use tfdatasvc::storage::ObjectStore;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // 1. Source data: 8 shard files of 32 images each.
    let store = ObjectStore::in_memory();
    let spec = generate_vision(
        &store,
        "datasets/demo",
        &VisionGenConfig { num_shards: 8, samples_per_shard: 32, ..Default::default() },
    );
    println!("dataset: {} samples in {} shards", spec.total_samples, spec.num_shards());

    // 2. Service deployment: dispatcher + 2 remote workers.
    let cell = Arc::new(Cell::new(
        store,
        UdfRegistry::with_builtins(),
        DispatcherConfig::default(),
    )?);
    cell.scale_to(2)?;
    println!(
        "deployed dispatcher at {} with {} workers",
        cell.dispatcher_addr(),
        cell.worker_count()
    );

    // 3. The Fig. 4 client program.
    let ds = PipelineBuilder::source_vision(spec)
        .map_parallel("vision.normalize+vision.augment", 4)
        .shuffle(64, 42)
        .batch(16)
        .prefetch(2)
        .build();
    let client = ServiceClient::new(&cell.dispatcher_addr());
    let mut it = client.distribute(
        &ds,
        ServiceClientConfig { sharding: ShardingPolicy::Dynamic, ..Default::default() },
    )?;

    let mut batches = 0;
    let mut samples = 0;
    while let Some(batch) = it.next()? {
        batches += 1;
        samples += batch.ids.len();
        if batches <= 3 {
            println!(
                "batch {batches}: images {:?} {} labels {:?}",
                batch.tensors[0].shape,
                batch.tensors[0].dtype.name(),
                batch.tensors[1].shape
            );
        }
    }
    println!("consumed {batches} batches / {samples} samples through the service");
    assert_eq!(samples, 256, "dynamic sharding delivers every sample exactly once");

    // The batched data plane amortizes RPC overhead: far fewer wire calls
    // than elements (each GetElements response carries a whole frame).
    let rpcs = client.metrics().counter("client/rpcs").get();
    let fetched = client.metrics().counter("client/elements_fetched").get();
    println!(
        "data plane: {fetched} elements over {rpcs} RPCs ({:.2} elements/RPC)",
        fetched as f64 / rpcs.max(1) as f64
    );
    println!("quickstart OK");
    Ok(())
}
