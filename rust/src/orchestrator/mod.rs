//! Orchestrator: the Borg/Kubernetes stand-in.
//!
//! The paper deploys dispatcher, workers, and clients as containers
//! managed by Borg, horizontally scaled by Autopilot from CPU-utilization
//! signals (§3.1 "Orchestrator"). This module reproduces the control
//! surface in-process:
//!
//! * [`Cell`] — a "cell" that deploys the dispatcher and a dynamic pool of
//!   workers as managed threads, with add/remove/kill operations.
//! * [`autoscaler`] — an Autopilot-like horizontal autoscaler driven by
//!   worker CPU utilization and client-starvation signals, with hysteresis
//!   and cooldown.
//! * [`failure`] — a failure injector that preempts and later restarts
//!   workers, driving the §3.4 fault-tolerance paths.

pub mod autoscaler;
pub mod failure;

pub use autoscaler::{Autoscaler, AutoscalerConfig};

use crate::data::udf::UdfRegistry;
use crate::service::dispatcher::{Dispatcher, DispatcherConfig};
use crate::service::worker::{Worker, WorkerConfig};
use crate::service::ServiceResult;
use crate::storage::ObjectStore;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// An in-process cell hosting one tf.data service deployment.
pub struct Cell {
    store: Arc<ObjectStore>,
    udfs: UdfRegistry,
    dispatcher: Dispatcher,
    workers: Mutex<HashMap<u64, Worker>>,
    next_handle: Mutex<u64>,
    worker_cfg_mutator: Mutex<Option<Box<dyn Fn(&mut WorkerConfig) + Send>>>,
}

impl Cell {
    /// Deploy a dispatcher and return the cell.
    pub fn new(store: Arc<ObjectStore>, udfs: UdfRegistry, mut cfg: DispatcherConfig) -> ServiceResult<Cell> {
        // The cell's store doubles as the spill/snapshot tier: hand it
        // to the dispatcher so superseded-snapshot GC can delete the
        // objects it journals as collected.
        if cfg.store.is_none() {
            cfg.store = Some(store.clone());
        }
        let dispatcher = Dispatcher::start("127.0.0.1:0", cfg)?;
        Ok(Cell {
            store,
            udfs,
            dispatcher,
            workers: Mutex::new(HashMap::new()),
            next_handle: Mutex::new(1),
            worker_cfg_mutator: Mutex::new(None),
        })
    }

    /// Customize future workers' configs (cache window, buffer sizes…).
    pub fn set_worker_config_mutator(&self, f: impl Fn(&mut WorkerConfig) + Send + 'static) {
        *self.worker_cfg_mutator.lock().unwrap() = Some(Box::new(f));
    }

    pub fn dispatcher(&self) -> &Dispatcher {
        &self.dispatcher
    }

    pub fn dispatcher_addr(&self) -> String {
        self.dispatcher.addr()
    }

    /// Deploy one more worker ("container"); returns its cell handle.
    pub fn add_worker(&self) -> ServiceResult<u64> {
        let mut cfg = WorkerConfig::new(self.store.clone(), self.udfs.clone());
        if let Some(f) = self.worker_cfg_mutator.lock().unwrap().as_ref() {
            f(&mut cfg);
        }
        let w = Worker::start("127.0.0.1:0", &self.dispatcher.addr(), cfg)?;
        let mut handles = self.next_handle.lock().unwrap();
        let handle = *handles;
        *handles += 1;
        self.workers.lock().unwrap().insert(handle, w);
        Ok(handle)
    }

    /// Non-blocking scale request: scale-up adds workers immediately;
    /// scale-down *begins* two-phase graceful drains of the least-loaded
    /// workers (dispatcher-journaled `Draining` state, revoke-ack-grant
    /// lease handoffs). The drains complete asynchronously — callers
    /// drive [`Cell::tick`] and [`Cell::reap_drained`] (the
    /// [`crate::service::scaling::ScalingController`] loop does both).
    pub fn request_scale_to(&self, n: usize) -> ServiceResult<()> {
        while self.worker_count() < n {
            self.add_worker()?;
        }
        loop {
            let candidates: Vec<u64> = {
                let ws = self.workers.lock().unwrap();
                ws.values()
                    .map(|w| w.worker_id())
                    .filter(|&id| !self.dispatcher.worker_draining(id))
                    .collect()
            };
            if candidates.len() <= n {
                return Ok(());
            }
            let Some(victim) = self.dispatcher.least_loaded_worker(&candidates) else {
                return Ok(());
            };
            if !self.dispatcher.begin_worker_drain(victim).unwrap_or(false) {
                return Ok(()); // cannot make progress (raced drain/removal)
            }
        }
    }

    /// Deploy (or gracefully drain down to) `n` workers, blocking until
    /// the cell holds exactly `n`. Scale-down routes through the
    /// two-phase drain path; a drain that cannot complete within ~10 s
    /// (e.g. nobody left to hand a lease to) falls back to hard removal
    /// so the call cannot wedge.
    pub fn scale_to(&self, n: usize) -> ServiceResult<()> {
        self.request_scale_to(n)?;
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        while self.worker_count() > n {
            self.tick();
            self.reap_drained();
            if self.worker_count() <= n {
                break;
            }
            if std::time::Instant::now() >= deadline {
                // Preemption semantics as last resort — draining workers
                // first (they were the chosen victims).
                let mut ws = self.workers.lock().unwrap();
                let mut victims: Vec<u64> = ws
                    .iter()
                    .filter(|(_, w)| self.dispatcher.worker_draining(w.worker_id()))
                    .map(|(&h, _)| h)
                    .collect();
                victims.extend(ws.keys().copied());
                for h in victims {
                    if ws.len() <= n {
                        break;
                    }
                    ws.remove(&h); // Drop shuts the worker down
                }
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        Ok(())
    }

    /// Remove workers whose graceful drain has completed (every lease
    /// handed off and acked, spill flushed, nothing left to lose). The
    /// `Worker` is dropped (shutting its threads down) *before*
    /// `finish_worker_drain` journals the drain exit and counts
    /// `dispatcher/workers_drained`, so no post-removal heartbeat can
    /// resurrect the entry. Returns the removed cell handles.
    pub fn reap_drained(&self) -> Vec<u64> {
        let done: Vec<(u64, u64)> = {
            let ws = self.workers.lock().unwrap();
            ws.iter()
                .map(|(&h, w)| (h, w.worker_id()))
                .filter(|&(_, id)| {
                    self.dispatcher.worker_draining(id) && self.dispatcher.drain_complete(id)
                })
                .collect()
        };
        let mut removed = Vec::new();
        for (h, id) in done {
            if self.workers.lock().unwrap().remove(&h).is_some() {
                let _ = self.dispatcher.finish_worker_drain(id);
                removed.push(h);
            }
        }
        removed
    }

    /// Gracefully remove one worker (scale-down), if any: the
    /// least-loaded worker is drained via the two-phase handoff and only
    /// removed once nothing is left on it.
    pub fn remove_any_worker(&self) -> bool {
        let count = self.worker_count();
        if count == 0 {
            return false;
        }
        self.scale_to(count - 1).is_ok() && self.worker_count() == count - 1
    }

    /// Begin a two-phase graceful drain of a specific worker (advance
    /// preemption notice). Non-blocking: the drain completes via
    /// [`Cell::tick`] + [`Cell::reap_drained`]. Returns false for an
    /// unknown handle or one already draining.
    pub fn drain_worker(&self, handle: u64) -> bool {
        let id = {
            let ws = self.workers.lock().unwrap();
            match ws.get(&handle) {
                Some(w) => w.worker_id(),
                None => return false,
            }
        };
        self.dispatcher.begin_worker_drain(id).unwrap_or(false)
    }

    /// Preempt a specific worker (abrupt kill, no draining).
    pub fn kill_worker(&self, handle: u64) -> bool {
        self.workers.lock().unwrap().remove(&handle).is_some()
    }

    pub fn worker_handles(&self) -> Vec<u64> {
        self.workers.lock().unwrap().keys().copied().collect()
    }

    pub fn worker_count(&self) -> usize {
        self.workers.lock().unwrap().len()
    }

    /// Aggregate worker status (buffered elements, cache stats) by RPC.
    pub fn worker_addrs(&self) -> Vec<String> {
        self.workers.lock().unwrap().values().map(|w| w.addr()).collect()
    }

    /// Sum a counter across every live worker's metrics registry —
    /// bench/test observability for worker-side data-plane counters
    /// (e.g. `worker/codec_skips`, `worker/compression_bytes_saved`)
    /// that are not part of any RPC status response.
    pub fn worker_counter_sum(&self, name: &str) -> u64 {
        self.workers.lock().unwrap().values().map(|w| w.metrics().counter(name).get()).sum()
    }

    /// Drive dispatcher liveness checks.
    pub fn tick(&self) -> Vec<u64> {
        self.dispatcher.tick()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::exec::ElemIter;
    use crate::data::graph::PipelineBuilder;
    use crate::service::proto::ShardingPolicy;
    use crate::service::{ServiceClient, ServiceClientConfig};
    use crate::storage::dataset::{generate_vision, VisionGenConfig};

    fn mk_cell() -> (Cell, crate::storage::dataset::DatasetSpec) {
        let store = ObjectStore::in_memory();
        let spec = generate_vision(
            &store,
            "ds",
            &VisionGenConfig { num_shards: 4, samples_per_shard: 4, ..Default::default() },
        );
        let cell = Cell::new(store, UdfRegistry::with_builtins(), DispatcherConfig::default()).unwrap();
        (cell, spec)
    }

    #[test]
    fn scale_up_and_down() {
        let (cell, _) = mk_cell();
        cell.scale_to(3).unwrap();
        assert_eq!(cell.worker_count(), 3);
        cell.scale_to(1).unwrap();
        assert_eq!(cell.worker_count(), 1);
    }

    #[test]
    fn kill_specific_worker() {
        let (cell, _) = mk_cell();
        let h = cell.add_worker().unwrap();
        assert!(cell.kill_worker(h));
        assert!(!cell.kill_worker(h));
        assert_eq!(cell.worker_count(), 0);
    }

    #[test]
    fn job_runs_through_cell() {
        let (cell, spec) = mk_cell();
        cell.scale_to(2).unwrap();
        let graph = PipelineBuilder::source_vision(spec).batch(4).build();
        let client = ServiceClient::new(&cell.dispatcher_addr());
        let mut it = client
            .distribute(
                &graph,
                ServiceClientConfig { sharding: ShardingPolicy::Dynamic, ..Default::default() },
            )
            .unwrap();
        let mut n = 0;
        while let Some(_) = it.next().unwrap() {
            n += 1;
        }
        assert_eq!(n, 4);
    }
}
