//! Metrics: counters, gauges, time series, and CSV emitters for figures.
//!
//! Every component (dispatcher, worker, client, trainer) owns a
//! [`Registry`]; benches snapshot registries and print the paper-figure
//! series. Counters are lock-free; time series take a short lock.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Monotonic counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, v: u64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Instantaneous gauge (signed).
#[derive(Debug, Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, v: i64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Timestamped series of (t_seconds, value) points relative to creation.
#[derive(Debug)]
pub struct TimeSeries {
    start: Instant,
    points: Mutex<Vec<(f64, f64)>>,
}

impl Default for TimeSeries {
    fn default() -> Self {
        TimeSeries { start: Instant::now(), points: Mutex::new(Vec::new()) }
    }
}

impl TimeSeries {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&self, value: f64) {
        let t = self.start.elapsed().as_secs_f64();
        self.points.lock().unwrap().push((t, value));
    }

    /// Record with an explicit x (e.g. a simulated clock or step number).
    pub fn record_at(&self, x: f64, value: f64) {
        self.points.lock().unwrap().push((x, value));
    }

    pub fn snapshot(&self) -> Vec<(f64, f64)> {
        self.points.lock().unwrap().clone()
    }

    pub fn len(&self) -> usize {
        self.points.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Named metric registry shared across threads.
#[derive(Debug, Default, Clone)]
pub struct Registry {
    inner: Arc<RegistryInner>,
}

#[derive(Debug, Default)]
struct RegistryInner {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    series: Mutex<BTreeMap<String, Arc<TimeSeries>>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    pub fn counter(&self, name: &str) -> Arc<Counter> {
        self.inner
            .counters
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        self.inner
            .gauges
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    pub fn series(&self, name: &str) -> Arc<TimeSeries> {
        self.inner
            .series
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    /// Flat snapshot of all counters and gauges.
    pub fn snapshot(&self) -> BTreeMap<String, f64> {
        let mut out = BTreeMap::new();
        for (k, c) in self.inner.counters.lock().unwrap().iter() {
            out.insert(format!("counter/{k}"), c.get() as f64);
        }
        for (k, g) in self.inner.gauges.lock().unwrap().iter() {
            out.insert(format!("gauge/{k}"), g.get() as f64);
        }
        out
    }

    /// Render all series as CSV blocks: `# name\nx,y\n...` — the format the
    /// figure benches write next to their stdout tables.
    pub fn to_csv(&self) -> String {
        let mut s = String::new();
        for (k, ts) in self.inner.series.lock().unwrap().iter() {
            s.push_str(&format!("# series {k}\n"));
            for (x, y) in ts.snapshot() {
                s.push_str(&format!("{x:.6},{y:.6}\n"));
            }
        }
        s
    }
}

/// Write a CSV file of (x, y) series under `out/` for plotting; creates the
/// directory if needed. Benches use this to persist figure data.
pub fn write_csv(path: &str, header: &str, rows: &[(f64, f64)]) -> std::io::Result<()> {
    if let Some(parent) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut body = String::with_capacity(rows.len() * 24 + header.len() + 1);
    body.push_str(header);
    body.push('\n');
    for (x, y) in rows {
        body.push_str(&format!("{x},{y}\n"));
    }
    std::fs::write(path, body)
}

/// Write a machine-readable benchmark/result document (creating parent
/// directories), so the perf trajectory is trackable across PRs: every
/// bench emits `out/bench_<name>.json` with throughput + latency
/// percentiles next to its human-readable stdout table.
pub fn write_json_file(path: &str, doc: &crate::util::json::Json) -> std::io::Result<()> {
    if let Some(parent) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut body = doc.dump();
    body.push('\n');
    std::fs::write(path, body)
}

/// Multi-column CSV variant for tables.
pub fn write_csv_rows(path: &str, header: &str, rows: &[Vec<String>]) -> std::io::Result<()> {
    if let Some(parent) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut body = String::new();
    body.push_str(header);
    body.push('\n');
    for r in rows {
        body.push_str(&r.join(","));
        body.push('\n');
    }
    std::fs::write(path, body)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge() {
        let r = Registry::new();
        r.counter("a").inc();
        r.counter("a").add(4);
        r.gauge("b").set(7);
        r.gauge("b").add(-2);
        assert_eq!(r.counter("a").get(), 5);
        assert_eq!(r.gauge("b").get(), 5);
        let snap = r.snapshot();
        assert_eq!(snap["counter/a"], 5.0);
        assert_eq!(snap["gauge/b"], 5.0);
    }

    #[test]
    fn registry_shares_handles() {
        let r = Registry::new();
        let c1 = r.counter("x");
        let r2 = r.clone();
        let c2 = r2.counter("x");
        c1.inc();
        c2.inc();
        assert_eq!(r.counter("x").get(), 2);
    }

    #[test]
    fn series_records_in_order() {
        let ts = TimeSeries::new();
        ts.record_at(1.0, 10.0);
        ts.record_at(2.0, 20.0);
        let snap = ts.snapshot();
        assert_eq!(snap, vec![(1.0, 10.0), (2.0, 20.0)]);
    }

    #[test]
    fn counters_threadsafe() {
        let r = Registry::new();
        let mut hs = vec![];
        for _ in 0..8 {
            let c = r.counter("n");
            hs.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    c.inc();
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(r.counter("n").get(), 8000);
    }

    #[test]
    fn csv_rendering() {
        let r = Registry::new();
        let ts = r.series("loss");
        ts.record_at(0.0, 3.5);
        ts.record_at(1.0, 2.5);
        let csv = r.to_csv();
        assert!(csv.contains("# series loss"));
        assert!(csv.contains("0.000000,3.500000"));
    }
}
