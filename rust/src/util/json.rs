//! Minimal JSON parser (no serde offline) for the artifact manifest.
//!
//! Supports the full JSON value grammar minus exotic number forms; good
//! enough for `artifacts/manifest.json` and config files. Strict: trailing
//! garbage and malformed escapes are errors.

use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing garbage at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize to canonical JSON text (object keys in BTreeMap order,
    /// minimal float formatting). Round-trips through [`Json::parse`];
    /// used by the benches' machine-readable `out/bench_*.json` results.
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    if *n == n.trunc() && n.abs() < 1e15 {
                        out.push_str(&format!("{}", *n as i64));
                    } else {
                        out.push_str(&format!("{n}"));
                    }
                } else {
                    // JSON has no NaN/Inf; null is the least-bad encoding.
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience constructors for assembling result objects by hand.
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}

impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}

/// Build a `Json::Obj` from `(key, value)` pairs.
pub fn obj<const N: usize>(pairs: [(&str, Json); N]) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape")?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let s = std::str::from_utf8(&self.b[self.i..]).map_err(|_| "invalid utf-8")?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("expected , or ] at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("expected , or }} at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -3.5e2 ").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn nested_structures() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().idx(1).unwrap().as_f64(), Some(2.0));
        assert_eq!(v.get("a").unwrap().idx(2).unwrap().get("b").unwrap().as_str(), Some("x"));
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse(r#""unterminated"#).is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn dump_roundtrips_through_parse() {
        let v = obj([
            ("name", "coordinated_rounds".into()),
            ("steps_per_sec", 123.5.into()),
            ("count", 42u64.into()),
            ("ok", true.into()),
            ("nested", Json::Arr(vec![Json::Null, "a\"b\n".into(), 1.25.into()])),
        ]);
        let text = v.dump();
        assert_eq!(Json::parse(&text).unwrap(), v);
        // Integral floats print without a fractional part.
        assert!(text.contains("\"count\":42"), "{text}");
    }

    #[test]
    fn parses_real_manifest_shape() {
        let doc = r#"{"format":"hlo-text/1","artifacts":{"x":{"file":"x.hlo.txt","inputs":[{"dtype":"f32","shape":[2,3]}],"bytes":10}}}"#;
        let v = Json::parse(doc).unwrap();
        let art = v.get("artifacts").unwrap().get("x").unwrap();
        assert_eq!(art.get("file").unwrap().as_str(), Some("x.hlo.txt"));
        let inp = art.get("inputs").unwrap().idx(0).unwrap();
        assert_eq!(inp.get("dtype").unwrap().as_str(), Some("f32"));
        assert_eq!(inp.get("shape").unwrap().idx(1).unwrap().as_usize(), Some(3));
    }
}
