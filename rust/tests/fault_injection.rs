//! Deterministic fault-injection e2e suite for the coordinated round
//! plane (§3.4 × §3.6): dispatcher kill+restore mid-epoch (journaled
//! round leases), owner kill → lease reassignment → revival re-balance,
//! and seeded random kill/revive/restart schedules. The CI hygiene job
//! runs this suite under several fixed seeds (`TFDATASVC_FAULT_SEED`)
//! with a hard timeout; every blocking wait below also carries its own
//! deadline so a hang fails fast instead of wedging the runner.

mod common;

use std::time::{Duration, Instant};

use common::{
    coord_cfg, fault_seed, journal_path, seeded_fault_plan, start_ticker, Cluster, FaultEvent,
};
use tfdatasvc::data::exec::ElemIter;
use tfdatasvc::data::graph::PipelineBuilder;
use tfdatasvc::service::client::DistributedIter;
use tfdatasvc::service::dispatcher::DispatcherConfig;
use tfdatasvc::service::visitation::RoundTracker;

/// Consume `n` rounds, feeding the tracker (signature constant: a single
/// consumer only checks the exactly-once-per-slot and floor halves).
fn drain_rounds(it: &mut DistributedIter, tracker: &mut RoundTracker, rounds: &mut u64, n: u64) {
    for _ in 0..n {
        let e = it.next().expect("round fetch failed").expect("stream ended early");
        assert!(!e.tensors.is_empty());
        tracker.observe(*rounds, 0, 0);
        *rounds += 1;
    }
}

/// Poll `probe` until it returns true or `what` times out.
fn wait_until(deadline: Instant, what: &str, mut probe: impl FnMut() -> bool) {
    while !probe() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(20));
    }
}

/// Acceptance: a coordinated job with 3 workers survives a mid-epoch
/// dispatcher kill+restore — rounds resume at the journaled floor and
/// exactly-once-per-slot holds — and a killed-then-revived owner regains
/// its residues within one heartbeat+hysteresis window (lease counters
/// asserted on both dispatcher and worker).
#[test]
fn coordinated_job_survives_dispatcher_restart_and_owner_revival() {
    let dcfg = DispatcherConfig {
        // Generous vs the ~max heartbeat gap across the dispatcher's own
        // restart (downtime + pool retry budget + interval), so the
        // restart itself cannot spuriously fail workers.
        worker_timeout: Duration::from_millis(800),
        journal_path: Some(journal_path("coord-restart")),
        revival_hysteresis: Duration::from_millis(200),
        ..Default::default()
    };
    let cluster = Cluster::with_config(3, dcfg);
    let _ticker = start_ticker(&cluster, Duration::from_millis(50));

    // A long source so the epoch cannot end mid-test.
    let graph = PipelineBuilder::source_range(100_000).build();
    let client = cluster.client();
    let mut it = client.distribute(&graph, coord_cfg("coord-restart", 1, 0)).unwrap();

    let mut tracker = RoundTracker::new();
    let mut rounds = 0u64;
    drain_rounds(&mut it, &mut tracker, &mut rounds, 6);

    // Mid-epoch dispatcher kill + journal-backed restore at the same
    // (stable) address: worker_order and the lease table replay, so the
    // job stays routable and rounds resume at the floor the first
    // post-restart heartbeats report.
    cluster.restart_dispatcher(Duration::from_millis(300));
    tracker.set_floor(rounds);
    drain_rounds(&mut it, &mut tracker, &mut rounds, 6);

    // Kill one owner: after the lease expires, its residues move to the
    // survivors and rounds keep flowing.
    cluster.kill_worker(2);
    wait_until(Instant::now() + Duration::from_secs(10), "lease reassignment", || {
        cluster.dispatcher().metrics().counter("dispatcher/round_leases_reassigned").get() >= 1
    });
    drain_rounds(&mut it, &mut tracker, &mut rounds, 6);

    // Revive the owner behind its stable address: one registration +
    // hysteresis window later its home residues re-balance back.
    cluster.revive_worker(2);
    let revived_at = Instant::now();
    wait_until(revived_at + Duration::from_secs(10), "revival re-balance", || {
        cluster.dispatcher().metrics().counter("dispatcher/round_leases_rebalanced").get() >= 1
    });
    // Generous sanity bound on "within one heartbeat+hysteresis window":
    // registration (immediate) + 200 ms hysteresis + 50 ms tick + one
    // 100 ms heartbeat, with scheduler slack.
    assert!(
        revived_at.elapsed() < Duration::from_secs(5),
        "re-balance took {:?}",
        revived_at.elapsed()
    );
    wait_until(Instant::now() + Duration::from_secs(10), "revived owner lease adoption", || {
        cluster
            .with_worker(2, |w| w.metrics().counter("worker/round_leases_updated").get() >= 1)
            .unwrap_or(false)
    });
    // The revived owner serves again: keep draining well past the
    // prefetch window so rounds of its residue class must flow through it.
    drain_rounds(&mut it, &mut tracker, &mut rounds, 12);

    let report = tracker.report();
    assert_eq!(report.duplicate_deliveries, 0, "{report:?}");
    assert_eq!(report.below_floor_deliveries, 0, "{report:?}");
    assert_eq!(rounds, 30, "rounds kept flowing through every fault");
    it.release();
}

/// Seeded schedule: random kill/revive/dispatcher-restart faults at
/// scripted consumer-progress points. Invariants: rounds never stall
/// past the deadline, no (consumer, round) slot is delivered twice, and
/// nothing below a restart floor is re-served. Reproducible: the
/// schedule is a pure function of the seed.
#[test]
fn seeded_fault_schedule_keeps_round_plane_consistent() {
    let seed = fault_seed(0x5eed_0001);
    let num_workers = 3usize;
    let steps = 48u64;
    let dcfg = DispatcherConfig {
        journal_path: Some(journal_path(&format!("fault-sched-{seed}"))),
        worker_timeout: Duration::from_millis(600),
        revival_hysteresis: Duration::from_millis(100),
        ..Default::default()
    };
    let cluster = Cluster::with_config(num_workers, dcfg);
    let _ticker = start_ticker(&cluster, Duration::from_millis(40));
    let plan = seeded_fault_plan(seed, num_workers, steps);
    assert!(!plan.is_empty(), "seed {seed} produced an empty schedule");

    let graph = PipelineBuilder::source_range(1_000_000).build();
    let client = cluster.client();
    let mut it = client.distribute(&graph, coord_cfg(&format!("fault-{seed}"), 1, 0)).unwrap();

    let mut tracker = RoundTracker::new();
    let mut next_event = 0usize;
    let deadline = Instant::now() + Duration::from_secs(180);
    for round in 0..steps {
        while next_event < plan.len() && plan[next_event].at_step <= round {
            match plan[next_event].event {
                FaultEvent::KillWorker(i) => cluster.kill_worker(i),
                FaultEvent::ReviveWorker(i) => cluster.revive_worker(i),
                FaultEvent::RestartDispatcher => {
                    cluster.restart_dispatcher(Duration::from_millis(200));
                    tracker.set_floor(round);
                }
            }
            next_event += 1;
        }
        let e = it.next().expect("round fetch failed under faults").expect("stream ended early");
        assert!(!e.tensors.is_empty());
        tracker.observe(round, 0, 0);
        assert!(Instant::now() < deadline, "fault schedule run exceeded its deadline");
    }
    let report = tracker.report();
    assert_eq!(report.duplicate_deliveries, 0, "{report:?}");
    assert_eq!(report.below_floor_deliveries, 0, "{report:?}");
    assert_eq!(report.rounds_seen as u64, steps);
    it.release();
}

/// The schedule generator really is deterministic per seed (the property
/// the CI seed matrix relies on) and never plans an impossible event
/// (kill of a down worker, revive of an up one, killing the last worker).
#[test]
fn seeded_fault_plan_is_deterministic_and_well_formed() {
    for seed in [1u64, 17, 42, 0x5eed_0001] {
        let a = seeded_fault_plan(seed, 3, 64);
        let b = seeded_fault_plan(seed, 3, 64);
        assert_eq!(a.len(), b.len(), "seed {seed}");
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at_step, y.at_step);
            assert_eq!(x.event, y.event);
        }
        let mut up = vec![true; 3];
        let mut restarts = 0;
        let mut last_step = 0;
        for f in &a {
            assert!(f.at_step >= last_step, "schedule is ordered");
            last_step = f.at_step;
            match f.event {
                FaultEvent::KillWorker(i) => {
                    assert!(up[i], "kill of a down worker");
                    up[i] = false;
                    assert!(up.iter().any(|&u| u), "killed the last worker");
                }
                FaultEvent::ReviveWorker(i) => {
                    assert!(!up[i], "revive of an up worker");
                    up[i] = true;
                }
                FaultEvent::RestartDispatcher => restarts += 1,
            }
        }
        assert!(up.iter().all(|&u| u), "every kill is paired with a revive");
        assert!(restarts <= 1);
    }
}
