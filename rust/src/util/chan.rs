//! Bounded MPMC channel and a small thread pool (no tokio offline).
//!
//! The service's hot paths are thread-based: worker pipeline parallelism,
//! client-side parallel fetchers, RPC server connection handlers. The
//! bounded channel doubles as the backpressure primitive the paper's
//! workers rely on (a full output buffer stalls production, not memory).

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Error returned when the channel is closed.
#[derive(Debug, PartialEq, Eq)]
pub struct Closed;

struct Inner<T> {
    queue: VecDeque<T>,
    cap: usize,
    closed: bool,
    senders: usize,
}

struct Shared<T> {
    inner: Mutex<Inner<T>>,
    not_full: Condvar,
    not_empty: Condvar,
}

/// Sending half. Cloneable (MPMC).
pub struct Sender<T>(Arc<Shared<T>>);

/// Receiving half. Cloneable (MPMC).
pub struct Receiver<T>(Arc<Shared<T>>);

/// Create a bounded channel with capacity `cap` (>= 1).
pub fn bounded<T>(cap: usize) -> (Sender<T>, Receiver<T>) {
    assert!(cap >= 1);
    let shared = Arc::new(Shared {
        inner: Mutex::new(Inner { queue: VecDeque::new(), cap, closed: false, senders: 1 }),
        not_full: Condvar::new(),
        not_empty: Condvar::new(),
    });
    (Sender(shared.clone()), Receiver(shared))
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        self.0.inner.lock().unwrap().senders += 1;
        Sender(self.0.clone())
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        let mut g = self.0.inner.lock().unwrap();
        g.senders -= 1;
        if g.senders == 0 {
            g.closed = true;
            drop(g);
            self.0.not_empty.notify_all();
            self.0.not_full.notify_all();
        }
    }
}

impl<T> Clone for Receiver<T> {
    fn clone(&self) -> Self {
        Receiver(self.0.clone())
    }
}

impl<T> Sender<T> {
    /// Blocking send; returns Err(Closed) if the channel was closed.
    pub fn send(&self, v: T) -> Result<(), Closed> {
        let mut g = self.0.inner.lock().unwrap();
        loop {
            if g.closed {
                return Err(Closed);
            }
            if g.queue.len() < g.cap {
                g.queue.push_back(v);
                drop(g);
                self.0.not_empty.notify_one();
                return Ok(());
            }
            g = self.0.not_full.wait(g).unwrap();
        }
    }

    /// Non-blocking send; Err(v) back if full/closed.
    pub fn try_send(&self, v: T) -> Result<(), T> {
        let mut g = self.0.inner.lock().unwrap();
        if g.closed || g.queue.len() >= g.cap {
            return Err(v);
        }
        g.queue.push_back(v);
        drop(g);
        self.0.not_empty.notify_one();
        Ok(())
    }

    /// Close the channel explicitly (receivers drain then get Err).
    pub fn close(&self) {
        self.0.inner.lock().unwrap().closed = true;
        self.0.not_empty.notify_all();
        self.0.not_full.notify_all();
    }

    pub fn len(&self) -> usize {
        self.0.inner.lock().unwrap().queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<T> Receiver<T> {
    /// Blocking receive; Err(Closed) when closed *and* drained.
    pub fn recv(&self) -> Result<T, Closed> {
        let mut g = self.0.inner.lock().unwrap();
        loop {
            if let Some(v) = g.queue.pop_front() {
                drop(g);
                self.0.not_full.notify_one();
                return Ok(v);
            }
            if g.closed {
                return Err(Closed);
            }
            g = self.0.not_empty.wait(g).unwrap();
        }
    }

    /// Receive with timeout; Ok(None) on timeout.
    pub fn recv_timeout(&self, dur: Duration) -> Result<Option<T>, Closed> {
        let deadline = std::time::Instant::now() + dur;
        let mut g = self.0.inner.lock().unwrap();
        loop {
            if let Some(v) = g.queue.pop_front() {
                drop(g);
                self.0.not_full.notify_one();
                return Ok(Some(v));
            }
            if g.closed {
                return Err(Closed);
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return Ok(None);
            }
            let (guard, res) = self.0.not_empty.wait_timeout(g, deadline - now).unwrap();
            g = guard;
            if res.timed_out() && g.queue.is_empty() {
                return if g.closed { Err(Closed) } else { Ok(None) };
            }
        }
    }

    /// True once the channel is closed *and* drained: `recv` would return
    /// `Err(Closed)`. Lets a thread that deliberately isn't consuming
    /// (e.g. a parked elastic worker) notice end-of-stream without
    /// stealing an item.
    pub fn is_closed(&self) -> bool {
        let g = self.0.inner.lock().unwrap();
        g.closed && g.queue.is_empty()
    }

    /// Non-blocking receive.
    pub fn try_recv(&self) -> Option<T> {
        let mut g = self.0.inner.lock().unwrap();
        let v = g.queue.pop_front();
        if v.is_some() {
            drop(g);
            self.0.not_full.notify_one();
        }
        v
    }

    pub fn len(&self) -> usize {
        self.0.inner.lock().unwrap().queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Fixed-size thread pool executing boxed jobs.
pub struct ThreadPool {
    tx: Option<Sender<Box<dyn FnOnce() + Send>>>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(threads: usize, name: &str) -> Self {
        assert!(threads >= 1);
        let (tx, rx) = bounded::<Box<dyn FnOnce() + Send>>(threads * 4);
        let handles = (0..threads)
            .map(|i| {
                let rx = rx.clone();
                std::thread::Builder::new()
                    .name(format!("{name}-{i}"))
                    .spawn(move || while let Ok(job) = rx.recv() { job() })
                    .expect("spawn pool thread")
            })
            .collect();
        ThreadPool { tx: Some(tx), handles }
    }

    /// Submit a job; blocks if the queue is full (backpressure).
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx.as_ref().unwrap().send(Box::new(f)).expect("pool closed");
    }

    /// Drain and join all workers.
    pub fn join(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        if let Some(tx) = self.tx.take() {
            drop(tx);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn fifo_order_single_thread() {
        let (tx, rx) = bounded(8);
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        for i in 0..5 {
            assert_eq!(rx.recv().unwrap(), i);
        }
    }

    #[test]
    fn backpressure_blocks_until_drained() {
        let (tx, rx) = bounded(2);
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        assert!(tx.try_send(3).is_err());
        assert_eq!(rx.recv().unwrap(), 1);
        assert!(tx.try_send(3).is_ok());
    }

    #[test]
    fn close_on_last_sender_drop() {
        let (tx, rx) = bounded(4);
        tx.send(9).unwrap();
        drop(tx);
        assert_eq!(rx.recv(), Ok(9));
        assert_eq!(rx.recv(), Err(Closed));
    }

    #[test]
    fn mpmc_all_items_delivered_once() {
        let (tx, rx) = bounded(16);
        let n = 1000;
        let counter = Arc::new(AtomicUsize::new(0));
        let mut handles = vec![];
        for _ in 0..4 {
            let rx = rx.clone();
            let c = counter.clone();
            handles.push(std::thread::spawn(move || {
                while rx.recv().is_ok() {
                    c.fetch_add(1, Ordering::SeqCst);
                }
            }));
        }
        for _ in 0..4 {
            let tx = tx.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..n / 4 {
                    tx.send(i).unwrap();
                }
            }));
        }
        drop(tx);
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), n);
    }

    #[test]
    fn recv_timeout_returns_none() {
        let (_tx, rx) = bounded::<u32>(1);
        let got = rx.recv_timeout(Duration::from_millis(20)).unwrap();
        assert!(got.is_none());
    }

    #[test]
    fn thread_pool_runs_all_jobs() {
        let pool = ThreadPool::new(4, "test");
        let c = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = c.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(c.load(Ordering::SeqCst), 100);
    }
}
