//! Wire-frame compression: a small self-contained LZ77 codec.
//!
//! In-tree replacement for the `flate2` dependency (the build is fully
//! offline). The worker compresses whole `GetElements` response frames —
//! amortizing the codec's token overhead across every element in the
//! batch — and single `GetElement` payloads with the same codec. The
//! format is internal to the service (both sides of the wire are this
//! crate), so there is no need for deflate compatibility:
//!
//! ```text
//! | raw_len: u32 LE | token* |
//! token := 0x00 | run_len: u16 LE | run_len literal bytes
//!        | 0x01 | match_len: u16 LE | distance: u16 LE
//! ```
//!
//! Matches are at least [`MIN_MATCH`] bytes and may overlap their own
//! output (distance < length encodes a repeating pattern), which is what
//! makes constant-filled tensors collapse to a few tokens.

use super::{WireError, WireResult};

/// Shortest match worth a 5-byte token.
const MIN_MATCH: usize = 6;
/// Token length fields are u16.
const MAX_CHUNK: usize = u16::MAX as usize;
/// Match distances are u16 (64 KiB window).
const MAX_DISTANCE: usize = u16::MAX as usize;

const TAG_LITERAL: u8 = 0;
const TAG_MATCH: u8 = 1;

fn hash3(d: &[u8], mask: usize) -> usize {
    let v = (d[0] as u32) | ((d[1] as u32) << 8) | ((d[2] as u32) << 16);
    (v.wrapping_mul(2654435761) >> 16) as usize & mask
}

fn emit_literals(out: &mut Vec<u8>, data: &[u8]) {
    for chunk in data.chunks(MAX_CHUNK) {
        out.push(TAG_LITERAL);
        out.extend_from_slice(&(chunk.len() as u16).to_le_bytes());
        out.extend_from_slice(chunk);
    }
}

/// Compress `data`. Output is never much larger than the input
/// (3 bytes of framing per 64 KiB literal run, plus the 4-byte header).
pub fn compress(data: &[u8]) -> Vec<u8> {
    let n = data.len();
    let mut out = Vec::with_capacity(n / 2 + 16);
    out.extend_from_slice(&(n as u32).to_le_bytes());
    // Last position each 3-byte hash was seen at. Sized to the input so
    // small payloads (the single-element GetElement path) don't pay a
    // fixed 64 Ki-entry table fill per call; extra collisions on small
    // inputs only cost missed matches, never correctness.
    let table_len = n.next_power_of_two().clamp(1 << 8, 1 << 16);
    let mask = table_len - 1;
    let mut table = vec![usize::MAX; table_len];
    let mut i = 0usize;
    let mut lit_start = 0usize;
    while i + 3 <= n {
        let h = hash3(&data[i..], mask);
        let cand = table[h];
        table[h] = i;
        if cand != usize::MAX
            && i - cand <= MAX_DISTANCE
            && data[cand..cand + 3] == data[i..i + 3]
        {
            let mut len = 3;
            while i + len < n && len < MAX_CHUNK && data[cand + len] == data[i + len] {
                len += 1;
            }
            if len >= MIN_MATCH {
                emit_literals(&mut out, &data[lit_start..i]);
                out.push(TAG_MATCH);
                out.extend_from_slice(&(len as u16).to_le_bytes());
                out.extend_from_slice(&((i - cand) as u16).to_le_bytes());
                i += len;
                lit_start = i;
                continue;
            }
        }
        i += 1;
    }
    emit_literals(&mut out, &data[lit_start..n]);
    out
}

/// Decompress a [`compress`]-produced buffer, validating framing.
pub fn decompress(bytes: &[u8]) -> WireResult<Vec<u8>> {
    if bytes.len() < 4 {
        return Err(WireError::Eof { wanted: 4, remaining: bytes.len() });
    }
    let raw_len = u32::from_le_bytes(bytes[..4].try_into().unwrap()) as usize;
    let mut out = Vec::with_capacity(raw_len.min(1 << 24));
    let mut pos = 4usize;
    while pos < bytes.len() {
        let tag = bytes[pos];
        pos += 1;
        match tag {
            TAG_LITERAL => {
                if bytes.len() - pos < 2 {
                    return Err(WireError::Eof { wanted: 2, remaining: bytes.len() - pos });
                }
                let len = u16::from_le_bytes(bytes[pos..pos + 2].try_into().unwrap()) as usize;
                pos += 2;
                if bytes.len() - pos < len {
                    return Err(WireError::Eof { wanted: len, remaining: bytes.len() - pos });
                }
                out.extend_from_slice(&bytes[pos..pos + len]);
                pos += len;
            }
            TAG_MATCH => {
                if bytes.len() - pos < 4 {
                    return Err(WireError::Eof { wanted: 4, remaining: bytes.len() - pos });
                }
                let len = u16::from_le_bytes(bytes[pos..pos + 2].try_into().unwrap()) as usize;
                let dist = u16::from_le_bytes(bytes[pos + 2..pos + 4].try_into().unwrap()) as usize;
                pos += 4;
                if dist == 0 || dist > out.len() {
                    return Err(WireError::Other(format!(
                        "lz match distance {dist} exceeds output length {}",
                        out.len()
                    )));
                }
                // Byte-wise copy: overlapping matches (dist < len) are the
                // run-length-encoding case and must see their own output.
                for _ in 0..len {
                    let b = out[out.len() - dist];
                    out.push(b);
                }
            }
            other => {
                return Err(WireError::BadTag { tag: other, ty: "lz token" });
            }
        }
        if out.len() > raw_len {
            return Err(WireError::TooLong { len: out.len(), limit: raw_len });
        }
    }
    if out.len() != raw_len {
        return Err(WireError::Other(format!(
            "lz frame decoded {} bytes, header said {raw_len}",
            out.len()
        )));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rt(data: &[u8]) {
        let z = compress(data);
        assert_eq!(decompress(&z).unwrap(), data, "len {}", data.len());
    }

    #[test]
    fn roundtrips() {
        rt(b"");
        rt(b"a");
        rt(b"hello");
        rt(b"abcabcabcabcabcabcabcabcabc");
        rt(&vec![7u8; 10_000]);
        let mixed: Vec<u8> = (0..5000u32)
            .map(|i| (i.wrapping_mul(2654435761) >> 13) as u8)
            .collect();
        rt(&mixed);
        // Structured data like tensor frames: repeating 128-byte rows.
        let row: Vec<u8> = (0..128u8).collect();
        let frame: Vec<u8> = row.iter().cycle().take(64 * 128).copied().collect();
        rt(&frame);
    }

    #[test]
    fn constant_data_compresses_hard() {
        let data = vec![42u8; 100_000];
        let z = compress(&data);
        assert!(z.len() < data.len() / 50, "{} vs {}", z.len(), data.len());
    }

    #[test]
    fn incompressible_data_bounded_expansion() {
        let data: Vec<u8> = (0..70_000u32)
            .map(|i| {
                let x = i.wrapping_mul(0x9E37_79B9).rotate_left(11).wrapping_add(i);
                (x ^ (x >> 7)) as u8
            })
            .collect();
        let z = compress(&data);
        assert!(z.len() < data.len() + data.len() / 100 + 64);
        assert_eq!(decompress(&z).unwrap(), data);
    }

    #[test]
    fn hostile_inputs_rejected() {
        assert!(decompress(&[]).is_err());
        assert!(decompress(&[1, 0, 0]).is_err());
        // Match with distance beyond output.
        let mut bad = 4u32.to_le_bytes().to_vec();
        bad.extend_from_slice(&[TAG_MATCH, 4, 0, 9, 0]);
        assert!(decompress(&bad).is_err());
        // Bad token tag.
        let mut bad = 1u32.to_le_bytes().to_vec();
        bad.extend_from_slice(&[7, 0, 0]);
        assert!(decompress(&bad).is_err());
        // Output longer than the header claims.
        let mut bad = 1u32.to_le_bytes().to_vec();
        bad.extend_from_slice(&[TAG_LITERAL, 2, 0, b'a', b'b']);
        assert!(decompress(&bad).is_err());
        // Truncated literal body.
        let mut bad = 8u32.to_le_bytes().to_vec();
        bad.extend_from_slice(&[TAG_LITERAL, 8, 0, b'a']);
        assert!(decompress(&bad).is_err());
    }
}
