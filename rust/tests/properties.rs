//! Property-based tests (hand-rolled: proptest is not vendored).
//!
//! Each property runs many randomized trials from a seeded RNG, so
//! failures are reproducible. Invariants covered: wire-format roundtrips
//! for arbitrary values, pipeline semantics against a reference
//! interpreter, split-tracker disjointness/at-most-once under random
//! worker churn, coordinated-round ownership, and optimizer semantic
//! equivalence.

mod common;

use tfdatasvc::data::element::{DType, Element, Tensor};
use tfdatasvc::data::exec::{ElemIter, Executor, ExecutorConfig};
use tfdatasvc::data::graph::{GraphDef, Node, PipelineBuilder};
use tfdatasvc::data::optimize::{optimize, OptimizeOptions};
use tfdatasvc::data::udf::UdfRegistry;
use tfdatasvc::service::dispatcher::{reassign_dead_residues, rebalance_home_residues};
use tfdatasvc::service::journal::{Journal, JournalRecord};
use tfdatasvc::service::proto::{ProcessingMode, SharingMode, ShardingPolicy};
use tfdatasvc::service::sharding::{static_assignment, SplitTracker};
use tfdatasvc::service::spill::{SegmentMeta, SpillManifest};
use tfdatasvc::storage::ObjectStore;
use tfdatasvc::util::rng::Rng;
use tfdatasvc::wire::{Decode, Encode};

const TRIALS: usize = 200;

fn rand_tensor(rng: &mut Rng) -> Tensor {
    let rank = rng.below(3) as usize;
    let shape: Vec<usize> = (0..rank).map(|_| rng.below(5) as usize + 1).collect();
    let n: usize = shape.iter().product();
    match rng.below(4) {
        0 => Tensor::from_f32(shape, &(0..n).map(|i| i as f32 * 0.5).collect::<Vec<_>>()),
        1 => Tensor::from_i32(shape, &(0..n).map(|i| i as i32 - 3).collect::<Vec<_>>()),
        2 => Tensor::from_u32(shape, &(0..n).map(|i| i as u32).collect::<Vec<_>>()),
        _ => Tensor::from_u8(shape, (0..n).map(|i| i as u8).collect()),
    }
}

fn rand_element(rng: &mut Rng) -> Element {
    let arity = rng.below(3) as usize + 1;
    let tensors = (0..arity).map(|_| rand_tensor(rng)).collect();
    let ids = (0..rng.below(4)).map(|_| rng.next_u64() % 1000).collect();
    let mut e = Element::with_ids(tensors, ids);
    if rng.chance(0.3) {
        e.bucket = Some(rng.next_u32() % 8);
    }
    e
}

#[test]
fn prop_element_wire_roundtrip() {
    let mut rng = Rng::new(0x9_0001);
    for _ in 0..TRIALS {
        let e = rand_element(&mut rng);
        let back = Element::from_bytes(&e.to_bytes()).expect("decode");
        assert_eq!(e, back);
    }
}

fn rand_graph(rng: &mut Rng) -> GraphDef {
    let n = rng.below(200) + 1;
    let mut b = PipelineBuilder::source_range(n);
    // At most one (terminal-ish) batch node: re-batching a ragged partial
    // batch is a shape error in tf.data too.
    let mut batched = false;
    for _ in 0..rng.below(5) {
        b = match rng.below(6) {
            0 if !batched => b.take(rng.below(2 * n) + 1),
            1 if !batched => b.skip(rng.below(n)),
            2 if !batched => b.shuffle(rng.next_u32() % 32 + 2, rng.next_u64()),
            3 if !batched => {
                batched = true;
                b.batch_partial(rng.next_u32() % 7 + 1)
            }
            4 if !batched => b.repeat(rng.next_u32() % 3 + 1),
            _ => b.map("identity"),
        };
    }
    b.build()
}

/// Reference interpreter over plain vectors for the operator subset used
/// by `rand_graph`.
fn reference_eval(graph: &GraphDef) -> Vec<Vec<i32>> {
    // Element stream as Vec<i32> values; batches become multi-value rows.
    let mut stream: Vec<Vec<i32>> = Vec::new();
    fn eval(nodes: &[Node], rng_seed_stack: &mut Vec<u64>) -> Vec<Vec<i32>> {
        let mut cur: Vec<Vec<i32>> = Vec::new();
        for node in nodes {
            match node {
                Node::SourceRange { n } => {
                    cur = (0..*n as i32).map(|v| vec![v]).collect();
                }
                Node::Take { n } => cur.truncate(*n as usize),
                Node::Skip { n } => {
                    cur.drain(..(*n as usize).min(cur.len()));
                }
                Node::Shuffle { buffer, seed } => {
                    // Mirror the executor's sliding-buffer shuffle.
                    cur = shuffle_ref(&cur, *buffer as usize, *seed);
                    rng_seed_stack.push(*seed);
                }
                Node::Batch { size, .. } => {
                    let mut out = Vec::new();
                    for chunk in cur.chunks(*size as usize) {
                        out.push(chunk.iter().flatten().copied().collect());
                    }
                    cur = out;
                }
                Node::Repeat { n } => {
                    let prefix_out = cur.clone();
                    let mut all = Vec::new();
                    for _ in 0..*n {
                        all.extend(prefix_out.clone());
                    }
                    cur = all;
                }
                Node::Map { .. } => {} // identity only
                _ => unreachable!("rand_graph subset"),
            }
        }
        cur
    }
    fn shuffle_ref(items: &[Vec<i32>], cap: usize, seed: u64) -> Vec<Vec<i32>> {
        let cap = cap.max(1);
        let mut rng = Rng::new(seed);
        let mut buf: Vec<Vec<i32>> = Vec::new();
        let mut out = Vec::new();
        let mut it = items.iter().cloned();
        for _ in 0..cap {
            match it.next() {
                Some(v) => buf.push(v),
                None => break,
            }
        }
        if buf.is_empty() {
            return out;
        }
        loop {
            if buf.is_empty() {
                break;
            }
            let idx = rng.below_usize(buf.len());
            match it.next() {
                Some(mut v) => {
                    std::mem::swap(&mut buf[idx], &mut v);
                    out.push(v);
                }
                None => out.push(buf.swap_remove(idx)),
            }
        }
        out
    }
    let mut stack = Vec::new();
    stream.extend(eval(&graph.nodes, &mut stack));
    stream
}

#[test]
fn prop_pipeline_matches_reference_interpreter() {
    let mut rng = Rng::new(0x9_0002);
    let ex = Executor::new(ExecutorConfig::local(
        ObjectStore::in_memory(),
        UdfRegistry::with_builtins(),
        0,
    ));
    for trial in 0..TRIALS {
        let g = rand_graph(&mut rng);
        let got: Vec<Vec<i32>> = ex
            .collect(&g)
            .unwrap_or_else(|e| panic!("trial {trial}: exec failed on {g:?}: {e}"))
            .iter()
            .map(|e| {
                e.tensors[0]
                    .as_i32()
            })
            .collect();
        let want = reference_eval(&g);
        assert_eq!(got, want, "trial {trial}: graph {g:?}");
    }
}

#[test]
fn prop_split_tracker_disjoint_under_churn() {
    let mut rng = Rng::new(0x9_0003);
    for trial in 0..TRIALS {
        let num_shards = rng.below(64) as usize + 1;
        let num_workers = rng.below(8) + 1;
        let t = SplitTracker::new(num_shards, rng.next_u64());
        let mut seen = std::collections::HashSet::new();
        let mut lost_total = 0usize;
        let mut alive: Vec<u64> = (0..num_workers).collect();
        loop {
            if alive.is_empty() {
                break;
            }
            // Random worker pulls; occasionally a worker dies.
            let w = *rng.choice(&alive);
            match t.next_split(w) {
                Some(s) => {
                    assert!(seen.insert(s), "trial {trial}: split {s} handed out twice");
                }
                None => break,
            }
            if rng.chance(0.05) && alive.len() > 1 {
                let dead = alive.swap_remove(rng.below_usize(alive.len()));
                lost_total += t.worker_failed(dead).len();
            }
        }
        // at-most-once accounting: everything handed out is either
        // completed, lost, or still assigned to a live worker.
        let completed = t.completed().len();
        let lost = t.lost().len();
        assert_eq!(lost, lost_total);
        assert!(completed + lost <= num_shards);
        assert!(seen.len() <= num_shards);
    }
}

#[test]
fn prop_static_assignment_partitions_and_balances() {
    let mut rng = Rng::new(0x9_0004);
    for _ in 0..TRIALS {
        let shards = rng.below(100) as usize;
        let workers = rng.below(10) as usize + 1;
        let a = static_assignment(shards, workers);
        assert_eq!(a.len(), workers);
        let mut all: Vec<u64> = a.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..shards as u64).collect::<Vec<_>>(), "partition exact");
        let lens: Vec<usize> = a.iter().map(|v| v.len()).collect();
        assert!(lens.iter().max().unwrap() - lens.iter().min().unwrap() <= 1, "balanced");
    }
}

#[test]
fn prop_round_ownership_is_a_partition() {
    // Every round is owned by exactly one worker index.
    let mut rng = Rng::new(0x9_0005);
    for _ in 0..TRIALS {
        let num_workers = rng.below(12) + 1;
        for round in 0..64u64 {
            let owners: Vec<u64> =
                (0..num_workers).filter(|w| round % num_workers == *w).collect();
            assert_eq!(owners.len(), 1, "round {round} owners {owners:?}");
        }
    }
}

#[test]
fn prop_optimizer_preserves_semantics() {
    let mut rng = Rng::new(0x9_0006);
    let ex = Executor::new(ExecutorConfig::local(
        ObjectStore::in_memory(),
        UdfRegistry::with_builtins(),
        0,
    ));
    for trial in 0..TRIALS {
        let g = rand_graph(&mut rng);
        let o = optimize(&g, &OptimizeOptions::default());
        let a: Vec<Vec<i32>> = ex.collect(&g).unwrap().iter().map(|e| e.tensors[0].as_i32()).collect();
        let b: Vec<Vec<i32>> = ex.collect(&o).unwrap().iter().map(|e| e.tensors[0].as_i32()).collect();
        assert_eq!(a, b, "trial {trial}: optimize changed semantics of {g:?}");
    }
}

#[test]
fn prop_graph_wire_roundtrip_random() {
    let mut rng = Rng::new(0x9_0007);
    for _ in 0..TRIALS {
        let g = rand_graph(&mut rng);
        assert_eq!(GraphDef::from_bytes(&g.to_bytes()).unwrap(), g);
        // Fingerprint is stable under re-encode.
        assert_eq!(g.fingerprint(), GraphDef::from_bytes(&g.to_bytes()).unwrap().fingerprint());
    }
}

// ----------------------------------------------------- round-lease model

/// Worker-side label model of a lease-table change: residues a worker no
/// longer owns lose their labels (the buffered rounds died with the
/// lease); a newly adopted residue labels from the floor, at the
/// smallest round in its class `>= floor`. Asserts the §3.6 recovery
/// invariant inline: no label ever drops below the floor — a consumed
/// round is never re-labeled.
fn apply_lease_table(
    owners: &[u64],
    labels: &mut std::collections::HashMap<(u64, u64), u64>,
    floor: u64,
    m: u64,
) {
    for (i, &o) in owners.iter().enumerate() {
        let r = i as u64;
        for w in 0..m {
            if w != o {
                labels.remove(&(w, r));
            }
        }
        let mut a = (floor / m) * m + r;
        if a < floor {
            a += m;
        }
        let label = *labels.entry((o, r)).or_insert(a);
        assert!(label >= floor, "consumed round re-labeled below the floor: {label} < {floor}");
    }
}

/// Random kill/revive/advance schedules against the *shipped* lease
/// transitions ([`reassign_dead_residues`] / [`rebalance_home_residues`]
/// are the exact functions `Dispatcher::tick` runs). Invariants:
/// residues only ever point at alive workers, every round is served by
/// exactly one owner, the owner's label equals the consumer's round at
/// every serve (so nothing below a floor is ever re-served), and every
/// round up to the final consumer position was eventually served.
#[test]
fn prop_round_lease_invariants_under_kill_revive_rebalance() {
    use std::collections::HashMap;
    let mut rng = Rng::new(0x9_000b);
    for trial in 0..TRIALS {
        let n = rng.below_usize(6) + 1;
        let m = n as u64;
        let worker_order: Vec<u64> = (0..m).collect();
        let mut owners = worker_order.clone();
        let mut alive = vec![true; n];
        // (worker, residue) -> next round label, present only while owned.
        let mut labels: HashMap<(u64, u64), u64> = (0..m).map(|w| ((w, w), w)).collect();
        let mut consumer_round = 0u64;
        let mut served: HashMap<u64, u64> = HashMap::new(); // round -> server

        for _step in 0..250 {
            let dead_count = alive.iter().filter(|&&a| !a).count();
            let roll = rng.f64();
            if roll < 0.15 && n - dead_count >= 2 {
                // Kill an alive worker; its residues move to survivors.
                let victims: Vec<u64> = (0..m).filter(|&w| alive[w as usize]).collect();
                let w = *rng.choice(&victims);
                alive[w as usize] = false;
                let gained = reassign_dead_residues(&mut owners, &|x: u64| alive[x as usize]);
                assert!(!gained.is_empty(), "trial {trial}: survivors must adopt");
                apply_lease_table(&owners, &mut labels, consumer_round, m);
            } else if roll < 0.30 && dead_count > 0 {
                // Revive a dead worker; home residues re-balance back.
                let downs: Vec<u64> = (0..m).filter(|&w| !alive[w as usize]).collect();
                let w = *rng.choice(&downs);
                alive[w as usize] = true;
                let affected = rebalance_home_residues(&mut owners, &worker_order, &|x: u64| {
                    alive[x as usize]
                });
                assert!(
                    affected.contains(&w),
                    "trial {trial}: revived worker {w} did not regain its home residue"
                );
                apply_lease_table(&owners, &mut labels, consumer_round, m);
            } else {
                // Consumer advances one round through the current table.
                let r = consumer_round % m;
                let o = owners[r as usize];
                assert!(
                    alive[o as usize],
                    "trial {trial}: residue {r} leased to dead worker {o}"
                );
                let label = labels
                    .get(&(o, r))
                    .copied()
                    .unwrap_or_else(|| panic!("trial {trial}: owner {o} has no label for {r}"));
                // The owner's next label is exactly the round the
                // consumer needs: never below (a consumed round
                // re-labeled), never above (an unserved round skipped).
                assert_eq!(label, consumer_round, "trial {trial}");
                labels.insert((o, r), consumer_round + m);
                assert!(
                    served.insert(consumer_round, o).is_none(),
                    "trial {trial}: round {consumer_round} served twice"
                );
                consumer_round += 1;
            }
        }
        // Eventual service: every round up to the final position was
        // served exactly once (sequential consumption + the uniqueness
        // assert above make the count sufficient).
        assert_eq!(served.len() as u64, consumer_round, "trial {trial}");
    }
}

// ----------------------------------------------------------- journal fuzz

fn rand_manifest(rng: &mut Rng) -> SpillManifest {
    let mut start_seq = 0u64;
    let segments = (0..rng.below(5))
        .map(|_| {
            let num_elements = rng.next_u32() % 64 + 1;
            let seg = SegmentMeta {
                key: rng.ident(16),
                offset: rng.next_u64() % (1 << 30),
                len: rng.next_u64() % (1 << 20),
                start_seq,
                num_elements,
                crc32: rng.next_u32(),
            };
            start_seq += num_elements as u64;
            seg
        })
        .collect();
    SpillManifest {
        fingerprint: rng.next_u64(),
        job_id: rng.next_u64(),
        epoch: rng.next_u64() % 16,
        total_elements: start_seq,
        complete: rng.chance(0.8),
        segments,
    }
}

fn rand_journal_record(rng: &mut Rng) -> JournalRecord {
    match rng.below(9) {
        0 => JournalRecord::RegisterDataset { dataset_id: rng.next_u64(), graph: rand_graph(rng) },
        1 => JournalRecord::CreateJob {
            job_id: rng.next_u64(),
            dataset_id: rng.next_u64(),
            job_name: if rng.chance(0.5) { String::new() } else { rng.ident(8) },
            sharding: *rng.choice(&[
                ShardingPolicy::Off,
                ShardingPolicy::Dynamic,
                ShardingPolicy::Static,
            ]),
            mode: *rng.choice(&[ProcessingMode::Independent, ProcessingMode::Coordinated]),
            num_consumers: rng.next_u32() % 8,
            sharing: *rng.choice(&[SharingMode::Auto, SharingMode::Off]),
            worker_order: (0..rng.below(6)).map(|_| rng.next_u64()).collect(),
            snapshot: rng.chance(0.25),
        },
        2 => JournalRecord::RegisterWorker { worker_id: rng.next_u64(), addr: rng.ident(12) },
        3 => JournalRecord::ClientJoined { job_id: rng.next_u64(), client_id: rng.next_u64() },
        4 => JournalRecord::ClientReleased { job_id: rng.next_u64(), client_id: rng.next_u64() },
        5 => JournalRecord::JobFinished { job_id: rng.next_u64() },
        6 => JournalRecord::RoundLeaseChanged {
            job_id: rng.next_u64(),
            residue_owners: (0..rng.below(8)).map(|_| rng.next_u64()).collect(),
        },
        7 => JournalRecord::SnapshotCommitted {
            fingerprint: rng.next_u64(),
            epoch: rng.next_u64() % 16,
            manifest: rand_manifest(rng),
        },
        _ => JournalRecord::ConsumerSetChanged {
            job_id: rng.next_u64(),
            epoch: rng.next_u32(),
            barrier_round: rng.next_u64(),
            num_consumers: rng.next_u32() % 16,
        },
    }
}

/// Every `JournalRecord` variant survives encode -> decode -> re-encode
/// byte-identically (replay determinism: a journal rewritten from its
/// decoded records is the same journal).
#[test]
fn prop_journal_records_roundtrip_byte_identical() {
    let mut rng = Rng::new(0x9_0009);
    let mut variants_seen = std::collections::HashSet::new();
    for trial in 0..TRIALS {
        let rec = rand_journal_record(&mut rng);
        variants_seen.insert(std::mem::discriminant(&rec));
        let bytes = rec.to_bytes();
        let back = JournalRecord::from_bytes(&bytes)
            .unwrap_or_else(|e| panic!("trial {trial}: decode failed: {e}"));
        assert_eq!(back, rec, "trial {trial}");
        assert_eq!(back.to_bytes(), bytes, "trial {trial}: re-encode byte-identical");
    }
    assert_eq!(variants_seen.len(), 9, "generator covered every record variant");
}

/// `SpillManifest` (the snapshot-commit payload) roundtrips
/// byte-identically on its own wire framing, including the empty and
/// incomplete shapes.
#[test]
fn prop_spill_manifest_roundtrips_byte_identical() {
    let mut rng = Rng::new(0x9_000b);
    for trial in 0..TRIALS {
        let m = rand_manifest(&mut rng);
        let bytes = m.to_bytes();
        let back = SpillManifest::from_bytes(&bytes)
            .unwrap_or_else(|e| panic!("trial {trial}: decode failed: {e}"));
        assert_eq!(back, m, "trial {trial}");
        assert_eq!(back.to_bytes(), bytes, "trial {trial}: re-encode byte-identical");
    }
}

/// A journal truncated anywhere in its tail (crash mid-append) replays
/// the longest prefix of whole records instead of erroring — fuzzed over
/// random and boundary-exact truncation points.
#[test]
fn prop_journal_truncated_tail_recovers_longest_prefix() {
    let mut rng = Rng::new(0x9_000a);
    for trial in 0..24 {
        let recs: Vec<JournalRecord> =
            (0..rng.below(8) + 2).map(|_| rand_journal_record(&mut rng)).collect();
        let p = common::journal_path(&format!("prop-trunc-{trial}"));
        {
            let j = Journal::open(&p).unwrap();
            for r in &recs {
                j.append(r).unwrap();
            }
        }
        let bytes = std::fs::read(&p).unwrap();
        // Frame sizes: 8-byte (len, crc) header + body.
        let frames: Vec<usize> = recs.iter().map(|r| 8 + r.to_bytes().len()).collect();
        assert_eq!(frames.iter().sum::<usize>(), bytes.len());
        // Random truncation points plus every frame boundary (+/- 1).
        let mut cuts: Vec<usize> = (0..16).map(|_| rng.below_usize(bytes.len() + 1)).collect();
        let mut acc = 0usize;
        for f in &frames {
            acc += f;
            cuts.push(acc);
            cuts.push(acc - 1);
        }
        for cut in cuts {
            let cut = cut.min(bytes.len());
            std::fs::write(&p, &bytes[..cut]).unwrap();
            let replayed = Journal::replay(&p)
                .unwrap_or_else(|e| panic!("trial {trial} cut {cut}: replay errored: {e}"));
            let mut fit = 0usize;
            let mut used = 0usize;
            for f in &frames {
                if used + f <= cut {
                    used += f;
                    fit += 1;
                } else {
                    break;
                }
            }
            assert_eq!(replayed, recs[..fit], "trial {trial} cut {cut}");
        }
        std::fs::remove_file(&p).ok();
    }
}

#[test]
fn prop_padded_batch_never_loses_tokens() {
    let mut rng = Rng::new(0x9_0008);
    for _ in 0..50 {
        let n = rng.below(30) as usize + 2;
        let tensors: Vec<Tensor> = (0..n)
            .map(|_| {
                let len = rng.below(20) as usize + 1;
                Tensor::from_u32(vec![len], &(1..=len as u32).collect::<Vec<_>>())
            })
            .collect();
        let padded = Tensor::stack_padded(&tensors, &0u32.to_le_bytes()).unwrap();
        assert_eq!(padded.dtype, DType::U32);
        let max_len = tensors.iter().map(|t| t.shape[0]).max().unwrap();
        assert_eq!(padded.shape, vec![n, max_len]);
        let vals = padded.as_u32();
        for (i, t) in tensors.iter().enumerate() {
            let row = &vals[i * max_len..(i + 1) * max_len];
            assert_eq!(&row[..t.shape[0]], t.as_u32().as_slice(), "payload preserved");
            assert!(row[t.shape[0]..].iter().all(|&v| v == 0), "padding is zero");
        }
    }
}
